//! # liquid-svm
//!
//! A Rust + JAX/Pallas reproduction of **liquidSVM: A Fast and Versatile
//! SVM package** (Steinwart & Thomann, 2017).
//!
//! The original is a C++ framework whose speed comes from a fully
//! integrated cross-validation pipeline (kernel-matrix reuse, warm
//! starts), carefully engineered dual solvers, working-set management
//! (tasks + cells), and SIMD/CUDA acceleration of the Gram-matrix hot
//! spot.  This port keeps the same architecture, with the accelerator
//! role played by AOT-compiled XLA artifacts (authored as JAX/Pallas
//! kernels, executed via PJRT from [`runtime`]).
//!
//! Layer map:
//! * **L3 (this crate)** — train/select/test pipeline, tasks, cells,
//!   CV engine, solvers, CLI, simulated distributed mode, and the
//!   batched multi-model inference server ([`serve`]).
//! * **L2 (python/compile/model.py)** — JAX graphs (multi-γ Gram,
//!   fused prediction) lowered once to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels/)** — tiled Pallas kernels called by
//!   L2; validated against a pure-jnp oracle at build time.
//!
//! Quickstart (the paper's banana-mc demo):
//! ```no_run
//! use liquid_svm::prelude::*;
//! let d = liquid_svm::data::synth::banana_mc(2000, 1000, 42);
//! let cfg = Config::default();
//! let model = mc_svm(&d.train, &cfg).unwrap();
//! let res = model.test(&d.test);
//! println!("error = {:.4}", res.error);
//! ```

// House style for the numeric kernels: hot loops index several
// parallel buffers at once, so the range-loop and complex-type lints
// fight the code instead of improving it.  Everything else in clippy
// is enforced by CI (`cargo clippy --all-targets -- -D warnings`).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]
#![allow(clippy::new_without_default)]
// The correctness-tooling plane (DESIGN.md §Static-analysis):
// `unsafe` is confined to the three modules that genuinely need it —
// `kernel/simd.rs` (std::arch intrinsics behind runtime detection),
// `runtime` (FFI Send/Sync contracts for the PJRT client), and
// `serve/poll.rs` (raw epoll/poll + self-pipe syscalls for the serve
// event loop) — each opting back in with a module-level `allow` next
// to its safety argument.  Every unsafe block must carry a `// SAFETY:` contract;
// CI denies `clippy::undocumented_unsafe_blocks` so an uncommented
// block cannot land.
#![deny(unsafe_code)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod cells;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod distributed;
pub mod kernel;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod sync;
pub mod tasks;

/// Convenience re-exports for the common learning scenarios
/// (mirrors liquidSVM's simplified interface: `mcSVM`, `lsSVM`, ...).
pub mod prelude {
    pub use crate::coordinator::config::Config;
    pub use crate::coordinator::scenarios::{
        ex_svm, ls_svm, mc_svm, npl_svm, qt_svm, roc_svm, svm_binary,
    };
    pub use crate::coordinator::{train_sparse, SvmModel};
    pub use crate::data::csr::SparseDataset;
    pub use crate::data::dataset::Dataset;
}
