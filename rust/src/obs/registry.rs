//! The unified metrics registry (DESIGN.md §Observability).
//!
//! The process-wide counters in [`crate::metrics::counters`] stay
//! exactly what they are — cheap relaxed atomics incremented from hot
//! paths — but each is *registered* here once with a stable exposition
//! name and help text, so every consumer (the serve `metrics` protocol
//! command, bench snapshots, ad-hoc tooling) reads the same catalogue
//! instead of hand-rolling format strings.  Components with
//! non-`'static` state (a server's request counters, its latency
//! histogram) contribute point-in-time [`Family`] values at scrape
//! time and reuse the same encoders.
//!
//! Two encoders, one input shape:
//!
//! * [`prometheus_text`] — Prometheus exposition text (`# HELP` /
//!   `# TYPE`, counters suffixed `_total`, histograms as cumulative
//!   `le`-labeled buckets plus `_sum` / `_count`);
//! * [`json_text`] — one JSON object keyed by metric name, each value
//!   `{"type": ..., ...}`.

use crate::sync::{Mutex, OnceLock};

use crate::metrics::counters::{self, Counter};
use crate::metrics::histogram::LatencyHistogram;

/// What kind of metric a family is (drives encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// Point-in-time value of one metric family.
#[derive(Clone, Debug)]
pub enum Value {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

/// A latency histogram frozen for encoding: per-bucket
/// `(upper_bound_us, count)` pairs plus the exact sum/count/max.
#[derive(Clone, Debug, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<(u64, u64)>,
    pub sum_us: u64,
    pub count: u64,
    pub max_us: u64,
}

impl From<&LatencyHistogram> for HistogramSnapshot {
    fn from(h: &LatencyHistogram) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: h.buckets(),
            sum_us: h.sum_us(),
            count: h.count(),
            max_us: h.max_us(),
        }
    }
}

/// One named metric with its current value — the unit both encoders
/// consume.  Families carry values (not handles), so scrape-time
/// builders can expose non-`'static` state.
#[derive(Clone, Debug)]
pub struct Family {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub value: Value,
}

impl Family {
    pub fn counter(name: &str, help: &str, value: u64) -> Family {
        Family { name: name.into(), help: help.into(), kind: MetricKind::Counter, value: Value::Counter(value) }
    }

    pub fn gauge(name: &str, help: &str, value: f64) -> Family {
        Family { name: name.into(), help: help.into(), kind: MetricKind::Gauge, value: Value::Gauge(value) }
    }

    pub fn histogram(name: &str, help: &str, h: &LatencyHistogram) -> Family {
        Family {
            name: name.into(),
            help: help.into(),
            kind: MetricKind::Histogram,
            value: Value::Histogram(HistogramSnapshot::from(h)),
        }
    }
}

enum Source {
    Counter(&'static Counter),
    Gauge(fn() -> f64),
}

struct Entry {
    name: &'static str,
    help: &'static str,
    source: Source,
}

/// A catalogue of registered metric handles.  The process-global one
/// (via [`global`]) carries every `'static` counter; scrape paths call
/// [`Registry::families`] for current values and append their own
/// instance-local families.
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { entries: Mutex::new(Vec::new()) }
    }

    /// Register a static counter under `name` (no `_total` suffix —
    /// the Prometheus encoder appends it).  Re-registering a name is
    /// a no-op, so module init order cannot duplicate families.
    pub fn register_counter(&self, name: &'static str, help: &'static str, c: &'static Counter) {
        let mut e = self.entries.lock().unwrap();
        if e.iter().any(|x| x.name == name) {
            return;
        }
        e.push(Entry { name, help, source: Source::Counter(c) });
    }

    /// Register a gauge read through a plain function.
    pub fn register_gauge(&self, name: &'static str, help: &'static str, f: fn() -> f64) {
        let mut e = self.entries.lock().unwrap();
        if e.iter().any(|x| x.name == name) {
            return;
        }
        e.push(Entry { name, help, source: Source::Gauge(f) });
    }

    /// Registered metric names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.lock().unwrap().iter().map(|e| e.name.to_string()).collect()
    }

    /// Snapshot every registered metric into encodable families.
    pub fn families(&self) -> Vec<Family> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .map(|e| match e.source {
                Source::Counter(c) => Family::counter(e.name, e.help, c.get()),
                Source::Gauge(f) => Family::gauge(e.name, e.help, f()),
            })
            .collect()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// The process-global registry, lazily initialized with every static
/// counter the crate maintains.  `GRAM_CACHE_HITS.inc()`-style call
/// sites are untouched; this is where those statics acquire their
/// exposition names.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let r = Registry::new();
        r.register_counter(
            "liquidsvm_gram_cache_hits",
            "Gram requests answered by a resident exponentiation (no work)",
            &counters::GRAM_CACHE_HITS,
        );
        r.register_counter(
            "liquidsvm_gram_cache_misses",
            "Gram requests that required an exponentiation pass",
            &counters::GRAM_CACHE_MISSES,
        );
        r.register_counter(
            "liquidsvm_gram_allocs",
            "Gram-plane buffer growths (flat in steady state)",
            &counters::GRAM_ALLOCS,
        );
        r.register_counter(
            "liquidsvm_gram_gather_entries",
            "Kernel entries recomputed through streaming gather (traced runs only)",
            &counters::GRAM_GATHER_ENTRIES,
        );
        r.register_counter(
            "liquidsvm_xla_calls",
            "Artifact executions on the PJRT runtime",
            &counters::XLA_CALLS,
        );
        r.register_counter(
            "liquidsvm_solver_sweeps",
            "Gradient/state entries written by solver sweeps",
            &counters::SOLVER_SWEEPS,
        );
        r.register_counter(
            "liquidsvm_solver_shrink_active",
            "Sum of active-set sizes at shrink refreshes",
            &counters::SOLVER_SHRINK_ACTIVE,
        );
        r.register_counter(
            "liquidsvm_solver_unshrink_passes",
            "Full-gradient verification passes before termination",
            &counters::SOLVER_UNSHRINK_PASSES,
        );
        r.register_counter(
            "liquidsvm_cell_units_trained",
            "(cell x task) working sets trained by the cell driver",
            &counters::CELL_UNITS_TRAINED,
        );
        r.register_counter(
            "liquidsvm_cell_train_us",
            "Accumulated unit training wall-clock in microseconds",
            &counters::CELL_TRAIN_US,
        );
        r.register_counter(
            "liquidsvm_dist_cells_dispatched",
            "Cells dispatched to wire workers (re-dispatches counted)",
            &counters::DIST_CELLS_DISPATCHED,
        );
        r.register_counter(
            "liquidsvm_dist_cells_redispatched",
            "Cells re-queued after a worker disconnect or timeout",
            &counters::DIST_CELLS_REDISPATCHED,
        );
        r.register_counter(
            "liquidsvm_dist_bytes_tx",
            "Bytes sent to workers over the train wire",
            &counters::DIST_BYTES_TX,
        );
        r.register_counter(
            "liquidsvm_dist_bytes_rx",
            "Bytes received from workers over the train wire",
            &counters::DIST_BYTES_RX,
        );
        r
    })
}

/// Exposition name of a family: counters carry the conventional
/// `_total` suffix, everything else is used as registered.
fn expo_name(f: &Family) -> String {
    if f.kind == MetricKind::Counter && !f.name.ends_with("_total") {
        format!("{}_total", f.name)
    } else {
        f.name.clone()
    }
}

/// Encode families as Prometheus exposition text.
pub fn prometheus_text(families: &[Family]) -> String {
    let mut out = String::new();
    for f in families {
        let name = expo_name(f);
        out.push_str(&format!("# HELP {} {}\n", name, f.help));
        match &f.value {
            Value::Counter(v) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
            }
            Value::Gauge(v) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
            }
            Value::Histogram(h) => {
                out.push_str(&format!("# TYPE {name} histogram\n"));
                let mut cum = 0u64;
                for &(le, c) in &h.buckets {
                    cum += c;
                    if c > 0 {
                        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                    }
                }
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
                out.push_str(&format!("{name}_sum {}\n", h.sum_us));
                out.push_str(&format!("{name}_count {}\n", h.count));
            }
        }
    }
    out
}

/// Encode families as one JSON object keyed by (registered) name.
pub fn json_text(families: &[Family]) -> String {
    let mut out = String::from("{");
    for (i, f) in families.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        match &f.value {
            Value::Counter(v) => {
                out.push_str(&format!("\"{}\":{{\"type\":\"counter\",\"value\":{}}}", f.name, v));
            }
            Value::Gauge(v) => {
                let v = if v.is_finite() { *v } else { 0.0 };
                out.push_str(&format!("\"{}\":{{\"type\":\"gauge\",\"value\":{}}}", f.name, v));
            }
            Value::Histogram(h) => {
                out.push_str(&format!(
                    "\"{}\":{{\"type\":\"histogram\",\"count\":{},\"sum_us\":{},\"max_us\":{},\"buckets\":[",
                    f.name, h.count, h.sum_us, h.max_us
                ));
                let mut first = true;
                for &(le, c) in &h.buckets {
                    if c > 0 {
                        if !first {
                            out.push(',');
                        }
                        first = false;
                        out.push_str(&format!("[{le},{c}]"));
                    }
                }
                out.push_str("]}");
            }
        }
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registers_every_static_counter() {
        let names = global().names();
        for expected in [
            "liquidsvm_gram_cache_hits",
            "liquidsvm_gram_cache_misses",
            "liquidsvm_gram_allocs",
            "liquidsvm_gram_gather_entries",
            "liquidsvm_xla_calls",
            "liquidsvm_solver_sweeps",
            "liquidsvm_solver_shrink_active",
            "liquidsvm_solver_unshrink_passes",
            "liquidsvm_cell_units_trained",
            "liquidsvm_cell_train_us",
            "liquidsvm_dist_cells_dispatched",
            "liquidsvm_dist_cells_redispatched",
            "liquidsvm_dist_bytes_tx",
            "liquidsvm_dist_bytes_rx",
        ] {
            assert!(names.iter().any(|n| n == expected), "missing {expected}");
        }
        assert_eq!(names.len(), 14);
    }

    #[test]
    fn duplicate_registration_is_ignored() {
        static C: Counter = Counter::new();
        let r = Registry::new();
        r.register_counter("x", "h", &C);
        r.register_counter("x", "other", &C);
        assert_eq!(r.names(), vec!["x".to_string()]);
    }

    #[test]
    fn registry_reads_live_counter_values() {
        static C: Counter = Counter::new();
        let r = Registry::new();
        r.register_counter("live", "h", &C);
        C.add(7);
        match &r.families()[0].value {
            Value::Counter(v) => assert!(*v >= 7),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn prometheus_counter_gets_total_suffix() {
        let fams = [Family::counter("liquidsvm_x", "help text", 3)];
        let text = prometheus_text(&fams);
        assert!(text.contains("# HELP liquidsvm_x_total help text\n"));
        assert!(text.contains("# TYPE liquidsvm_x_total counter\n"));
        assert!(text.contains("liquidsvm_x_total 3\n"));
    }

    #[test]
    fn prometheus_histogram_is_cumulative_with_inf() {
        let h = LatencyHistogram::new();
        h.record(std::time::Duration::from_micros(100)); // bucket le=127
        h.record(std::time::Duration::from_micros(100));
        h.record(std::time::Duration::from_micros(10_000)); // le=16383
        let fams = [Family::histogram("liquidsvm_lat", "lat", &h)];
        let text = prometheus_text(&fams);
        assert!(text.contains("# TYPE liquidsvm_lat histogram\n"));
        assert!(text.contains("liquidsvm_lat_bucket{le=\"127\"} 2\n"));
        assert!(text.contains("liquidsvm_lat_bucket{le=\"16383\"} 3\n"), "{text}");
        assert!(text.contains("liquidsvm_lat_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("liquidsvm_lat_sum 10200\n"));
        assert!(text.contains("liquidsvm_lat_count 3\n"));
    }

    #[test]
    fn json_encodes_each_kind() {
        let h = LatencyHistogram::new();
        h.record(std::time::Duration::from_micros(3));
        let fams = [
            Family::counter("c", "", 1),
            Family::gauge("g", "", 2.5),
            Family::histogram("h", "", &h),
        ];
        let text = json_text(&fams);
        assert!(text.starts_with('{') && text.ends_with('}'));
        assert!(text.contains("\"c\":{\"type\":\"counter\",\"value\":1}"));
        assert!(text.contains("\"g\":{\"type\":\"gauge\",\"value\":2.5}"));
        assert!(text.contains("\"h\":{\"type\":\"histogram\",\"count\":1,\"sum_us\":3,\"max_us\":3,\"buckets\":[[3,1]]}"));
    }
}
