//! The **observability plane** — phase-level tracing, the unified
//! metrics registry, and machine-readable perf snapshots (DESIGN.md
//! §Observability).
//!
//! Three pillars:
//!
//! * **Phase tracing** (this module): a lightweight RAII [`Span`]
//!   (`obs::span("cv.fold_chain")`) with thread-safe aggregation into
//!   a per-phase table — calls, total/self wall µs, and bytes where
//!   the phase knows them.  Nesting is thread-local: a span's *self*
//!   time is its total minus the totals of the spans opened (and
//!   closed) inside it on the same thread, so on a single-threaded
//!   run the self-times of all phases partition the root's wall.
//! * **Metrics registry** ([`registry`]): the process-wide counters
//!   become registered, named handles with one snapshot path and
//!   Prometheus-text / JSON encoders.
//! * **Perf snapshots**: `benches/harness.rs` emits `BENCH_<name>.json`
//!   per bench; `scripts/bench_diff.py` compares two snapshot sets.
//!
//! Tracing is **off by default** and gated by one process-global
//! `AtomicBool`: a disabled [`span`] call is a relaxed load plus a
//! branch — no clock read, no allocation, no lock — so leaving the
//! instrumentation compiled into hot paths is free (bench-asserted in
//! `benches/table_obs.rs`).  When enabled, spans cost two clock reads
//! and one short mutex section at drop; phases are therefore placed at
//! solve/fill/fold granularity, never per coordinate update.

pub mod registry;

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use crate::sync::{Mutex, OnceLock};
// always-std (sync.rs §static_atomic): a `static` needs the const
// constructor, and the gate is a telemetry toggle, not a
// synchronization edge — the mutex inside [`PhaseTable`] orders the
// actual recorded data
use crate::sync::static_atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing on?  Relaxed load — the single branch disabled call
/// sites pay.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off (the `--trace` flag; tests).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Aggregated statistics for one phase name.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseStat {
    /// spans closed under this name
    pub calls: u64,
    /// summed wall time, including child spans, in µs
    pub total_us: u64,
    /// summed wall time *excluding* same-thread child spans, in µs
    pub self_us: u64,
    /// bytes attributed via [`Span::add_bytes`]
    pub bytes: u64,
}

/// The span aggregation table — the concurrency seam under the RAII
/// [`Span`]s, extracted (`#[doc(hidden)] pub`) so the loom models in
/// `tests/loom_models.rs` can drive concurrent recording against a
/// non-global instance.  The process-global table lives in a
/// `OnceLock` behind [`phases`]/[`reset`].
#[doc(hidden)]
pub struct PhaseTable {
    map: Mutex<HashMap<&'static str, PhaseStat>>,
}

impl PhaseTable {
    pub fn new() -> PhaseTable {
        PhaseTable { map: Mutex::new(HashMap::new()) }
    }

    /// Merge one closed span into its phase row.
    pub fn record(&self, name: &'static str, total_us: u64, self_us: u64, bytes: u64) {
        let mut t = self.map.lock().unwrap();
        let s = t.entry(name).or_default();
        s.calls += 1;
        s.total_us += total_us;
        s.self_us += self_us;
        s.bytes += bytes;
    }

    /// Snapshot, sorted by phase name (deterministic).
    pub fn phases(&self) -> Vec<(&'static str, PhaseStat)> {
        let t = self.map.lock().unwrap();
        let mut out: Vec<_> = t.iter().map(|(&k, &v)| (k, v)).collect();
        out.sort_by_key(|&(k, _)| k);
        out
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

fn table() -> &'static PhaseTable {
    static TABLE: OnceLock<PhaseTable> = OnceLock::new();
    TABLE.get_or_init(PhaseTable::new)
}

thread_local! {
    /// One child-time accumulator per live enabled span on this
    /// thread; a closing span adds its total to its parent's slot.
    static CHILD_US: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

struct SpanInner {
    name: &'static str,
    start: Instant,
    bytes: u64,
}

/// RAII phase marker.  Create with [`span`]; the phase is recorded
/// when the guard drops.  Inert (zero work at creation *and* drop)
/// when tracing is disabled.
pub struct Span(Option<SpanInner>);

/// Open a phase span.  Phase names are static, dot-separated paths
/// (`"train.scale"`, `"cv.fold_chain"`, `"serve.predict"`); the name
/// contract is documented in DESIGN.md §Observability.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    span_slow(name)
}

#[cold]
fn span_slow(name: &'static str) -> Span {
    let _ = CHILD_US.try_with(|c| c.borrow_mut().push(0));
    Span(Some(SpanInner { name, start: Instant::now(), bytes: 0 }))
}

impl Span {
    /// Attribute processed bytes to this phase (e.g. a Gram fill's
    /// output size).  No-op on an inert span.
    #[inline]
    pub fn add_bytes(&mut self, n: u64) {
        if let Some(inner) = &mut self.0 {
            inner.bytes += n;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.0.take() else { return };
        let total_us = inner.start.elapsed().as_micros() as u64;
        // pop own child accumulator; credit own total to the parent
        let child_us = CHILD_US
            .try_with(|c| {
                let mut stack = c.borrow_mut();
                let own = stack.pop().unwrap_or(0);
                if let Some(parent) = stack.last_mut() {
                    *parent += total_us;
                }
                own
            })
            .unwrap_or(0);
        let self_us = total_us.saturating_sub(child_us);
        table().record(inner.name, total_us, self_us, inner.bytes);
    }
}

/// Snapshot the phase table, sorted by phase name (deterministic).
pub fn phases() -> Vec<(&'static str, PhaseStat)> {
    table().phases()
}

/// Clear the phase table (tests; between traced runs).
pub fn reset() {
    table().clear();
}

/// Render the phase table for `--trace` output: one row per phase,
/// sorted by total time descending, with a Σself footer.
pub fn render_table() -> String {
    let mut rows = phases();
    rows.sort_by(|a, b| b.1.total_us.cmp(&a.1.total_us).then(a.0.cmp(b.0)));
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>12} {:>12}\n",
        "phase", "calls", "total_ms", "self_ms", "bytes"
    ));
    let mut sum_self = 0u64;
    for (name, s) in &rows {
        sum_self += s.self_us;
        out.push_str(&format!(
            "{:<28} {:>8} {:>12.3} {:>12.3} {:>12}\n",
            name,
            s.calls,
            s.total_us as f64 / 1e3,
            s.self_us as f64 / 1e3,
            s.bytes
        ));
    }
    out.push_str(&format!("{:<28} {:>8} {:>12} {:>12.3}\n", "(sum of self)", "", "", sum_self as f64 / 1e3));
    out
}

/// Render the phase table as JSON (the `--trace-json` dump):
/// `{"phases":[{"name":...,"calls":...,"total_us":...,"self_us":...,
/// "bytes":...}]}`, sorted by name.
pub fn render_json() -> String {
    let rows = phases();
    let mut out = String::from("{\"phases\":[");
    for (i, (name, s)) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"calls\":{},\"total_us\":{},\"self_us\":{},\"bytes\":{}}}",
            name, s.calls, s.total_us, s.self_us, s.bytes
        ));
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::MutexGuard;

    /// The phase table and enable flag are process-global; tests that
    /// touch them serialize on this lock.
    pub(crate) fn guard() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        match LOCK.get_or_init(|| Mutex::new(())).lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        }
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = guard();
        set_enabled(false);
        reset();
        {
            let mut s = span("test.off");
            s.add_bytes(64);
        }
        assert!(phases().is_empty());
    }

    #[test]
    fn nesting_splits_self_from_total() {
        let _g = guard();
        set_enabled(true);
        reset();
        {
            let _outer = span("test.outer");
            std::thread::sleep(std::time::Duration::from_millis(4));
            {
                let _inner = span("test.inner");
                std::thread::sleep(std::time::Duration::from_millis(4));
            }
        }
        set_enabled(false);
        let rows: HashMap<_, _> = phases().into_iter().collect();
        let outer = rows["test.outer"];
        let inner = rows["test.inner"];
        assert_eq!(outer.calls, 1);
        assert_eq!(inner.calls, 1);
        assert!(inner.total_us >= 3_000, "inner too short: {inner:?}");
        assert!(outer.total_us >= inner.total_us + 3_000, "outer {outer:?} vs inner {inner:?}");
        // outer's self excludes inner's total
        assert_eq!(outer.self_us, outer.total_us - inner.total_us);
        // and the sum of self times equals the root total
        assert_eq!(outer.self_us + inner.self_us, outer.total_us);
        reset();
    }

    #[test]
    fn bytes_and_calls_accumulate() {
        let _g = guard();
        set_enabled(true);
        reset();
        for i in 0..3u64 {
            let mut s = span("test.bytes");
            s.add_bytes(10 + i);
        }
        set_enabled(false);
        let rows: HashMap<_, _> = phases().into_iter().collect();
        let s = rows["test.bytes"];
        assert_eq!(s.calls, 3);
        assert_eq!(s.bytes, 33);
        reset();
    }

    #[test]
    fn json_and_table_render_all_phases() {
        let _g = guard();
        set_enabled(true);
        reset();
        {
            let _a = span("test.render_a");
            let _b = span("test.render_b");
        }
        set_enabled(false);
        let j = render_json();
        assert!(j.starts_with("{\"phases\":["));
        assert!(j.contains("\"name\":\"test.render_a\""));
        assert!(j.contains("\"name\":\"test.render_b\""));
        let t = render_table();
        assert!(t.contains("test.render_a"));
        assert!(t.contains("(sum of self)"));
        reset();
    }
}
