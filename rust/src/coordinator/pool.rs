//! Minimal scoped thread pool for the (cell × task) work units — the
//! liquidSVM `threads=` knob.  No external crates in this image, so
//! this is a straight work-queue over `std::thread::scope`.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;

/// The work-claim seam of [`run_parallel`]: a fetch-add ticket counter
/// where every index in `0..n` is claimed by exactly one thread.
/// Extracted (`#[doc(hidden)] pub`) so the loom models in
/// `tests/loom_models.rs` can prove claim exclusivity directly.
/// Relaxed suffices: the claim only needs atomicity of the counter —
/// job/result hand-off ordering comes from the per-slot mutexes and
/// the scope join.
#[doc(hidden)]
pub struct JobCounter {
    next: AtomicUsize,
    n: usize,
}

impl JobCounter {
    pub fn new(n: usize) -> JobCounter {
        JobCounter { next: AtomicUsize::new(0), n }
    }

    /// Claim the next unclaimed job index, or `None` when all are
    /// taken.  No index is ever handed out twice.
    pub fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.n).then_some(i)
    }
}

/// Run `jobs` closures on `threads` workers; returns results in job
/// order.  Falls back to a plain loop for a single thread (no spawn
/// overhead — this is the common case in the paper's single-threaded
/// benchmark columns).
pub fn run_parallel<T, F>(threads: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if threads <= 1 || n <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let next = JobCounter::new(n);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    // hand each job exactly one slot; unsafe-free: split slots into
    // per-job cells via Mutex-free claim over an index counter
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<&mut Option<T>>> = slots.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| {
                while let Some(i) = next.claim() {
                    let job = jobs[i].lock().unwrap().take().expect("job claimed twice");
                    let out = job();
                    **results[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    drop(results);
    slots.into_iter().map(|s| s.expect("worker died before finishing job")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..17).map(|i| move || i * 2).collect();
        assert_eq!(run_parallel(4, jobs), (0..17).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i).collect();
        assert_eq!(run_parallel(1, jobs), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn runs_all_jobs_with_more_threads_than_jobs() {
        let jobs: Vec<_> = (0..3).map(|i| move || i + 100).collect();
        assert_eq!(run_parallel(16, jobs), vec![100, 101, 102]);
    }

    #[test]
    fn empty_jobs_ok() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(4, jobs).is_empty());
    }
}
