//! The train → select → test pipeline (paper §2: "an application cycle
//! is divided into a training phase, ... a selection phase, ... and a
//! test phase").  This module is the top of the L3 coordinator: it
//! crosses cells with tasks, schedules the per-working-set CV runs on
//! the thread pool, and owns the trained model used by the test phase.

use std::time::{Duration, Instant};

use crate::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cells::{make_cells, CellPartition, CellRouter, CellStrategy};
use crate::coordinator::config::{BackendChoice, Config};
use crate::coordinator::driver::run_cell_grid;
use crate::cv::{predict_average_x, run_cv_ws, CvConfig, CvResult, Grid};
use crate::data::csr::SparseDataset;
use crate::data::dataset::{distinct_labels, Dataset};
use crate::data::scale::Scaler;
use crate::data::store::{Store, StoreRef, WorkingSet};
use crate::kernel::{GramBackend, SimdLevel, SimdPlan};
use crate::metrics::{multiclass_error, Confusion, Loss};
use crate::runtime::{default_artifact_dir, XlaRuntime};
use crate::tasks::{combine_predictions, create_tasks_for_classes, TaskSpec};

/// One trained (cell × task) unit: the CV outcome plus the data the
/// fold models expand over.  The working set carries either layout —
/// dense matrices from [`train`], CSR from [`train_sparse`] — and the
/// predict path reads whichever it finds (DESIGN.md §Data-plane).
#[derive(Clone, Debug)]
pub struct TrainedUnit {
    pub cell: usize,
    pub task: usize,
    /// the task's working set inside the cell (already label-transformed)
    pub data: WorkingSet,
    pub cv: Option<CvResult>,
}

/// A trained liquidSVM model.
pub struct SvmModel {
    pub config: Config,
    pub spec: TaskSpec,
    pub scaler: Option<Scaler>,
    pub partition: CellPartition,
    /// global class list (classification) — combination order
    pub classes: Vec<f32>,
    pub n_tasks: usize,
    pub units: Vec<TrainedUnit>,
    pub train_time: Duration,
    /// measured training time per cell (summed over the cell's tasks);
    /// all-zero for models reassembled from disk
    pub cell_times: Vec<Duration>,
    /// total grid points solved across all units (perf accounting)
    pub points_evaluated: usize,
    backend: GramBackend,
}

/// Resolve the configured backend into a concrete GramBackend.  The
/// Simd choices resolve their dispatch plan here — once, up front —
/// with the documented override order (`LIQUIDSVM_SIMD` env > CLI
/// level > auto-detect; see DESIGN.md §Compute-plane).
pub fn make_backend(cfg: &Config) -> Result<GramBackend> {
    let simd = |cli: Option<SimdLevel>, mixed: bool| -> Result<GramBackend> {
        let plan = SimdPlan::resolve(cli, mixed).map_err(|e| anyhow!(e))?;
        if cfg.display > 0 {
            eprintln!("[backend] {}", plan.describe());
        }
        Ok(GramBackend::Simd(plan))
    };
    Ok(match cfg.backend {
        BackendChoice::Scalar => GramBackend::Scalar,
        BackendChoice::Blocked => GramBackend::Blocked,
        BackendChoice::Simd => simd(None, false)?,
        BackendChoice::SimdAvx2 => simd(Some(SimdLevel::Avx2), false)?,
        BackendChoice::SimdAvx512 => simd(Some(SimdLevel::Avx512), false)?,
        BackendChoice::SimdF32 => simd(None, true)?,
        BackendChoice::Xla => {
            let dir = cfg.artifact_dir.clone().unwrap_or_else(default_artifact_dir);
            GramBackend::Xla(Arc::new(XlaRuntime::open(dir)?))
        }
    })
}

/// Shared driver tail of [`train`] / [`train_sparse`]: split the
/// `--jobs`/`--max-gram-mb` budgets, schedule the (cell × task) grid,
/// and assemble the model.  One copy on purpose — the sparse pipeline's
/// bit-identity with the dense one depends on identical budgeting and
/// per-unit seed mixing, so neither path may drift alone.
#[allow(clippy::too_many_arguments)]
fn run_training(
    cfg: &Config,
    backend: GramBackend,
    spec: &TaskSpec,
    scaler: Option<Scaler>,
    partition: CellPartition,
    classes: Vec<f32>,
    n_tasks: usize,
    units: Vec<(usize, usize, WorkingSet, crate::tasks::Task)>,
    t0: Instant,
    label: &str,
) -> SvmModel {
    let n_cells = partition.n_cells();
    // scope the counter report to this run: the statics are
    // process-global and monotonic, so the display diffs two snapshots
    // instead of printing lifetime totals (see DESIGN.md §Observability)
    let counters_before = crate::metrics::counters::snapshot();
    let (driver_threads, cv_jobs) = cfg.split_jobs(units.len());
    // like the thread budget, the Gram byte budget is a whole-process
    // figure: with `driver_threads` CV runs resident at once, each run
    // gets its share so the aggregate stays within --max-gram-mb
    let cv_gram_mb = cfg.max_gram_mb.map(|mb| (mb / driver_threads.max(1)).max(1));

    let mut jobs: Vec<(usize, Box<dyn FnOnce() -> TrainedUnit + Send>)> = Vec::new();
    for (c, t, ws, task) in units {
        let cfg = cfg.clone();
        let backend = backend.clone();
        let seed = cfg.seed ^ ((c as u64) << 20) ^ t as u64;
        jobs.push((
            c,
            Box::new(move || {
                let cv = train_unit(
                    &ws, task.solver, task.val_loss, &cfg, backend, seed, cv_jobs, cv_gram_mb,
                );
                TrainedUnit { cell: c, task: t, data: ws, cv }
            }),
        ));
    }
    if cfg.display > 0 {
        eprintln!(
            "[{label}] {} cells x {} tasks = {} working sets ({} driver threads x {} cv jobs)",
            n_cells,
            n_tasks,
            jobs.len(),
            driver_threads,
            cv_jobs
        );
    }
    let (units, report) = {
        let _sp = crate::obs::span("train.grid");
        run_cell_grid(driver_threads, n_cells, jobs)
    };
    let points_evaluated = units
        .iter()
        .filter_map(|u| u.cv.as_ref().map(|c| c.points_evaluated))
        .sum();

    let model = SvmModel {
        config: cfg.clone(),
        spec: spec.clone(),
        scaler,
        partition,
        classes,
        n_tasks,
        units,
        train_time: t0.elapsed(),
        cell_times: report.per_cell.clone(),
        points_evaluated,
        backend,
    };
    if cfg.display > 0 {
        eprintln!(
            "[{label}] done in {:.2}s, driver {} ({} grid points solved; {})",
            model.train_time.as_secs_f64(),
            report.summary(),
            model.points_evaluated,
            crate::metrics::counters::snapshot().diff(&counters_before).report()
        );
    }
    model
}

/// Output of the dense training front-end (scale → classes → cells →
/// working sets), shared verbatim between the in-process [`train`]
/// path and the wire coordinator (`distributed::wire`).  One copy on
/// purpose: the distributed bundle's byte-identity with the
/// single-process one starts here — both must build the exact same
/// `(cell, task, working set)` roster in the exact same order.
pub(crate) struct TrainFrontEnd {
    pub scaler: Option<Scaler>,
    pub partition: CellPartition,
    pub classes: Vec<f32>,
    pub n_tasks: usize,
    pub units: Vec<(usize, usize, WorkingSet, crate::tasks::Task)>,
}

impl TrainFrontEnd {
    /// The model dimension the bundle manifest records — same
    /// precedence as [`SvmModel::input_dim`] (the unit list here is in
    /// the same order the model's units end up in).
    pub(crate) fn input_dim(&self) -> usize {
        if let Some(s) = &self.scaler {
            return s.parts().0.len();
        }
        if let Some((_, _, ws, _)) = self.units.iter().find(|(_, _, ws, _)| !ws.is_empty()) {
            return ws.dim();
        }
        match &self.partition.router {
            CellRouter::Centers(c) => c.cols(),
            _ => 0,
        }
    }
}

/// Dense training front-end: fit + apply scaling, derive the class
/// list, cut cells, and cross them with the task roster into
/// working sets.
pub(crate) fn build_dense_units(
    data: &Dataset,
    spec: &TaskSpec,
    cfg: &Config,
) -> Result<TrainFrontEnd> {
    if data.is_empty() {
        return Err(anyhow!("empty training set"));
    }
    // scaling fitted on the training set only (paper §B.1)
    let mut scaled = data.clone();
    let scaler = {
        let _sp = crate::obs::span("train.scale");
        cfg.scale.map(|kind| {
            let s = Scaler::fit(&scaled.x, kind);
            s.apply(&mut scaled.x);
            s
        })
    };

    let classes = scaled.classes();
    let partition = {
        let _sp = crate::obs::span("train.cells");
        make_cells(&scaled, &cfg.cells, cfg.seed)
    };

    // build the (cell × task) working sets, each tagged with its cell
    // so the driver can aggregate per-cell timing.  The --jobs budget
    // is split between the cell driver and each unit's per-fold CV chain grid
    // (one budget, two levels — see DESIGN.md §Compute-plane): the
    // working sets are materialized once, their count fixes the split,
    // and every unit then gets its CV share.
    let mut units: Vec<(usize, usize, WorkingSet, crate::tasks::Task)> = Vec::new();
    let mut n_tasks = 0usize;
    for (c, cell_idx) in partition.cells.iter().enumerate() {
        let cell_data = scaled.subset(cell_idx);
        let tasks = create_tasks_for_classes(&cell_data.y, spec, &classes);
        n_tasks = n_tasks.max(tasks.len());
        for (t, task) in tasks.into_iter().enumerate() {
            let ws =
                WorkingSet::dense(cell_data.x.select_rows(&task.indices), task.y.clone());
            units.push((c, t, ws, task));
        }
    }
    Ok(TrainFrontEnd { scaler, partition, classes, n_tasks, units })
}

/// Train a model for a task spec under a config — the whole training +
/// selection phase.
pub fn train(data: &Dataset, spec: &TaskSpec, cfg: &Config) -> Result<SvmModel> {
    let _sp = crate::obs::span("train");
    let t0 = Instant::now();
    let backend = make_backend(cfg)?;
    let fe = build_dense_units(data, spec, cfg)?;
    Ok(run_training(
        cfg,
        backend,
        spec,
        fe.scaler,
        fe.partition,
        fe.classes,
        fe.n_tasks,
        fe.units,
        t0,
        "train",
    ))
}

/// Train on a CSR dataset without ever densifying the samples — the
/// sparse end of the data plane (see DESIGN.md §Data-plane).
///
/// Differences from [`train`], both deliberate densification
/// boundaries the sparse path refuses to cross:
///
/// * **no scaling** — a per-column shift turns every stored zero into
///   a non-zero; `cfg.scale` is ignored (with a note at `display > 0`).
///   High-dimensional sparse data is typically pre-normalized row-wise
///   (tf-idf style) anyway;
/// * **no geometric cells** — Voronoi/tree routing walks dense rows;
///   only `CellStrategy::None` and `RandomChunks` (label-free) are
///   accepted, others are an error rather than a silent densify.
///
/// Everything else — task roster, per-fold (γ, λ) CV chain grid, `--max-gram-mb`
/// tiers, all four solvers, the tiled predict path — is the same code
/// as the dense pipeline, reading kernels through the sparse Gram
/// sources; predictions are bit-identical to [`train`] on the
/// densified data (tested in `tests/sparse_pipeline.rs`).
pub fn train_sparse(data: &SparseDataset, spec: &TaskSpec, cfg: &Config) -> Result<SvmModel> {
    let _sp = crate::obs::span("train");
    let t0 = Instant::now();
    if data.is_empty() {
        return Err(anyhow!("empty training set"));
    }
    let backend = make_backend(cfg)?;
    if cfg.scale.is_some() && cfg.display > 0 {
        eprintln!("[train-sparse] note: scaling disabled (a shift would densify; see DESIGN.md)");
    }

    let classes = distinct_labels(&data.y);
    let n = data.len();
    let _sp_cells = crate::obs::span("train.cells");
    let partition = match &cfg.cells {
        CellStrategy::None => CellPartition::single(n),
        // label/geometry-free: the same shuffle-split as the dense path
        CellStrategy::RandomChunks { size } => crate::cells::random_chunks(n, *size, cfg.seed),
        other => {
            return Err(anyhow!(
                "cell strategy {other:?} routes on dense geometry; sparse training supports \
                 --cells 0 (none) or chunks,SIZE"
            ))
        }
    };
    drop(_sp_cells);

    let mut units: Vec<(usize, usize, WorkingSet, crate::tasks::Task)> = Vec::new();
    let mut n_tasks = 0usize;
    for (c, cell_idx) in partition.cells.iter().enumerate() {
        let cell_y: Vec<f32> = cell_idx.iter().map(|&i| data.y[i]).collect();
        let tasks = create_tasks_for_classes(&cell_y, spec, &classes);
        n_tasks = n_tasks.max(tasks.len());
        for (t, task) in tasks.into_iter().enumerate() {
            // task.indices index the cell's working set; map back to
            // dataset rows for the CSR selection
            let rows: Vec<usize> = task.indices.iter().map(|&i| cell_idx[i]).collect();
            let ws = WorkingSet::sparse(data.x.select_rows(&rows), task.y.clone());
            units.push((c, t, ws, task));
        }
    }
    if cfg.display > 0 {
        eprintln!("[train-sparse] n={} d={} nnz={}", n, data.dim(), data.x.nnz());
    }
    Ok(run_training(
        cfg, backend, spec, None, partition, classes, n_tasks, units, t0, "train-sparse",
    ))
}

/// CV on one working set, with degenerate-size fallbacks:
/// * too few samples for k folds ⇒ shrink k;
/// * single-class / tiny sets ⇒ no model (constant-zero predictor).
///
/// `cv_jobs` / `cv_gram_mb` are this unit's shares of the process-wide
/// `--jobs` / `--max-gram-mb` budgets (see [`Config::split_jobs`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn train_unit(
    ws: &WorkingSet,
    solver: crate::solver::SolverKind,
    val_loss: Loss,
    cfg: &Config,
    backend: GramBackend,
    seed: u64,
    cv_jobs: usize,
    cv_gram_mb: Option<usize>,
) -> Option<CvResult> {
    let n = ws.len();
    if n < 8 {
        return None;
    }
    let _sp = crate::obs::span("train.unit");
    let folds = cfg.folds.min(n / 2).max(2);
    let n_fold = n - n / folds;
    let grid = if cfg.use_libsvm_grid {
        Grid::libsvm(n_fold)
    } else {
        Grid::default_grid(cfg.grid_choice, n_fold, ws.dim())
    };
    let mut cv_cfg = CvConfig::new(grid, solver, val_loss);
    cv_cfg.folds = folds;
    cv_cfg.fold_kind = cfg.fold_kind;
    cv_cfg.kernel = cfg.kernel;
    cv_cfg.adaptivity = cfg.adaptivity_control;
    cv_cfg.select = cfg.select;
    cv_cfg.params = cfg.solver_params;
    cv_cfg.backend = backend;
    cv_cfg.seed = seed;
    cv_cfg.jobs = cv_jobs;
    cv_cfg.max_gram_mb = cv_gram_mb;
    Some(run_cv_ws(ws, &cv_cfg))
}

/// Test-phase result.
#[derive(Clone, Debug)]
pub struct TestResult {
    /// combined predictions (labels for classification, values for
    /// regression; per-task curves are in `task_scores`)
    pub predictions: Vec<f32>,
    /// `task_scores[t][i]` = raw decision value of task t on sample i
    pub task_scores: Vec<Vec<f32>>,
    /// scenario-appropriate headline error (0-1 error / MSE / pinball)
    pub error: f32,
    pub test_time: Duration,
}

impl SvmModel {
    /// Reassemble a model from persisted parts (see
    /// [`crate::coordinator::persist`]).  The backend is resolved from
    /// `cfg` (it is a runtime choice, not part of the solution).
    pub fn from_parts(
        cfg: Config,
        spec: TaskSpec,
        scaler: Option<Scaler>,
        partition: CellPartition,
        classes: Vec<f32>,
        n_tasks: usize,
        units: Vec<TrainedUnit>,
    ) -> anyhow::Result<SvmModel> {
        let backend = make_backend(&cfg)?;
        let points_evaluated =
            units.iter().filter_map(|u| u.cv.as_ref().map(|c| c.points_evaluated)).sum();
        let cell_times = vec![Duration::ZERO; partition.n_cells()];
        Ok(SvmModel {
            config: cfg,
            spec,
            scaler,
            partition,
            classes,
            n_tasks,
            units,
            train_time: Duration::ZERO,
            cell_times,
            points_evaluated,
            backend,
        })
    }

    /// Expected input dimension of this model (0 = unknown): from the
    /// fitted scaler when present, else the first non-empty working
    /// set, else the router's center geometry.
    pub fn input_dim(&self) -> usize {
        if let Some(s) = &self.scaler {
            return s.parts().0.len();
        }
        if let Some(u) = self.units.iter().find(|u| !u.data.is_empty()) {
            return u.data.dim();
        }
        match &self.partition.router {
            CellRouter::Centers(c) => c.cols(),
            _ => 0,
        }
    }

    /// Decision values of every task on `x` (unscaled dense input).
    pub fn decision_values(&self, x: &crate::data::matrix::Matrix) -> Vec<Vec<f32>> {
        self.decision_values_x(StoreRef::Dense(x))
    }

    /// Decision values on CSR input — the sparse predict entry: no
    /// n×d densification anywhere when the model is sparse-trained
    /// (scaled dense-trained models densify at the scaler boundary,
    /// see DESIGN.md §Data-plane).
    pub fn decision_values_csr(&self, x: &crate::data::csr::CsrMatrix) -> Vec<Vec<f32>> {
        self.decision_values_x(StoreRef::Sparse(x))
    }

    /// Decision values over either input layout.
    pub fn decision_values_x(&self, x: StoreRef) -> Vec<Vec<f32>> {
        let _sp = crate::obs::span("predict");
        // scaling is a densification boundary: dense inputs transform
        // as before; sparse inputs densify only when a scaler demands
        // it (sparse-trained models never fit one)
        let scaled: Option<crate::data::matrix::Matrix> = match (&self.scaler, x) {
            (Some(s), StoreRef::Dense(m)) => Some(s.transform(m)),
            (Some(s), StoreRef::Sparse(m)) => Some(s.transform(&m.to_dense())),
            (None, _) => None,
        };
        let xr: StoreRef = match &scaled {
            Some(m) => StoreRef::Dense(m),
            None => x,
        };
        let m = xr.rows();
        let mut scores = vec![vec![0.0f32; m]; self.n_tasks];
        let mut counts = vec![vec![0u32; m]; self.n_tasks];

        // group test points by cell to batch kernel evaluations
        let broadcast = matches!(self.partition.router, CellRouter::Broadcast(_));
        let routed = self.partition.route_batch_x(xr);

        for unit in &self.units {
            let Some(cv) = &unit.cv else { continue };
            let pts = &routed[unit.cell];
            if pts.is_empty() || unit.data.is_empty() {
                continue;
            }
            let sub: Store = xr.select_rows(pts);
            let preds = predict_average_x(
                &cv.models,
                unit.data.x.as_ref(),
                sub.as_ref(),
                cv.best_gamma,
                self.config.kernel,
                &self.backend,
                self.config.max_gram_mb,
            );
            for (j, &i) in pts.iter().enumerate() {
                scores[unit.task][i] += preds[j];
                counts[unit.task][i] += 1;
            }
        }
        // broadcast routing (random chunks) averages the cell ensemble
        if broadcast {
            for t in 0..self.n_tasks {
                for i in 0..m {
                    if counts[t][i] > 1 {
                        scores[t][i] /= counts[t][i] as f32;
                    }
                }
            }
        }
        scores
    }

    /// Predict combined outputs for raw inputs.
    pub fn predict(&self, x: &crate::data::matrix::Matrix) -> Vec<f32> {
        let scores = self.decision_values(x);
        combine_predictions(&self.spec, &self.classes, &scores)
    }

    /// Predict combined outputs for CSR inputs.
    pub fn predict_csr(&self, x: &crate::data::csr::CsrMatrix) -> Vec<f32> {
        let scores = self.decision_values_csr(x);
        combine_predictions(&self.spec, &self.classes, &scores)
    }

    /// [`SvmModel::test`] on a CSR test set — same combination and
    /// error computation, sparse kernel path throughout.
    pub fn test_sparse(&self, test: &SparseDataset) -> TestResult {
        let t0 = Instant::now();
        let task_scores = self.decision_values_csr(&test.x);
        let predictions = combine_predictions(&self.spec, &self.classes, &task_scores);
        let error = self.scenario_error(&test.y, &task_scores, &predictions);
        TestResult { predictions, task_scores, error, test_time: t0.elapsed() }
    }

    /// Full test phase: predictions + scenario error.
    pub fn test(&self, test: &Dataset) -> TestResult {
        let t0 = Instant::now();
        let task_scores = self.decision_values(&test.x);
        let predictions = combine_predictions(&self.spec, &self.classes, &task_scores);
        let error = self.scenario_error(&test.y, &task_scores, &predictions);
        TestResult { predictions, task_scores, error, test_time: t0.elapsed() }
    }

    /// Scenario-appropriate headline error (0-1 / MSE / pinball …).
    fn scenario_error(&self, y: &[f32], task_scores: &[Vec<f32>], predictions: &[f32]) -> f32 {
        match &self.spec {
            TaskSpec::Binary { .. } | TaskSpec::NeymanPearson { .. } => {
                Confusion::from_scores(y, &task_scores[0]).error()
            }
            TaskSpec::MultiClassOvA | TaskSpec::MultiClassOvALs | TaskSpec::MultiClassAvA => {
                multiclass_error(y, predictions)
            }
            TaskSpec::LeastSquares => Loss::LeastSquares.mean(y, predictions),
            TaskSpec::MultiQuantile { taus } => {
                // mean pinball across levels
                let mut s = 0.0;
                for (t, &tau) in taus.iter().enumerate() {
                    s += Loss::Pinball { tau }.mean(y, &task_scores[t]);
                }
                s / taus.len().max(1) as f32
            }
            TaskSpec::MultiExpectile { taus } => {
                let mut s = 0.0;
                for (t, &tau) in taus.iter().enumerate() {
                    s += Loss::Expectile { tau }.mean(y, &task_scores[t]);
                }
                s / taus.len().max(1) as f32
            }
        }
    }

    /// Selected hyper-parameters of every unit (for inspection/tests).
    pub fn selected_params(&self) -> Vec<(usize, usize, f32, f32)> {
        self.units
            .iter()
            .filter_map(|u| u.cv.as_ref().map(|c| (u.cell, u.task, c.best_gamma, c.best_lambda)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::CellStrategy;
    use crate::data::synth;

    #[test]
    fn binary_pipeline_end_to_end() {
        let d = synth::banana_binary(300, 1);
        let cfg = Config::default().folds(3);
        let m = train(&d, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
        let test = synth::banana_binary(200, 2);
        let res = m.test(&test);
        // binary banana (arcs vs blobs) is a hard boundary at n=300
        assert!(res.error < 0.25, "banana error {}", res.error);
    }

    #[test]
    fn multiclass_ova_pipeline() {
        let tt = synth::banana_mc(300, 150, 3);
        let cfg = Config::default().folds(3);
        let m = train(&tt.train, &TaskSpec::MultiClassOvA, &cfg).unwrap();
        assert_eq!(m.n_tasks, 4);
        let res = m.test(&tt.test);
        assert!(res.error < 0.2, "banana-mc error {}", res.error);
    }

    #[test]
    fn cells_pipeline_matches_single_cell_quality() {
        let d = synth::by_name("cod-rna", 900, 4).unwrap().split(600, 9);
        let base = train(&d.train, &TaskSpec::Binary { w: 0.5 }, &Config::default().folds(3))
            .unwrap()
            .test(&d.test);
        let cells_cfg = Config::default()
            .folds(3)
            .voronoi(CellStrategy::RecursiveTree { max_size: 200 });
        let cells = train(&d.train, &TaskSpec::Binary { w: 0.5 }, &cells_cfg)
            .unwrap()
            .test(&d.test);
        assert!(cells.error <= base.error + 0.08, "{} vs {}", cells.error, base.error);
    }

    #[test]
    fn quantile_pipeline_orders_levels() {
        let d = synth::sinc_hetero(250, 5);
        let cfg = Config::default().folds(3);
        let spec = TaskSpec::MultiQuantile { taus: vec![0.1, 0.9] };
        let m = train(&d, &spec, &cfg).unwrap();
        let test = synth::sinc_hetero(120, 6);
        let res = m.test(&test);
        let gap: f32 = res.task_scores[1]
            .iter()
            .zip(&res.task_scores[0])
            .map(|(hi, lo)| hi - lo)
            .sum::<f32>()
            / 120.0;
        assert!(gap > 0.0, "quantile curves crossed on average: {gap}");
    }

    #[test]
    fn driver_records_per_cell_times() {
        let d = synth::banana_binary(240, 11);
        let cfg = Config::default()
            .folds(2)
            .jobs(2)
            .voronoi(CellStrategy::Voronoi { size: 60 });
        let m = train(&d, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
        assert_eq!(m.cell_times.len(), m.partition.n_cells());
        assert!(m.cell_times.iter().any(|t| *t > Duration::ZERO));
        assert_eq!(m.input_dim(), 2);
    }

    #[test]
    fn tiny_cells_fall_back_gracefully() {
        let d = synth::banana_binary(60, 7);
        let cfg = Config::default().folds(5).voronoi(CellStrategy::Voronoi { size: 10 });
        let m = train(&d, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
        // must not panic; prediction still runs
        let preds = m.predict(&d.x);
        assert_eq!(preds.len(), 60);
    }
}
