//! Pre-defined learning scenarios — the simplified interface the paper
//! advertises for all bindings (`mcSVM`, `lsSVM`, `qtSVM`, `exSVM`,
//! `nplSVM`, `rocSVM`; §2 "User Interfaces and Pre-defined Learning
//! Scenarios").  Each is a thin wrapper that picks the task spec and
//! calls the pipeline.

use anyhow::Result;

use crate::coordinator::config::Config;
use crate::coordinator::model::{train, SvmModel};
use crate::data::dataset::Dataset;
use crate::tasks::TaskSpec;

/// (Weighted) binary classification.  `w = 0.5` is unweighted.
pub fn svm_binary(data: &Dataset, w: f32, cfg: &Config) -> Result<SvmModel> {
    train(data, &TaskSpec::Binary { w }, cfg)
}

/// Multiclass classification, AvA with hinge machines by default, OvA
/// when `ova` is set (mirrors `mcSVM(..., mc_type=...)`).
pub fn mc_svm_type(data: &Dataset, ova: bool, cfg: &Config) -> Result<SvmModel> {
    let spec = if ova { TaskSpec::MultiClassOvA } else { TaskSpec::MultiClassAvA };
    train(data, &spec, cfg)
}

/// Multiclass classification with the default decomposition (OvA — the
/// combination the paper uses in its GURLS comparison).
pub fn mc_svm(data: &Dataset, cfg: &Config) -> Result<SvmModel> {
    mc_svm_type(data, true, cfg)
}

/// Least-squares regression (`lsSVM`).
pub fn ls_svm(data: &Dataset, cfg: &Config) -> Result<SvmModel> {
    train(data, &TaskSpec::LeastSquares, cfg)
}

/// Quantile regression at the given levels (`qtSVM`).
pub fn qt_svm(data: &Dataset, taus: &[f32], cfg: &Config) -> Result<SvmModel> {
    train(data, &TaskSpec::MultiQuantile { taus: taus.to_vec() }, cfg)
}

/// Expectile regression at the given levels (`exSVM`).
pub fn ex_svm(data: &Dataset, taus: &[f32], cfg: &Config) -> Result<SvmModel> {
    train(data, &TaskSpec::MultiExpectile { taus: taus.to_vec() }, cfg)
}

/// Neyman-Pearson-type classification: sweep class weights, then pick
/// (at test time) the weight whose false-alarm rate stays below
/// `alpha`.  Returns the model; use
/// [`crate::coordinator::npl::select_npl_task`] on validation scores.
pub fn npl_svm(data: &Dataset, alpha: f32, cfg: &Config) -> Result<SvmModel> {
    let weights = npl_weight_grid(alpha);
    train(data, &TaskSpec::NeymanPearson { weights }, cfg)
}

/// ROC-curve scenario: a dense sweep of weighted machines whose
/// (false-alarm, detection) pairs trace the ROC front (`rocSVM`).
pub fn roc_svm(data: &Dataset, n_points: usize, cfg: &Config) -> Result<SvmModel> {
    let n = n_points.clamp(3, 19);
    let weights: Vec<f32> = (1..=n).map(|i| i as f32 / (n + 1) as f32).collect();
    train(data, &TaskSpec::NeymanPearson { weights }, cfg)
}

/// Weight grid bracketing the target false-alarm rate (liquidSVM uses
/// a small grid around the NP constraint).
pub fn npl_weight_grid(alpha: f32) -> Vec<f32> {
    let base = (1.0 - alpha).clamp(0.55, 0.95);
    vec![
        (base - 0.15).clamp(0.5, 0.99),
        (base - 0.05).clamp(0.5, 0.99),
        base,
        (base + 0.04).clamp(0.5, 0.99),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn cfg() -> Config {
        Config::default().folds(3)
    }

    #[test]
    fn mc_svm_banana_demo() {
        // the README demo: mcSVM on banana-mc
        let tt = synth::banana_mc(250, 120, 42);
        let m = mc_svm(&tt.train, &cfg()).unwrap();
        let res = m.test(&tt.test);
        assert!(res.error < 0.25, "error {}", res.error);
    }

    #[test]
    fn ava_has_pairwise_tasks() {
        let tt = synth::banana_mc(200, 50, 1);
        let m = mc_svm_type(&tt.train, false, &cfg()).unwrap();
        assert_eq!(m.n_tasks, 6); // C(4,2)
    }

    #[test]
    fn ls_svm_regression() {
        let d = synth::sinc_hetero(200, 2);
        let m = ls_svm(&d, &cfg()).unwrap();
        let test = synth::sinc_hetero(100, 3);
        let res = m.test(&test);
        // variance of y is ~0.1-0.2; a fit must beat predicting 0
        let var: f32 = test.y.iter().map(|v| v * v).sum::<f32>() / 100.0;
        assert!(res.error < var, "mse {} vs var {}", res.error, var);
    }

    #[test]
    fn npl_weight_grid_brackets() {
        let g = npl_weight_grid(0.05);
        assert_eq!(g.len(), 4);
        assert!(g.windows(2).all(|w| w[0] <= w[1]));
        assert!(g.iter().all(|&w| (0.5..1.0).contains(&w)));
    }

    #[test]
    fn roc_svm_task_count() {
        let d = synth::banana_binary(150, 5);
        let m = roc_svm(&d, 5, &cfg()).unwrap();
        assert_eq!(m.n_tasks, 5);
    }
}
