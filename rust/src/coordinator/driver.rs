//! Parallel cell training driver — runs the (cell × task) grid on the
//! work-stealing pool and accounts for where the time went.
//!
//! The paper's scalability story (§2, Table 3) is that cells turn one
//! O(n²) problem into many independent O(k²) problems; this driver is
//! the piece that actually exploits that independence.  Every working
//! set becomes one job tagged with its cell; jobs are claimed off a
//! shared counter (`pool::run_parallel`), so a straggler cell never
//! idles the other workers — the same work-stealing shape the Spark
//! mode needs (see DESIGN.md §Scheduling).
//!
//! Each job is timed individually.  The per-cell sums feed three
//! consumers: the returned [`DriverReport`] (displayed by `train`),
//! the process-wide counters in [`crate::metrics::counters`]
//! (`cell_units` / `cell_train_us`, surfaced by `liquidsvm serve`'s
//! `stats` command), and the distributed mode's wall-clock model,
//! which replaces its formerly self-timed sequential loop with the
//! measured per-cell times from a genuinely parallel run.

use std::time::{Duration, Instant};

use crate::coordinator::pool::run_parallel;
use crate::metrics::counters;

/// Timing breakdown of one driver run over a (cell × task) grid.
#[derive(Clone, Debug)]
pub struct DriverReport {
    /// summed training time of every unit in the cell, indexed by cell
    pub per_cell: Vec<Duration>,
    /// wall-clock of the whole grid (parallel)
    pub wall: Duration,
    /// worker threads the driver ran with
    pub threads: usize,
    /// number of jobs executed
    pub jobs: usize,
}

impl DriverReport {
    /// Total CPU time across all cells (the sequential cost).
    pub fn total(&self) -> Duration {
        self.per_cell.iter().sum()
    }

    /// Observed parallel speedup (CPU time / wall-clock).
    pub fn speedup(&self) -> f64 {
        self.total().as_secs_f64() / self.wall.as_secs_f64().max(1e-9)
    }

    /// One-line summary for `display > 0` output.
    pub fn summary(&self) -> String {
        format!(
            "jobs={} threads={} wall={:.2}s cpu={:.2}s speedup={:.1}x",
            self.jobs,
            self.threads,
            self.wall.as_secs_f64(),
            self.total().as_secs_f64(),
            self.speedup()
        )
    }
}

/// Run a (cell × task) grid of jobs on `threads` workers, timing each
/// job and aggregating per-cell.  `jobs` pairs each closure with the
/// cell it belongs to (`cell < n_cells`); results come back in job
/// order, exactly like [`run_parallel`].  Advances the global
/// `cell_units`/`cell_train_us` counters.
pub fn run_cell_grid<T, F>(
    threads: usize,
    n_cells: usize,
    jobs: Vec<(usize, F)>,
) -> (Vec<T>, DriverReport)
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    run_grid(threads, n_cells, jobs, true)
}

/// [`run_cell_grid`] without the global counters — for *outer* drivers
/// whose jobs themselves call `run_cell_grid` (the distributed mode's
/// coarse level): counting both levels would double-book every unit's
/// training time.
pub fn run_cell_grid_untracked<T, F>(
    threads: usize,
    n_cells: usize,
    jobs: Vec<(usize, F)>,
) -> (Vec<T>, DriverReport)
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    run_grid(threads, n_cells, jobs, false)
}

fn run_grid<T, F>(
    threads: usize,
    n_cells: usize,
    jobs: Vec<(usize, F)>,
    track: bool,
) -> (Vec<T>, DriverReport)
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n_jobs = jobs.len();
    let t0 = Instant::now();
    let timed: Vec<_> = jobs
        .into_iter()
        .map(|(cell, f)| {
            move || {
                let t = Instant::now();
                let out = f();
                (cell, out, t.elapsed())
            }
        })
        .collect();
    let results = run_parallel(threads, timed);
    let wall = t0.elapsed();

    let mut per_cell = vec![Duration::ZERO; n_cells];
    let mut outs = Vec::with_capacity(results.len());
    for (cell, out, dt) in results {
        if let Some(slot) = per_cell.get_mut(cell) {
            *slot += dt;
        }
        if track {
            counters::CELL_UNITS_TRAINED.inc();
            counters::CELL_TRAIN_US.add(dt.as_micros() as u64);
        }
        outs.push(out);
    }
    (outs, DriverReport { per_cell, wall, threads: threads.max(1), jobs: n_jobs })
}

/// Greedy longest-processing-time assignment of weighted items to
/// `workers` bins; returns each item's bin.  Used by the distributed
/// mode to place coarse cells on workers (largest cells first, always
/// onto the least-loaded worker).
pub fn lpt_assign(weights: &[u64], workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(weights[i]));
    let mut load = vec![0u64; workers];
    let mut assign = vec![0usize; weights.len()];
    for &i in &order {
        let w = load
            .iter()
            .enumerate()
            .min_by_key(|(_, &l)| l)
            .map(|(w, _)| w)
            .unwrap_or(0);
        assign[i] = w;
        load[w] += weights[i];
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_preserves_job_order_and_times_cells() {
        let jobs: Vec<(usize, _)> = (0..9usize).map(|i| (i % 3, move || i * 10)).collect();
        let (out, report) = run_cell_grid(4, 3, jobs);
        assert_eq!(out, (0..9usize).map(|i| i * 10).collect::<Vec<_>>());
        assert_eq!(report.per_cell.len(), 3);
        assert_eq!(report.jobs, 9);
        assert!(report.wall >= Duration::ZERO);
    }

    #[test]
    fn counters_advance() {
        let before = counters::CELL_UNITS_TRAINED.get();
        let jobs: Vec<(usize, _)> = (0..5).map(|i| (0usize, move || i)).collect();
        let (_, _) = run_cell_grid(2, 1, jobs);
        assert!(counters::CELL_UNITS_TRAINED.get() >= before + 5);
    }

    #[test]
    fn out_of_range_cell_tags_do_not_panic() {
        let jobs: Vec<(usize, _)> = vec![(7, || 1)];
        let (out, report) = run_cell_grid(1, 2, jobs);
        assert_eq!(out, vec![1]);
        assert_eq!(report.per_cell, vec![Duration::ZERO; 2]);
    }

    #[test]
    fn untracked_grid_returns_same_shape_report() {
        // counters are process-global and other tests train models
        // concurrently, so this only checks the untracked entry point
        // behaves like the tracked one result-wise
        let jobs: Vec<(usize, _)> = (0..4usize).map(|i| (i % 2, move || i)).collect();
        let (out, report) = run_cell_grid_untracked(2, 2, jobs);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(report.per_cell.len(), 2);
        assert_eq!(report.jobs, 4);
    }

    #[test]
    fn lpt_balances_loads() {
        let weights = [10u64, 9, 8, 1, 1, 1];
        let assign = lpt_assign(&weights, 3);
        let mut load = [0u64; 3];
        for (i, &w) in assign.iter().enumerate() {
            load[w] += weights[i];
        }
        let (mx, mn) = (*load.iter().max().unwrap(), *load.iter().min().unwrap());
        assert!(mx - mn <= 2, "unbalanced: {load:?}");
    }

    #[test]
    fn lpt_single_worker() {
        assert_eq!(lpt_assign(&[3, 2, 1], 1), vec![0, 0, 0]);
        assert!(lpt_assign(&[], 4).is_empty());
    }

    #[test]
    fn summary_mentions_speedup() {
        let r = DriverReport {
            per_cell: vec![Duration::from_millis(10); 4],
            wall: Duration::from_millis(20),
            threads: 2,
            jobs: 4,
        };
        assert!(r.summary().contains("speedup="));
        assert!(r.speedup() > 1.0);
    }
}
