//! Neyman-Pearson task selection: after an `npl_svm`/`roc_svm` sweep,
//! pick the weighted machine that satisfies the false-alarm constraint
//! (paper §2: "classification with a constraint on the false alarm
//! rate").

use crate::metrics::Confusion;

/// Per-task (false-alarm, detection) operating points from decision
/// values on labeled data.
pub fn operating_points(y: &[f32], task_scores: &[Vec<f32>]) -> Vec<(f32, f32)> {
    task_scores
        .iter()
        .map(|scores| {
            let c = Confusion::from_scores(y, scores);
            (c.false_alarm_rate(), c.detection_rate())
        })
        .collect()
}

/// Index of the task with the best detection rate among those whose
/// false-alarm rate is ≤ `alpha`; falls back to the lowest-false-alarm
/// task if none satisfies the constraint.
pub fn select_npl_task(y: &[f32], task_scores: &[Vec<f32>], alpha: f32) -> usize {
    let pts = operating_points(y, task_scores);
    let mut feasible: Vec<(usize, f32)> = pts
        .iter()
        .enumerate()
        .filter(|(_, &(fa, _))| fa <= alpha)
        .map(|(i, &(_, det))| (i, det))
        .collect();
    if let Some(&(best, _)) = feasible
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    {
        feasible.sort_by_key(|&(i, _)| i);
        return best;
    }
    // infeasible everywhere: minimize the violation
    pts.iter()
        .enumerate()
        .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_highest_detection_under_constraint() {
        // y: 2 negatives, 2 positives
        let y = vec![-1.0, -1.0, 1.0, 1.0];
        let scores = vec![
            vec![1.0, 1.0, 1.0, 1.0],   // fa=1.0, det=1.0
            vec![-1.0, 1.0, 1.0, 1.0],  // fa=0.5, det=1.0
            vec![-1.0, -1.0, 1.0, -1.0] // fa=0.0, det=0.5
        ];
        assert_eq!(select_npl_task(&y, &scores, 0.6), 1);
        assert_eq!(select_npl_task(&y, &scores, 0.1), 2);
    }

    #[test]
    fn infeasible_falls_back_to_min_false_alarm() {
        let y = vec![-1.0, 1.0];
        let scores = vec![vec![1.0, 1.0], vec![1.0, -1.0]];
        // both have fa=1.0 > alpha: pick the first minimal
        assert_eq!(select_npl_task(&y, &scores, 0.0), 0);
    }

    #[test]
    fn operating_points_shape() {
        let y = vec![-1.0, 1.0];
        let pts = operating_points(&y, &[vec![-1.0, 1.0]]);
        assert_eq!(pts, vec![(0.0, 1.0)]);
    }
}
