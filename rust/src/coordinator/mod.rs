//! L3 coordinator: configuration, the (cell × task) scheduler, the
//! train/select/test pipeline, and the pre-defined learning scenarios.

pub mod config;
pub mod driver;
pub mod model;
pub mod npl;
pub mod persist;
pub mod pool;
pub mod scenarios;

pub use config::{BackendChoice, Config};
pub use driver::{lpt_assign, run_cell_grid, DriverReport};
pub use model::{train, train_sparse, SvmModel, TestResult, TrainedUnit};
