//! The configuration surface — mirrors liquidSVM's documented options
//! (Appendix C: `threads`, `grid_choice`, `adaptivity_control`,
//! `voronoi`, plus folds/kernel/display) with this port's additions
//! (Gram back-end selection, artifact directory).

use crate::cells::CellStrategy;
use crate::cv::SelectMethod;
use crate::data::folds::FoldKind;
use crate::data::scale::ScaleKind;
use crate::kernel::KernelKind;
use crate::solver::SolverParams;

/// Which Gram back-end to use (the SIMD/accelerator ladder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// naive scalar loops (the "SSE2" rung of Tables 14–17)
    Scalar,
    /// blocked/unrolled CPU loops (the "AVX2" rung) — default
    Blocked,
    /// explicit-SIMD dispatch seam, auto-detected level
    /// (DESIGN.md §Compute-plane; `LIQUIDSVM_SIMD` overrides)
    Simd,
    /// Simd rung pinned to the AVX2 level (clamped to the CPU)
    SimdAvx2,
    /// Simd rung pinned to the AVX-512 level (needs the `avx512`
    /// cargo feature; clamped to the CPU/build)
    SimdAvx512,
    /// Simd rung with the opt-in f32 mixed-precision Gram fill
    /// (ULP-bounded against the f64-accumulate rungs, not bit-exact)
    SimdF32,
    /// AOT Pallas/XLA artifacts via PJRT (the CUDA/TPU rung)
    Xla,
}

/// Global configuration (liquidSVM's `Config` in the bindings).
#[derive(Clone, Debug)]
pub struct Config {
    /// verbosity 0..2 (liquidSVM `display`)
    pub display: u8,
    /// worker threads for the (cell × task) scheduler (`threads`)
    pub threads: usize,
    /// worker threads for the parallel cell driver (`--jobs`);
    /// `None` falls back to `threads`.  The same budget is shared with
    /// the per-unit CV grid (see [`Config::split_jobs`]) so cell-level
    /// and fold-level parallelism compose without oversubscription.
    pub jobs: Option<usize>,
    /// byte budget (MiB) for resident distance/Gram state per CV run
    /// (`--max-gram-mb`); `None` = unlimited.  Past the cap the CV
    /// engine drops to fold-by-fold caching and then to streamed
    /// row-tiles (see DESIGN.md §Compute-plane).
    pub max_gram_mb: Option<usize>,
    /// 0 ⇒ 10×10 default grid, 1 ⇒ 15×15, 2 ⇒ 20×20 (`grid_choice`);
    /// `use_libsvm_grid` overrides with the 10×11 libsvm grid
    pub grid_choice: u8,
    pub use_libsvm_grid: bool,
    /// 0/1/2 (`adaptivity_control`)
    pub adaptivity_control: u8,
    /// data decomposition (`voronoi` + cell size)
    pub cells: CellStrategy,
    /// k of k-fold CV
    pub folds: usize,
    pub fold_kind: FoldKind,
    pub kernel: KernelKind,
    pub scale: Option<ScaleKind>,
    pub select: SelectMethod,
    pub solver_params: SolverParams,
    pub backend: BackendChoice,
    /// artifact directory for the Xla backend
    pub artifact_dir: Option<std::path::PathBuf>,
    /// sparse data plane: read LIBSVM files straight into CSR and train
    /// through the sparse Gram sources (`--sparse`; auto-detected for
    /// `.csr` file extensions).  Implies no scaling and no geometric
    /// cells — see DESIGN.md §Data-plane for the boundaries.
    pub sparse: bool,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            display: 0,
            threads: 1,
            jobs: None,
            max_gram_mb: Some(1024),
            grid_choice: 0,
            use_libsvm_grid: false,
            adaptivity_control: 0,
            cells: CellStrategy::None,
            folds: 5,
            fold_kind: FoldKind::Stratified,
            kernel: KernelKind::Gauss,
            scale: Some(ScaleKind::MinMax),
            select: SelectMethod::FoldAverage,
            solver_params: SolverParams::default(),
            backend: BackendChoice::Blocked,
            artifact_dir: None,
            sparse: false,
            seed: 42,
        }
    }
}

impl Config {
    /// Builder-style helpers mirroring `Config().display(1).threads(2)`
    /// from the Java/Python bindings.
    pub fn display(mut self, v: u8) -> Self {
        self.display = v;
        self
    }

    pub fn threads(mut self, v: usize) -> Self {
        self.threads = v.max(1);
        self
    }

    /// Worker threads for the parallel cell driver (defaults to
    /// `threads` when unset).
    pub fn jobs(mut self, v: usize) -> Self {
        self.jobs = Some(v.max(1));
        self
    }

    /// Resolved driver parallelism: explicit `jobs` or `threads`.
    pub fn effective_jobs(&self) -> usize {
        self.jobs.unwrap_or(self.threads).max(1)
    }

    /// Gram-state budget in MiB; 0 means unlimited.
    pub fn max_gram_mb(mut self, mb: usize) -> Self {
        self.max_gram_mb = if mb == 0 { None } else { Some(mb) };
        self
    }

    /// Solver KKT stopping threshold (`--solver-eps`).
    pub fn solver_eps(mut self, eps: f32) -> Self {
        self.solver_params.eps = eps;
        self
    }

    /// Solver iteration cap (`--max-iter`; coordinate updates).
    pub fn max_iter(mut self, n: usize) -> Self {
        self.solver_params.max_iter = n.max(1);
        self
    }

    /// Coordinate updates between shrinking refreshes
    /// (`--shrink-every`; 0 disables shrinking).
    pub fn shrink_every(mut self, n: usize) -> Self {
        self.solver_params.shrink_every = n;
        self
    }

    /// Split the `--jobs` budget between the cell driver and each
    /// unit's per-fold CV chain grid: with `n_units` work units in flight the
    /// driver takes `min(jobs, n_units)` threads and every unit's CV
    /// grid gets the leftover factor, so the product never exceeds the
    /// budget (small working sets keep `cv = 1`, one huge cell gets
    /// the whole budget).  Returns `(driver_threads, cv_jobs)`.
    pub fn split_jobs(&self, n_units: usize) -> (usize, usize) {
        let total = self.effective_jobs();
        let driver = total.min(n_units.max(1));
        (driver, (total / driver).max(1))
    }

    pub fn grid_choice(mut self, v: u8) -> Self {
        self.grid_choice = v;
        self
    }

    pub fn libsvm_grid(mut self, v: bool) -> Self {
        self.use_libsvm_grid = v;
        self
    }

    pub fn adaptivity(mut self, v: u8) -> Self {
        self.adaptivity_control = v;
        self
    }

    pub fn voronoi(mut self, strategy: CellStrategy) -> Self {
        self.cells = strategy;
        self
    }

    pub fn folds(mut self, k: usize) -> Self {
        self.folds = k.max(2);
        self
    }

    pub fn backend(mut self, b: BackendChoice) -> Self {
        self.backend = b;
        self
    }

    /// Enable the sparse (CSR) data plane.
    pub fn sparse(mut self, v: bool) -> Self {
        self.sparse = v;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Parse the Appendix-C style `voronoi=c(5,1000)` CLI syntax:
    /// "5" / "6" / "5,1000" / "6,1000" / "0" (none) / "chunks,500".
    pub fn parse_voronoi(text: &str) -> Option<CellStrategy> {
        let parts: Vec<&str> = text.split(',').map(str::trim).collect();
        let size = parts.get(1).and_then(|s| s.parse::<usize>().ok()).unwrap_or(2000);
        match parts[0] {
            "0" => Some(CellStrategy::None),
            "chunks" => Some(CellStrategy::RandomChunks { size }),
            "1" | "voronoi" => Some(CellStrategy::Voronoi { size }),
            "5" => Some(CellStrategy::OverlappingVoronoi { size, overlap: 0.25 }),
            "6" => Some(CellStrategy::RecursiveTree { max_size: size }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let c = Config::default().display(1).threads(2).grid_choice(1).adaptivity(2);
        assert_eq!(c.display, 1);
        assert_eq!(c.threads, 2);
        assert_eq!(c.grid_choice, 1);
        assert_eq!(c.adaptivity_control, 2);
    }

    #[test]
    fn voronoi_syntax() {
        assert_eq!(Config::parse_voronoi("0"), Some(CellStrategy::None));
        assert_eq!(
            Config::parse_voronoi("6,1000"),
            Some(CellStrategy::RecursiveTree { max_size: 1000 })
        );
        assert!(matches!(
            Config::parse_voronoi("5").unwrap(),
            CellStrategy::OverlappingVoronoi { size: 2000, .. }
        ));
        assert_eq!(Config::parse_voronoi("bogus"), None);
    }

    #[test]
    fn threads_floor_at_one() {
        assert_eq!(Config::default().threads(0).threads, 1);
    }

    #[test]
    fn solver_knobs_reach_params() {
        let c = Config::default().solver_eps(1e-4).max_iter(5000).shrink_every(0);
        assert_eq!(c.solver_params.eps, 1e-4);
        assert_eq!(c.solver_params.max_iter, 5000);
        assert_eq!(c.solver_params.shrink_every, 0);
        // defaults keep shrinking on
        assert!(Config::default().solver_params.shrink_every > 0);
    }

    #[test]
    fn jobs_defaults_to_threads() {
        assert_eq!(Config::default().threads(3).effective_jobs(), 3);
        assert_eq!(Config::default().threads(3).jobs(8).effective_jobs(), 8);
        assert_eq!(Config::default().jobs(0).effective_jobs(), 1);
    }

    #[test]
    fn split_jobs_composes_without_oversubscription() {
        let cfg = Config::default().jobs(8);
        assert_eq!(cfg.split_jobs(16), (8, 1)); // many cells: all driver
        assert_eq!(cfg.split_jobs(1), (1, 8)); // one cell: all CV grid
        assert_eq!(cfg.split_jobs(3), (3, 2)); // mixed: 3 × 2 ≤ 8
        assert_eq!(cfg.split_jobs(0), (1, 8));
        let (d, c) = Config::default().split_jobs(4);
        assert_eq!((d, c), (1, 1)); // default budget of 1 stays 1
    }

    #[test]
    fn max_gram_mb_zero_is_unlimited() {
        assert_eq!(Config::default().max_gram_mb, Some(1024));
        assert_eq!(Config::default().max_gram_mb(64).max_gram_mb, Some(64));
        assert_eq!(Config::default().max_gram_mb(0).max_gram_mb, None);
    }
}
