//! Model persistence — liquidSVM writes trained solutions to `.sol` /
//! `.fsol` files so the test phase can run in a separate process
//! (that's how its CLI and Spark workers exchange models).  This port
//! uses a versioned, line-oriented text format (no serde in the
//! offline registry) that round-trips the full [`SvmModel`]:
//! config essentials, scaler, cell partition + router, class list,
//! and every (cell × task) unit with its fold models.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::cells::{CellPartition, CellRouter, TreeNode};
use crate::coordinator::config::Config;
use crate::coordinator::model::{SvmModel, TrainedUnit};
use crate::cv::{CvResult, FoldModel};
use crate::data::dataset::Dataset;
use crate::data::matrix::Matrix;
use crate::data::scale::Scaler;
use crate::tasks::TaskSpec;

const MAGIC: &str = "liquidsvm-sol v1";

/// Serialize a trained model to the `.sol` text format.
pub fn save_model(model: &SvmModel, path: &Path) -> Result<()> {
    let mut s = String::new();
    writeln!(s, "{MAGIC}")?;
    writeln!(s, "spec {}", spec_tag(&model.spec))?;
    writeln!(s, "kernel {:?}", model.config.kernel)?;
    writeln!(s, "classes {}", join_f32(&model.classes))?;
    writeln!(s, "n_tasks {}", model.n_tasks)?;

    match &model.scaler {
        Some(sc) => {
            let (shift, scale) = scaler_parts(sc);
            writeln!(s, "scaler {} {}", join_f32(&shift), join_f32(&scale))?;
        }
        None => writeln!(s, "scaler none")?,
    }

    write_router(&mut s, &model.partition.router)?;
    writeln!(s, "cells {}", model.partition.cells.len())?;
    for cell in &model.partition.cells {
        writeln!(s, "cell {}", join_usize(cell))?;
    }

    writeln!(s, "units {}", model.units.len())?;
    for u in &model.units {
        writeln!(s, "unit {} {} {}", u.cell, u.task, u.data.dim())?;
        writeln!(s, "x {}", join_f32(u.data.x.as_slice()))?;
        writeln!(s, "y {}", join_f32(&u.data.y))?;
        match &u.cv {
            Some(cv) => {
                writeln!(s, "cv {} {} {}", cv.best_gamma, cv.best_lambda, cv.models.len())?;
                for fm in &cv.models {
                    writeln!(s, "fold {}", join_usize(&fm.train_idx))?;
                    writeln!(s, "coef {}", join_f32(&fm.coef))?;
                }
            }
            None => writeln!(s, "cv none")?,
        }
    }
    // write-then-rename so readers (e.g. a serving process hot-reloading
    // this file) never observe a half-written solution
    let tmp = path.with_extension("sol.tmp");
    std::fs::write(&tmp, s).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Load a model saved by [`save_model`].  `config` supplies runtime
/// choices not stored in the file (backend, threads, display).
pub fn load_model(path: &Path, config: &Config) -> Result<SvmModel> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut lines = text.lines();
    macro_rules! next {
        () => {
            lines.next().ok_or_else(|| anyhow!("truncated .sol file"))
        };
    }

    if next!()? != MAGIC {
        bail!("not a {MAGIC} file");
    }
    let spec = parse_spec(field(next!()?, "spec")?)?;
    let kernel = match field(next!()?, "kernel")? {
        "Gauss" => crate::kernel::KernelKind::Gauss,
        "Laplace" => crate::kernel::KernelKind::Laplace,
        other => bail!("unknown kernel {other}"),
    };
    let classes = parse_f32s(field(next!()?, "classes")?)?;
    let n_tasks: usize = field(next!()?, "n_tasks")?.parse()?;

    let scaler_line = next!()?;
    let scaler = if scaler_line == "scaler none" {
        None
    } else {
        let rest = field(scaler_line, "scaler")?;
        let vals = parse_f32s(rest)?;
        if vals.len() % 2 != 0 {
            bail!("scaler line malformed");
        }
        let d = vals.len() / 2;
        Some(Scaler::from_parts(vals[..d].to_vec(), vals[d..].to_vec()))
    };

    let (router, mut lines_used) = read_router(next!()?, &mut lines)?;
    let _ = &mut lines_used;
    let n_cells: usize = field(next!()?, "cells")?.parse()?;
    let mut cells = Vec::with_capacity(n_cells);
    for _ in 0..n_cells {
        cells.push(parse_usizes(field(next!()?, "cell")?)?);
    }
    let partition = CellPartition { cells, router };

    let n_units: usize = field(next!()?, "units")?.parse()?;
    let mut units = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        let head = field(next!()?, "unit")?;
        let parts: Vec<usize> = head
            .split_whitespace()
            .map(|t| t.parse().map_err(|_| anyhow!("bad unit header")))
            .collect::<Result<_>>()?;
        let [cell, task, dim] = parts[..] else { bail!("unit header arity") };
        let x = parse_f32s(field(next!()?, "x")?)?;
        let y = parse_f32s(field(next!()?, "y")?)?;
        let rows = y.len();
        if x.len() != rows * dim {
            bail!("unit data shape mismatch");
        }
        let data = Dataset::new(Matrix::from_vec(x, rows, dim), y);
        let cv_line = next!()?;
        let cv = if cv_line == "cv none" {
            None
        } else {
            let head = field(cv_line, "cv")?;
            let toks: Vec<&str> = head.split_whitespace().collect();
            if toks.len() != 3 {
                bail!("cv header arity");
            }
            let best_gamma: f32 = toks[0].parse()?;
            let best_lambda: f32 = toks[1].parse()?;
            let n_models: usize = toks[2].parse()?;
            let mut models = Vec::with_capacity(n_models);
            for _ in 0..n_models {
                let train_idx = parse_usizes(field(next!()?, "fold")?)?;
                let coef = parse_f32s(field(next!()?, "coef")?)?;
                if train_idx.len() != coef.len() {
                    bail!("fold model arity mismatch");
                }
                models.push(FoldModel { train_idx, coef });
            }
            Some(CvResult {
                best_gamma,
                best_lambda,
                best_val_loss: f32::NAN, // not needed at test time
                val_matrix: Vec::new(),
                models,
                total_iterations: 0,
                points_evaluated: 0,
            })
        };
        units.push(TrainedUnit { cell, task, data, cv });
    }

    let mut cfg = config.clone();
    cfg.kernel = kernel;
    SvmModel::from_parts(cfg, spec, scaler, partition, classes, n_tasks, units)
}

// ---------------------------------------------------------------- helpers

fn spec_tag(spec: &TaskSpec) -> String {
    match spec {
        TaskSpec::Binary { w } => format!("binary:{w}"),
        TaskSpec::MultiClassOvA => "ova".into(),
        TaskSpec::MultiClassAvA => "ava".into(),
        TaskSpec::MultiClassOvALs => "ova-ls".into(),
        TaskSpec::LeastSquares => "ls".into(),
        TaskSpec::NeymanPearson { weights } => format!("npl:{}", join_f32(weights)),
        TaskSpec::MultiQuantile { taus } => format!("qt:{}", join_f32(taus)),
        TaskSpec::MultiExpectile { taus } => format!("ex:{}", join_f32(taus)),
    }
}

fn parse_spec(tag: &str) -> Result<TaskSpec> {
    let (kind, rest) = tag.split_once(':').unwrap_or((tag, ""));
    Ok(match kind {
        "binary" => TaskSpec::Binary { w: rest.parse()? },
        "ova" => TaskSpec::MultiClassOvA,
        "ava" => TaskSpec::MultiClassAvA,
        "ova-ls" => TaskSpec::MultiClassOvALs,
        "ls" => TaskSpec::LeastSquares,
        "npl" => TaskSpec::NeymanPearson { weights: parse_f32s(rest)? },
        "qt" => TaskSpec::MultiQuantile { taus: parse_f32s(rest)? },
        "ex" => TaskSpec::MultiExpectile { taus: parse_f32s(rest)? },
        other => bail!("unknown spec tag {other}"),
    })
}

fn write_router(s: &mut String, router: &CellRouter) -> Result<()> {
    match router {
        CellRouter::Single => writeln!(s, "router single")?,
        CellRouter::Broadcast(k) => writeln!(s, "router broadcast {k}")?,
        CellRouter::Centers(c) => {
            writeln!(s, "router centers {} {}", c.rows(), c.cols())?;
            writeln!(s, "{}", join_f32(c.as_slice()))?;
        }
        CellRouter::Tree(root) => {
            let mut flat = String::new();
            flatten_tree(root, &mut flat);
            writeln!(s, "router tree {}", flat.trim())?;
        }
    }
    Ok(())
}

fn read_router<'a>(
    first: &'a str,
    lines: &mut std::str::Lines<'a>,
) -> Result<(CellRouter, usize)> {
    let rest = field(first, "router")?;
    let mut toks = rest.split_whitespace();
    match toks.next().ok_or_else(|| anyhow!("router kind missing"))? {
        "single" => Ok((CellRouter::Single, 0)),
        "broadcast" => {
            let k: usize = toks.next().ok_or_else(|| anyhow!("broadcast k"))?.parse()?;
            Ok((CellRouter::Broadcast(k), 0))
        }
        "centers" => {
            let r: usize = toks.next().ok_or_else(|| anyhow!("rows"))?.parse()?;
            let c: usize = toks.next().ok_or_else(|| anyhow!("cols"))?.parse()?;
            let data = parse_f32s(lines.next().ok_or_else(|| anyhow!("centers data"))?)?;
            if data.len() != r * c {
                bail!("centers shape mismatch");
            }
            Ok((CellRouter::Centers(Matrix::from_vec(data, r, c)), 1))
        }
        "tree" => {
            let toks: Vec<&str> = rest.split_whitespace().skip(1).collect();
            let mut pos = 0usize;
            let root = unflatten_tree(&toks, &mut pos)?;
            Ok((CellRouter::Tree(Box::new(root)), 0))
        }
        other => bail!("unknown router {other}"),
    }
}

/// Pre-order flatten: `L <cell>` / `S <dim> <threshold>`.
fn flatten_tree(node: &TreeNode, out: &mut String) {
    match node {
        TreeNode::Leaf { cell } => {
            let _ = write!(out, "L {cell} ");
        }
        TreeNode::Split { dim, threshold, left, right } => {
            let _ = write!(out, "S {dim} {threshold} ");
            flatten_tree(left, out);
            flatten_tree(right, out);
        }
    }
}

fn unflatten_tree(toks: &[&str], pos: &mut usize) -> Result<TreeNode> {
    let tag = toks.get(*pos).ok_or_else(|| anyhow!("tree truncated"))?;
    *pos += 1;
    match *tag {
        "L" => {
            let cell: usize = toks.get(*pos).ok_or_else(|| anyhow!("leaf cell"))?.parse()?;
            *pos += 1;
            Ok(TreeNode::Leaf { cell })
        }
        "S" => {
            let dim: usize = toks.get(*pos).ok_or_else(|| anyhow!("split dim"))?.parse()?;
            let threshold: f32 =
                toks.get(*pos + 1).ok_or_else(|| anyhow!("split thr"))?.parse()?;
            *pos += 2;
            let left = unflatten_tree(toks, pos)?;
            let right = unflatten_tree(toks, pos)?;
            Ok(TreeNode::Split { dim, threshold, left: Box::new(left), right: Box::new(right) })
        }
        other => bail!("bad tree token {other}"),
    }
}

fn field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    line.strip_prefix(key)
        .map(str::trim)
        .ok_or_else(|| anyhow!("expected `{key} ...`, got `{line}`"))
}

fn join_f32(v: &[f32]) -> String {
    v.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(" ")
}

fn join_usize(v: &[usize]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" ")
}

fn parse_f32s(s: &str) -> Result<Vec<f32>> {
    s.split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad f32 `{t}`")))
        .collect()
}

fn parse_usizes(s: &str) -> Result<Vec<usize>> {
    s.split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad usize `{t}`")))
        .collect()
}

/// Scaler internals access for persistence (kept here to avoid exposing
/// raw fields in the scale module's public API surface).
fn scaler_parts(s: &Scaler) -> (Vec<f32>, Vec<f32>) {
    s.parts()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::CellStrategy;
    use crate::data::synth;
    use crate::prelude::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lsvm-persist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_binary_model_predictions_identical() {
        let d = synth::banana_binary(200, 1);
        let cfg = Config::default().folds(3);
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let path = tmp("binary.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::banana_binary(80, 2);
        assert_eq!(m.predict(&test.x), back.predict(&test.x));
    }

    #[test]
    fn roundtrip_multiclass_with_tree_cells() {
        let tt = synth::banana_mc(300, 80, 3);
        let cfg = Config::default()
            .folds(3)
            .voronoi(CellStrategy::RecursiveTree { max_size: 100 });
        let m = mc_svm(&tt.train, &cfg).unwrap();
        let path = tmp("mc.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        assert_eq!(m.predict(&tt.test.x), back.predict(&tt.test.x));
        assert_eq!(back.n_tasks, m.n_tasks);
    }

    #[test]
    fn roundtrip_voronoi_centers_router() {
        let d = synth::by_name("cod-rna", 400, 4).unwrap();
        let cfg = Config::default().folds(3).voronoi(CellStrategy::Voronoi { size: 120 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let path = tmp("vor.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::by_name("cod-rna", 150, 5).unwrap();
        assert_eq!(m.predict(&test.x), back.predict(&test.x));
    }

    #[test]
    fn roundtrip_quantile_spec() {
        let d = synth::sinc_hetero(150, 6);
        let cfg = Config::default().folds(3);
        let m = qt_svm(&d, &[0.25, 0.75], &cfg).unwrap();
        let path = tmp("qt.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::sinc_hetero(60, 7);
        let a = m.decision_values(&test.x);
        let b = back.decision_values(&test.x);
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.sol");
        std::fs::write(&path, "not a model").unwrap();
        assert!(load_model(&path, &Config::default()).is_err());
    }
}
