//! Model persistence — liquidSVM writes trained solutions to `.sol` /
//! `.fsol` files so the test phase can run in a separate process
//! (that's how its CLI and Spark workers exchange models).  This port
//! uses a versioned, line-oriented text format (no serde in the
//! offline registry) in two layouts (see DESIGN.md §Persistence):
//!
//! * **monolithic `.sol`** — one file round-tripping the full
//!   [`SvmModel`]: config essentials, scaler, cell partition + router,
//!   class list, and every (cell × task) unit with its fold models;
//! * **sharded `.sol.d/` bundle** — a directory holding a `MANIFEST`
//!   (spec/kernel/classes/scaler/router, the cell strategy, and a
//!   shard list with per-shard byte counts and FNV-1a checksums) plus
//!   one shard file per cell carrying that cell's training indices and
//!   units.  The manifest is tiny and loads eagerly; shards load
//!   lazily and independently, which is what lets `liquidsvm serve`
//!   answer traffic against a model far larger than memory.
//!
//! Both layouts write atomically (write-then-rename; for bundles the
//! whole temporary directory is renamed into place) so a serving
//! process hot-reloading the path never observes a half-written
//! solution.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::cells::{CellPartition, CellRouter, CellStrategy, TreeNode};
use crate::coordinator::config::Config;
use crate::coordinator::model::{SvmModel, TrainedUnit};
use crate::cv::{CvResult, FoldModel};
use crate::data::matrix::Matrix;
use crate::data::scale::Scaler;
use crate::data::store::{Store, WorkingSet};
use crate::tasks::TaskSpec;

const MAGIC: &str = "liquidsvm-sol v1";
const BUNDLE_MAGIC: &str = "liquidsvm-bundle v1";
const SHARD_MAGIC: &str = "liquidsvm-shard v1";
/// Name of the bundle's manifest file inside the `.sol.d/` directory.
pub const MANIFEST_FILE: &str = "MANIFEST";

/// Serialize a trained model to the `.sol` text format.
pub fn save_model(model: &SvmModel, path: &Path) -> Result<()> {
    let _sp = crate::obs::span("persist.save");
    let mut s = String::new();
    writeln!(s, "{MAGIC}")?;
    write_header(&mut s, model)?;
    write_router(&mut s, &model.partition.router)?;
    writeln!(s, "cells {}", model.partition.cells.len())?;
    for cell in &model.partition.cells {
        writeln!(s, "cell {}", join_usize(cell))?;
    }

    writeln!(s, "units {}", model.units.len())?;
    for u in &model.units {
        write_unit(&mut s, u)?;
    }
    // write-then-rename so readers (e.g. a serving process hot-reloading
    // this file) never observe a half-written solution
    let tmp = path.with_extension("sol.tmp");
    std::fs::write(&tmp, s).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Shared `spec`/`kernel`/`classes`/`n_tasks`/`scaler` header of both
/// the `.sol` format and the bundle manifest.
fn write_header(s: &mut String, model: &SvmModel) -> Result<()> {
    write_header_parts(
        s,
        &model.spec,
        model.config.kernel,
        &model.classes,
        model.n_tasks,
        model.scaler.as_ref(),
    )
}

fn write_header_parts(
    s: &mut String,
    spec: &TaskSpec,
    kernel: crate::kernel::KernelKind,
    classes: &[f32],
    n_tasks: usize,
    scaler: Option<&Scaler>,
) -> Result<()> {
    writeln!(s, "spec {}", spec_tag(spec))?;
    writeln!(s, "kernel {kernel:?}")?;
    writeln!(s, "classes {}", join_f32(classes))?;
    writeln!(s, "n_tasks {n_tasks}")?;
    match scaler {
        Some(sc) => {
            let (shift, scale) = scaler_parts(sc);
            writeln!(s, "scaler {} {}", join_f32(&shift), join_f32(&scale))?;
        }
        None => writeln!(s, "scaler none")?,
    }
    Ok(())
}

/// One (cell × task) unit: header, working set, CV outcome.  Dense
/// working sets persist as one flat `x` line; CSR working sets persist
/// their triplet (`xs` indptr / `xi` indices / `xv` values) so a
/// sparse-trained model never densifies on disk either.
fn write_unit(s: &mut String, u: &TrainedUnit) -> Result<()> {
    writeln!(s, "unit {} {} {}", u.cell, u.task, u.data.dim())?;
    match &u.data.x {
        Store::Dense(x) => writeln!(s, "x {}", join_f32(x.as_slice()))?,
        Store::Sparse(x) => {
            let (indptr, indices, values) = x.parts();
            writeln!(s, "xs {}", join_usize(indptr))?;
            writeln!(
                s,
                "xi {}",
                indices.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ")
            )?;
            writeln!(s, "xv {}", join_f32(values))?;
        }
    }
    writeln!(s, "y {}", join_f32(&u.data.y))?;
    match &u.cv {
        Some(cv) => {
            writeln!(s, "cv {} {} {}", cv.best_gamma, cv.best_lambda, cv.models.len())?;
            for fm in &cv.models {
                writeln!(s, "fold {}", join_usize(&fm.train_idx))?;
                writeln!(s, "coef {}", join_f32(&fm.coef))?;
            }
        }
        None => writeln!(s, "cv none")?,
    }
    Ok(())
}

fn read_unit(lines: &mut std::str::Lines) -> Result<TrainedUnit> {
    let mut next = || lines.next().ok_or_else(|| anyhow!("truncated unit block"));
    let head = field(next()?, "unit")?;
    let parts: Vec<usize> = head
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad unit header")))
        .collect::<Result<_>>()?;
    let [cell, task, dim] = parts[..] else { bail!("unit header arity") };
    let x_line = next()?;
    let data = if let Ok(flat) = field(x_line, "xs") {
        // CSR working set: indptr / indices / values triplet
        let indptr = parse_usizes(flat)?;
        let indices: Vec<u32> = field(next()?, "xi")?
            .split_whitespace()
            .map(|t| t.parse().map_err(|_| anyhow!("bad u32 `{t}`")))
            .collect::<Result<_>>()?;
        let values = parse_f32s(field(next()?, "xv")?)?;
        let y = parse_f32s(field(next()?, "y")?)?;
        if indptr.len() != y.len() + 1 {
            bail!("sparse unit shape mismatch");
        }
        let x = crate::data::csr::CsrMatrix::from_parts(indptr, indices, values, dim);
        WorkingSet::sparse(x, y)
    } else {
        let x = parse_f32s(field(x_line, "x")?)?;
        let y = parse_f32s(field(next()?, "y")?)?;
        let rows = y.len();
        if x.len() != rows * dim {
            bail!("unit data shape mismatch");
        }
        WorkingSet::dense(Matrix::from_vec(x, rows, dim), y)
    };
    let cv_line = next()?;
    let cv = if cv_line == "cv none" {
        None
    } else {
        let head = field(cv_line, "cv")?;
        let toks: Vec<&str> = head.split_whitespace().collect();
        if toks.len() != 3 {
            bail!("cv header arity");
        }
        let best_gamma: f32 = toks[0].parse()?;
        let best_lambda: f32 = toks[1].parse()?;
        let n_models: usize = toks[2].parse()?;
        let mut models = Vec::with_capacity(n_models);
        for _ in 0..n_models {
            let train_idx = parse_usizes(field(next()?, "fold")?)?;
            let coef = parse_f32s(field(next()?, "coef")?)?;
            if train_idx.len() != coef.len() {
                bail!("fold model arity mismatch");
            }
            models.push(FoldModel { train_idx, coef });
        }
        Some(CvResult {
            best_gamma,
            best_lambda,
            best_val_loss: f32::NAN, // not needed at test time
            val_matrix: Vec::new(),
            models,
            total_iterations: 0,
            points_evaluated: 0,
        })
    };
    Ok(TrainedUnit { cell, task, data, cv })
}

/// Load a model saved by [`save_model`] — or, transparently, a sharded
/// bundle written by [`save_bundle`] (every shard loaded eagerly).
/// `config` supplies runtime choices not stored in the file (backend,
/// threads, display).
pub fn load_model(path: &Path, config: &Config) -> Result<SvmModel> {
    if is_bundle_path(path) {
        return load_bundle(path, config);
    }
    let _sp = crate::obs::span("persist.load");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut lines = text.lines();
    macro_rules! next {
        () => {
            lines.next().ok_or_else(|| anyhow!("truncated .sol file"))
        };
    }

    if next!()? != MAGIC {
        bail!("not a {MAGIC} file");
    }
    let spec = parse_spec(field(next!()?, "spec")?)?;
    let kernel = match field(next!()?, "kernel")? {
        "Gauss" => crate::kernel::KernelKind::Gauss,
        "Laplace" => crate::kernel::KernelKind::Laplace,
        other => bail!("unknown kernel {other}"),
    };
    let classes = parse_f32s(field(next!()?, "classes")?)?;
    let n_tasks: usize = field(next!()?, "n_tasks")?.parse()?;

    let scaler = parse_scaler_line(next!()?)?;

    let (router, mut lines_used) = read_router(next!()?, &mut lines)?;
    let _ = &mut lines_used;
    let n_cells: usize = field(next!()?, "cells")?.parse()?;
    let mut cells = Vec::with_capacity(n_cells);
    for _ in 0..n_cells {
        cells.push(parse_usizes(field(next!()?, "cell")?)?);
    }
    let partition = CellPartition { cells, router };

    let n_units: usize = field(next!()?, "units")?.parse()?;
    let mut units = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        units.push(read_unit(&mut lines)?);
    }

    let mut cfg = config.clone();
    cfg.kernel = kernel;
    SvmModel::from_parts(cfg, spec, scaler, partition, classes, n_tasks, units)
}

// ------------------------------------------------------- sharded bundles

/// Metadata of one shard file inside a `.sol.d/` bundle.
#[derive(Clone, Debug)]
pub struct ShardMeta {
    /// cell this shard carries
    pub cell: usize,
    /// file name inside the bundle directory
    pub file: String,
    /// exact byte length of the shard file
    pub bytes: u64,
    /// FNV-1a 64-bit checksum of the shard file
    pub checksum: u64,
}

/// The eagerly-loaded part of a `.sol.d/` bundle: everything needed to
/// scale + route a request, plus the shard table — but none of the
/// per-cell fold models, which load lazily via [`load_shard`].
#[derive(Clone, Debug)]
pub struct BundleManifest {
    pub spec: TaskSpec,
    pub kernel: crate::kernel::KernelKind,
    pub classes: Vec<f32>,
    pub n_tasks: usize,
    /// expected input dimension (0 = unknown)
    pub dim: usize,
    pub scaler: Option<Scaler>,
    /// cell strategy the model was trained with (informational)
    pub strategy: CellStrategy,
    pub router: CellRouter,
    /// one entry per cell, in cell order
    pub shards: Vec<ShardMeta>,
}

impl BundleManifest {
    pub fn n_cells(&self) -> usize {
        self.shards.len()
    }

    /// Sum of all shard file sizes — the resident cost of a fully
    /// loaded bundle.
    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).sum()
    }
}

/// Does `path` look like a `.sol.d/` bundle on disk?
pub fn is_bundle_path(path: &Path) -> bool {
    path.is_dir() && path.join(MANIFEST_FILE).is_file()
}

/// FNV-1a 64-bit hash — cheap corruption check for shard files (no
/// crypto needed; this guards against torn writes and bit rot, not
/// adversaries).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn strategy_tag(s: &CellStrategy) -> String {
    match s {
        CellStrategy::None => "none".into(),
        CellStrategy::RandomChunks { size } => format!("chunks,{size}"),
        CellStrategy::Voronoi { size } => format!("voronoi,{size}"),
        CellStrategy::OverlappingVoronoi { size, overlap } => format!("overlap,{size},{overlap}"),
        CellStrategy::RecursiveTree { max_size } => format!("tree,{max_size}"),
    }
}

fn parse_strategy(tag: &str) -> Result<CellStrategy> {
    let parts: Vec<&str> = tag.split(',').collect();
    let num = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("strategy tag `{tag}` arity"))?
            .parse()
            .map_err(|_| anyhow!("strategy tag `{tag}`: bad number"))
    };
    Ok(match parts[0] {
        "none" => CellStrategy::None,
        "chunks" => CellStrategy::RandomChunks { size: num(1)? },
        "voronoi" => CellStrategy::Voronoi { size: num(1)? },
        "overlap" => CellStrategy::OverlappingVoronoi {
            size: num(1)?,
            overlap: parts
                .get(2)
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| anyhow!("strategy tag `{tag}`: bad overlap"))?,
        },
        "tree" => CellStrategy::RecursiveTree { max_size: num(1)? },
        other => bail!("unknown strategy tag `{other}`"),
    })
}

/// Serialize one cell's shard — the cell's training indices plus its
/// solved (cell × task) units — to the exact bytes a `.sol.d/` shard
/// file holds.  This is the unit of exchange of the distributed wire
/// protocol (DESIGN.md §Distributed-wire): a worker encodes its shard
/// with this function and the coordinator writes the bytes verbatim,
/// which is what makes a distributed bundle byte-identical to a
/// single-process one by construction.
pub fn encode_shard(cell: usize, indices: &[usize], units: &[&TrainedUnit]) -> Result<Vec<u8>> {
    let mut s = String::new();
    writeln!(s, "{SHARD_MAGIC}")?;
    writeln!(s, "cell {cell}")?;
    writeln!(s, "indices {}", join_usize(indices))?;
    writeln!(s, "units {}", units.len())?;
    for u in units {
        write_unit(&mut s, u)?;
    }
    Ok(s.into_bytes())
}

/// Everything the bundle `MANIFEST` records besides the shard table.
/// [`save_bundle`] derives one from a trained [`SvmModel`]; the wire
/// coordinator builds one from its training front-end state (it never
/// holds the full model — shards stream from workers straight to disk).
#[derive(Clone, Debug)]
pub struct BundleHeader {
    pub spec: TaskSpec,
    pub kernel: crate::kernel::KernelKind,
    pub classes: Vec<f32>,
    pub n_tasks: usize,
    pub scaler: Option<Scaler>,
    /// expected input dimension (0 = unknown)
    pub dim: usize,
    pub strategy: CellStrategy,
    pub router: CellRouter,
}

impl BundleHeader {
    fn manifest_text(&self, shard_lines: &[String]) -> Result<String> {
        let mut m = String::new();
        writeln!(m, "{BUNDLE_MAGIC}")?;
        write_header_parts(
            &mut m,
            &self.spec,
            self.kernel,
            &self.classes,
            self.n_tasks,
            self.scaler.as_ref(),
        )?;
        writeln!(m, "dim {}", self.dim)?;
        writeln!(m, "strategy {}", strategy_tag(&self.strategy))?;
        write_router(&mut m, &self.router)?;
        writeln!(m, "shards {}", shard_lines.len())?;
        for line in shard_lines {
            writeln!(m, "{line}")?;
        }
        Ok(m)
    }
}

/// Incremental `.sol.d/` bundle assembly: shards arrive in any order
/// (the wire coordinator ingests them as workers finish, including
/// re-dispatched cells), each is written under its cell-derived file
/// name, and [`finish`](BundleWriter::finish) writes the manifest in
/// cell order and atomically swaps the bundle into place.  Until then
/// everything lives in a `<path>.tmp` directory, so readers never see
/// a partial bundle.
pub struct BundleWriter {
    path: PathBuf,
    tmp: PathBuf,
    /// per-cell `(file, len, fnv)` — filled as shards arrive
    shards: Vec<Option<(String, usize, u64)>>,
}

impl BundleWriter {
    pub fn create(path: &Path, n_cells: usize) -> Result<BundleWriter> {
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp).with_context(|| format!("clearing {tmp:?}"))?;
        }
        std::fs::create_dir_all(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        Ok(BundleWriter { path: path.to_path_buf(), tmp, shards: vec![None; n_cells] })
    }

    /// Write one cell's shard bytes (as produced by [`encode_shard`]).
    /// Re-ingesting a cell overwrites the previous copy — harmless,
    /// since `encode_shard` is deterministic per cell.
    pub fn put_shard(&mut self, cell: usize, bytes: &[u8]) -> Result<()> {
        if cell >= self.shards.len() {
            bail!("shard for cell {cell} out of range ({} cells)", self.shards.len());
        }
        let file = format!("shard-{cell:05}.sol");
        std::fs::write(self.tmp.join(&file), bytes)
            .with_context(|| format!("writing shard {file}"))?;
        self.shards[cell] = Some((file, bytes.len(), fnv1a64(bytes)));
        Ok(())
    }

    /// Write the manifest and swap the bundle into place.  Errors if
    /// any cell's shard never arrived.
    pub fn finish(self, header: &BundleHeader) -> Result<()> {
        let mut shard_lines = Vec::with_capacity(self.shards.len());
        for (c, slot) in self.shards.iter().enumerate() {
            let (file, len, sum) =
                slot.as_ref().ok_or_else(|| anyhow!("bundle incomplete: no shard for cell {c}"))?;
            shard_lines.push(format!("shard {c} {file} {len} {sum:016x}"));
        }
        let m = header.manifest_text(&shard_lines)?;
        std::fs::write(self.tmp.join(MANIFEST_FILE), m).context("writing MANIFEST")?;
        swap_into_place(&self.tmp, &self.path)
    }
}

/// Swap a fully-written temporary bundle directory into place.  When
/// replacing, the previous bundle is renamed aside first and deleted
/// only after the new one is in place, so a crash at any point leaves
/// a loadable bundle on disk (at `path`, or recoverable at
/// `<path>.old`) — never nothing.
fn swap_into_place(tmp: &Path, path: &Path) -> Result<()> {
    if path.exists() {
        let mut old_name = path.as_os_str().to_owned();
        old_name.push(".old");
        let old = PathBuf::from(old_name);
        if old.exists() {
            std::fs::remove_dir_all(&old).with_context(|| format!("clearing {old:?}"))?;
        }
        std::fs::rename(path, &old).with_context(|| format!("setting aside {path:?}"))?;
        std::fs::rename(tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        let _ = std::fs::remove_dir_all(&old);
    } else {
        std::fs::rename(tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    }
    Ok(())
}

/// Write a model as a sharded `.sol.d/` bundle: one shard file per
/// cell plus a `MANIFEST`, assembled in a temporary directory and
/// renamed into place as a whole, so readers never see a partial
/// bundle (a pre-existing bundle at `path` is replaced).
pub fn save_bundle(model: &SvmModel, path: &Path) -> Result<()> {
    let _sp = crate::obs::span("persist.save");

    // group units by cell in one linear pass (models at scale have
    // thousands of cells — an inner filter scan per cell is quadratic)
    let n_cells = model.partition.n_cells();
    let mut by_cell: Vec<Vec<&TrainedUnit>> = vec![Vec::new(); n_cells];
    for u in &model.units {
        if u.cell < n_cells {
            by_cell[u.cell].push(u);
        }
    }

    let mut writer = BundleWriter::create(path, n_cells)?;
    for (c, indices) in model.partition.cells.iter().enumerate() {
        let bytes = encode_shard(c, indices, &by_cell[c])?;
        writer.put_shard(c, &bytes)?;
    }
    writer.finish(&BundleHeader {
        spec: model.spec.clone(),
        kernel: model.config.kernel,
        classes: model.classes.clone(),
        n_tasks: model.n_tasks,
        scaler: model.scaler.clone(),
        dim: model.input_dim(),
        strategy: model.config.cells.clone(),
        router: model.partition.router.clone(),
    })
}

/// Read and parse a bundle's `MANIFEST` (cheap: no shard data).
pub fn read_manifest(dir: &Path) -> Result<BundleManifest> {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
    let mut lines = text.lines();
    macro_rules! next {
        () => {
            lines.next().ok_or_else(|| anyhow!("truncated MANIFEST"))
        };
    }

    if next!()? != BUNDLE_MAGIC {
        bail!("not a {BUNDLE_MAGIC} directory");
    }
    let spec = parse_spec(field(next!()?, "spec")?)?;
    let kernel = match field(next!()?, "kernel")? {
        "Gauss" => crate::kernel::KernelKind::Gauss,
        "Laplace" => crate::kernel::KernelKind::Laplace,
        other => bail!("unknown kernel {other}"),
    };
    let classes = parse_f32s(field(next!()?, "classes")?)?;
    let n_tasks: usize = field(next!()?, "n_tasks")?.parse()?;
    let scaler = parse_scaler_line(next!()?)?;
    let dim: usize = field(next!()?, "dim")?.parse()?;
    let strategy = parse_strategy(field(next!()?, "strategy")?)?;
    let router_first = next!()?;
    let (router, _) = read_router(router_first, &mut lines)?;
    let n_shards: usize = field(next!()?, "shards")?.parse()?;
    let mut shards = Vec::with_capacity(n_shards);
    for i in 0..n_shards {
        let rest = field(next!()?, "shard")?;
        let toks: Vec<&str> = rest.split_whitespace().collect();
        if toks.len() != 4 {
            bail!("shard line arity");
        }
        let cell: usize = toks[0].parse()?;
        if cell != i {
            bail!("shard table out of order: expected cell {i}, got {cell}");
        }
        shards.push(ShardMeta {
            cell,
            file: toks[1].to_string(),
            bytes: toks[2].parse()?,
            checksum: u64::from_str_radix(toks[3], 16)
                .map_err(|_| anyhow!("bad checksum `{}`", toks[3]))?,
        });
    }
    Ok(BundleManifest { spec, kernel, classes, n_tasks, dim, scaler, strategy, router, shards })
}

/// Load one shard of a bundle, verifying its size and checksum
/// against the manifest.  Returns the cell's training indices and its
/// (cell × task) units.
pub fn load_shard(
    dir: &Path,
    manifest: &BundleManifest,
    cell: usize,
) -> Result<(Vec<usize>, Vec<TrainedUnit>)> {
    let meta = manifest
        .shards
        .get(cell)
        .ok_or_else(|| anyhow!("bundle has no shard for cell {cell}"))?;
    let path = dir.join(&meta.file);
    let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() as u64 != meta.bytes {
        bail!("shard {cell}: size {} != manifest {}", bytes.len(), meta.bytes);
    }
    let sum = fnv1a64(&bytes);
    if sum != meta.checksum {
        bail!("shard {cell}: checksum {sum:016x} != manifest {:016x}", meta.checksum);
    }
    let text = std::str::from_utf8(&bytes).context("shard not UTF-8")?;
    let mut lines = text.lines();
    let mut next = || lines.next().ok_or_else(|| anyhow!("truncated shard"));
    if next()? != SHARD_MAGIC {
        bail!("not a {SHARD_MAGIC} file");
    }
    let stored_cell: usize = field(next()?, "cell")?.parse()?;
    if stored_cell != cell {
        bail!("shard file claims cell {stored_cell}, manifest says {cell}");
    }
    let indices = parse_usizes(field(next()?, "indices")?)?;
    let n_units: usize = field(next()?, "units")?.parse()?;
    drop(next);
    let mut units = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        units.push(read_unit(&mut lines)?);
    }
    Ok((indices, units))
}

/// Load a whole bundle eagerly into an [`SvmModel`] (the test-phase /
/// `liquidsvm predict` path; serving loads shards lazily instead).
pub fn load_bundle(dir: &Path, config: &Config) -> Result<SvmModel> {
    let _sp = crate::obs::span("persist.load");
    let manifest = read_manifest(dir)?;
    let mut cells = Vec::with_capacity(manifest.n_cells());
    let mut units = Vec::new();
    for c in 0..manifest.n_cells() {
        let (indices, mut shard_units) = load_shard(dir, &manifest, c)?;
        cells.push(indices);
        units.append(&mut shard_units);
    }
    let partition = CellPartition { cells, router: manifest.router.clone() };
    let mut cfg = config.clone();
    cfg.kernel = manifest.kernel;
    cfg.cells = manifest.strategy.clone();
    SvmModel::from_parts(
        cfg,
        manifest.spec,
        manifest.scaler,
        partition,
        manifest.classes,
        manifest.n_tasks,
        units,
    )
}

// ---------------------------------------------------------------- helpers

fn spec_tag(spec: &TaskSpec) -> String {
    match spec {
        TaskSpec::Binary { w } => format!("binary:{w}"),
        TaskSpec::MultiClassOvA => "ova".into(),
        TaskSpec::MultiClassAvA => "ava".into(),
        TaskSpec::MultiClassOvALs => "ova-ls".into(),
        TaskSpec::LeastSquares => "ls".into(),
        TaskSpec::NeymanPearson { weights } => format!("npl:{}", join_f32(weights)),
        TaskSpec::MultiQuantile { taus } => format!("qt:{}", join_f32(taus)),
        TaskSpec::MultiExpectile { taus } => format!("ex:{}", join_f32(taus)),
    }
}

fn parse_spec(tag: &str) -> Result<TaskSpec> {
    let (kind, rest) = tag.split_once(':').unwrap_or((tag, ""));
    Ok(match kind {
        "binary" => TaskSpec::Binary { w: rest.parse()? },
        "ova" => TaskSpec::MultiClassOvA,
        "ava" => TaskSpec::MultiClassAvA,
        "ova-ls" => TaskSpec::MultiClassOvALs,
        "ls" => TaskSpec::LeastSquares,
        "npl" => TaskSpec::NeymanPearson { weights: parse_f32s(rest)? },
        "qt" => TaskSpec::MultiQuantile { taus: parse_f32s(rest)? },
        "ex" => TaskSpec::MultiExpectile { taus: parse_f32s(rest)? },
        other => bail!("unknown spec tag {other}"),
    })
}

fn write_router(s: &mut String, router: &CellRouter) -> Result<()> {
    match router {
        CellRouter::Single => writeln!(s, "router single")?,
        CellRouter::Broadcast(k) => writeln!(s, "router broadcast {k}")?,
        CellRouter::Centers(c) => {
            writeln!(s, "router centers {} {}", c.rows(), c.cols())?;
            writeln!(s, "{}", join_f32(c.as_slice()))?;
        }
        CellRouter::Tree(root) => {
            let mut flat = String::new();
            flatten_tree(root, &mut flat);
            writeln!(s, "router tree {}", flat.trim())?;
        }
    }
    Ok(())
}

fn read_router<'a>(
    first: &'a str,
    lines: &mut std::str::Lines<'a>,
) -> Result<(CellRouter, usize)> {
    let rest = field(first, "router")?;
    let mut toks = rest.split_whitespace();
    match toks.next().ok_or_else(|| anyhow!("router kind missing"))? {
        "single" => Ok((CellRouter::Single, 0)),
        "broadcast" => {
            let k: usize = toks.next().ok_or_else(|| anyhow!("broadcast k"))?.parse()?;
            Ok((CellRouter::Broadcast(k), 0))
        }
        "centers" => {
            let r: usize = toks.next().ok_or_else(|| anyhow!("rows"))?.parse()?;
            let c: usize = toks.next().ok_or_else(|| anyhow!("cols"))?.parse()?;
            let data = parse_f32s(lines.next().ok_or_else(|| anyhow!("centers data"))?)?;
            if data.len() != r * c {
                bail!("centers shape mismatch");
            }
            Ok((CellRouter::Centers(Matrix::from_vec(data, r, c)), 1))
        }
        "tree" => {
            let toks: Vec<&str> = rest.split_whitespace().skip(1).collect();
            let mut pos = 0usize;
            let root = unflatten_tree(&toks, &mut pos)?;
            Ok((CellRouter::Tree(Box::new(root)), 0))
        }
        other => bail!("unknown router {other}"),
    }
}

/// Pre-order flatten: `L <cell>` / `S <dim> <threshold>`.
fn flatten_tree(node: &TreeNode, out: &mut String) {
    match node {
        TreeNode::Leaf { cell } => {
            let _ = write!(out, "L {cell} ");
        }
        TreeNode::Split { dim, threshold, left, right } => {
            let _ = write!(out, "S {dim} {threshold} ");
            flatten_tree(left, out);
            flatten_tree(right, out);
        }
    }
}

fn unflatten_tree(toks: &[&str], pos: &mut usize) -> Result<TreeNode> {
    let tag = toks.get(*pos).ok_or_else(|| anyhow!("tree truncated"))?;
    *pos += 1;
    match *tag {
        "L" => {
            let cell: usize = toks.get(*pos).ok_or_else(|| anyhow!("leaf cell"))?.parse()?;
            *pos += 1;
            Ok(TreeNode::Leaf { cell })
        }
        "S" => {
            let dim: usize = toks.get(*pos).ok_or_else(|| anyhow!("split dim"))?.parse()?;
            let threshold: f32 =
                toks.get(*pos + 1).ok_or_else(|| anyhow!("split thr"))?.parse()?;
            *pos += 2;
            let left = unflatten_tree(toks, pos)?;
            let right = unflatten_tree(toks, pos)?;
            Ok(TreeNode::Split { dim, threshold, left: Box::new(left), right: Box::new(right) })
        }
        other => bail!("bad tree token {other}"),
    }
}

/// Parse a `scaler none` / `scaler <shifts> <scales>` line (shared by
/// the `.sol` format and the bundle manifest).
fn parse_scaler_line(line: &str) -> Result<Option<Scaler>> {
    if line == "scaler none" {
        return Ok(None);
    }
    let rest = field(line, "scaler")?;
    let vals = parse_f32s(rest)?;
    if vals.is_empty() || vals.len() % 2 != 0 {
        bail!("scaler line malformed");
    }
    let d = vals.len() / 2;
    Ok(Some(Scaler::from_parts(vals[..d].to_vec(), vals[d..].to_vec())))
}

fn field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    line.strip_prefix(key)
        .map(str::trim)
        .ok_or_else(|| anyhow!("expected `{key} ...`, got `{line}`"))
}

fn join_f32(v: &[f32]) -> String {
    v.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(" ")
}

fn join_usize(v: &[usize]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" ")
}

fn parse_f32s(s: &str) -> Result<Vec<f32>> {
    s.split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad f32 `{t}`")))
        .collect()
}

fn parse_usizes(s: &str) -> Result<Vec<usize>> {
    s.split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad usize `{t}`")))
        .collect()
}

/// Scaler internals access for persistence (kept here to avoid exposing
/// raw fields in the scale module's public API surface).
fn scaler_parts(s: &Scaler) -> (Vec<f32>, Vec<f32>) {
    s.parts()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::CellStrategy;
    use crate::data::synth;
    use crate::prelude::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lsvm-persist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_binary_model_predictions_identical() {
        let d = synth::banana_binary(200, 1);
        let cfg = Config::default().folds(3);
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let path = tmp("binary.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::banana_binary(80, 2);
        assert_eq!(m.predict(&test.x), back.predict(&test.x));
    }

    #[test]
    fn roundtrip_multiclass_with_tree_cells() {
        let tt = synth::banana_mc(300, 80, 3);
        let cfg = Config::default()
            .folds(3)
            .voronoi(CellStrategy::RecursiveTree { max_size: 100 });
        let m = mc_svm(&tt.train, &cfg).unwrap();
        let path = tmp("mc.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        assert_eq!(m.predict(&tt.test.x), back.predict(&tt.test.x));
        assert_eq!(back.n_tasks, m.n_tasks);
    }

    #[test]
    fn roundtrip_voronoi_centers_router() {
        let d = synth::by_name("cod-rna", 400, 4).unwrap();
        let cfg = Config::default().folds(3).voronoi(CellStrategy::Voronoi { size: 120 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let path = tmp("vor.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::by_name("cod-rna", 150, 5).unwrap();
        assert_eq!(m.predict(&test.x), back.predict(&test.x));
    }

    #[test]
    fn roundtrip_quantile_spec() {
        let d = synth::sinc_hetero(150, 6);
        let cfg = Config::default().folds(3);
        let m = qt_svm(&d, &[0.25, 0.75], &cfg).unwrap();
        let path = tmp("qt.sol");
        save_model(&m, &path).unwrap();
        let back = load_model(&path, &cfg).unwrap();
        let test = synth::sinc_hetero(60, 7);
        let a = m.decision_values(&test.x);
        let b = back.decision_values(&test.x);
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.sol");
        std::fs::write(&path, "not a model").unwrap();
        assert!(load_model(&path, &Config::default()).is_err());
    }

    #[test]
    fn bundle_roundtrip_voronoi_predictions_identical() {
        let d = synth::by_name("cod-rna", 400, 14).unwrap();
        let cfg = Config::default().folds(3).voronoi(CellStrategy::Voronoi { size: 100 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let dir = tmp("vor.sol.d");
        save_bundle(&m, &dir).unwrap();

        let manifest = read_manifest(&dir).unwrap();
        assert_eq!(manifest.n_cells(), m.partition.n_cells());
        assert_eq!(manifest.dim, 8);
        assert!(manifest.total_bytes() > 0);
        assert!(matches!(manifest.strategy, CellStrategy::Voronoi { size: 100 }));

        // load_model is transparent over bundles
        let back = load_model(&dir, &cfg).unwrap();
        let test = synth::by_name("cod-rna", 150, 15).unwrap();
        assert_eq!(m.predict(&test.x), back.predict(&test.x));
    }

    #[test]
    fn bundle_roundtrip_every_strategy() {
        let d = synth::banana_binary(260, 16);
        let strategies = [
            CellStrategy::None,
            CellStrategy::RandomChunks { size: 70 },
            CellStrategy::RecursiveTree { max_size: 80 },
            CellStrategy::OverlappingVoronoi { size: 90, overlap: 0.25 },
        ];
        for (i, strat) in strategies.into_iter().enumerate() {
            let cfg = Config::default().folds(2).voronoi(strat.clone());
            let m = svm_binary(&d, 0.5, &cfg).unwrap();
            let dir = tmp(&format!("strat-{i}.sol.d"));
            save_bundle(&m, &dir).unwrap();
            let back = load_bundle(&dir, &cfg).unwrap();
            let test = synth::banana_binary(60, 17);
            assert_eq!(m.predict(&test.x), back.predict(&test.x), "strategy {strat:?}");
            assert_eq!(read_manifest(&dir).unwrap().strategy, strat);
        }
    }

    #[test]
    fn bundle_detects_shard_corruption() {
        let d = synth::banana_binary(150, 18);
        let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 50 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let dir = tmp("corrupt.sol.d");
        save_bundle(&m, &dir).unwrap();
        let manifest = read_manifest(&dir).unwrap();
        // flip bytes in shard 0 without changing its length
        let shard_path = dir.join(&manifest.shards[0].file);
        let mut bytes = std::fs::read(&shard_path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] = bytes[last].wrapping_add(1);
        std::fs::write(&shard_path, &bytes).unwrap();
        let err = load_shard(&dir, &manifest, 0).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(load_bundle(&dir, &cfg).is_err());
    }

    #[test]
    fn bundle_overwrite_is_atomic_swap() {
        let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 60 });
        let m1 = svm_binary(&synth::banana_binary(140, 19), 0.5, &cfg).unwrap();
        let m2 = svm_binary(&synth::banana_binary(220, 20), 0.5, &cfg).unwrap();
        let dir = tmp("swap.sol.d");
        save_bundle(&m1, &dir).unwrap();
        save_bundle(&m2, &dir).unwrap(); // replaces the first bundle wholesale
        let back = load_bundle(&dir, &cfg).unwrap();
        let test = synth::banana_binary(50, 21);
        assert_eq!(back.predict(&test.x), m2.predict(&test.x));
        // no leftover temp or set-aside directories
        assert!(!dir.with_file_name("swap.sol.d.tmp").exists());
        assert!(!dir.with_file_name("swap.sol.d.old").exists());
    }
}
