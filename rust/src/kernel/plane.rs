//! The **Gram plane** — the shared kernel compute layer between raw
//! data and every consumer of kernel values (solvers, the CV grid, the
//! predict/serve path).  See DESIGN.md §Compute-plane.
//!
//! The paper's speed claim rests on computing squared distances once
//! and re-exponentiating them cheaply per γ.  The plane turns that idea
//! into an explicit contract:
//!
//! * [`GramSource`] — how solvers *read* kernel values: row, row-pair
//!   and entry access.  Methods take `&mut self` so an implementation
//!   may fill internal scratch; a returned row stays valid until the
//!   next access.
//! * [`DenseGram`] — a borrowed, fully materialized Gram matrix (the
//!   seed behavior, and the adapter for existing `&Matrix` call sites).
//! * [`GramBuffer`] — an *owned, reusable* buffer a worker
//!   exponentiates distances into **in place**.  Refilling for a new γ
//!   never allocates once capacity is grown; the process-wide
//!   `gram_allocs` counter proves it (see `metrics::counters`).
//! * [`StreamedGram`] — row-tile streaming for when n² exceeds
//!   `--max-gram-mb`: rows are recomputed on demand from the sample
//!   matrices and row norms, bit-identically to the cached path
//!   (guaranteed by sharing `backend`'s per-pair distance kernels).
//! * [`accumulate_decisions`] — the batched predict path: cross
//!   distances computed tile-by-tile into one reusable buffer,
//!   exponentiated in place, and immediately folded into decision
//!   values — replacing both the per-model full cross-Gram allocation
//!   and any per-row kernel loop.

use crate::data::csr::CsrMatrix;
use crate::data::matrix::{sq_dist, Matrix};
use crate::data::store::StoreRef;
use crate::metrics::counters;

use super::backend::{self, GramBackend, PairKernel};
use super::simd;
use super::KernelKind;

/// Hand out a fresh identity for a distance source.  [`GramBuffer`]
/// keys its residency check on `(epoch, γ)`; an epoch is never reused,
/// so a buffer can roam across folds/working sets without ever
/// mistaking a new distance matrix at a recycled address for the one
/// it last exponentiated.
pub fn next_epoch() -> u64 {
    // always-std: a `static` needs the const constructor, and an epoch
    // ticket is not a synchronization edge (see sync.rs §static_atomic)
    use crate::sync::static_atomic::{AtomicU64, Ordering};
    static EPOCH: AtomicU64 = AtomicU64::new(1);
    EPOCH.fetch_add(1, Ordering::Relaxed)
}

/// Read access to a Gram matrix, as the solvers need it: single rows
/// (gradient updates, matvec sweeps), row pairs (two-coordinate
/// working sets), and scalar entries (diagonals, 2×2 subproblems).
///
/// Methods take `&mut self` because a streaming source materializes
/// the requested row into internal scratch; a slice returned by
/// [`GramSource::row`] is valid until the next call.  Dense sources
/// simply return views into their storage.
pub trait GramSource {
    /// Number of left-hand rows (x side).
    fn rows(&self) -> usize;
    /// Number of right-hand rows (y side) — the expansion size.
    fn cols(&self) -> usize;
    /// Kernel row `i`: `k(x_i, y_j)` for all `j`.
    fn row(&mut self, i: usize) -> &[f32];
    /// Two rows at once (for 2-coordinate solvers); `i != j` expected.
    fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]);
    /// Single entry `k(x_i, y_j)`.
    fn get(&mut self, i: usize, j: usize) -> f32;
    /// Diagonal entry `k(x_i, y_i)` (square sources).
    #[inline]
    fn diag(&mut self, i: usize) -> f32 {
        self.get(i, i)
    }
    /// Gather `k(x_i, y_{idx[t]})` into `out[t]` — the active-set
    /// access path of the shrinking solver engine (DESIGN.md
    /// §Solver-core): a shrunk sweep reads O(|idx|) entries instead of
    /// a full row.  The default materializes the row and indexes into
    /// it (free for dense/buffered sources); streaming sources
    /// override it with per-pair recomputation so the gather costs
    /// O(|idx|·d), not O(n·d).  Values are bit-identical to the
    /// corresponding [`GramSource::row`] entries on every source.
    fn gather(&mut self, i: usize, idx: &[usize], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), out.len());
        let row = self.row(i);
        for (o, &j) in out.iter_mut().zip(idx) {
            *o = row[j];
        }
    }
}

/// A borrowed dense Gram matrix — the adapter between `&Matrix`
/// producers (e.g. [`GramBackend::gram`]) and [`GramSource`] consumers.
pub struct DenseGram<'a> {
    k: &'a Matrix,
}

impl<'a> DenseGram<'a> {
    pub fn new(k: &'a Matrix) -> DenseGram<'a> {
        DenseGram { k }
    }
}

impl GramSource for DenseGram<'_> {
    #[inline]
    fn rows(&self) -> usize {
        self.k.rows()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.k.cols()
    }

    #[inline]
    fn row(&mut self, i: usize) -> &[f32] {
        self.k.row(i)
    }

    #[inline]
    fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        (self.k.row(i), self.k.row(j))
    }

    #[inline]
    fn get(&mut self, i: usize, j: usize) -> f32 {
        self.k.get(i, j)
    }
}

/// An owned, reusable Gram buffer: one per worker, exponentiated into
/// in place for each γ the worker visits.  The residency key
/// `(epoch, γ)` skips redundant exponentiation (the λ-chain access
/// pattern), and refills never allocate once the buffer has grown to
/// the largest working set the worker has seen — the "zero per-γ
/// allocation" half of the plane contract, observable through the
/// global `gram_allocs` / `gram_hits` / `gram_misses` counters.
#[derive(Debug, Default)]
pub struct GramBuffer {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
    resident: Option<(u64, f32)>,
}

impl GramBuffer {
    pub fn new() -> GramBuffer {
        GramBuffer::default()
    }

    /// Exponentiate `d2` into this buffer for `gamma`, in place.
    /// `epoch` identifies the distance source (see [`next_epoch`]); a
    /// repeat `(epoch, γ)` request is a cache hit and does no work.
    pub fn fill(&mut self, epoch: u64, d2: &Matrix, kind: KernelKind, gamma: f32) {
        if self.resident == Some((epoch, gamma))
            && (self.rows, self.cols) == (d2.rows(), d2.cols())
        {
            counters::GRAM_CACHE_HITS.inc();
            return;
        }
        counters::GRAM_CACHE_MISSES.inc();
        let mut sp = crate::obs::span("gram.fill");
        let n = d2.rows() * d2.cols();
        sp.add_bytes(4 * n as u64);
        if self.data.capacity() < n {
            counters::GRAM_ALLOCS.inc();
        }
        self.data.clear();
        self.data
            .extend(d2.as_slice().iter().map(|&v| kind.of_sq_dist(v, gamma)));
        self.rows = d2.rows();
        self.cols = d2.cols();
        self.resident = Some((epoch, gamma));
    }

    /// Drop residency (e.g. the distance source is gone); keeps the
    /// allocation for reuse.
    pub fn invalidate(&mut self) {
        self.resident = None;
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data[..self.rows * self.cols]
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    /// Read an entry without requiring `&mut` (for tests/inspection).
    pub fn value(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Current storage capacity in elements (for alloc-reuse tests).
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }
}

impl GramSource for GramBuffer {
    #[inline]
    fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn row(&mut self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        debug_assert_ne!(i, j);
        let c = self.cols;
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (head, tail) = self.data.split_at(hi * c);
        let (a, b) = (&head[lo * c..(lo + 1) * c], &tail[..c]);
        if i < j {
            (a, b)
        } else {
            (b, a)
        }
    }

    #[inline]
    fn get(&mut self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
}

/// Streaming Gram source for working sets whose distance matrix does
/// not fit the `--max-gram-mb` cap: no n² state is ever held; each
/// requested row is recomputed from the sample matrices into a small
/// scratch (two rows, so two-coordinate solvers can hold a pair).
///
/// Row values are bit-identical to the cached path because the same
/// per-pair distance kernels are used ([`backend::sq_dist_norms`] /
/// [`sq_dist`]) — property-tested in `tests/property_tests.rs`.
/// Access cost is O(d·cols) per row, so this trades time for memory;
/// the CV engine only selects it when the cap forces it.
pub struct StreamedGram<'a> {
    x: &'a Matrix,
    y: &'a Matrix,
    xn: &'a [f32],
    yn: &'a [f32],
    pk: PairKernel,
    kind: KernelKind,
    gamma: f32,
    scratch: [Vec<f32>; 2],
    resident: [usize; 2],
    /// which scratch slot the next single-row fill overwrites
    flip: usize,
}

impl<'a> StreamedGram<'a> {
    /// `xn`/`yn` are the row norms of `x`/`y` (compute once per fold,
    /// share across γ).  The backend picks the per-pair distance rung
    /// (scalar vs norm-trick) so values match what the cached path
    /// would have produced for the same backend.
    pub fn new(
        backend: &GramBackend,
        x: &'a Matrix,
        y: &'a Matrix,
        xn: &'a [f32],
        yn: &'a [f32],
        kind: KernelKind,
        gamma: f32,
    ) -> StreamedGram<'a> {
        StreamedGram {
            x,
            y,
            xn,
            yn,
            pk: backend.pair_kernel(),
            kind,
            gamma,
            scratch: [vec![0.0; y.rows()], vec![0.0; y.rows()]],
            resident: [usize::MAX, usize::MAX],
            flip: 0,
        }
    }

    fn fill_slot(&mut self, slot: usize, i: usize) {
        if self.resident[slot] == i {
            return;
        }
        let xi = self.x.row(i);
        let buf = &mut self.scratch[slot];
        match self.pk {
            PairKernel::Scalar => backend::sq_dists_row_scalar(xi, self.y, buf),
            PairKernel::Blocked => {
                backend::sq_dists_row_blocked(xi, self.y, self.xn[i], self.yn, buf)
            }
            PairKernel::Simd(p) => {
                simd::sq_dists_row_simd(p, xi, self.y, self.xn[i], self.yn, buf)
            }
        }
        for v in buf.iter_mut() {
            *v = self.kind.of_sq_dist(*v, self.gamma);
        }
        self.resident[slot] = i;
    }

    fn d2_pair(&self, i: usize, j: usize) -> f32 {
        match self.pk {
            PairKernel::Scalar => sq_dist(self.x.row(i), self.y.row(j)),
            PairKernel::Blocked => {
                backend::sq_dist_norms(self.x.row(i), self.y.row(j), self.xn[i], self.yn[j])
            }
            PairKernel::Simd(p) => {
                simd::sq_dist_norms_simd(p, self.x.row(i), self.y.row(j), self.xn[i], self.yn[j])
            }
        }
    }
}

impl GramSource for StreamedGram<'_> {
    #[inline]
    fn rows(&self) -> usize {
        self.x.rows()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.y.rows()
    }

    fn row(&mut self, i: usize) -> &[f32] {
        // keep the most recent *other* row around: the coordinate
        // solvers frequently revisit the same one or two rows
        let slot = if self.resident[0] == i {
            0
        } else if self.resident[1] == i {
            1
        } else {
            self.flip ^= 1;
            self.flip
        };
        self.fill_slot(slot, i);
        &self.scratch[slot]
    }

    fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        // pin i to slot 0 and j to slot 1 unless already resident
        if self.resident[1] == i || self.resident[0] == j {
            self.fill_slot(1, i);
            self.fill_slot(0, j);
            let [a, b] = &self.scratch;
            (b.as_slice(), a.as_slice())
        } else {
            self.fill_slot(0, i);
            self.fill_slot(1, j);
            let [a, b] = &self.scratch;
            (a.as_slice(), b.as_slice())
        }
    }

    fn get(&mut self, i: usize, j: usize) -> f32 {
        if self.resident[0] == i {
            return self.scratch[0][j];
        }
        if self.resident[1] == i {
            return self.scratch[1][j];
        }
        self.kind.of_sq_dist(self.d2_pair(i, j), self.gamma)
    }

    /// Active-set gather without materializing the row: a resident
    /// row is indexed directly; otherwise each requested entry is
    /// recomputed per pair — O(|idx|·d) instead of the O(n·d) a full
    /// row recomputation would cost.  Bit-identical to the row path
    /// because both go through the same per-pair distance kernels.
    fn gather(&mut self, i: usize, idx: &[usize], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), out.len());
        if crate::obs::enabled() {
            counters::GRAM_GATHER_ENTRIES.add(idx.len() as u64);
        }
        for slot in 0..2 {
            if self.resident[slot] == i {
                for (o, &j) in out.iter_mut().zip(idx) {
                    *o = self.scratch[slot][j];
                }
                return;
            }
        }
        for (o, &j) in out.iter_mut().zip(idx) {
            *o = self.kind.of_sq_dist(self.d2_pair(i, j), self.gamma);
        }
    }
}

/// Streaming Gram source over CSR samples — the sparse twin of
/// [`StreamedGram`], and the reason the whole solver stack runs on
/// sparse data unchanged: solvers read through [`GramSource`], this
/// source recomputes rows on demand from the CSR triplets, and the
/// per-pair kernels (`sq_dist_sp` / `sq_dist_norms_sp`) are
/// bit-identical to the dense ones on densified rows (see
/// DESIGN.md §Data-plane).  No n² state, no n×d state: resident cost
/// is the triplets plus two row-scratches.
pub struct SparseGram<'a> {
    x: &'a CsrMatrix,
    y: &'a CsrMatrix,
    xn: &'a [f32],
    yn: &'a [f32],
    pk: PairKernel,
    kind: KernelKind,
    gamma: f32,
    scratch: [Vec<f32>; 2],
    resident: [usize; 2],
    flip: usize,
    /// dense scatter surface for the Simd rung's gather kernels
    /// (stays empty on the merge-join rungs)
    scatter: simd::ScatterScratch,
}

impl<'a> SparseGram<'a> {
    /// `xn`/`yn` are the sparse row norms (compute once per fold,
    /// share across γ) — used by the blocked rung only, like the dense
    /// streamed source.
    pub fn new(
        backend: &GramBackend,
        x: &'a CsrMatrix,
        y: &'a CsrMatrix,
        xn: &'a [f32],
        yn: &'a [f32],
        kind: KernelKind,
        gamma: f32,
    ) -> SparseGram<'a> {
        SparseGram {
            x,
            y,
            xn,
            yn,
            pk: backend.pair_kernel(),
            kind,
            gamma,
            scratch: [vec![0.0; y.rows()], vec![0.0; y.rows()]],
            resident: [usize::MAX, usize::MAX],
            flip: 0,
            scatter: simd::ScatterScratch::new(),
        }
    }

    fn fill_slot(&mut self, slot: usize, i: usize) {
        if self.resident[slot] == i {
            return;
        }
        let xi = self.x.row(i);
        let buf = &mut self.scratch[slot];
        match self.pk {
            PairKernel::Scalar => backend::sq_dists_row_csr_scalar(xi, self.y, buf),
            PairKernel::Blocked => backend::sq_dists_row_csr_blocked(
                xi, self.y, self.xn[i], self.yn, self.x.cols(), buf,
            ),
            PairKernel::Simd(p) => simd::sq_dists_row_csr_simd(
                p, xi, self.y, self.xn[i], self.yn, &mut self.scatter, buf,
            ),
        }
        for v in buf.iter_mut() {
            *v = self.kind.of_sq_dist(*v, self.gamma);
        }
        self.resident[slot] = i;
    }

    fn d2_pair(&mut self, i: usize, j: usize) -> f32 {
        match self.pk {
            PairKernel::Scalar => backend::sq_dist_sp(self.x.row(i), self.y.row(j)),
            PairKernel::Blocked => backend::sq_dist_norms_sp(
                self.x.row(i),
                self.y.row(j),
                self.xn[i],
                self.yn[j],
                self.x.cols(),
            ),
            PairKernel::Simd(p) => simd::sq_dist_sp_simd(
                p,
                self.x.row(i),
                self.y.row(j),
                self.xn[i],
                self.yn[j],
                self.x.cols(),
                &mut self.scatter,
            ),
        }
    }
}

impl GramSource for SparseGram<'_> {
    #[inline]
    fn rows(&self) -> usize {
        self.x.rows()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.y.rows()
    }

    fn row(&mut self, i: usize) -> &[f32] {
        let slot = if self.resident[0] == i {
            0
        } else if self.resident[1] == i {
            1
        } else {
            self.flip ^= 1;
            self.flip
        };
        self.fill_slot(slot, i);
        &self.scratch[slot]
    }

    fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        if self.resident[1] == i || self.resident[0] == j {
            self.fill_slot(1, i);
            self.fill_slot(0, j);
            let [a, b] = &self.scratch;
            (b.as_slice(), a.as_slice())
        } else {
            self.fill_slot(0, i);
            self.fill_slot(1, j);
            let [a, b] = &self.scratch;
            (a.as_slice(), b.as_slice())
        }
    }

    fn get(&mut self, i: usize, j: usize) -> f32 {
        if self.resident[0] == i {
            return self.scratch[0][j];
        }
        if self.resident[1] == i {
            return self.scratch[1][j];
        }
        let d2 = self.d2_pair(i, j);
        self.kind.of_sq_dist(d2, self.gamma)
    }

    /// Active-set gather — same contract as the dense streamed
    /// source: resident rows are indexed, everything else recomputed
    /// per pair through the sparse distance kernels (O(|idx|·nnz) for
    /// the merge-join rungs, O(nnz_i + |idx|·nnz) for the Simd rung's
    /// scatter/gather route).
    fn gather(&mut self, i: usize, idx: &[usize], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), out.len());
        if crate::obs::enabled() {
            counters::GRAM_GATHER_ENTRIES.add(idx.len() as u64);
        }
        for slot in 0..2 {
            if self.resident[slot] == i {
                for (o, &j) in out.iter_mut().zip(idx) {
                    *o = self.scratch[slot][j];
                }
                return;
            }
        }
        for (o, &j) in out.iter_mut().zip(idx) {
            let d2 = self.d2_pair(i, j);
            *o = self.kind.of_sq_dist(d2, self.gamma);
        }
    }
}

/// Reusable cross-tile buffer for the batched predict path: one per
/// caller, grown to the largest tile seen, reused across models,
/// tiles, and requests.
#[derive(Debug, Default)]
pub struct TileBuffer {
    data: Vec<f32>,
}

impl TileBuffer {
    pub fn new() -> TileBuffer {
        TileBuffer::default()
    }

    fn ensure(&mut self, n: usize) -> &mut [f32] {
        if self.data.len() < n {
            if self.data.capacity() < n {
                counters::GRAM_ALLOCS.inc();
            }
            self.data.resize(n, 0.0);
        }
        &mut self.data[..n]
    }
}

/// Rows per cross tile under a byte cap: the tile (`rows × cols` f32)
/// must fit `cap_mb` when a cap is set, with a floor of one row and a
/// default of 256 rows otherwise.
pub fn tile_rows(cap_mb: Option<usize>, cols: usize) -> usize {
    const DEFAULT_ROWS: usize = 256;
    match cap_mb {
        None => DEFAULT_ROWS,
        Some(mb) => {
            let cap_elems = mb.saturating_mul(1 << 20) / 4;
            (cap_elems / cols.max(1)).clamp(1, DEFAULT_ROWS)
        }
    }
}

/// Batched decision-value accumulation: for every `test_x` row `i`,
/// add `Σ_j coef_j · k(x_i, sv_j)` into `acc[i]`.
///
/// Cross distances are computed tile-by-tile into `buf` (zero
/// allocation in steady state), exponentiated in place, and folded
/// into `acc` — the Gram-plane replacement for materializing an
/// `m × n` cross Gram per model (and for per-row kernel loops in the
/// serve path).  `xn` carries the `test_x` row norms, computed once by
/// the caller and shared across the fold models of a prediction (the
/// `sv`-side norms are per-model and computed here).  On the XLA
/// backend with a Gauss kernel each tile goes through the fused
/// artifact instead, falling back to the CPU tiles on a bucket miss.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_decisions(
    backend: &GramBackend,
    kind: KernelKind,
    gamma: f32,
    test_x: &Matrix,
    xn: &[f32],
    sv: &Matrix,
    coef: &[f32],
    cap_mb: Option<usize>,
    buf: &mut TileBuffer,
    acc: &mut [f32],
) {
    let (m, n) = (test_x.rows(), sv.rows());
    assert_eq!(coef.len(), n, "coefficient/expansion mismatch");
    assert_eq!(acc.len(), m);
    assert_eq!(xn.len(), m, "test-row norms mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let mut sp = crate::obs::span("predict.tiles");
    sp.add_bytes(4 * (m * n) as u64);
    let step = tile_rows(cap_mb, n);
    if matches!(backend, GramBackend::Xla(_)) && kind == KernelKind::Gauss {
        // fused artifact path: distances+exp happen inside the
        // artifact, so neither norm vector is touched; marshalling
        // copies anyway, so a per-tile sub-matrix is the natural unit
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + step).min(m);
            let idx: Vec<usize> = (r0..r1).collect();
            let tile_x = test_x.select_rows(&idx);
            let k = backend.gram(&tile_x, sv, gamma, kind);
            for (t, i) in (r0..r1).enumerate() {
                acc[i] += dot_sparse(coef, k.row(t));
            }
            r0 = r1;
        }
        return;
    }
    let yn = sv.row_sq_norms();
    let mut r0 = 0;
    while r0 < m {
        let r1 = (r0 + step).min(m);
        let tile = buf.ensure((r1 - r0) * n);
        backend.sq_dists_tile_into(test_x, r0, r1, sv, xn, &yn, tile);
        for v in tile.iter_mut() {
            *v = kind.of_sq_dist(*v, gamma);
        }
        for (t, i) in (r0..r1).enumerate() {
            acc[i] += dot_sparse(coef, &tile[t * n..(t + 1) * n]);
        }
        r0 = r1;
    }
}

/// [`accumulate_decisions`] over either storage layout on either side
/// — the predict tile source of the sparse data plane.  Layout rules
/// (DESIGN.md §Data-plane):
///
/// * dense test × dense SVs — the existing path, including the fused
///   XLA tile when available;
/// * sparse SVs — tiles computed by the sparse per-pair kernels; a
///   *dense* test row crossing sparse SVs is sparsified on the fly
///   (bit-identical: dropped zeros are exact `±0.0` terms);
/// * dense SVs × sparse test — each test row densifies into one
///   reusable scratch row at the tile boundary (the dense expansion
///   demands dense rows; this is the only densification and it is one
///   row wide).
#[allow(clippy::too_many_arguments)]
pub fn accumulate_decisions_x(
    backend: &GramBackend,
    kind: KernelKind,
    gamma: f32,
    test_x: StoreRef,
    xn: &[f32],
    sv: StoreRef,
    coef: &[f32],
    cap_mb: Option<usize>,
    buf: &mut TileBuffer,
    acc: &mut [f32],
) {
    let (m, n) = (test_x.rows(), sv.rows());
    assert_eq!(coef.len(), n, "coefficient/expansion mismatch");
    assert_eq!(acc.len(), m);
    assert_eq!(xn.len(), m, "test-row norms mismatch");
    assert_eq!(
        test_x.cols(),
        sv.cols(),
        "test/expansion dimension mismatch (was the model trained at a different dim?)"
    );
    if m == 0 || n == 0 {
        return;
    }
    let (test_x, sv) = match (test_x, sv) {
        (StoreRef::Dense(t), StoreRef::Dense(s)) => {
            accumulate_decisions(backend, kind, gamma, t, xn, s, coef, cap_mb, buf, acc);
            return;
        }
        pair => pair,
    };
    let mut sp = crate::obs::span("predict.tiles");
    sp.add_bytes(4 * (m * n) as u64);
    let pk = backend.pair_kernel();
    let step = tile_rows(cap_mb, n);
    match sv {
        StoreRef::Sparse(sv) => {
            let yn = sv.row_sq_norms();
            let d = sv.cols();
            // scratch for sparsifying dense test rows on the fly
            // (merge-join rungs) / the Simd rung's scatter surface
            let mut si: Vec<u32> = Vec::new();
            let mut sval: Vec<f32> = Vec::new();
            let mut scatter = simd::ScatterScratch::new();
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + step).min(m);
                let tile = buf.ensure((r1 - r0) * n);
                for (t, i) in (r0..r1).enumerate() {
                    let row = &mut tile[t * n..(t + 1) * n];
                    if let PairKernel::Simd(p) = pk {
                        // a dense test row already *is* a scatter
                        // surface; a sparse one scatters into scratch —
                        // identical bits either way (dropped zeros only
                        // contribute exact ±0 products)
                        match test_x {
                            StoreRef::Sparse(tm) => simd::sq_dists_row_csr_simd(
                                p,
                                tm.row(i),
                                sv,
                                xn[i],
                                &yn,
                                &mut scatter,
                                row,
                            ),
                            StoreRef::Dense(tm) => simd::sq_dists_row_surface_csr_simd(
                                p,
                                tm.row(i),
                                sv,
                                xn[i],
                                &yn,
                                row,
                            ),
                        }
                        continue;
                    }
                    let xi: backend::SparseRow = match test_x {
                        StoreRef::Sparse(tm) => tm.row(i),
                        StoreRef::Dense(tm) => {
                            si.clear();
                            sval.clear();
                            for (j, &v) in tm.row(i).iter().enumerate() {
                                if v != 0.0 {
                                    si.push(j as u32);
                                    sval.push(v);
                                }
                            }
                            (&si, &sval)
                        }
                    };
                    if matches!(pk, PairKernel::Scalar) {
                        backend::sq_dists_row_csr_scalar(xi, sv, row);
                    } else {
                        backend::sq_dists_row_csr_blocked(xi, sv, xn[i], &yn, d, row);
                    }
                }
                for v in tile.iter_mut() {
                    *v = kind.of_sq_dist(*v, gamma);
                }
                for (t, i) in (r0..r1).enumerate() {
                    acc[i] += dot_sparse(coef, &tile[t * n..(t + 1) * n]);
                }
                r0 = r1;
            }
        }
        StoreRef::Dense(sv) => {
            // sparse test × dense SVs: densify one test row at a time
            let yn = sv.row_sq_norms();
            let mut dense_row = vec![0.0f32; sv.cols()];
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + step).min(m);
                let tile = buf.ensure((r1 - r0) * n);
                for (t, i) in (r0..r1).enumerate() {
                    let row = &mut tile[t * n..(t + 1) * n];
                    test_x.densify_row_into(i, &mut dense_row);
                    match pk {
                        PairKernel::Scalar => backend::sq_dists_row_scalar(&dense_row, sv, row),
                        PairKernel::Blocked => {
                            backend::sq_dists_row_blocked(&dense_row, sv, xn[i], &yn, row)
                        }
                        PairKernel::Simd(p) => {
                            simd::sq_dists_row_simd(p, &dense_row, sv, xn[i], &yn, row)
                        }
                    }
                }
                for v in tile.iter_mut() {
                    *v = kind.of_sq_dist(*v, gamma);
                }
                for (t, i) in (r0..r1).enumerate() {
                    acc[i] += dot_sparse(coef, &tile[t * n..(t + 1) * n]);
                }
                r0 = r1;
            }
        }
    }
}

/// `Σ_j coef_j · row_j`, skipping zero coefficients (most are zero at
/// hinge solutions; prediction cost scales with #SV).  The single
/// accumulation shared by the tiled predict path here and
/// [`crate::solver::Solution::decision_values_src`], so the CV and
/// serve paths can never drift apart numerically.
#[inline]
pub fn dot_sparse(coef: &[f32], row: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (c, r) in coef.iter().zip(row) {
        if *c != 0.0 {
            s += c * r;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randmat(m: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = crate::data::rng::Rng::new(seed);
        Matrix::from_vec((0..m * d).map(|_| rng.range(-2.0, 2.0)).collect(), m, d)
    }

    #[test]
    fn gram_buffer_matches_dense_and_reuses_capacity() {
        let x = randmat(17, 6, 1);
        let be = GramBackend::Blocked;
        let d2 = be.sq_dists(&x, &x);
        let epoch = next_epoch();
        let mut buf = GramBuffer::new();
        let before = counters::snapshot();
        buf.fill(epoch, &d2, KernelKind::Gauss, 1.3);
        buf.fill(epoch, &d2, KernelKind::Gauss, 1.3); // hit
        buf.fill(epoch, &d2, KernelKind::Gauss, 0.7); // new γ, same storage
        let after = counters::snapshot();
        assert!(after.gram_cache_hits >= before.gram_cache_hits + 1);
        assert!(after.gram_cache_misses >= before.gram_cache_misses + 2);
        let dense = be.gram(&x, &x, 0.7, KernelKind::Gauss);
        assert_eq!(buf.as_slice(), dense.as_slice());
    }

    #[test]
    fn gamma_switch_reuses_buffer_storage() {
        // the CV λ-inside-γ access pattern: four distinct γ on one
        // distance source cost one allocation, then pure reuse
        let x = Matrix::from_rows(&[&[0.0], &[1.0], &[3.0]]);
        let d2 = GramBackend::Blocked.sq_dists(&x, &x);
        let epoch = next_epoch();
        let mut buf = GramBuffer::new();
        buf.fill(epoch, &d2, KernelKind::Gauss, 0.5);
        let cap_after_first = buf.capacity();
        for &g in &[1.5, 0.7, 2.5, 1.5] {
            buf.fill(epoch, &d2, KernelKind::Gauss, g);
        }
        assert_eq!(buf.capacity(), cap_after_first);
        // d2(0,2)=9, γ=2 → exp(-9/4)
        buf.fill(epoch, &d2, KernelKind::Gauss, 2.0);
        assert!((buf.value(0, 2) - (-2.25f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn gram_buffer_row_pair_is_disjoint_and_ordered() {
        let x = randmat(9, 4, 2);
        let d2 = GramBackend::Blocked.sq_dists(&x, &x);
        let mut buf = GramBuffer::new();
        buf.fill(next_epoch(), &d2, KernelKind::Gauss, 1.0);
        let dense = GramBackend::Blocked.gram(&x, &x, 1.0, KernelKind::Gauss);
        let (a, b) = buf.row_pair(6, 2);
        assert_eq!(a, dense.row(6));
        assert_eq!(b, dense.row(2));
        let (a, b) = buf.row_pair(2, 6);
        assert_eq!(a, dense.row(2));
        assert_eq!(b, dense.row(6));
    }

    #[test]
    fn streamed_rows_bit_identical_to_dense() {
        let x = randmat(14, 5, 3);
        let y = randmat(11, 5, 4);
        let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            for kind in [KernelKind::Gauss, KernelKind::Laplace] {
                let dense = be.gram(&x, &y, 0.9, kind);
                let mut s = StreamedGram::new(&be, &x, &y, &xn, &yn, kind, 0.9);
                for i in 0..x.rows() {
                    assert_eq!(s.row(i), dense.row(i), "{be:?} {kind:?} row {i}");
                }
                let (a, b) = s.row_pair(3, 8);
                assert_eq!(a, dense.row(3));
                assert_eq!(b, dense.row(8));
                assert_eq!(s.get(7, 2), dense.get(7, 2));
                // entry read with no resident row: computed directly
                let mut fresh = StreamedGram::new(&be, &x, &y, &xn, &yn, kind, 0.9);
                assert_eq!(fresh.get(9, 10), dense.get(9, 10));
            }
        }
    }

    #[test]
    fn gather_matches_row_on_every_source() {
        // the active-set access path must be bit-identical to row
        // indexing on dense, buffered, and streamed sources alike
        let x = randmat(13, 5, 9);
        let idx = [0usize, 4, 7, 11];
        let be = GramBackend::Blocked;
        let dense = be.gram(&x, &x, 1.1, KernelKind::Gauss);
        let want: Vec<f32> = idx.iter().map(|&j| dense.get(3, j)).collect();
        let mut out = vec![0.0f32; idx.len()];

        let mut dg = DenseGram::new(&dense);
        dg.gather(3, &idx, &mut out);
        assert_eq!(out, want);

        let d2 = be.sq_dists(&x, &x);
        let mut buf = GramBuffer::new();
        buf.fill(next_epoch(), &d2, KernelKind::Gauss, 1.1);
        buf.gather(3, &idx, &mut out);
        assert_eq!(out, want);

        let xn = x.row_sq_norms();
        let mut s = StreamedGram::new(&be, &x, &x, &xn, &xn, KernelKind::Gauss, 1.1);
        // fresh source: per-pair path
        s.gather(3, &idx, &mut out);
        assert_eq!(out, want, "streamed per-pair gather");
        // resident-row path after touching the row
        s.row(3);
        s.gather(3, &idx, &mut out);
        assert_eq!(out, want, "streamed resident gather");
    }

    #[test]
    fn sparse_gather_matches_row() {
        let x = rand_sparse(11, 16, 4, 51);
        let xn = x.row_sq_norms();
        let be = GramBackend::Blocked;
        let dense = be.gram(&x.to_dense(), &x.to_dense(), 0.7, KernelKind::Gauss);
        let idx = [1usize, 5, 9];
        let want: Vec<f32> = idx.iter().map(|&j| dense.get(6, j)).collect();
        let mut out = vec![0.0f32; idx.len()];
        let mut s = SparseGram::new(&be, &x, &x, &xn, &xn, KernelKind::Gauss, 0.7);
        s.gather(6, &idx, &mut out);
        assert_eq!(out, want, "sparse per-pair gather");
        s.row(6);
        s.gather(6, &idx, &mut out);
        assert_eq!(out, want, "sparse resident gather");
    }

    #[test]
    fn tile_rows_respects_cap() {
        assert_eq!(tile_rows(None, 100), 256);
        // 1 MB / 4 bytes = 262144 elems; 262144 / 1000 cols = 262 rows → clamped to 256
        assert_eq!(tile_rows(Some(1), 1000), 256);
        // tiny cap still makes progress
        assert_eq!(tile_rows(Some(0), 1000), 1);
    }

    fn rand_sparse(m: usize, d: usize, nnz_row: usize, seed: u64) -> CsrMatrix {
        let mut rng = crate::data::rng::Rng::new(seed);
        let mut dense = Matrix::zeros(m, d);
        for i in 0..m {
            for _ in 0..nnz_row {
                let j = rng.below(d);
                dense.set(i, j, rng.range(-2.0, 2.0));
            }
        }
        CsrMatrix::from_dense(&dense)
    }

    #[test]
    fn sparse_gram_rows_bit_identical_to_densified_streamed() {
        let x = rand_sparse(12, 18, 5, 31);
        let y = rand_sparse(9, 18, 4, 32);
        let (xd, yd) = (x.to_dense(), y.to_dense());
        let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            for kind in [KernelKind::Gauss, KernelKind::Laplace] {
                let dense = be.gram(&xd, &yd, 0.8, kind);
                let mut s = SparseGram::new(&be, &x, &y, &xn, &yn, kind, 0.8);
                for i in 0..x.rows() {
                    assert_eq!(s.row(i), dense.row(i), "{be:?} {kind:?} row {i}");
                }
                let (a, b) = s.row_pair(2, 7);
                assert_eq!(a, dense.row(2));
                assert_eq!(b, dense.row(7));
                assert_eq!(s.get(5, 3), dense.get(5, 3));
                let mut fresh = SparseGram::new(&be, &x, &y, &xn, &yn, kind, 0.8);
                assert_eq!(fresh.get(8, 1), dense.get(8, 1));
            }
        }
    }

    #[test]
    fn accumulate_decisions_x_all_layout_pairs_agree() {
        let test_s = rand_sparse(17, 23, 6, 41);
        let sv_s = rand_sparse(13, 23, 5, 42);
        let (test_d, sv_d) = (test_s.to_dense(), sv_s.to_dense());
        let mut rng = crate::data::rng::Rng::new(43);
        let coef: Vec<f32> =
            (0..13).map(|i| if i % 4 == 0 { 0.0 } else { rng.range(-1.0, 1.0) }).collect();
        let xn = test_s.row_sq_norms();
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            let mut want = vec![0.0f32; 17];
            let mut buf = TileBuffer::new();
            accumulate_decisions(
                &be, KernelKind::Gauss, 0.9, &test_d, &xn, &sv_d, &coef, None, &mut buf,
                &mut want,
            );
            let pairs: [(StoreRef, StoreRef); 3] = [
                (StoreRef::Sparse(&test_s), StoreRef::Sparse(&sv_s)),
                (StoreRef::Dense(&test_d), StoreRef::Sparse(&sv_s)),
                (StoreRef::Sparse(&test_s), StoreRef::Dense(&sv_d)),
            ];
            for (tx, sx) in pairs {
                let mut acc = vec![0.0f32; 17];
                let mut buf = TileBuffer::new();
                accumulate_decisions_x(
                    &be, KernelKind::Gauss, 0.9, tx, &xn, sx, &coef, Some(0), &mut buf,
                    &mut acc,
                );
                let bits_a: Vec<u32> = acc.iter().map(|v| v.to_bits()).collect();
                let bits_w: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits_a, bits_w, "{be:?} {tx:?}×{sx:?}");
            }
        }
    }

    #[test]
    fn accumulate_decisions_matches_full_cross_gram() {
        let test_x = randmat(33, 7, 5);
        let sv = randmat(21, 7, 6);
        let mut rng = crate::data::rng::Rng::new(7);
        let coef: Vec<f32> =
            (0..21).map(|i| if i % 3 == 0 { 0.0 } else { rng.range(-1.0, 1.0) }).collect();
        let xn = test_x.row_sq_norms();
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            let full = be.gram(&test_x, &sv, 1.1, KernelKind::Gauss);
            let want: Vec<f32> = (0..33).map(|i| dot_sparse(&coef, full.row(i))).collect();
            for cap in [None, Some(0)] {
                let mut acc = vec![0.0f32; 33];
                let mut buf = TileBuffer::new();
                accumulate_decisions(
                    &be, KernelKind::Gauss, 1.1, &test_x, &xn, &sv, &coef, cap, &mut buf,
                    &mut acc,
                );
                assert_eq!(acc, want, "{be:?} cap {cap:?}");
            }
        }
    }
}
