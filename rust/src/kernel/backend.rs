//! Gram-matrix back-ends — the reproduction of the paper's SIMD
//! ladder (Tables 14–17: SSE2 / AVX / AVX2) plus the accelerator path:
//!
//! * [`GramBackend::Scalar`]  — naive per-pair loop (the "SSE2" rung);
//! * [`GramBackend::Blocked`] — norm-trick + register-blocked dot
//!   products the autovectorizer can chew on (the "AVX/AVX2" rung);
//! * [`GramBackend::Simd`]    — explicit `std::arch` kernels behind the
//!   runtime-dispatch seam in [`super::simd`] (portable/AVX2/AVX-512
//!   levels, all bit-identical to each other);
//! * [`GramBackend::Xla`]     — the AOT Pallas/XLA artifact executed via
//!   PJRT (the CUDA/TPU rung).

use crate::sync::Arc;

use crate::data::csr::CsrMatrix;
use crate::data::matrix::{sq_dist, Matrix};
use crate::data::store::StoreRef;
use crate::runtime::XlaRuntime;

use super::simd::{self, SimdPlan};
use super::KernelKind;

/// Strategy for computing (squared-distance and) Gram matrices.
#[derive(Clone)]
pub enum GramBackend {
    Scalar,
    Blocked,
    Simd(SimdPlan),
    Xla(Arc<XlaRuntime>),
}

impl std::fmt::Debug for GramBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GramBackend::Scalar => write!(f, "Scalar"),
            GramBackend::Blocked => write!(f, "Blocked"),
            GramBackend::Simd(p) => {
                write!(f, "Simd({}{})", p.level.name(), if p.mixed { "-f32" } else { "" })
            }
            GramBackend::Xla(_) => write!(f, "Xla"),
        }
    }
}

/// The per-pair distance rung a streamed Gram source should use —
/// resolved once at source construction so the per-row/per-pair hot
/// paths dispatch on a `Copy` tag instead of re-matching the backend.
#[derive(Clone, Copy, Debug)]
pub enum PairKernel {
    Scalar,
    Blocked,
    Simd(SimdPlan),
}

impl Default for GramBackend {
    fn default() -> Self {
        GramBackend::Blocked
    }
}

impl GramBackend {
    /// Pairwise squared distances `[x.rows × y.rows]`.
    pub fn sq_dists(&self, x: &Matrix, y: &Matrix) -> Matrix {
        match self {
            GramBackend::Scalar => sq_dists_scalar(x, y),
            GramBackend::Simd(p) => simd::sq_dists_simd(*p, x, y),
            // the XLA artifact fuses distances+exp, so the distance-only
            // entry point falls back to the blocked CPU path
            GramBackend::Blocked | GramBackend::Xla(_) => sq_dists_blocked(x, y),
        }
    }

    /// The per-pair rung streamed sources should read through — the
    /// dispatch-seam hook that lets `StreamedGram`/`SparseGram` pick
    /// up the Simd tables with zero call-site changes.  The Xla rung
    /// maps to Blocked: its streamed/per-pair fallbacks always were
    /// the blocked CPU kernels.
    pub fn pair_kernel(&self) -> PairKernel {
        match self {
            GramBackend::Scalar => PairKernel::Scalar,
            GramBackend::Blocked | GramBackend::Xla(_) => PairKernel::Blocked,
            GramBackend::Simd(p) => PairKernel::Simd(*p),
        }
    }

    /// Gram matrices for a γ grid; one distance pass, G exponentiations.
    pub fn gram_multi(
        &self,
        x: &Matrix,
        y: &Matrix,
        gammas: &[f32],
        kind: KernelKind,
    ) -> Vec<Matrix> {
        match self {
            GramBackend::Xla(rt) if kind == KernelKind::Gauss => {
                match rt.gram_multi(x, y, gammas) {
                    Ok(mats) => mats,
                    // artifact bucket miss (too large/odd shape): CPU path
                    Err(_) => gram_multi_cpu(self, x, y, gammas, kind),
                }
            }
            _ => gram_multi_cpu(self, x, y, gammas, kind),
        }
    }

    /// Single-γ Gram matrix.
    pub fn gram(&self, x: &Matrix, y: &Matrix, gamma: f32, kind: KernelKind) -> Matrix {
        self.gram_multi(x, y, &[gamma], kind).pop().unwrap()
    }

    /// Pairwise squared distances over CSR samples, `[x.rows × y.rows]`
    /// — same rung semantics as [`GramBackend::sq_dists`], bit-identical
    /// to running that on the densified matrices (the sparse kernels
    /// below replicate the dense accumulation orders exactly).  The XLA
    /// artifact takes dense buffers only, so sparse stops here: the Xla
    /// rung computes on the blocked CPU path (see DESIGN.md §Data-plane).
    pub fn sq_dists_csr(&self, x: &CsrMatrix, y: &CsrMatrix) -> Matrix {
        let (m, n) = (x.rows(), y.rows());
        assert_eq!(x.cols(), y.cols(), "dimension mismatch");
        if let GramBackend::Simd(p) = self {
            return simd::sq_dists_csr_simd(*p, x, y);
        }
        let mut out = Matrix::zeros(m, n);
        match self {
            GramBackend::Scalar => {
                for i in 0..m {
                    sq_dists_row_csr_scalar(x.row(i), y, out.row_mut(i));
                }
            }
            GramBackend::Simd(_) => unreachable!("handled above"),
            GramBackend::Blocked | GramBackend::Xla(_) => {
                let xn = x.row_sq_norms();
                let yn = y.row_sq_norms();
                for i in 0..m {
                    sq_dists_row_csr_blocked(x.row(i), y, xn[i], &yn, x.cols(), out.row_mut(i));
                }
            }
        }
        out
    }

    /// [`GramBackend::sq_dists`] over either storage layout.  Mixed
    /// layouts densify the sparse side first (an explicit boundary —
    /// the CV engine never mixes; see DESIGN.md §Data-plane).
    pub fn sq_dists_ref(&self, x: StoreRef, y: StoreRef) -> Matrix {
        match (x, y) {
            (StoreRef::Dense(a), StoreRef::Dense(b)) => self.sq_dists(a, b),
            (StoreRef::Sparse(a), StoreRef::Sparse(b)) => self.sq_dists_csr(a, b),
            (a, b) => self.sq_dists(&a.to_dense(), &b.to_dense()),
        }
    }

    /// Squared distances of `x` rows `r0..r1` against every `y` row,
    /// written into `out` (row-major `(r1-r0) × y.rows()`, no
    /// allocation).  `xn`/`yn` are the full row-norm vectors of `x`
    /// and `y`; the scalar rung ignores them.  Values are bit-identical
    /// to the same rows of [`GramBackend::sq_dists`] — the contract the
    /// streamed/tiled Gram plane is built on.
    pub fn sq_dists_tile_into(
        &self,
        x: &Matrix,
        r0: usize,
        r1: usize,
        y: &Matrix,
        xn: &[f32],
        yn: &[f32],
        out: &mut [f32],
    ) {
        let n = y.rows();
        debug_assert!(r1 <= x.rows() && r0 <= r1);
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        for (t, i) in (r0..r1).enumerate() {
            let row = &mut out[t * n..(t + 1) * n];
            match self {
                GramBackend::Scalar => sq_dists_row_scalar(x.row(i), y, row),
                GramBackend::Simd(p) => simd::sq_dists_row_simd(*p, x.row(i), y, xn[i], yn, row),
                GramBackend::Blocked | GramBackend::Xla(_) => {
                    sq_dists_row_blocked(x.row(i), y, xn[i], yn, row)
                }
            }
        }
    }
}

fn gram_multi_cpu(
    be: &GramBackend,
    x: &Matrix,
    y: &Matrix,
    gammas: &[f32],
    kind: KernelKind,
) -> Vec<Matrix> {
    let d2 = be.sq_dists(x, y);
    gammas.iter().map(|&g| super::apply_kernel(&d2, kind, g)).collect()
}

/// Naive double loop — the scalar rung of the SIMD ladder.
fn sq_dists_scalar(x: &Matrix, y: &Matrix) -> Matrix {
    let (m, n) = (x.rows(), y.rows());
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let row = out.row_mut(i);
        for j in 0..n {
            row[j] = sq_dist(xi, y.row(j));
        }
    }
    out
}

/// 4-way unrolled dot product — the innermost kernel of the blocked
/// path, shared by the full-matrix, row-tile, and single-entry entry
/// points so all three produce bit-identical values (the streamed
/// Gram plane relies on this; see `kernel::plane`).
#[inline]
pub(crate) fn dot4(xi: &[f32], yj: &[f32]) -> f32 {
    let d = xi.len();
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = d / 4;
    for c in 0..chunks {
        let k = c * 4;
        s0 += xi[k] * yj[k];
        s1 += xi[k + 1] * yj[k + 1];
        s2 += xi[k + 2] * yj[k + 2];
        s3 += xi[k + 3] * yj[k + 3];
    }
    let mut dot = s0 + s1 + s2 + s3;
    for k in chunks * 4..d {
        dot += xi[k] * yj[k];
    }
    dot
}

/// One blocked-path squared distance from precomputed row norms.
/// Floating-point cancellation in `‖x‖² + ‖y‖² − 2⟨x,y⟩` can go
/// negative for near-duplicate rows, so the clamp lives here — at the
/// source — rather than in each kernel's exponentiation.
#[inline]
pub(crate) fn sq_dist_norms(xi: &[f32], yj: &[f32], xn_i: f32, yn_j: f32) -> f32 {
    (xn_i + yn_j - 2.0 * dot4(xi, yj)).max(0.0)
}

/// Norm-trick + blocked dot products:
/// `d²(x,y) = ‖x‖² + ‖y‖² − 2⟨x,y⟩`, with the inner products computed
/// in 4×-unrolled accumulators over j-tiles so the compiler emits SIMD
/// (the CPU analogue of the Pallas kernel's MXU tile).
pub fn sq_dists_blocked(x: &Matrix, y: &Matrix) -> Matrix {
    const TILE_J: usize = 64;
    let (m, n, d) = (x.rows(), y.rows(), x.cols());
    assert_eq!(d, y.cols(), "dimension mismatch");
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    let mut out = Matrix::zeros(m, n);
    for j0 in (0..n).step_by(TILE_J) {
        let j1 = (j0 + TILE_J).min(n);
        for i in 0..m {
            let xi = x.row(i);
            let row = out.row_mut(i);
            for j in j0..j1 {
                row[j] = sq_dist_norms(xi, y.row(j), xn[i], yn[j]);
            }
        }
    }
    out
}

/// Squared distances of one `x` row against every `y` row, written
/// into `out` (no allocation).  Per-pair math is identical to
/// [`sq_dists_blocked`] (same `dot4`, same clamp), so a row produced
/// here is bit-identical to the corresponding row of the full matrix.
pub fn sq_dists_row_blocked(xi: &[f32], y: &Matrix, xn_i: f32, yn: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), y.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = sq_dist_norms(xi, y.row(j), xn_i, yn[j]);
    }
}

/// Scalar-path squared distances of one `x` row (bit-identical to the
/// corresponding row of [`GramBackend::Scalar`]'s full matrix).
pub fn sq_dists_row_scalar(xi: &[f32], y: &Matrix, out: &mut [f32]) {
    debug_assert_eq!(out.len(), y.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = sq_dist(xi, y.row(j));
    }
}

// -------------------------------------------------------- sparse kernels
//
// The sparse·sparse kernels below are bit-identical to their dense
// counterparts on the densified rows.  The argument, once: the dense
// loops add one term per column; every term where a factor is zero is
// an exact `±0.0`, and `acc + (±0.0) == acc` bitwise for every `acc`
// except `-0.0` — which the accumulators can never be (they start at
// `+0.0`, and IEEE round-to-nearest never produces `-0.0` from a sum
// of non-(-0.0) addends; `x + (-x) = +0.0`).  So walking only the
// stored entries, in the same column order and into the same
// accumulator structure, reproduces the dense bits exactly.
// Property-tested in `tests/property_tests.rs`.

/// One sparse row as parallel (indices, values) slices — the shape
/// [`CsrMatrix::row`] returns.
pub type SparseRow<'a> = (&'a [u32], &'a [f32]);

/// Scalar-rung squared distance between two sparse rows: the merge-join
/// twin of [`sq_dist`], one accumulator, terms in column order.
pub fn sq_dist_sp((ai, av): SparseRow, (bi, bv): SparseRow) -> f32 {
    let mut s = 0.0f32;
    let (mut p, mut q) = (0usize, 0usize);
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => {
                let d = av[p];
                s += d * d;
                p += 1;
            }
            std::cmp::Ordering::Greater => {
                let d = bv[q];
                s += d * d;
                q += 1;
            }
            std::cmp::Ordering::Equal => {
                let d = av[p] - bv[q];
                s += d * d;
                p += 1;
                q += 1;
            }
        }
    }
    for k in p..ai.len() {
        s += av[k] * av[k];
    }
    for k in q..bi.len() {
        s += bv[k] * bv[k];
    }
    s
}

/// Blocked-rung dot product between two sparse rows — the merge-join
/// twin of [`dot4`], replicating its accumulator structure exactly:
/// columns `< (d/4)·4` feed four lanes keyed by `col % 4`, the lanes
/// reduce as `s0+s1+s2+s3`, and the ≤3 tail columns are added after, in
/// column order.  `d` is the (dense) dimension, which fixes the
/// lane/tail split.
pub(crate) fn dot4_sp((ai, av): SparseRow, (bi, bv): SparseRow, d: usize) -> f32 {
    let cut = ((d / 4) * 4) as u32;
    let mut s = [0.0f32; 4];
    // at most 3 columns fall past the cut; collected in order
    let mut tail = [0.0f32; 3];
    let mut n_tail = 0usize;
    let (mut p, mut q) = (0usize, 0usize);
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                let col = ai[p];
                let prod = av[p] * bv[q];
                if col < cut {
                    s[(col % 4) as usize] += prod;
                } else {
                    tail[n_tail] = prod;
                    n_tail += 1;
                }
                p += 1;
                q += 1;
            }
        }
    }
    let mut dot = s[0] + s[1] + s[2] + s[3];
    for &t in &tail[..n_tail] {
        dot += t;
    }
    dot
}

/// Blocked-rung sparse squared distance from precomputed row norms —
/// the twin of [`sq_dist_norms`], sharing its clamp-at-source contract.
#[inline]
pub fn sq_dist_norms_sp(a: SparseRow, b: SparseRow, an: f32, bn: f32, d: usize) -> f32 {
    (an + bn - 2.0 * dot4_sp(a, b, d)).max(0.0)
}

/// Scalar-path squared distances of one sparse row against every `y`
/// row (bit-identical to the corresponding row of
/// [`GramBackend::sq_dists_csr`] on the Scalar rung).
pub fn sq_dists_row_csr_scalar(xi: SparseRow, y: &CsrMatrix, out: &mut [f32]) {
    debug_assert_eq!(out.len(), y.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = sq_dist_sp(xi, y.row(j));
    }
}

/// Blocked-path squared distances of one sparse row against every `y`
/// row (no allocation; `d` is the dense dimension fixing the dot4
/// lane split).
pub fn sq_dists_row_csr_blocked(
    xi: SparseRow,
    y: &CsrMatrix,
    xn_i: f32,
    yn: &[f32],
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), y.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = sq_dist_norms_sp(xi, y.row(j), xn_i, yn[j], d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randmat(m: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = crate::data::rng::Rng::new(seed);
        Matrix::from_vec((0..m * d).map(|_| rng.range(-2.0, 2.0)).collect(), m, d)
    }

    #[test]
    fn blocked_matches_scalar() {
        let x = randmat(23, 17, 1);
        let y = randmat(31, 17, 2);
        let a = GramBackend::Scalar.sq_dists(&x, &y);
        let b = GramBackend::Blocked.sq_dists(&x, &y);
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() < 1e-3 * (1.0 + u.abs()), "{u} vs {v}");
        }
    }

    #[test]
    fn gram_multi_matches_single() {
        let x = randmat(10, 5, 3);
        let y = randmat(12, 5, 4);
        let gs = [0.5f32, 2.0];
        let multi = GramBackend::Blocked.gram_multi(&x, &y, &gs, KernelKind::Gauss);
        for (i, &g) in gs.iter().enumerate() {
            let single = GramBackend::Blocked.gram(&x, &y, g, KernelKind::Gauss);
            assert_eq!(multi[i].as_slice(), single.as_slice());
        }
    }

    #[test]
    fn gram_diag_is_one_on_self() {
        let x = randmat(8, 4, 5);
        let k = GramBackend::Blocked.gram(&x, &x, 1.3, KernelKind::Gauss);
        for i in 0..8 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn laplace_gram_positive() {
        let x = randmat(6, 3, 6);
        let k = GramBackend::Scalar.gram(&x, &x, 0.7, KernelKind::Laplace);
        assert!(k.as_slice().iter().all(|&v| (0.0..=1.0 + 1e-6).contains(&v)));
    }

    #[test]
    fn tile_rows_bit_identical_to_full_matrix() {
        let x = randmat(19, 9, 7);
        let y = randmat(27, 9, 8);
        let xn = x.row_sq_norms();
        let yn = y.row_sq_norms();
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            let full = be.sq_dists(&x, &y);
            let (r0, r1) = (5usize, 13usize);
            let mut tile = vec![0.0f32; (r1 - r0) * y.rows()];
            be.sq_dists_tile_into(&x, r0, r1, &y, &xn, &yn, &mut tile);
            for (t, i) in (r0..r1).enumerate() {
                assert_eq!(&tile[t * y.rows()..(t + 1) * y.rows()], full.row(i), "backend {be:?}");
            }
        }
    }

    fn rand_sparse(m: usize, d: usize, nnz_row: usize, seed: u64) -> CsrMatrix {
        let mut rng = crate::data::rng::Rng::new(seed);
        let mut dense = Matrix::zeros(m, d);
        for i in 0..m {
            for _ in 0..nnz_row {
                let j = rng.below(d);
                dense.set(i, j, rng.range(-2.0, 2.0));
            }
        }
        CsrMatrix::from_dense(&dense)
    }

    #[test]
    fn sparse_sq_dists_bit_identical_to_densified() {
        // includes the empty row, duplicate-ish tiny values, and a
        // dimension with a dot4 tail (d % 4 != 0)
        for d in [7usize, 16, 33] {
            let x = rand_sparse(9, d, 3, 100 + d as u64);
            let y = rand_sparse(11, d, 4, 200 + d as u64);
            let (xd, yd) = (x.to_dense(), y.to_dense());
            for be in [GramBackend::Scalar, GramBackend::Blocked] {
                let dense = be.sq_dists(&xd, &yd);
                let sparse = be.sq_dists_csr(&x, &y);
                for (a, b) in dense.as_slice().iter().zip(sparse.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{be:?} d={d}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn sparse_row_kernels_match_full_matrix() {
        let x = rand_sparse(6, 13, 4, 7);
        let y = rand_sparse(8, 13, 3, 8);
        let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
        let scalar_full = GramBackend::Scalar.sq_dists_csr(&x, &y);
        let blocked_full = GramBackend::Blocked.sq_dists_csr(&x, &y);
        let mut row = vec![0.0f32; 8];
        for i in 0..6 {
            sq_dists_row_csr_scalar(x.row(i), &y, &mut row);
            assert_eq!(&row, scalar_full.row(i));
            sq_dists_row_csr_blocked(x.row(i), &y, xn[i], &yn, 13, &mut row);
            assert_eq!(&row, blocked_full.row(i));
        }
    }

    #[test]
    fn sparse_norms_match_dense_bitwise() {
        let x = rand_sparse(10, 21, 5, 9);
        let a = x.row_sq_norms();
        let b = x.to_dense().row_sq_norms();
        for (u, v) in a.iter().zip(&b) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn sq_dists_ref_dispatches_both_layouts() {
        let x = rand_sparse(5, 10, 3, 11);
        let xd = x.to_dense();
        let a = GramBackend::Blocked.sq_dists_ref(StoreRef::Sparse(&x), StoreRef::Sparse(&x));
        let b = GramBackend::Blocked.sq_dists_ref(StoreRef::Dense(&xd), StoreRef::Dense(&xd));
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn cancellation_never_goes_negative_and_backends_agree() {
        // near-duplicate rows with large norms: the worst case for the
        // norm trick's ‖x‖²+‖y‖²−2⟨x,y⟩ cancellation
        let mut rng = crate::data::rng::Rng::new(11);
        let base: Vec<f32> = (0..24).map(|_| rng.range(50.0, 60.0)).collect();
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for r in 0..12 {
            let mut v = base.clone();
            v[r % 24] += 1e-4 * (r as f32);
            rows.push(v);
        }
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let x = Matrix::from_rows(&refs);
        let a = GramBackend::Scalar.sq_dists(&x, &x);
        let b = GramBackend::Blocked.sq_dists(&x, &x);
        assert!(a.as_slice().iter().all(|&v| v >= 0.0));
        assert!(b.as_slice().iter().all(|&v| v >= 0.0), "blocked backend produced d² < 0");
        // and the kernels built from either backend agree closely
        for kind in [KernelKind::Gauss, KernelKind::Laplace] {
            for (&u, &v) in a.as_slice().iter().zip(b.as_slice()) {
                let (ku, kv) = (kind.of_sq_dist(u, 0.7), kind.of_sq_dist(v, 0.7));
                assert!((ku - kv).abs() < 1e-4, "{kind:?}: {ku} vs {kv}");
            }
        }
    }
}
