//! Gram-matrix back-ends — the reproduction of the paper's SIMD
//! ladder (Tables 14–17: SSE2 / AVX / AVX2) plus the accelerator path:
//!
//! * [`GramBackend::Scalar`]  — naive per-pair loop (the "SSE2" rung);
//! * [`GramBackend::Blocked`] — norm-trick + register-blocked dot
//!   products the autovectorizer can chew on (the "AVX/AVX2" rung);
//! * [`GramBackend::Xla`]     — the AOT Pallas/XLA artifact executed via
//!   PJRT (the CUDA/TPU rung).

use std::sync::Arc;

use crate::data::matrix::{sq_dist, Matrix};
use crate::runtime::XlaRuntime;

use super::KernelKind;

/// Strategy for computing (squared-distance and) Gram matrices.
#[derive(Clone)]
pub enum GramBackend {
    Scalar,
    Blocked,
    Xla(Arc<XlaRuntime>),
}

impl std::fmt::Debug for GramBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GramBackend::Scalar => write!(f, "Scalar"),
            GramBackend::Blocked => write!(f, "Blocked"),
            GramBackend::Xla(_) => write!(f, "Xla"),
        }
    }
}

impl Default for GramBackend {
    fn default() -> Self {
        GramBackend::Blocked
    }
}

impl GramBackend {
    /// Pairwise squared distances `[x.rows × y.rows]`.
    pub fn sq_dists(&self, x: &Matrix, y: &Matrix) -> Matrix {
        match self {
            GramBackend::Scalar => sq_dists_scalar(x, y),
            // the XLA artifact fuses distances+exp, so the distance-only
            // entry point falls back to the blocked CPU path
            GramBackend::Blocked | GramBackend::Xla(_) => sq_dists_blocked(x, y),
        }
    }

    /// Gram matrices for a γ grid; one distance pass, G exponentiations.
    pub fn gram_multi(
        &self,
        x: &Matrix,
        y: &Matrix,
        gammas: &[f32],
        kind: KernelKind,
    ) -> Vec<Matrix> {
        match self {
            GramBackend::Xla(rt) if kind == KernelKind::Gauss => {
                match rt.gram_multi(x, y, gammas) {
                    Ok(mats) => mats,
                    // artifact bucket miss (too large/odd shape): CPU path
                    Err(_) => gram_multi_cpu(self, x, y, gammas, kind),
                }
            }
            _ => gram_multi_cpu(self, x, y, gammas, kind),
        }
    }

    /// Single-γ Gram matrix.
    pub fn gram(&self, x: &Matrix, y: &Matrix, gamma: f32, kind: KernelKind) -> Matrix {
        self.gram_multi(x, y, &[gamma], kind).pop().unwrap()
    }
}

fn gram_multi_cpu(
    be: &GramBackend,
    x: &Matrix,
    y: &Matrix,
    gammas: &[f32],
    kind: KernelKind,
) -> Vec<Matrix> {
    let d2 = be.sq_dists(x, y);
    gammas.iter().map(|&g| super::apply_kernel(&d2, kind, g)).collect()
}

/// Naive double loop — the scalar rung of the SIMD ladder.
fn sq_dists_scalar(x: &Matrix, y: &Matrix) -> Matrix {
    let (m, n) = (x.rows(), y.rows());
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let row = out.row_mut(i);
        for j in 0..n {
            row[j] = sq_dist(xi, y.row(j));
        }
    }
    out
}

/// Norm-trick + blocked dot products:
/// `d²(x,y) = ‖x‖² + ‖y‖² − 2⟨x,y⟩`, with the inner products computed
/// in 4×-unrolled accumulators over j-tiles so the compiler emits SIMD
/// (the CPU analogue of the Pallas kernel's MXU tile).
pub fn sq_dists_blocked(x: &Matrix, y: &Matrix) -> Matrix {
    const TILE_J: usize = 64;
    let (m, n, d) = (x.rows(), y.rows(), x.cols());
    assert_eq!(d, y.cols(), "dimension mismatch");
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    let mut out = Matrix::zeros(m, n);
    for j0 in (0..n).step_by(TILE_J) {
        let j1 = (j0 + TILE_J).min(n);
        for i in 0..m {
            let xi = x.row(i);
            let row = out.row_mut(i);
            for j in j0..j1 {
                let yj = y.row(j);
                // 4-way unrolled dot product
                let mut s0 = 0.0f32;
                let mut s1 = 0.0f32;
                let mut s2 = 0.0f32;
                let mut s3 = 0.0f32;
                let chunks = d / 4;
                for c in 0..chunks {
                    let k = c * 4;
                    s0 += xi[k] * yj[k];
                    s1 += xi[k + 1] * yj[k + 1];
                    s2 += xi[k + 2] * yj[k + 2];
                    s3 += xi[k + 3] * yj[k + 3];
                }
                let mut dot = s0 + s1 + s2 + s3;
                for k in chunks * 4..d {
                    dot += xi[k] * yj[k];
                }
                row[j] = (xn[i] + yn[j] - 2.0 * dot).max(0.0);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randmat(m: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = crate::data::rng::Rng::new(seed);
        Matrix::from_vec((0..m * d).map(|_| rng.range(-2.0, 2.0)).collect(), m, d)
    }

    #[test]
    fn blocked_matches_scalar() {
        let x = randmat(23, 17, 1);
        let y = randmat(31, 17, 2);
        let a = GramBackend::Scalar.sq_dists(&x, &y);
        let b = GramBackend::Blocked.sq_dists(&x, &y);
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() < 1e-3 * (1.0 + u.abs()), "{u} vs {v}");
        }
    }

    #[test]
    fn gram_multi_matches_single() {
        let x = randmat(10, 5, 3);
        let y = randmat(12, 5, 4);
        let gs = [0.5f32, 2.0];
        let multi = GramBackend::Blocked.gram_multi(&x, &y, &gs, KernelKind::Gauss);
        for (i, &g) in gs.iter().enumerate() {
            let single = GramBackend::Blocked.gram(&x, &y, g, KernelKind::Gauss);
            assert_eq!(multi[i].as_slice(), single.as_slice());
        }
    }

    #[test]
    fn gram_diag_is_one_on_self() {
        let x = randmat(8, 4, 5);
        let k = GramBackend::Blocked.gram(&x, &x, 1.3, KernelKind::Gauss);
        for i in 0..8 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn laplace_gram_positive() {
        let x = randmat(6, 3, 6);
        let k = GramBackend::Scalar.gram(&x, &x, 0.7, KernelKind::Laplace);
        assert!(k.as_slice().iter().all(|&v| (0.0..=1.0 + 1e-6).contains(&v)));
    }
}
