//! Explicit-SIMD rung of the Gram ladder — runtime-dispatched
//! `std::arch` kernels behind a single seam (DESIGN.md §Compute-plane).
//!
//! The existing rungs are untouched: `Scalar` and `Blocked` keep their
//! exact accumulation orders and stay the executable bit-exactness
//! references.  This module adds a third rung, `GramBackend::Simd`,
//! with three levels sharing ONE canonical accumulation order:
//!
//! * `Portable` — plain Rust, 8 f64 accumulator lanes striding the
//!   element index (`lanes[l] += x[8c+l] as f64 * y[8c+l] as f64`),
//!   lanes reduced left-to-right from `+0.0`, then a sequential f64
//!   tail, one final rounding to f32.  This is the executable
//!   specification of the rung.
//! * `Avx2` — AVX2+FMA intrinsics.  Bit-identical to `Portable` by
//!   construction: an f32·f32 product is *exact* in f64 (24×24 ≤ 48
//!   significand bits < 53), so `fma(x, y, acc)` rounds the same value
//!   `mul`+`add` rounds, and the per-lane sequences match the portable
//!   loop term for term.
//! * `Avx512` — AVX-512F (behind the off-by-default `avx512` cargo
//!   feature; stdarch stabilized these intrinsics only recently), one
//!   zmm holding the same 8 lanes.  Same argument, same bits.
//!
//! Because every level computes identical bits, clamping a requested
//! level down to what the CPU/build supports can never change results
//! — only throughput.  Level resolution (env > CLI > auto-detect) and
//! the per-level function tables live here; `backend.rs` holds the
//! `GramBackend::Simd` arms that call through them.
//!
//! The opt-in mixed-precision path (`SimdPlan { mixed: true }`)
//! accumulates in f32 instead (8 lanes, mul+add on every level, so it
//! is also bit-stable *across levels*) and is only ULP-bounded against
//! the f64-accumulate rung — the contract `tests/kernel_parity.rs`
//! pins.
//!
//! Sparse rows take a scatter/gather route: the x row is scattered
//! into a dense zero scratch ([`ScatterScratch`]), each y row's stored
//! entries are gathered out of it, and the 8 f64 lanes are keyed by
//! *entry position* rather than column index.  That makes the sparse
//! Simd plane self-consistent (row/pair/tile all bit-identical) but a
//! different exactness class from the dense Simd plane — which is why
//! the default backend stays `Blocked`, whose sparse kernels replicate
//! the dense bits exactly.

// One of the two modules allowed to opt back into `unsafe` (the crate
// root denies it): the `std::arch` intrinsics below require it, every
// call is behind the runtime `detect()` gate, and every unsafe block
// carries a SAFETY comment (CI denies
// `clippy::undocumented_unsafe_blocks`).  See DESIGN.md
// §Static-analysis.
#![allow(unsafe_code)]

use crate::data::csr::CsrMatrix;
use crate::data::matrix::Matrix;

use super::backend::SparseRow;

/// SIMD instruction level of the `Simd` rung.  Ordered so that
/// clamping is `min(requested, detected)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// plain-Rust twin of the vector kernels — the rung's fallback and
    /// its executable specification (named `scalar` on the CLI/env)
    Portable,
    /// AVX2 + FMA
    Avx2,
    /// AVX-512F (requires the `avx512` cargo feature)
    Avx512,
}

impl SimdLevel {
    /// Grammar shared by `LIQUIDSVM_SIMD` and the parity tests.
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s {
            "scalar" | "portable" => Some(SimdLevel::Portable),
            "avx2" => Some(SimdLevel::Avx2),
            "avx512" => Some(SimdLevel::Avx512),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Portable => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }
}

/// Best level this CPU *and* this build support.  Detected once per
/// process (the paper's ladder is a compile-time choice; here it is a
/// one-time `cpuid`).
pub fn detect() -> SimdLevel {
    static DETECTED: crate::sync::OnceLock<SimdLevel> = crate::sync::OnceLock::new();
    *DETECTED.get_or_init(detect_raw)
}

fn detect_raw() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if avx512_available() {
            return SimdLevel::Avx512;
        }
        if std::arch::is_x86_64_feature_detected!("avx2")
            && std::arch::is_x86_64_feature_detected!("fma")
        {
            return SimdLevel::Avx2;
        }
    }
    SimdLevel::Portable
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
fn avx512_available() -> bool {
    std::arch::is_x86_64_feature_detected!("avx512f")
        && std::arch::is_x86_64_feature_detected!("avx2")
        && std::arch::is_x86_64_feature_detected!("fma")
}

#[cfg(all(target_arch = "x86_64", not(feature = "avx512")))]
fn avx512_available() -> bool {
    false
}

/// Every level runnable here, worst to best — what the parity suite
/// sweeps.
pub fn available() -> Vec<SimdLevel> {
    let mut v = vec![SimdLevel::Portable];
    if detect() >= SimdLevel::Avx2 {
        v.push(SimdLevel::Avx2);
    }
    if detect() >= SimdLevel::Avx512 {
        v.push(SimdLevel::Avx512);
    }
    v
}

/// Resolved dispatch decision carried inside `GramBackend::Simd`:
/// which level's function table to use and whether the opt-in f32
/// mixed-precision accumulation is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdPlan {
    pub level: SimdLevel,
    /// f32-compute/f32-accumulate Gram fill: faster, ULP-bounded (not
    /// bit-exact) against the default f64-accumulate rung
    pub mixed: bool,
}

impl SimdPlan {
    /// Resolve a plan with the documented override order: the
    /// `LIQUIDSVM_SIMD` env escape hatch beats the CLI's level, which
    /// beats auto-detection; whatever was requested is clamped to what
    /// this CPU/build can run (safe because all levels compute
    /// identical bits).  Errors only on an unparseable env value.
    pub fn resolve(cli: Option<SimdLevel>, mixed: bool) -> Result<SimdPlan, String> {
        let requested = match env_level()? {
            Some(l) => Some(l),
            None => cli,
        };
        let level = match requested {
            Some(l) => l.min(detect()),
            None => detect(),
        };
        Ok(SimdPlan { level, mixed })
    }

    /// A clamped plan with no env consultation — what tests and benches
    /// use to pin a level without racing on the process environment.
    pub fn forced(level: SimdLevel, mixed: bool) -> SimdPlan {
        SimdPlan { level: level.min(detect()), mixed }
    }

    /// Table of kernel entry points for this plan's level.
    #[inline]
    pub fn kernels(&self) -> &'static SimdKernels {
        kernels(self.level)
    }

    /// One-line rung report — tests print this so CI logs show what
    /// was actually exercised.
    pub fn describe(&self) -> String {
        format!(
            "simd rung: detected={} selected={}{}",
            detect().name(),
            self.level.name(),
            if self.mixed { " precision=f32-mixed" } else { " precision=f64-acc" }
        )
    }
}

fn env_level() -> Result<Option<SimdLevel>, String> {
    match std::env::var("LIQUIDSVM_SIMD") {
        Ok(v) if v.trim().is_empty() => Ok(None),
        Ok(v) => SimdLevel::parse(v.trim()).map(Some).ok_or_else(|| {
            format!("LIQUIDSVM_SIMD: unknown rung `{v}` (expected scalar|avx2|avx512)")
        }),
        Err(_) => Ok(None),
    }
}

/// Per-level function table.  All entries share the canonical
/// accumulation orders documented at the top of this module, so every
/// table computes identical bits for `dot`/`sp_dot`, and identical
/// bits for `dot_mp`.
pub struct SimdKernels {
    pub level: SimdLevel,
    /// dense dot, 8-lane f64 accumulation (the bit-exact class)
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// dense dot, 8-lane f32 accumulation (mixed-precision class)
    pub dot_mp: fn(&[f32], &[f32]) -> f32,
    /// dot of a dense surface against one CSR row's stored entries,
    /// 8-lane f64 accumulation keyed by entry position
    pub sp_dot: fn(&[f32], &[u32], &[f32]) -> f32,
}

/// Function table for a level.  Levels this build cannot run fall back
/// to the portable table — bit-identical by the module contract, and
/// unreachable anyway because [`SimdPlan`] construction clamps.
pub fn kernels(level: SimdLevel) -> &'static SimdKernels {
    match level {
        SimdLevel::Portable => &PORTABLE,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => &x86::AVX2,
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdLevel::Avx512 => &x86::AVX512,
        #[allow(unreachable_patterns)]
        _ => &PORTABLE,
    }
}

static PORTABLE: SimdKernels = SimdKernels {
    level: SimdLevel::Portable,
    dot: dot_f64_portable,
    dot_mp: dot_f32_portable,
    sp_dot: sp_dot_portable,
};

// ------------------------------------------------ portable reference

/// The canonical order, spelled out: 8 f64 lanes striding the element
/// index, left-to-right lane reduction from `+0.0`, sequential f64
/// tail, one rounding at the end.
fn dot_f64_portable(x: &[f32], y: &[f32]) -> f32 {
    let d = x.len();
    debug_assert_eq!(d, y.len());
    let chunks = d / 8;
    let mut lanes = [0.0f64; 8];
    for c in 0..chunks {
        let k = c * 8;
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x[k + l] as f64 * y[k + l] as f64;
        }
    }
    let mut dot = 0.0f64;
    for lane in lanes {
        dot += lane;
    }
    for k in chunks * 8..d {
        dot += x[k] as f64 * y[k] as f64;
    }
    dot as f32
}

/// Mixed-precision twin: same lane structure, f32 mul+add per term
/// (two roundings — deliberately *not* fma, so every level reproduces
/// these bits too and only the contract against the f64 rung is ULP).
fn dot_f32_portable(x: &[f32], y: &[f32]) -> f32 {
    let d = x.len();
    debug_assert_eq!(d, y.len());
    let chunks = d / 8;
    let mut lanes = [0.0f32; 8];
    for c in 0..chunks {
        let k = c * 8;
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x[k + l] * y[k + l];
        }
    }
    let mut dot = 0.0f32;
    for lane in lanes {
        dot += lane;
    }
    for k in chunks * 8..d {
        dot += x[k] * y[k];
    }
    dot
}

/// Gather-style sparse dot: `surface` is a dense row (or a scattered
/// scratch), `(yi, yv)` one CSR row.  Lanes are keyed by the *stored
/// entry position* `t % 8` — the order a vector gather consumes them.
fn sp_dot_portable(surface: &[f32], yi: &[u32], yv: &[f32]) -> f32 {
    let n = yi.len();
    debug_assert_eq!(n, yv.len());
    let chunks = n / 8;
    let mut lanes = [0.0f64; 8];
    for c in 0..chunks {
        let k = c * 8;
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += surface[yi[k + l] as usize] as f64 * yv[k + l] as f64;
        }
    }
    let mut dot = 0.0f64;
    for lane in lanes {
        dot += lane;
    }
    for k in chunks * 8..n {
        dot += surface[yi[k] as usize] as f64 * yv[k] as f64;
    }
    dot as f32
}

// ------------------------------------------------------ x86 intrinsics

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{SimdKernels, SimdLevel};
    use std::arch::x86_64::*;

    pub(super) static AVX2: SimdKernels = SimdKernels {
        level: SimdLevel::Avx2,
        dot: dot_f64_avx2,
        dot_mp: dot_f32_avx2,
        sp_dot: sp_dot_avx2,
    };

    #[cfg(feature = "avx512")]
    pub(super) static AVX512: SimdKernels = SimdKernels {
        level: SimdLevel::Avx512,
        dot: dot_f64_avx512,
        dot_mp: dot_f32_avx2, // same 8-lane mul+add order on purpose
        sp_dot: sp_dot_avx2,  // gather width is 8 on both levels
    };

    fn dot_f64_avx2(x: &[f32], y: &[f32]) -> f32 {
        // SAFETY: this table is only reachable through SimdPlan
        // clamping, which requires runtime-detected avx2+fma.
        unsafe { dot_f64_avx2_inner(x, y) }
    }

    fn dot_f32_avx2(x: &[f32], y: &[f32]) -> f32 {
        // SAFETY: as above — avx2 detected before this table is used.
        unsafe { dot_f32_avx2_inner(x, y) }
    }

    fn sp_dot_avx2(surface: &[f32], yi: &[u32], yv: &[f32]) -> f32 {
        // SAFETY: as above — avx2+fma detected before this table is
        // used; gather indices are CSR column indices < surface.len().
        unsafe { sp_dot_avx2_inner(surface, yi, yv) }
    }

    /// 8 f64 lanes as two ymm accumulators: lanes 0–3 take element
    /// positions `8c..8c+3`, lanes 4–7 take `8c+4..8c+7`.  Per-lane
    /// term sequences are exactly the portable loop's; the products
    /// are exact in f64, so each fma rounds the same value the
    /// portable mul+add rounds.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_f64_avx2_inner(x: &[f32], y: &[f32]) -> f32 {
        let d = x.len();
        debug_assert_eq!(d, y.len());
        let chunks = d / 8;
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        for c in 0..chunks {
            let k = c * 8;
            let xv = _mm256_loadu_ps(xp.add(k));
            let yv = _mm256_loadu_ps(yp.add(k));
            let x_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(xv));
            let x_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(xv));
            let y_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(yv));
            let y_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(yv));
            acc_lo = _mm256_fmadd_pd(x_lo, y_lo, acc_lo);
            acc_hi = _mm256_fmadd_pd(x_hi, y_hi, acc_hi);
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        let mut dot = 0.0f64;
        for lane in lanes {
            dot += lane;
        }
        for k in chunks * 8..d {
            dot += x[k] as f64 * y[k] as f64;
        }
        dot as f32
    }

    /// f32 mixed-precision path: mul+add (NOT fma) so the per-term
    /// double rounding matches the portable twin bit for bit.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_f32_avx2_inner(x: &[f32], y: &[f32]) -> f32 {
        let d = x.len();
        debug_assert_eq!(d, y.len());
        let chunks = d / 8;
        let mut acc = _mm256_setzero_ps();
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        for c in 0..chunks {
            let k = c * 8;
            let xv = _mm256_loadu_ps(xp.add(k));
            let yv = _mm256_loadu_ps(yp.add(k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut dot = 0.0f32;
        for lane in lanes {
            dot += lane;
        }
        for k in chunks * 8..d {
            dot += x[k] * y[k];
        }
        dot
    }

    /// Gather-based sparse dot: 8 column indices per iteration pull
    /// f32s out of the dense surface, then the same two-ymm f64
    /// accumulation as the dense kernel, lanes keyed by entry
    /// position (matching `sp_dot_portable`).
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn sp_dot_avx2_inner(surface: &[f32], yi: &[u32], yv: &[f32]) -> f32 {
        let n = yi.len();
        debug_assert_eq!(n, yv.len());
        let chunks = n / 8;
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let sp = surface.as_ptr();
        for c in 0..chunks {
            let k = c * 8;
            let idx = _mm256_loadu_si256(yi.as_ptr().add(k) as *const __m256i);
            let gathered = _mm256_i32gather_ps::<4>(sp, idx);
            let vv = _mm256_loadu_ps(yv.as_ptr().add(k));
            let g_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(gathered));
            let g_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(gathered));
            let v_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(vv));
            let v_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(vv));
            acc_lo = _mm256_fmadd_pd(g_lo, v_lo, acc_lo);
            acc_hi = _mm256_fmadd_pd(g_hi, v_hi, acc_hi);
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        let mut dot = 0.0f64;
        for lane in lanes {
            dot += lane;
        }
        for k in chunks * 8..n {
            dot += surface[yi[k] as usize] as f64 * yv[k] as f64;
        }
        dot as f32
    }

    #[cfg(feature = "avx512")]
    fn dot_f64_avx512(x: &[f32], y: &[f32]) -> f32 {
        // SAFETY: this table is only reachable through SimdPlan
        // clamping, which requires runtime-detected avx512f.
        unsafe { dot_f64_avx512_inner(x, y) }
    }

    /// One zmm holds all 8 f64 lanes; per-lane sequences are identical
    /// to the avx2 and portable versions, so the bits are too.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    unsafe fn dot_f64_avx512_inner(x: &[f32], y: &[f32]) -> f32 {
        let d = x.len();
        debug_assert_eq!(d, y.len());
        let chunks = d / 8;
        let mut acc = _mm512_setzero_pd();
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        for c in 0..chunks {
            let k = c * 8;
            let xv = _mm512_cvtps_pd(_mm256_loadu_ps(xp.add(k)));
            let yv = _mm512_cvtps_pd(_mm256_loadu_ps(yp.add(k)));
            acc = _mm512_fmadd_pd(xv, yv, acc);
        }
        let mut lanes = [0.0f64; 8];
        _mm512_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut dot = 0.0f64;
        for lane in lanes {
            dot += lane;
        }
        for k in chunks * 8..d {
            dot += x[k] as f64 * y[k] as f64;
        }
        dot as f32
    }
}

// ----------------------------------------------- distance entry points

/// One Simd-rung squared distance from precomputed norms.  The clamp
/// lives here — at the source, exactly where the blocked rung clamps
/// (`backend::sq_dist_norms`) — so near-duplicate cancellation can
/// never leak a negative d² downstream.
#[inline]
pub fn sq_dist_norms_simd(p: SimdPlan, xi: &[f32], yj: &[f32], xn_i: f32, yn_j: f32) -> f32 {
    let k = p.kernels();
    let dot = if p.mixed { (k.dot_mp)(xi, yj) } else { (k.dot)(xi, yj) };
    (xn_i + yn_j - 2.0 * dot).max(0.0)
}

/// Squared distances of one dense row against every `y` row —
/// bit-identical to the corresponding row of [`sq_dists_simd`].
pub fn sq_dists_row_simd(
    p: SimdPlan,
    xi: &[f32],
    y: &Matrix,
    xn_i: f32,
    yn: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), y.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = sq_dist_norms_simd(p, xi, y.row(j), xn_i, yn[j]);
    }
}

/// Full dense distance matrix on the Simd rung.
pub fn sq_dists_simd(p: SimdPlan, x: &Matrix, y: &Matrix) -> Matrix {
    let (m, n) = (x.rows(), y.rows());
    assert_eq!(x.cols(), y.cols(), "dimension mismatch");
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        sq_dists_row_simd(p, x.row(i), y, xn[i], &yn, out.row_mut(i));
    }
    out
}

/// Reusable dense scratch for the sparse scatter/gather route: sized
/// to the dimension once, kept all-zero between uses (each scatter is
/// undone entry-by-entry, so clearing costs O(nnz), not O(d)).
#[derive(Debug, Default)]
pub struct ScatterScratch {
    buf: Vec<f32>,
}

impl ScatterScratch {
    pub fn new() -> ScatterScratch {
        ScatterScratch::default()
    }

    /// Scatter `row` onto the zeroed surface, run `f` over the dense
    /// view, then restore the zeros.
    fn with_row<R>(&mut self, row: SparseRow, d: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        if self.buf.len() < d {
            self.buf.resize(d, 0.0);
        }
        let (idx, val) = row;
        for (t, &c) in idx.iter().enumerate() {
            self.buf[c as usize] = val[t];
        }
        let out = f(&self.buf[..d]);
        for &c in idx {
            self.buf[c as usize] = 0.0;
        }
        out
    }
}

/// Simd-rung squared distances of a *dense* surface row against every
/// CSR `y` row.  Shared by the scattered-sparse and dense-test paths
/// of the predict plane so both produce identical bits.
pub fn sq_dists_row_surface_csr_simd(
    p: SimdPlan,
    surface: &[f32],
    y: &CsrMatrix,
    xn_i: f32,
    yn: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), y.rows());
    debug_assert!(surface.len() >= y.cols());
    let k = p.kernels();
    for (j, o) in out.iter_mut().enumerate() {
        let (yi, yv) = y.row(j);
        *o = (xn_i + yn[j] - 2.0 * (k.sp_dot)(surface, yi, yv)).max(0.0);
    }
}

/// Simd-rung squared distances of one CSR row against every `y` row:
/// scatter, gather-dot each `y` row, unscatter.
pub fn sq_dists_row_csr_simd(
    p: SimdPlan,
    xi: SparseRow,
    y: &CsrMatrix,
    xn_i: f32,
    yn: &[f32],
    scratch: &mut ScatterScratch,
    out: &mut [f32],
) {
    scratch.with_row(xi, y.cols(), |surface| {
        sq_dists_row_surface_csr_simd(p, surface, y, xn_i, yn, out)
    })
}

/// Simd-rung single sparse pair — same scatter route and same clamp
/// as the row kernel, so per-pair gathers are bit-identical to row
/// fills (the `SparseGram` streamed source depends on this).
pub fn sq_dist_sp_simd(
    p: SimdPlan,
    a: SparseRow,
    b: SparseRow,
    an: f32,
    bn: f32,
    d: usize,
    scratch: &mut ScatterScratch,
) -> f32 {
    let k = p.kernels();
    let (bi, bv) = b;
    let dot = scratch.with_row(a, d, |surface| (k.sp_dot)(surface, bi, bv));
    (an + bn - 2.0 * dot).max(0.0)
}

/// Full CSR distance matrix on the Simd rung.
pub fn sq_dists_csr_simd(p: SimdPlan, x: &CsrMatrix, y: &CsrMatrix) -> Matrix {
    let (m, n) = (x.rows(), y.rows());
    assert_eq!(x.cols(), y.cols(), "dimension mismatch");
    let xn = x.row_sq_norms();
    let yn = y.row_sq_norms();
    let mut scratch = ScatterScratch::new();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        sq_dists_row_csr_simd(p, x.row(i), y, xn[i], &yn, &mut scratch, out.row_mut(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..d).map(|_| rng.range(-2.0, 2.0)).collect()
    }

    #[test]
    fn portable_dot_is_correctly_rounded_ref() {
        // against a plain sequential f64 dot the portable kernel is a
        // reassociation — both stay within one ulp of the exact value
        for d in [0usize, 1, 7, 8, 9, 33, 64, 129] {
            let x = randvec(d, d as u64);
            let y = randvec(d, d as u64 + 1000);
            let exact: f64 = x.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
            let got = dot_f64_portable(&x, &y) as f64;
            assert!(
                (got - exact).abs() <= 1e-4 * (1.0 + exact.abs()),
                "d={d}: {got} vs {exact}"
            );
        }
    }

    #[test]
    fn every_available_level_matches_portable_bits() {
        for level in available() {
            let k = kernels(level);
            for d in 0..=67usize {
                let x = randvec(d, d as u64);
                let y = randvec(d, d as u64 + 500);
                assert_eq!(
                    (k.dot)(&x, &y).to_bits(),
                    dot_f64_portable(&x, &y).to_bits(),
                    "level={} d={d}",
                    level.name()
                );
                assert_eq!(
                    (k.dot_mp)(&x, &y).to_bits(),
                    dot_f32_portable(&x, &y).to_bits(),
                    "mp level={} d={d}",
                    level.name()
                );
            }
        }
    }

    #[test]
    fn scatter_scratch_restores_zeros() {
        let mut s = ScatterScratch::new();
        let idx = [1u32, 4, 7];
        let val = [3.0f32, -2.0, 0.5];
        let got = s.with_row((&idx, &val), 9, |surf| surf.to_vec());
        assert_eq!(got, vec![0.0, 3.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.5, 0.0]);
        assert!(s.buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn detection_is_stable_and_level_order_clamps() {
        assert_eq!(detect(), detect());
        assert!(SimdLevel::Portable < SimdLevel::Avx2);
        assert!(SimdLevel::Avx2 < SimdLevel::Avx512);
        let p = SimdPlan::forced(SimdLevel::Avx512, false);
        assert!(p.level <= detect());
        assert!(p.describe().contains("selected="));
    }
}
