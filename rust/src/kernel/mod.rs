//! Kernel functions and Gram-matrix computation.
//!
//! liquidSVM's speed rests on (a) fast Gram computation (SIMD/CUDA in
//! the original; here a blocked Rust path and an XLA/PJRT artifact
//! path) and (b) *reusing* the distance matrix across the whole γ grid
//! during cross-validation.  Both live here: raw distance/Gram
//! computation in [`backend`], and the reuse machinery — the
//! [`plane`] (Gram plane) with its `GramSource` contract, reusable
//! exponentiation buffers, and streamed row-tiles — on top.

pub mod backend;
pub mod plane;
pub mod simd;

pub use backend::GramBackend;
pub use plane::{DenseGram, GramBuffer, GramSource, SparseGram, StreamedGram};
pub use simd::{SimdLevel, SimdPlan};

use crate::data::matrix::Matrix;

/// Kernel family.  liquidSVM parameterization (Table 5):
/// Gauss `exp(-d²/γ²)`, Laplace/"Poisson" `exp(-d/γ)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    Gauss,
    Laplace,
}

impl KernelKind {
    /// Apply the kernel to a squared distance.  Both branches clamp
    /// `d² ≥ 0`: distances are clamped at the source for the CPU
    /// backends ([`backend::sq_dist_norms`]), but fused accelerator
    /// paths hand us raw values, and `exp(+ε/γ²) > 1` would otherwise
    /// leak out of the kernel's `[0, 1]` range.
    #[inline]
    pub fn of_sq_dist(&self, d2: f32, gamma: f32) -> f32 {
        match self {
            KernelKind::Gauss => (-d2.max(0.0) / (gamma * gamma)).exp(),
            KernelKind::Laplace => (-d2.max(0.0).sqrt() / gamma).exp(),
        }
    }

    /// Convert a *libsvm-convention* gamma (`exp(-g·d²)`) into this
    /// parameterization, so the "libsvm grid" benchmarks run the exact
    /// same kernels the other packages would.
    pub fn from_libsvm_gamma(g_lib: f32) -> f32 {
        (1.0 / g_lib).sqrt()
    }
}

/// Exponentiate a squared-distance matrix into a Gram matrix for one γ.
pub fn apply_kernel(d2: &Matrix, kind: KernelKind, gamma: f32) -> Matrix {
    let mut out = d2.clone();
    for v in out.as_mut_slice() {
        *v = kind.of_sq_dist(*v, gamma);
    }
    out
}

/// Single kernel row k(x, y_j) for all rows y_j.  Kept as the
/// one-off/debug primitive; batched prediction goes through
/// [`plane::accumulate_decisions`] (tiled, zero-realloc) instead of
/// looping this per row.
pub fn kernel_row(x: &[f32], ys: &Matrix, kind: KernelKind, gamma: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), ys.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = kind.of_sq_dist(crate::data::matrix::sq_dist(x, ys.row(j)), gamma);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauss_at_zero_distance_is_one() {
        assert!((KernelKind::Gauss.of_sq_dist(0.0, 2.0) - 1.0).abs() < 1e-7);
        assert!((KernelKind::Laplace.of_sq_dist(0.0, 2.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn gauss_liquidsvm_parameterization() {
        // exp(-d2/gamma^2), gamma=2, d2=4 -> exp(-1)
        let v = KernelKind::Gauss.of_sq_dist(4.0, 2.0);
        assert!((v - (-1.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn libsvm_gamma_bridge() {
        // libsvm exp(-g*d2) with g=0.25 == ours with gamma=2
        let ours = KernelKind::from_libsvm_gamma(0.25);
        assert!((ours - 2.0).abs() < 1e-6);
    }

    #[test]
    fn laplace_uses_unsquared_distance() {
        let v = KernelKind::Laplace.of_sq_dist(9.0, 3.0);
        assert!((v - (-1.0f32).exp()).abs() < 1e-6);
    }
}
