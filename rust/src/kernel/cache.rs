//! Distance-matrix cache: the data structure behind the paper's
//! "to speed up the CV, the required kernel matrices may be re-used".
//!
//! One squared-distance matrix per (fold, block) pair is computed once
//! and exponentiated per γ; with a G-point γ grid this turns G distance
//! passes (the dominant cost, O(n²d)) into one pass plus G cheap
//! element-wise exponentials (O(n²)).

use crate::data::matrix::Matrix;

use super::{GramBackend, KernelKind};

/// Cached squared distances between a fixed pair of sample sets.
pub struct DistanceCache {
    d2: Matrix,
    kind: KernelKind,
    /// most recent (gamma, Gram) — CV iterates λ inside γ, so a single
    /// slot gives full reuse without holding G matrices alive.
    last: Option<(f32, Matrix)>,
    /// how many Gram requests were served from `last`
    pub hits: usize,
    /// how many required an exponentiation pass
    pub misses: usize,
}

impl DistanceCache {
    /// Compute and hold distances between `x` rows and `y` rows.
    pub fn new(backend: &GramBackend, x: &Matrix, y: &Matrix, kind: KernelKind) -> Self {
        DistanceCache { d2: backend.sq_dists(x, y), kind, last: None, hits: 0, misses: 0 }
    }

    /// Wrap an existing distance matrix.
    pub fn from_d2(d2: Matrix, kind: KernelKind) -> Self {
        DistanceCache { d2, kind, last: None, hits: 0, misses: 0 }
    }

    pub fn rows(&self) -> usize {
        self.d2.rows()
    }

    pub fn cols(&self) -> usize {
        self.d2.cols()
    }

    pub fn d2(&self) -> &Matrix {
        &self.d2
    }

    /// Gram matrix for γ — exponentiates at most once per distinct γ in
    /// a row (CV visits λ-grid inside each γ, so this is a full hit).
    pub fn gram(&mut self, gamma: f32) -> &Matrix {
        let fresh = match &self.last {
            Some((g, _)) if *g == gamma => false,
            _ => true,
        };
        if fresh {
            self.misses += 1;
            crate::metrics::counters::GRAM_CACHE_MISSES.inc();
            let k = super::apply_kernel(&self.d2, self.kind, gamma);
            self.last = Some((gamma, k));
        } else {
            self.hits += 1;
            crate::metrics::counters::GRAM_CACHE_HITS.inc();
        }
        &self.last.as_ref().unwrap().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    fn cache() -> DistanceCache {
        let x = Matrix::from_rows(&[&[0.0], &[1.0], &[3.0]]);
        DistanceCache::new(&GramBackend::Blocked, &x, &x, KernelKind::Gauss)
    }

    #[test]
    fn distances_correct() {
        let c = cache();
        assert_eq!(c.d2().get(0, 1), 1.0);
        assert_eq!(c.d2().get(0, 2), 9.0);
    }

    #[test]
    fn repeat_gamma_hits_cache() {
        let mut c = cache();
        let _ = c.gram(1.0);
        let _ = c.gram(1.0);
        let _ = c.gram(2.0);
        let _ = c.gram(2.0);
        assert_eq!(c.misses, 2);
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn global_counters_track_two_gamma_grid() {
        // the CV λ-inside-γ access pattern on a 2-γ grid: each γ is
        // requested more than once, so the process-wide counters that
        // `liquidsvm serve`'s stats report must show hits
        let before = crate::metrics::counters::snapshot();
        let mut c = cache();
        for &g in &[0.5, 0.5, 0.5, 1.5, 1.5] {
            let _ = c.gram(g);
        }
        let after = crate::metrics::counters::snapshot();
        assert!(c.hits > 0);
        assert!(
            after.gram_cache_hits >= before.gram_cache_hits + 3,
            "{} -> {}",
            before.gram_cache_hits,
            after.gram_cache_hits
        );
        assert!(after.gram_cache_misses >= before.gram_cache_misses + 2);
    }

    #[test]
    fn gram_values_match_kernel() {
        let mut c = cache();
        let k = c.gram(2.0);
        // d2(0,2)=9, gamma=2 -> exp(-9/4)
        assert!((k.get(0, 2) - (-2.25f32).exp()).abs() < 1e-6);
    }
}
