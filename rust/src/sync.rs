//! The crate's single concurrency seam (DESIGN.md §Static-analysis).
//!
//! Every lock, condition variable, and control-flow atomic in this
//! crate is imported from here, never from `std::sync` directly — a
//! project invariant enforced by `scripts/check_invariants.py`.  In a
//! normal build the re-exports below *are* the `std::sync` types
//! (zero wrappers, zero behavior change — pinned by
//! `tests/sync_shim.rs`).  Under `RUSTFLAGS="--cfg loom"` they become
//! the [loom](https://docs.rs/loom) model checker's permutation-tested
//! twins, and `tests/loom_models.rs` drives the hand-rolled
//! concurrent structures (bounded MPMC queue, dispatch/retry state,
//! shard LRU, phase table, work claim counter) through every
//! interleaving loom's bounded exploration can reach.
//!
//! Three deliberate carve-outs stay on `std` in both modes:
//!
//! * [`static_atomic`] — atomics for `const`-initialized process-wide
//!   statics (the `metrics::counters` statics, the obs enable flag)
//!   and pure-telemetry accumulators (the latency histogram).  Loom
//!   atomics have no `const fn new` and loom cannot model state that
//!   outlives a single model run, so globals are out of its reach by
//!   construction; nothing in this module's carve-out ever guards
//!   control flow, which is what keeps that sound.
//! * [`mpsc`] — reply channels.  Loom does not ship an mpsc; the
//!   channels only ferry results out of already-modeled critical
//!   sections, so std's implementation is used verbatim.
//! * [`OnceLock`] — lazy statics (the phase table, SIMD detection).
//!   Same `'static` argument as above.
//!
//! When adding a new concurrency seam: take `Mutex`/`Condvar`/
//! `RwLock`/`Arc`/[`atomic`] from this module, then add (or extend) a
//! loom model for the new interleaving in `tests/loom_models.rs`.

#[cfg(not(loom))]
mod imp {
    pub use std::sync::{
        Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    };

    /// Loom-switched atomics: use these for flags and counters that
    /// participate in synchronization or control flow.
    pub mod atomic {
        pub use std::sync::atomic::{
            AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
        };
    }
}

#[cfg(loom)]
mod imp {
    pub use loom::sync::{
        Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    };

    /// Loom-switched atomics: use these for flags and counters that
    /// participate in synchronization or control flow.
    pub mod atomic {
        pub use loom::sync::atomic::{
            AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
        };
    }
}

pub use imp::*;

/// Always-`std` atomics for `const`-initialized statics and
/// pure-telemetry accumulators (see the module docs for why these are
/// deliberately outside loom's model).  Never use one of these to
/// guard control flow between threads — that is what [`atomic`] is
/// for.
pub mod static_atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Reply channels (always `std`; loom has no mpsc — see module docs).
pub use std::sync::mpsc;

/// Lazy statics (always `std`; loom cannot model `'static` state).
pub use std::sync::OnceLock;
