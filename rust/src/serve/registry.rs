//! Multi-model registry: the server's model cache and the home of
//! cell-sharded bundles.
//!
//! Two kinds of solutions are served (see DESIGN.md §Serving):
//!
//! * **monolithic `.sol` files** load fully via
//!   [`crate::coordinator::persist::load_model`];
//! * **sharded `.sol.d/` bundles** load their `MANIFEST` eagerly
//!   (scaler + router + shard table — enough to route any request)
//!   while the per-cell shards load lazily on first use and stay
//!   resident under a byte-budgeted LRU, so one server instance can
//!   answer traffic against a model far larger than memory.
//!
//! The registry itself bounds *models* with LRU eviction
//! (`max_models`) and hot-reloads a model when its backing file — the
//! `.sol`, or a bundle's `MANIFEST` — changes on disk: liquidSVM's
//! train and test phases are separate processes, so a trainer can
//! overwrite a solution under a running server and new requests pick
//! up the fresh one without a restart.  Reloads are single-flight: one
//! caller parses the new file while everyone else keeps serving the
//! resident solution, and a failed reload (trainer mid-overwrite)
//! falls back to the resident model rather than failing requests.
//! One bundle-specific caveat: during a swap, a request needing a
//! shard the resident generation never cached can fail retryably —
//! the per-shard checksum refuses to mix generations silently (see
//! DESIGN.md §Serving).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::SystemTime;

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::cells::{CellPartition, CellRouter};
use crate::coordinator::config::Config;
use crate::coordinator::persist::{
    is_bundle_path, load_model, load_shard, read_manifest, BundleManifest,
};
use crate::coordinator::SvmModel;
use crate::data::matrix::Matrix;
use crate::metrics::counters::Counter;
use crate::tasks::combine_predictions;

/// Default shard-cache budget per bundle (bytes of shard files
/// resident at once) when the server does not configure one.
pub const DEFAULT_SHARD_BUDGET: u64 = 256 << 20;

/// Where a prediction row must execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RouteTarget {
    /// monolithic model — no cell routing
    Whole,
    /// exactly one owning cell (Voronoi / tree / single routers)
    Cell(usize),
    /// every cell votes (random-chunk ensembles)
    AllCells,
}

/// A model resident in the registry, shared immutably across worker
/// and connection threads.
pub struct ServedModel {
    pub name: String,
    /// source path; `None` for models inserted directly (tests/benches)
    pub path: Option<PathBuf>,
    /// (mtime, size) fingerprint of the source — the `.sol` file or a
    /// bundle's `MANIFEST` — at load time; size participates because
    /// mtime granularity can be a full second on some filesystems
    pub mtime: Option<SystemTime>,
    pub size: u64,
    /// expected input dimension (0 = unknown, skip validation)
    pub dim: usize,
    /// the full solution — or, for bundles, a routing *skeleton*
    /// (scaler, router, spec, classes; no units).  Calling `predict`
    /// directly on a bundle skeleton returns zeros; go through
    /// [`ServedModel::predict_routed`] instead.
    pub model: SvmModel,
    /// present iff this model is a sharded `.sol.d/` bundle
    pub bundle: Option<BundleHandle>,
}

impl ServedModel {
    /// Wrap an in-memory model (no backing file, never hot-reloaded).
    pub fn from_model(name: &str, model: SvmModel) -> ServedModel {
        ServedModel {
            name: name.to_string(),
            path: None,
            mtime: None,
            size: 0,
            dim: model.input_dim(),
            model,
            bundle: None,
        }
    }

    /// Decide where a feature row executes.  For bundles with a
    /// geometric router the row is scaled exactly as at training time
    /// and walked through the router; the batcher uses the result to
    /// coalesce rows per (model, cell).
    pub fn route(&self, features: &[f32]) -> RouteTarget {
        let Some(b) = &self.bundle else { return RouteTarget::Whole };
        match &b.manifest.router {
            CellRouter::Broadcast(_) => RouteTarget::AllCells,
            CellRouter::Single => RouteTarget::Cell(0),
            _ => {
                if self.dim > 0 && features.len() != self.dim {
                    // dim-mismatched rows are rejected upstream; park
                    // stragglers in cell 0 where the predict path will
                    // surface the mismatch
                    return RouteTarget::Cell(0);
                }
                let cells = match &self.model.scaler {
                    Some(s) => self.model.partition.route(&s.transform_row(features)),
                    None => self.model.partition.route(features),
                };
                RouteTarget::Cell(cells.first().copied().unwrap_or(0))
            }
        }
    }

    /// Predict `x` at a routing target.  Monolithic models ignore the
    /// target; bundles dispatch to the owning shard (loading it if
    /// needed), to every shard (broadcast ensembles), or row-by-row
    /// for un-routed batches.
    pub fn predict_routed(&self, target: RouteTarget, x: &Matrix) -> Result<Vec<f32>, String> {
        match (&self.bundle, target) {
            (None, _) => Ok(self.model.predict(x)),
            (Some(b), RouteTarget::Cell(c)) => b.predict_cell(c, x),
            (Some(b), RouteTarget::AllCells) => b.predict_broadcast(x),
            (Some(b), RouteTarget::Whole) => b.predict_mixed(x, self),
        }
    }

    /// Per-shard residency and hit counts (bundles only).
    pub fn shard_info(&self) -> Option<Vec<ShardInfo>> {
        let b = self.bundle.as_ref()?;
        Some(
            b.cache
                .cell_stats()
                .into_iter()
                .enumerate()
                .map(|(c, (resident, hits))| ShardInfo {
                    cell: c,
                    resident,
                    bytes: b.manifest.shards[c].bytes,
                    hits,
                })
                .collect(),
        )
    }
}

/// One row of [`ServedModel::shard_info`].
#[derive(Clone, Copy, Debug)]
pub struct ShardInfo {
    pub cell: usize,
    pub resident: bool,
    pub bytes: u64,
    /// total accesses (cache hits + loads) of this shard
    pub hits: u64,
}

struct LruEntry<V> {
    value: V,
    bytes: u64,
    last_used: u64,
}

struct LruState<V> {
    map: HashMap<usize, LruEntry<V>>,
    tick: u64,
    resident_bytes: u64,
    /// cumulative accesses per cell (survives eviction)
    accesses: Vec<u64>,
}

/// Outcome of [`ShardLru::insert`].
#[doc(hidden)]
pub enum LruInsert<V> {
    /// the value went in; `evicted` older entries left to stay under
    /// the byte budget
    Inserted { evicted: usize },
    /// another thread inserted this cell while the caller was loading
    /// it outside the lock — the caller adopts the winner's copy and
    /// drops its own (the loser-adopts-winner protocol)
    Adopted(V),
}

/// A byte-budgeted LRU over cell-indexed values — the concurrency seam
/// under [`BundleHandle`]'s lazy shard cache, extracted so the loom
/// models in `tests/loom_models.rs` can drive eviction races directly
/// (hence `#[doc(hidden)] pub`; not a public API).
///
/// Values load *outside* the lock (they are expensive disk parses), so
/// the LRU must absorb the two races that creates: a duplicate insert
/// (solved by adopt-winner) and an eviction sweep racing a lazy load
/// (solved by never evicting the cell being inserted).
#[doc(hidden)]
pub struct ShardLru<V> {
    max_bytes: u64,
    state: Mutex<LruState<V>>,
}

impl<V: Clone> ShardLru<V> {
    pub fn new(n_cells: usize, max_bytes: u64) -> ShardLru<V> {
        ShardLru {
            max_bytes: max_bytes.max(1),
            state: Mutex::new(LruState {
                map: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
                accesses: vec![0; n_cells],
            }),
        }
    }

    /// Look up `cell`, counting the access and bumping recency on a
    /// hit.  A miss still counts as an access (the caller will load
    /// and [`ShardLru::insert`]).
    pub fn touch(&self, cell: usize) -> Option<V> {
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if cell < st.accesses.len() {
            st.accesses[cell] += 1;
        }
        let e = st.map.get_mut(&cell)?;
        e.last_used = tick;
        Some(e.value.clone())
    }

    /// Insert a freshly loaded value, evicting least-recently-used
    /// entries past the byte budget — never the entry being inserted,
    /// even when it alone exceeds the budget.  If another thread won
    /// the load race, returns its copy instead.
    pub fn insert(&self, cell: usize, value: V, bytes: u64) -> LruInsert<V> {
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if let Some(existing) = st.map.get_mut(&cell) {
            existing.last_used = tick;
            return LruInsert::Adopted(existing.value.clone());
        }
        st.resident_bytes += bytes;
        st.map.insert(cell, LruEntry { value, bytes, last_used: tick });
        let mut evicted = 0;
        while st.resident_bytes > self.max_bytes && st.map.len() > 1 {
            let victim = st
                .map
                .iter()
                .filter(|(&c, _)| c != cell)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&c, _)| c);
            match victim {
                Some(v) => {
                    if let Some(e) = st.map.remove(&v) {
                        st.resident_bytes -= e.bytes;
                        evicted += 1;
                    }
                }
                None => break,
            }
        }
        LruInsert::Inserted { evicted }
    }

    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().resident_bytes
    }

    pub fn resident_count(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// `(resident, accesses)` per cell, read under one lock.
    pub fn cell_stats(&self) -> Vec<(bool, u64)> {
        let st = self.state.lock().unwrap();
        (0..st.accesses.len()).map(|c| (st.map.contains_key(&c), st.accesses[c])).collect()
    }

    /// Structural invariant probe for the model checker: the byte
    /// accounting must equal the sum over resident entries, and the
    /// budget may only be exceeded by a single oversized entry.
    pub fn invariants_hold(&self) -> bool {
        let st = self.state.lock().unwrap();
        let sum: u64 = st.map.values().map(|e| e.bytes).sum();
        sum == st.resident_bytes && (st.resident_bytes <= self.max_bytes || st.map.len() == 1)
    }
}

/// A try-lock-shaped guard over an [`AtomicBool`]: at most one caller
/// holds the flight at a time; everyone else moves on immediately
/// (they keep serving the resident model).  Extracted from
/// [`Registry::get`]'s hot-reload path so the loom models can prove
/// mutual exclusion; the guard releases on drop, so a panicking
/// reload no longer wedges the flag permanently shut.
#[doc(hidden)]
pub struct SingleFlight {
    busy: AtomicBool,
}

#[doc(hidden)]
pub struct SingleFlightGuard<'a> {
    busy: &'a AtomicBool,
}

impl SingleFlight {
    // not `const`: under `cfg(loom)` the atomic's constructor is a
    // tracked runtime operation
    pub fn new() -> SingleFlight {
        SingleFlight { busy: AtomicBool::new(false) }
    }

    /// Acquire the flight, or `None` if another caller holds it.
    /// Acquire on success pairs with the guard's Release store so the
    /// next winner observes everything the previous flight wrote.
    pub fn try_begin(&self) -> Option<SingleFlightGuard<'_>> {
        self.busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
            .then_some(SingleFlightGuard { busy: &self.busy })
    }
}

impl Drop for SingleFlightGuard<'_> {
    fn drop(&mut self) {
        self.busy.store(false, Ordering::Release);
    }
}

/// The lazily-loading shard store of one `.sol.d/` bundle.
///
/// Each loaded shard becomes a self-contained single-cell mini
/// [`SvmModel`] (cell ids remapped to 0, router forced to `Single`),
/// so the existing predict path runs unchanged and bit-identically to
/// the monolithic model.  Residency is bounded by `max_bytes` of shard
/// file size with LRU eviction; the shard being inserted is never the
/// eviction victim.
pub struct BundleHandle {
    dir: PathBuf,
    manifest: BundleManifest,
    /// runtime config applied to shard mini-models (kernel pinned from
    /// the manifest)
    cfg: Config,
    cache: ShardLru<Arc<SvmModel>>,
    /// shard accesses answered from the cache
    pub hits: Counter,
    /// shard loads from disk (cache misses)
    pub loads: Counter,
    /// shards evicted to stay under the byte budget
    pub evictions: Counter,
}

impl BundleHandle {
    /// Read the manifest and build the handle plus the routing
    /// skeleton model (no shards resident yet).
    fn open(dir: &Path, cfg: &Config, max_bytes: u64) -> Result<(BundleHandle, SvmModel)> {
        let manifest = read_manifest(dir)?;
        let mut cfg = cfg.clone();
        cfg.kernel = manifest.kernel;
        cfg.cells = manifest.strategy.clone();
        let skeleton = SvmModel::from_parts(
            cfg.clone(),
            manifest.spec.clone(),
            manifest.scaler.clone(),
            CellPartition {
                cells: vec![Vec::new(); manifest.n_cells()],
                router: manifest.router.clone(),
            },
            manifest.classes.clone(),
            manifest.n_tasks,
            Vec::new(),
        )?;
        let n_cells = manifest.n_cells();
        let handle = BundleHandle {
            dir: dir.to_path_buf(),
            manifest,
            cfg,
            cache: ShardLru::new(n_cells, max_bytes),
            hits: Counter::new(),
            loads: Counter::new(),
            evictions: Counter::new(),
        };
        Ok((handle, skeleton))
    }

    pub fn manifest(&self) -> &BundleManifest {
        &self.manifest
    }

    pub fn resident_bytes(&self) -> u64 {
        self.cache.resident_bytes()
    }

    pub fn resident_shards(&self) -> usize {
        self.cache.resident_count()
    }

    /// Fetch the mini-model of `cell`, loading (and checksumming) its
    /// shard from disk on first use and evicting least-recently-used
    /// shards past the byte budget.
    fn shard(&self, cell: usize) -> Result<Arc<SvmModel>, String> {
        if let Some(m) = self.cache.touch(cell) {
            self.hits.inc();
            return Ok(m);
        }
        // miss: read + parse *outside* the lock so traffic for
        // already-resident shards (and the stats commands) never
        // stalls behind a cold load.  Two threads missing on the same
        // cell may rarely parse it twice; the loser adopts the
        // winner's copy below.  If the bundle was replaced on disk
        // under this (stale) handle, the checksum catches the
        // generation mismatch and the batch fails retryably — the
        // registry swaps in the new generation on its next lookup.
        self.loads.inc();
        let (indices, units) = load_shard(&self.dir, &self.manifest, cell)
            .map_err(|e| format!("shard {cell} unavailable (bundle replaced on disk? retry): {e:#}"))?;
        let units = units
            .into_iter()
            .map(|mut u| {
                u.cell = 0;
                u
            })
            .collect();
        let mini = SvmModel::from_parts(
            self.cfg.clone(),
            self.manifest.spec.clone(),
            self.manifest.scaler.clone(),
            CellPartition { cells: vec![indices], router: CellRouter::Single },
            self.manifest.classes.clone(),
            self.manifest.n_tasks,
            units,
        )
        .map_err(|e| format!("{e:#}"))?;
        let bytes = self.manifest.shards[cell].bytes;
        let arc = Arc::new(mini);
        match self.cache.insert(cell, arc.clone(), bytes) {
            // another thread loaded this shard while we parsed
            LruInsert::Adopted(winner) => Ok(winner),
            LruInsert::Inserted { evicted } => {
                self.evictions.add(evicted as u64);
                Ok(arc)
            }
        }
    }

    /// Predict a batch that routes entirely to one cell.
    fn predict_cell(&self, cell: usize, x: &Matrix) -> Result<Vec<f32>, String> {
        Ok(self.shard(cell)?.predict(x))
    }

    /// Broadcast ensembles (random chunks): every cell's decision
    /// values averaged per task, then combined — the same accumulation
    /// order and division the monolithic predict path uses, so results
    /// stay bit-identical.
    fn predict_broadcast(&self, x: &Matrix) -> Result<Vec<f32>, String> {
        let n_tasks = self.manifest.n_tasks;
        let mut scores = vec![vec![0.0f32; x.rows()]; n_tasks];
        let mut counts = vec![0u32; n_tasks];
        for c in 0..self.manifest.n_cells() {
            let mini = self.shard(c)?;
            let dv = mini.decision_values(x);
            for t in 0..n_tasks {
                for (a, b) in scores[t].iter_mut().zip(&dv[t]) {
                    *a += b;
                }
                if mini.units.iter().any(|u| u.task == t && u.cv.is_some() && !u.data.is_empty())
                {
                    counts[t] += 1;
                }
            }
        }
        for t in 0..n_tasks {
            if counts[t] > 1 {
                for a in &mut scores[t] {
                    *a /= counts[t] as f32;
                }
            }
        }
        Ok(combine_predictions(&self.manifest.spec, &self.manifest.classes, &scores))
    }

    /// Un-routed batch: route each row, group per cell, predict per
    /// shard, scatter back in row order.
    fn predict_mixed(&self, x: &Matrix, served: &ServedModel) -> Result<Vec<f32>, String> {
        if matches!(self.manifest.router, CellRouter::Broadcast(_)) {
            return self.predict_broadcast(x);
        }
        let mut routed: Vec<Vec<usize>> = vec![Vec::new(); self.manifest.n_cells()];
        for i in 0..x.rows() {
            match served.route(x.row(i)) {
                RouteTarget::Cell(c) if c < routed.len() => routed[c].push(i),
                _ => routed[0].push(i),
            }
        }
        let mut out = vec![0.0f32; x.rows()];
        for (c, idx) in routed.iter().enumerate() {
            if idx.is_empty() {
                continue;
            }
            let sub = x.select_rows(idx);
            let preds = self.predict_cell(c, &sub)?;
            for (j, &i) in idx.iter().enumerate() {
                out[i] = preds[j];
            }
        }
        Ok(out)
    }
}

/// Aggregated shard-cache telemetry across every resident bundle
/// (reported by the protocol's `stats` command).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardUsage {
    pub bundles: usize,
    pub total_shards: usize,
    pub resident_shards: usize,
    pub total_bytes: u64,
    pub resident_bytes: u64,
    pub hits: u64,
    pub loads: u64,
    pub evictions: u64,
}

struct Entry {
    model: Arc<ServedModel>,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
}

/// LRU-bounded, hot-reloading model cache.
pub struct Registry {
    cfg: Config,
    max_models: usize,
    shard_budget: u64,
    inner: Mutex<Inner>,
    /// single-flight guard: at most one hot-reload parses at a time,
    /// everyone else keeps serving the resident model meanwhile
    reloading: SingleFlight,
}

/// Fingerprint of a model source: the `.sol` file itself, or a
/// bundle's `MANIFEST` (the directory mtime alone is not reliable).
/// `None` when the source cannot be stat'ed — callers must then keep
/// serving the resident model rather than treating it as changed
/// (the path may be mid-swap or deleted).
fn fingerprint(path: &Path) -> Option<(Option<SystemTime>, u64)> {
    let target = if path.is_dir() {
        path.join(crate::coordinator::persist::MANIFEST_FILE)
    } else {
        path.to_path_buf()
    };
    std::fs::metadata(&target).ok().map(|m| (m.modified().ok(), m.len()))
}

impl Registry {
    /// `cfg` supplies the runtime choices (backend, threads) applied to
    /// every loaded model; `max_models` bounds resident solutions.
    /// Bundles get [`DEFAULT_SHARD_BUDGET`] unless overridden with
    /// [`Registry::shard_budget`].
    pub fn new(cfg: Config, max_models: usize) -> Registry {
        Registry {
            cfg,
            max_models: max_models.max(1),
            shard_budget: DEFAULT_SHARD_BUDGET,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            reloading: SingleFlight::new(),
        }
    }

    /// Override the per-bundle resident-shard byte budget.
    pub fn shard_budget(mut self, bytes: u64) -> Registry {
        self.shard_budget = bytes.max(1);
        self
    }

    /// Load (or replace) a model from a `.sol` file or `.sol.d/`
    /// bundle.  Bundles only read their manifest here; shards load
    /// lazily at predict time.
    pub fn load(&self, name: &str, path: &Path) -> Result<Arc<ServedModel>> {
        let (mtime, size) = fingerprint(path).unwrap_or((None, 0));
        let served = if is_bundle_path(path) {
            let (handle, skeleton) = BundleHandle::open(path, &self.cfg, self.shard_budget)?;
            let dim = if handle.manifest.dim > 0 { handle.manifest.dim } else { skeleton.input_dim() };
            ServedModel {
                name: name.to_string(),
                path: Some(path.to_path_buf()),
                mtime,
                size,
                dim,
                model: skeleton,
                bundle: Some(handle),
            }
        } else {
            let model = load_model(path, &self.cfg)?;
            ServedModel {
                name: name.to_string(),
                path: Some(path.to_path_buf()),
                mtime,
                size,
                dim: model.input_dim(),
                model,
                bundle: None,
            }
        };
        let served = Arc::new(served);
        self.put(name, served.clone());
        Ok(served)
    }

    /// Register an in-memory model under `name` (tests/benches).
    pub fn insert(&self, name: &str, model: SvmModel) -> Arc<ServedModel> {
        let served = Arc::new(ServedModel::from_model(name, model));
        self.put(name, served.clone());
        served
    }

    fn put(&self, name: &str, served: Arc<ServedModel>) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(name.to_string(), Entry { model: served, last_used: tick });
        while inner.map.len() > self.max_models {
            let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            inner.map.remove(&oldest);
        }
    }

    /// Fetch a model by name, bumping its recency.  If the backing
    /// source changed since load (mtime or size of the `.sol` /
    /// bundle `MANIFEST`), one caller reloads it while the rest keep
    /// serving the resident solution; a failed reload (e.g. the
    /// trainer is mid-overwrite) also falls back to the resident
    /// model rather than failing the request.
    pub fn get(&self, name: &str) -> Result<Arc<ServedModel>> {
        let served = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let entry = inner
                .map
                .get_mut(name)
                .ok_or_else(|| anyhow!("unknown model `{name}`"))?;
            entry.last_used = tick;
            entry.model.clone()
        };
        // hot-reload check outside the lock: a slow disk stat (or the
        // reload itself) must not stall other models' lookups.  An
        // un-stat-able source (mid-swap, deleted) is NOT "changed" —
        // keep serving the resident solution.
        if let Some(path) = &served.path {
            if let Some((mtime, size)) = fingerprint(path) {
                let changed = mtime != served.mtime || size != served.size;
                if changed {
                    if let Some(_flight) = self.reloading.try_begin() {
                        // the guard releases on drop, so a reload that
                        // panics (or errors) cannot wedge the flag shut
                        if let Ok(fresh) = self.load(name, path) {
                            return Ok(fresh);
                        }
                    }
                }
            }
        }
        Ok(served)
    }

    /// Drop a model; returns false if it was not resident.
    pub fn unload(&self, name: &str) -> bool {
        self.inner.lock().unwrap().map.remove(name).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().map.keys().cloned().collect();
        v.sort();
        v
    }

    /// Aggregate shard-cache telemetry across resident bundles.
    pub fn shard_usage(&self) -> ShardUsage {
        let inner = self.inner.lock().unwrap();
        let mut u = ShardUsage::default();
        for e in inner.map.values() {
            let Some(b) = &e.model.bundle else { continue };
            u.bundles += 1;
            u.total_shards += b.manifest.n_cells();
            u.total_bytes += b.manifest.total_bytes();
            u.resident_shards += b.cache.resident_count();
            u.resident_bytes += b.cache.resident_bytes();
            u.hits += b.hits.get();
            u.loads += b.loads.get();
            u.evictions += b.evictions.get();
        }
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::CellStrategy;
    use crate::coordinator::persist::{save_bundle, save_model};
    use crate::data::synth;
    use crate::prelude::*;

    fn tiny_model(n: usize, seed: u64) -> SvmModel {
        let d = synth::banana_binary(n, seed);
        svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap()
    }

    fn cell_model(n: usize, seed: u64) -> SvmModel {
        let d = synth::banana_binary(n, seed);
        let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: n / 4 });
        svm_binary(&d, 0.5, &cfg).unwrap()
    }

    fn tmp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "lsvm-registry-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_get_predicts_like_source_model() {
        let m = tiny_model(80, 1);
        let test = synth::banana_binary(40, 2);
        let expect = m.predict(&test.x);
        let path = tmp_dir().join("a.sol");
        save_model(&m, &path).unwrap();

        let reg = Registry::new(Config::default(), 4);
        reg.load("a", &path).unwrap();
        let served = reg.get("a").unwrap();
        assert_eq!(served.dim, 2);
        assert_eq!(served.model.predict(&test.x), expect);
    }

    #[test]
    fn unknown_model_errors() {
        let reg = Registry::new(Config::default(), 4);
        assert!(reg.get("nope").is_err());
        assert!(!reg.unload("nope"));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let reg = Registry::new(Config::default(), 2);
        reg.insert("a", tiny_model(60, 3));
        reg.insert("b", tiny_model(60, 4));
        reg.get("a").unwrap(); // bump a over b
        reg.insert("c", tiny_model(60, 5));
        assert_eq!(reg.names(), vec!["a".to_string(), "c".to_string()]);
        assert!(reg.get("b").is_err());
    }

    #[test]
    fn hot_reloads_on_file_change() {
        let path = tmp_dir().join("hot.sol");
        let m1 = tiny_model(60, 6);
        save_model(&m1, &path).unwrap();
        let reg = Registry::new(Config::default(), 4);
        reg.load("hot", &path).unwrap();

        // overwrite with a different solution (different size fingerprint)
        let m2 = tiny_model(110, 7);
        save_model(&m2, &path).unwrap();
        let served = reg.get("hot").unwrap();

        let test = synth::banana_binary(30, 8);
        assert_eq!(served.model.predict(&test.x), m2.predict(&test.x));
    }

    #[test]
    fn in_memory_models_skip_reload() {
        let reg = Registry::new(Config::default(), 4);
        reg.insert("mem", tiny_model(60, 9));
        let a = reg.get("mem").unwrap();
        let b = reg.get("mem").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn bundle_loads_manifest_eagerly_and_shards_lazily() {
        let m = cell_model(240, 40);
        let dir = tmp_dir().join("lazy.sol.d");
        save_bundle(&m, &dir).unwrap();

        let reg = Registry::new(Config::default(), 4);
        let served = reg.load("b", &dir).unwrap();
        let bundle = served.bundle.as_ref().unwrap();
        assert!(bundle.manifest().n_cells() > 1);
        assert_eq!(bundle.resident_shards(), 0, "no shard should load at manifest time");
        assert_eq!(served.dim, 2);

        // a single-cell request loads exactly the shards it touches
        let test = synth::banana_binary(6, 41);
        let row = test.x.row(0);
        let target = served.route(row);
        let RouteTarget::Cell(c) = target else { panic!("expected cell target, got {target:?}") };
        let x = Matrix::from_vec(row.to_vec(), 1, 2);
        let got = served.predict_routed(target, &x).unwrap();
        assert_eq!(got, m.predict(&x));
        assert_eq!(bundle.resident_shards(), 1);
        assert!(bundle.resident_bytes() < bundle.manifest().total_bytes());
        let info = served.shard_info().unwrap();
        assert!(info[c].resident);
        assert_eq!(info[c].hits, 1);
    }

    #[test]
    fn bundle_mixed_batch_matches_monolithic() {
        let m = cell_model(260, 42);
        let dir = tmp_dir().join("mixed.sol.d");
        save_bundle(&m, &dir).unwrap();
        let reg = Registry::new(Config::default(), 4);
        let served = reg.load("b", &dir).unwrap();

        let test = synth::banana_binary(70, 43);
        let got = served.predict_routed(RouteTarget::Whole, &test.x).unwrap();
        assert_eq!(got, m.predict(&test.x));
    }

    #[test]
    fn broadcast_bundle_matches_monolithic() {
        let d = synth::banana_binary(200, 44);
        let cfg = Config::default().folds(2).voronoi(CellStrategy::RandomChunks { size: 60 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let dir = tmp_dir().join("bcast.sol.d");
        save_bundle(&m, &dir).unwrap();
        let reg = Registry::new(Config::default(), 4);
        let served = reg.load("b", &dir).unwrap();

        let test = synth::banana_binary(30, 45);
        assert_eq!(served.route(test.x.row(0)), RouteTarget::AllCells);
        let got = served.predict_routed(RouteTarget::AllCells, &test.x).unwrap();
        assert_eq!(got, m.predict(&test.x));
    }

    #[test]
    fn shard_budget_evicts_lru() {
        let m = cell_model(300, 46);
        let dir = tmp_dir().join("budget.sol.d");
        save_bundle(&m, &dir).unwrap();
        let manifest = crate::coordinator::persist::read_manifest(&dir).unwrap();
        assert!(manifest.n_cells() >= 3, "need several cells for this test");
        // budget fits roughly one shard: every new cell evicts the last
        let one_shard = manifest.shards.iter().map(|s| s.bytes).max().unwrap();
        let reg = Registry::new(Config::default(), 4).shard_budget(one_shard);
        let served = reg.load("b", &dir).unwrap();

        let test = synth::banana_binary(80, 47);
        let got = served.predict_routed(RouteTarget::Whole, &test.x).unwrap();
        assert_eq!(got, m.predict(&test.x));
        // touch every shard explicitly: with a one-shard budget each
        // load past the first must evict the previous resident
        let probe = Matrix::from_vec(test.x.row(0).to_vec(), 1, 2);
        for c in 0..manifest.n_cells() {
            served.predict_routed(RouteTarget::Cell(c), &probe).unwrap();
        }
        let bundle = served.bundle.as_ref().unwrap();
        assert!(bundle.evictions.get() > 0, "expected evictions under a 1-shard budget");
        assert!(bundle.resident_bytes() <= one_shard.max(1));

        let usage = reg.shard_usage();
        assert_eq!(usage.bundles, 1);
        assert!(usage.resident_bytes < usage.total_bytes);
        assert!(usage.loads > usage.evictions);
    }

    #[test]
    fn bundle_hot_reloads_on_manifest_change() {
        let dir = tmp_dir().join("hotb.sol.d");
        let m1 = cell_model(200, 48);
        save_bundle(&m1, &dir).unwrap();
        let reg = Registry::new(Config::default(), 4);
        reg.load("hb", &dir).unwrap();

        let m2 = cell_model(280, 49);
        save_bundle(&m2, &dir).unwrap();
        let served = reg.get("hb").unwrap();
        let test = synth::banana_binary(25, 50);
        let got = served.predict_routed(RouteTarget::Whole, &test.x).unwrap();
        assert_eq!(got, m2.predict(&test.x));
    }
}
