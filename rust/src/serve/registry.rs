//! Multi-model registry: loads `.sol` solutions via
//! [`crate::coordinator::persist`], hands out shared handles to the
//! batcher/workers, bounds resident models with LRU eviction, and
//! hot-reloads a model when its file changes on disk (liquidSVM's
//! train and test phases are separate processes, so a trainer can
//! overwrite a `.sol` under a running server and new requests pick up
//! the fresh solution without a restart).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::config::Config;
use crate::coordinator::persist::load_model;
use crate::coordinator::SvmModel;

/// A model resident in the registry, shared immutably across worker
/// and connection threads.
pub struct ServedModel {
    pub name: String,
    /// source file; `None` for models inserted directly (tests/benches)
    pub path: Option<PathBuf>,
    /// (mtime, size) fingerprint of the source file at load time —
    /// size participates because mtime granularity can be a full
    /// second on some filesystems
    pub mtime: Option<SystemTime>,
    pub size: u64,
    /// expected input dimension (0 = unknown, skip validation)
    pub dim: usize,
    pub model: SvmModel,
}

impl ServedModel {
    /// Wrap an in-memory model (no backing file, never hot-reloaded).
    pub fn from_model(name: &str, model: SvmModel) -> ServedModel {
        ServedModel {
            name: name.to_string(),
            path: None,
            mtime: None,
            size: 0,
            dim: input_dim(&model),
            model,
        }
    }
}

fn input_dim(model: &SvmModel) -> usize {
    if let Some(s) = &model.scaler {
        return s.parts().0.len();
    }
    model.units.iter().find(|u| !u.data.is_empty()).map(|u| u.data.dim()).unwrap_or(0)
}

struct Entry {
    model: Arc<ServedModel>,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    tick: u64,
}

/// LRU-bounded, hot-reloading model cache.
pub struct Registry {
    cfg: Config,
    max_models: usize,
    inner: Mutex<Inner>,
    /// single-flight guard: at most one hot-reload parses at a time,
    /// everyone else keeps serving the resident model meanwhile
    reloading: AtomicBool,
}

impl Registry {
    /// `cfg` supplies the runtime choices (backend, threads) applied to
    /// every loaded model; `max_models` bounds resident solutions.
    pub fn new(cfg: Config, max_models: usize) -> Registry {
        Registry {
            cfg,
            max_models: max_models.max(1),
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            reloading: AtomicBool::new(false),
        }
    }

    /// Load (or replace) a model from a `.sol` file.
    pub fn load(&self, name: &str, path: &Path) -> Result<Arc<ServedModel>> {
        let model = load_model(path, &self.cfg)?;
        let meta = std::fs::metadata(path).with_context(|| format!("stat {path:?}"))?;
        let served = Arc::new(ServedModel {
            name: name.to_string(),
            path: Some(path.to_path_buf()),
            mtime: meta.modified().ok(),
            size: meta.len(),
            dim: input_dim(&model),
            model,
        });
        self.put(name, served.clone());
        Ok(served)
    }

    /// Register an in-memory model under `name` (tests/benches).
    pub fn insert(&self, name: &str, model: SvmModel) -> Arc<ServedModel> {
        let served = Arc::new(ServedModel::from_model(name, model));
        self.put(name, served.clone());
        served
    }

    fn put(&self, name: &str, served: Arc<ServedModel>) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(name.to_string(), Entry { model: served, last_used: tick });
        while inner.map.len() > self.max_models {
            let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            inner.map.remove(&oldest);
        }
    }

    /// Fetch a model by name, bumping its recency.  If the backing file
    /// changed since load (mtime or size), one caller reloads it while
    /// the rest keep serving the resident solution; a failed reload
    /// (e.g. the trainer is mid-overwrite) also falls back to the
    /// resident model rather than failing the request.
    pub fn get(&self, name: &str) -> Result<Arc<ServedModel>> {
        let served = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            let entry = inner
                .map
                .get_mut(name)
                .ok_or_else(|| anyhow!("unknown model `{name}`"))?;
            entry.last_used = tick;
            entry.model.clone()
        };
        // hot-reload check outside the lock: a slow disk stat (or the
        // reload itself) must not stall other models' lookups
        if let Some(path) = &served.path {
            if let Ok(meta) = std::fs::metadata(path) {
                let changed = meta.modified().ok() != served.mtime || meta.len() != served.size;
                if changed
                    && self
                        .reloading
                        .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                        .is_ok()
                {
                    let reloaded = self.load(name, path);
                    self.reloading.store(false, Ordering::Release);
                    if let Ok(fresh) = reloaded {
                        return Ok(fresh);
                    }
                }
            }
        }
        Ok(served)
    }

    /// Drop a model; returns false if it was not resident.
    pub fn unload(&self, name: &str) -> bool {
        self.inner.lock().unwrap().map.remove(name).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().map.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::persist::save_model;
    use crate::data::synth;
    use crate::prelude::*;

    fn tiny_model(n: usize, seed: u64) -> SvmModel {
        let d = synth::banana_binary(n, seed);
        svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap()
    }

    fn tmp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "lsvm-registry-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_get_predicts_like_source_model() {
        let m = tiny_model(80, 1);
        let test = synth::banana_binary(40, 2);
        let expect = m.predict(&test.x);
        let path = tmp_dir().join("a.sol");
        save_model(&m, &path).unwrap();

        let reg = Registry::new(Config::default(), 4);
        reg.load("a", &path).unwrap();
        let served = reg.get("a").unwrap();
        assert_eq!(served.dim, 2);
        assert_eq!(served.model.predict(&test.x), expect);
    }

    #[test]
    fn unknown_model_errors() {
        let reg = Registry::new(Config::default(), 4);
        assert!(reg.get("nope").is_err());
        assert!(!reg.unload("nope"));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let reg = Registry::new(Config::default(), 2);
        reg.insert("a", tiny_model(60, 3));
        reg.insert("b", tiny_model(60, 4));
        reg.get("a").unwrap(); // bump a over b
        reg.insert("c", tiny_model(60, 5));
        assert_eq!(reg.names(), vec!["a".to_string(), "c".to_string()]);
        assert!(reg.get("b").is_err());
    }

    #[test]
    fn hot_reloads_on_file_change() {
        let path = tmp_dir().join("hot.sol");
        let m1 = tiny_model(60, 6);
        save_model(&m1, &path).unwrap();
        let reg = Registry::new(Config::default(), 4);
        reg.load("hot", &path).unwrap();

        // overwrite with a different solution (different size fingerprint)
        let m2 = tiny_model(110, 7);
        save_model(&m2, &path).unwrap();
        let served = reg.get("hot").unwrap();

        let test = synth::banana_binary(30, 8);
        assert_eq!(served.model.predict(&test.x), m2.predict(&test.x));
    }

    #[test]
    fn in_memory_models_skip_reload() {
        let reg = Registry::new(Config::default(), 4);
        reg.insert("mem", tiny_model(60, 9));
        let a = reg.get("mem").unwrap();
        let b = reg.get("mem").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
