//! The wire protocol of `liquidsvm serve` — line-delimited text over
//! TCP, hand-rolled like the CLI's argument parsing (no serde/json in
//! this image's offline registry).
//!
//! Requests, one per line:
//!
//! ```text
//! predict <model> <f1,f2,...>[;<f1,f2,...>...]   # one or more rows
//!                             # rows may be sparse: `idx:val` pairs
//!                             # (1-based, libsvm-style), e.g.
//!                             # `predict m 3:0.5,17:1.2;1:2`
//! load <name> <path>          # path: a .sol file or a .sol.d bundle
//! unload <name>
//! stats                       # server-wide counters incl. shard cache
//! shards <name>               # per-shard residency/hits of a bundle
//! metrics                     # Prometheus exposition of the registry
//! metrics json                # same snapshot as one JSON object
//! ping
//! quit
//! ```
//!
//! Responses, one line per request, in request order:
//!
//! ```text
//! ok <v1>[;<v2>...]          # predict
//! ok <message>               # load/unload/stats/shards/ping
//! ok metrics lines=<N>       # then exactly N payload lines follow
//! err <code> <message>       # e.g. `err busy retry_after_ms=4`
//! ```
//!
//! `metrics` is the only multi-line response: its header announces the
//! payload line count so clients reading in lockstep know exactly how
//! many lines to consume; `metrics json` stays single-line (`ok
//! <json>`).  See DESIGN.md §Observability for the snapshot schema.
//!
//! Error codes: `bad-request` (parse failure), `unknown-model`,
//! `load-failed`, `dim-mismatch`, `predict-failed`, `not-sharded`
//! (`shards` on a monolithic model), `busy` (backpressure — wait
//! `retry_after_ms` and retry), `internal`.
//!
//! Clients may pipeline: the server preserves ordering, so a batch of
//! requests can be written back-to-back and the responses read in
//! sequence — that is exactly what lets concurrent rows coalesce into
//! one fused predict call.

/// Longest accepted request line (guards the server against unbounded
/// buffering from a misbehaving client).
pub const MAX_LINE: usize = 1 << 20;

/// One prediction row off the wire: dense (`v1,v2,...`) or sparse
/// (`idx:val` pairs, 1-based like LIBSVM).  Sparse rows densify at the
/// server boundary against the target model's dimension — the serving
/// expansion is dense, so this is the documented densification
/// boundary of the serve path (DESIGN.md §Data-plane).
#[derive(Clone, Debug, PartialEq)]
pub enum PredictRow {
    Dense(Vec<f32>),
    /// 0-based (index, value) pairs, strictly increasing
    Sparse(Vec<(u32, f32)>),
}

impl PredictRow {
    /// The row's minimum viable dimension: dense length, or highest
    /// sparse index + 1.
    pub fn min_dim(&self) -> usize {
        match self {
            PredictRow::Dense(v) => v.len(),
            PredictRow::Sparse(p) => p.last().map_or(0, |&(j, _)| j as usize + 1),
        }
    }

    /// Densify to exactly `dim` features.  Errors when the row cannot
    /// fit (dense length mismatch is left to the caller's dim check;
    /// sparse indices past `dim` are rejected here).
    pub fn densify(self, dim: usize) -> Result<Vec<f32>, String> {
        match self {
            PredictRow::Dense(v) => Ok(v),
            PredictRow::Sparse(pairs) => {
                let mut out = vec![0.0f32; dim];
                for (j, v) in pairs {
                    if j as usize >= dim {
                        return Err(format!("sparse index {} exceeds model dim {dim}", j + 1));
                    }
                    out[j as usize] = v;
                }
                Ok(out)
            }
        }
    }
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Predict { model: String, rows: Vec<PredictRow> },
    Load { name: String, path: String },
    Unload { name: String },
    Stats,
    /// per-shard residency and hit counts of a sharded bundle
    Shards { name: String },
    /// metrics-registry snapshot: Prometheus text, or JSON with `json`
    Metrics { json: bool },
    Ping,
    Quit,
}

/// Parse one request line.  Errors are human-readable fragments that
/// the server echoes back as `err bad-request <msg>`.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim();
    if line.is_empty() {
        return Err("empty request".into());
    }
    if line.len() > MAX_LINE {
        return Err("request line too long".into());
    }
    let (cmd, rest) = match line.split_once(char::is_whitespace) {
        Some((c, r)) => (c, r.trim()),
        None => (line, ""),
    };
    match cmd {
        "predict" => {
            let (model, data) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "predict needs `<model> <rows>`".to_string())?;
            let rows = parse_rows(data.trim())?;
            Ok(Request::Predict { model: model.to_string(), rows })
        }
        "load" => {
            let (name, path) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "load needs `<name> <path>`".to_string())?;
            Ok(Request::Load { name: name.to_string(), path: path.trim().to_string() })
        }
        "unload" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("unload needs `<name>`".into());
            }
            Ok(Request::Unload { name: rest.to_string() })
        }
        "shards" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("shards needs `<name>`".into());
            }
            Ok(Request::Shards { name: rest.to_string() })
        }
        "stats" => Ok(Request::Stats),
        "metrics" => match rest {
            "" => Ok(Request::Metrics { json: false }),
            "json" => Ok(Request::Metrics { json: true }),
            other => Err(format!("metrics takes no argument or `json`, got `{other}`")),
        },
        "ping" => Ok(Request::Ping),
        "quit" => Ok(Request::Quit),
        other => Err(format!("unknown command `{other}`")),
    }
}

/// Parse `;`-separated rows of `,`-separated values.  A row whose
/// first token contains `:` is sparse (`idx:val` pairs, 1-based);
/// mixed tokens within one row are rejected, as are duplicate or
/// zero indices — the same strictness as the LIBSVM file reader.
pub fn parse_rows(text: &str) -> Result<Vec<PredictRow>, String> {
    if text.is_empty() {
        return Err("no feature rows".into());
    }
    let mut rows = Vec::new();
    for row in text.split(';') {
        let sparse = row.split(',').next().is_some_and(|t| t.contains(':'));
        if sparse {
            let mut pairs: Vec<(u32, f32)> = Vec::new();
            for t in row.split(',') {
                let t = t.trim();
                let (i, v) = t
                    .split_once(':')
                    .ok_or_else(|| format!("mixed sparse/dense row at `{t}`"))?;
                let i: u32 = i.parse().map_err(|_| format!("bad index `{i}`"))?;
                if i == 0 {
                    return Err("sparse indices are 1-based".into());
                }
                let v: f32 = v.parse().map_err(|_| format!("bad value `{v}`"))?;
                pairs.push((i - 1, v));
            }
            pairs.sort_unstable_by_key(|&(j, _)| j);
            if pairs.windows(2).any(|w| w[0].0 == w[1].0) {
                return Err("duplicate sparse index".into());
            }
            if pairs.is_empty() {
                return Err("empty feature row".into());
            }
            rows.push(PredictRow::Sparse(pairs));
        } else {
            let vals: Result<Vec<f32>, String> = row
                .split(',')
                .map(|t| {
                    let t = t.trim();
                    t.parse::<f32>().map_err(|_| format!("bad float `{t}`"))
                })
                .collect();
            let vals = vals?;
            if vals.is_empty() {
                return Err("empty feature row".into());
            }
            rows.push(PredictRow::Dense(vals));
        }
    }
    Ok(rows)
}

/// `ok v1;v2;...` for predict responses.
pub fn ok_values(vals: &[f32]) -> String {
    let body: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
    format!("ok {}", body.join(";"))
}

pub fn ok_msg(msg: &str) -> String {
    format!("ok {msg}")
}

pub fn err_msg(code: &str, msg: &str) -> String {
    format!("err {code} {msg}")
}

/// Backpressure rejection — the client should wait and retry.
pub fn err_busy(retry_after_ms: u64) -> String {
    format!("err busy retry_after_ms={retry_after_ms}")
}

/// Client-side classification of a response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok(String),
    Busy { retry_after_ms: u64 },
    Err { code: String, msg: String },
}

pub fn parse_response(line: &str) -> Response {
    let line = line.trim();
    if let Some(body) = line.strip_prefix("ok") {
        return Response::Ok(body.trim_start().to_string());
    }
    let body = line.strip_prefix("err").map(str::trim_start).unwrap_or(line);
    let (code, msg) = body.split_once(char::is_whitespace).unwrap_or((body, ""));
    if code == "busy" {
        let ms = msg
            .trim()
            .strip_prefix("retry_after_ms=")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        return Response::Busy { retry_after_ms: ms };
    }
    Response::Err { code: code.to_string(), msg: msg.trim().to_string() }
}

/// Parse the `v1;v2;...` payload of an `ok` predict response.
pub fn parse_values(body: &str) -> Result<Vec<f32>, String> {
    body.split(';')
        .map(|t| {
            let t = t.trim();
            t.parse::<f32>().map_err(|_| format!("bad value `{t}`"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_row_predict() {
        let r = parse_request("predict banana 0.5,-1.25").unwrap();
        assert_eq!(
            r,
            Request::Predict {
                model: "banana".into(),
                rows: vec![PredictRow::Dense(vec![0.5, -1.25])]
            }
        );
    }

    #[test]
    fn parses_multi_row_predict() {
        let r = parse_request("predict m 1,2;3,4;5,6").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        assert_eq!(
            rows,
            vec![
                PredictRow::Dense(vec![1.0, 2.0]),
                PredictRow::Dense(vec![3.0, 4.0]),
                PredictRow::Dense(vec![5.0, 6.0])
            ]
        );
    }

    #[test]
    fn parses_sparse_rows_and_densifies() {
        let r = parse_request("predict m 3:0.5,1:2;7:1").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        // indices sorted, 0-based
        assert_eq!(rows[0], PredictRow::Sparse(vec![(0, 2.0), (2, 0.5)]));
        assert_eq!(rows[1].min_dim(), 7);
        assert_eq!(rows[0].clone().densify(4).unwrap(), vec![2.0, 0.0, 0.5, 0.0]);
        // index past the model dim is a row error, not a panic
        assert!(rows[1].clone().densify(4).is_err());
        // dense and sparse rows may mix across (not within) a request
        let r = parse_request("predict m 1,2;2:5").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn rejects_bad_sparse_rows() {
        assert!(parse_request("predict m 0:1").is_err()); // 1-based
        assert!(parse_request("predict m 2:1,2:3").is_err()); // duplicate
        assert!(parse_request("predict m 2:1,5").is_err()); // mixed row
        assert!(parse_request("predict m x:1").is_err());
    }

    #[test]
    fn parses_control_commands() {
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("ping").unwrap(), Request::Ping);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(
            parse_request("load m /tmp/m.sol").unwrap(),
            Request::Load { name: "m".into(), path: "/tmp/m.sol".into() }
        );
        assert_eq!(parse_request("unload m").unwrap(), Request::Unload { name: "m".into() });
        assert_eq!(parse_request("shards m").unwrap(), Request::Shards { name: "m".into() });
        assert_eq!(parse_request("metrics").unwrap(), Request::Metrics { json: false });
        assert_eq!(parse_request("metrics json").unwrap(), Request::Metrics { json: true });
        assert!(parse_request("metrics xml").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("").is_err());
        assert!(parse_request("predict m").is_err());
        assert!(parse_request("predict m 1,x").is_err());
        assert!(parse_request("load just-a-name").is_err());
        assert!(parse_request("unload").is_err());
        assert!(parse_request("shards").is_err());
        assert!(parse_request("shards a b").is_err());
        assert!(parse_request("frobnicate 1").is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = ok_values(&[1.0, -2.5]);
        let Response::Ok(body) = parse_response(&line) else { panic!() };
        assert_eq!(parse_values(&body).unwrap(), vec![1.0, -2.5]);

        assert_eq!(parse_response(&err_busy(7)), Response::Busy { retry_after_ms: 7 });
        assert_eq!(
            parse_response(&err_msg("unknown-model", "no `m`")),
            Response::Err { code: "unknown-model".into(), msg: "no `m`".into() }
        );
    }
}
