//! The wire protocol of `liquidsvm serve` — line-delimited text over
//! TCP, hand-rolled like the CLI's argument parsing (no serde/json in
//! this image's offline registry).
//!
//! Requests, one per line:
//!
//! ```text
//! predict <model> <f1,f2,...>[;<f1,f2,...>...]   # one or more rows
//! load <name> <path>          # path: a .sol file or a .sol.d bundle
//! unload <name>
//! stats                       # server-wide counters incl. shard cache
//! shards <name>               # per-shard residency/hits of a bundle
//! ping
//! quit
//! ```
//!
//! Responses, one line per request, in request order:
//!
//! ```text
//! ok <v1>[;<v2>...]          # predict
//! ok <message>               # load/unload/stats/shards/ping
//! err <code> <message>       # e.g. `err busy retry_after_ms=4`
//! ```
//!
//! Error codes: `bad-request` (parse failure), `unknown-model`,
//! `load-failed`, `dim-mismatch`, `predict-failed`, `not-sharded`
//! (`shards` on a monolithic model), `busy` (backpressure — wait
//! `retry_after_ms` and retry), `internal`.
//!
//! Clients may pipeline: the server preserves ordering, so a batch of
//! requests can be written back-to-back and the responses read in
//! sequence — that is exactly what lets concurrent rows coalesce into
//! one fused predict call.

/// Longest accepted request line (guards the server against unbounded
/// buffering from a misbehaving client).
pub const MAX_LINE: usize = 1 << 20;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Predict { model: String, rows: Vec<Vec<f32>> },
    Load { name: String, path: String },
    Unload { name: String },
    Stats,
    /// per-shard residency and hit counts of a sharded bundle
    Shards { name: String },
    Ping,
    Quit,
}

/// Parse one request line.  Errors are human-readable fragments that
/// the server echoes back as `err bad-request <msg>`.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim();
    if line.is_empty() {
        return Err("empty request".into());
    }
    if line.len() > MAX_LINE {
        return Err("request line too long".into());
    }
    let (cmd, rest) = match line.split_once(char::is_whitespace) {
        Some((c, r)) => (c, r.trim()),
        None => (line, ""),
    };
    match cmd {
        "predict" => {
            let (model, data) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "predict needs `<model> <rows>`".to_string())?;
            let rows = parse_rows(data.trim())?;
            Ok(Request::Predict { model: model.to_string(), rows })
        }
        "load" => {
            let (name, path) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "load needs `<name> <path>`".to_string())?;
            Ok(Request::Load { name: name.to_string(), path: path.trim().to_string() })
        }
        "unload" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("unload needs `<name>`".into());
            }
            Ok(Request::Unload { name: rest.to_string() })
        }
        "shards" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("shards needs `<name>`".into());
            }
            Ok(Request::Shards { name: rest.to_string() })
        }
        "stats" => Ok(Request::Stats),
        "ping" => Ok(Request::Ping),
        "quit" => Ok(Request::Quit),
        other => Err(format!("unknown command `{other}`")),
    }
}

/// Parse `;`-separated rows of `,`-separated floats.
pub fn parse_rows(text: &str) -> Result<Vec<Vec<f32>>, String> {
    if text.is_empty() {
        return Err("no feature rows".into());
    }
    let mut rows = Vec::new();
    for row in text.split(';') {
        let vals: Result<Vec<f32>, String> = row
            .split(',')
            .map(|t| {
                let t = t.trim();
                t.parse::<f32>().map_err(|_| format!("bad float `{t}`"))
            })
            .collect();
        let vals = vals?;
        if vals.is_empty() {
            return Err("empty feature row".into());
        }
        rows.push(vals);
    }
    Ok(rows)
}

/// `ok v1;v2;...` for predict responses.
pub fn ok_values(vals: &[f32]) -> String {
    let body: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
    format!("ok {}", body.join(";"))
}

pub fn ok_msg(msg: &str) -> String {
    format!("ok {msg}")
}

pub fn err_msg(code: &str, msg: &str) -> String {
    format!("err {code} {msg}")
}

/// Backpressure rejection — the client should wait and retry.
pub fn err_busy(retry_after_ms: u64) -> String {
    format!("err busy retry_after_ms={retry_after_ms}")
}

/// Client-side classification of a response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok(String),
    Busy { retry_after_ms: u64 },
    Err { code: String, msg: String },
}

pub fn parse_response(line: &str) -> Response {
    let line = line.trim();
    if let Some(body) = line.strip_prefix("ok") {
        return Response::Ok(body.trim_start().to_string());
    }
    let body = line.strip_prefix("err").map(str::trim_start).unwrap_or(line);
    let (code, msg) = body.split_once(char::is_whitespace).unwrap_or((body, ""));
    if code == "busy" {
        let ms = msg
            .trim()
            .strip_prefix("retry_after_ms=")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        return Response::Busy { retry_after_ms: ms };
    }
    Response::Err { code: code.to_string(), msg: msg.trim().to_string() }
}

/// Parse the `v1;v2;...` payload of an `ok` predict response.
pub fn parse_values(body: &str) -> Result<Vec<f32>, String> {
    body.split(';')
        .map(|t| {
            let t = t.trim();
            t.parse::<f32>().map_err(|_| format!("bad value `{t}`"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_row_predict() {
        let r = parse_request("predict banana 0.5,-1.25").unwrap();
        assert_eq!(
            r,
            Request::Predict { model: "banana".into(), rows: vec![vec![0.5, -1.25]] }
        );
    }

    #[test]
    fn parses_multi_row_predict() {
        let r = parse_request("predict m 1,2;3,4;5,6").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        assert_eq!(rows, vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
    }

    #[test]
    fn parses_control_commands() {
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("ping").unwrap(), Request::Ping);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(
            parse_request("load m /tmp/m.sol").unwrap(),
            Request::Load { name: "m".into(), path: "/tmp/m.sol".into() }
        );
        assert_eq!(parse_request("unload m").unwrap(), Request::Unload { name: "m".into() });
        assert_eq!(parse_request("shards m").unwrap(), Request::Shards { name: "m".into() });
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("").is_err());
        assert!(parse_request("predict m").is_err());
        assert!(parse_request("predict m 1,x").is_err());
        assert!(parse_request("load just-a-name").is_err());
        assert!(parse_request("unload").is_err());
        assert!(parse_request("shards").is_err());
        assert!(parse_request("shards a b").is_err());
        assert!(parse_request("frobnicate 1").is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = ok_values(&[1.0, -2.5]);
        let Response::Ok(body) = parse_response(&line) else { panic!() };
        assert_eq!(parse_values(&body).unwrap(), vec![1.0, -2.5]);

        assert_eq!(parse_response(&err_busy(7)), Response::Busy { retry_after_ms: 7 });
        assert_eq!(
            parse_response(&err_msg("unknown-model", "no `m`")),
            Response::Err { code: "unknown-model".into(), msg: "no `m`".into() }
        );
    }
}
