//! The wire protocol of `liquidsvm serve` — line-delimited text over
//! TCP, hand-rolled like the CLI's argument parsing (no serde/json in
//! this image's offline registry).
//!
//! Requests, one per line:
//!
//! ```text
//! predict <model> <f1,f2,...>[;<f1,f2,...>...]   # one or more rows
//!                             # rows may be sparse: `idx:val` pairs
//!                             # (1-based, libsvm-style), e.g.
//!                             # `predict m 3:0.5,17:1.2;1:2`
//! load <name> <path>          # path: a .sol file or a .sol.d bundle
//! unload <name>
//! stats                       # server-wide counters incl. shard cache
//! shards <name>               # per-shard residency/hits of a bundle
//! metrics                     # Prometheus exposition of the registry
//! metrics json                # same snapshot as one JSON object
//! ping
//! quit
//! ```
//!
//! Responses, one line per request, in request order:
//!
//! ```text
//! ok <v1>[;<v2>...]          # predict
//! ok <message>               # load/unload/stats/shards/ping
//! ok metrics lines=<N>       # then exactly N payload lines follow
//! err <code> <message>       # e.g. `err busy retry_after_ms=4`
//! ```
//!
//! `metrics` is the only multi-line response: its header announces the
//! payload line count so clients reading in lockstep know exactly how
//! many lines to consume; `metrics json` stays single-line (`ok
//! <json>`).  See DESIGN.md §Observability for the snapshot schema.
//!
//! Error codes: `bad-request` (parse failure), `unknown-model`,
//! `load-failed`, `dim-mismatch`, `predict-failed`, `not-sharded`
//! (`shards` on a monolithic model), `busy` (backpressure — wait
//! `retry_after_ms` and retry), `internal`.
//!
//! Clients may pipeline: the server preserves ordering, so a batch of
//! requests can be written back-to-back and the responses read in
//! sequence — that is exactly what lets concurrent rows coalesce into
//! one fused predict call.

/// Longest accepted request line (guards the server against unbounded
/// buffering from a misbehaving client).
pub const MAX_LINE: usize = 1 << 20;

// ------------------------------------------------- binary train framing
//
// The distributed training plane (`liquidsvm worker` + the wire
// coordinator, see DESIGN.md §Distributed-wire) extends this protocol
// with a compact length-prefixed binary framing for bulk payloads:
// f32 row blocks travel coordinator → worker, solved shard bytes come
// back.  The text protocol above stays the handshake/debugging
// surface — a session opens with one text `train-hello` line that
// negotiates text or binary mode, and only then switches to frames.
//
// Frame layout (all integers little-endian):
//
// ```text
// +-----+-------------+------------------+
// | tag |   len: u32  |  payload (len B) |
// | u8  |             |                  |
// +-----+-------------+------------------+
// ```
//
// `len` is bounded by [`FRAME_MAX`]; an oversized prefix is rejected
// *before* any allocation, so a corrupt or adversarial header costs a
// 5-byte read, not 4 GiB of memory.

/// Largest accepted frame payload (256 MiB — a full coarse cell of
/// ~20k × 3k f32 features fits with headroom).
pub const FRAME_MAX: usize = 1 << 28;

/// Frame type tags of the binary train protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameTag {
    /// coordinator → worker: session config (UTF-8 text payload)
    Cfg = 1,
    /// coordinator → worker: one cell's training job (header + f32 blocks)
    Job = 2,
    /// worker → coordinator: one solved shard (cell, train_us, shard bytes)
    Shard = 3,
    /// coordinator → worker: clean end of session (empty payload)
    Done = 4,
    /// either direction: deterministic failure (UTF-8 message) — the
    /// receiver must NOT re-dispatch, the same job would fail again
    Err = 5,
}

impl FrameTag {
    pub fn from_u8(b: u8) -> Option<FrameTag> {
        Some(match b {
            1 => FrameTag::Cfg,
            2 => FrameTag::Job,
            3 => FrameTag::Shard,
            4 => FrameTag::Done,
            5 => FrameTag::Err,
            _ => return None,
        })
    }
}

/// Serialized size of a frame carrying `payload_len` bytes.
pub fn frame_overhead() -> usize {
    5
}

/// Encode one frame into a buffer (tests; in-memory pipes).  Errors
/// when the payload exceeds [`FRAME_MAX`].
pub fn encode_frame(tag: FrameTag, payload: &[u8]) -> Result<Vec<u8>, String> {
    if payload.len() > FRAME_MAX {
        return Err(format!("frame payload {} exceeds FRAME_MAX {FRAME_MAX}", payload.len()));
    }
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(tag as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Write one frame.  Same bounds as [`encode_frame`].
pub fn write_frame(
    w: &mut impl std::io::Write,
    tag: FrameTag,
    payload: &[u8],
) -> std::io::Result<()> {
    if payload.len() > FRAME_MAX {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame payload {} exceeds FRAME_MAX {FRAME_MAX}", payload.len()),
        ));
    }
    let mut head = [0u8; 5];
    head[0] = tag as u8;
    head[1..5].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame.  A truncated stream surfaces as `UnexpectedEof`
/// (from `read_exact`); an unknown tag or an oversized length prefix
/// is `InvalidData` — and the oversized case errors on the 5-byte
/// header alone, before any payload allocation.
pub fn read_frame(r: &mut impl std::io::Read) -> std::io::Result<(FrameTag, Vec<u8>)> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let tag = FrameTag::from_u8(head[0]).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("unknown frame tag {}", head[0]))
    })?;
    let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]) as usize;
    if len > FRAME_MAX {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds FRAME_MAX {FRAME_MAX}"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((tag, payload))
}

/// f32 slice → little-endian bytes (the bulk row-block encoding).
pub fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Little-endian bytes → f32 slice; bit-exact round-trip of
/// [`f32s_to_bytes`] (NaN payloads included — the wire never goes
/// through text, so worker-side floats are the coordinator's floats).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>, String> {
    if bytes.len() % 4 != 0 {
        return Err(format!("f32 block length {} not a multiple of 4", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Bulk transfer mode negotiated by the `train-hello` handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// debugging sessions: only text `ping`/`quit` after the handshake
    Text,
    /// real sessions: binary frames after the handshake
    Binary,
}

const HELLO_PREFIX: &str = "train-hello v1";

/// The client's opening line: `train-hello v1 <text|binary>`.
pub fn hello_line(mode: WireMode) -> String {
    match mode {
        WireMode::Text => format!("{HELLO_PREFIX} text"),
        WireMode::Binary => format!("{HELLO_PREFIX} binary"),
    }
}

/// Worker's acknowledgement: `ok train-hello v1 <mode>` — echoes the
/// accepted mode so the client knows what the stream speaks next.
pub fn hello_ack(mode: WireMode) -> String {
    ok_msg(&hello_line(mode))
}

/// Parse a `train-hello` line (strict: one version, two modes).
pub fn parse_hello(line: &str) -> Result<WireMode, String> {
    let rest = line
        .trim()
        .strip_prefix(HELLO_PREFIX)
        .ok_or_else(|| format!("expected `{HELLO_PREFIX} <mode>`, got `{line}`"))?;
    match rest.trim() {
        "binary" => Ok(WireMode::Binary),
        "text" => Ok(WireMode::Text),
        other => Err(format!("unknown wire mode `{other}` (text|binary)")),
    }
}

/// Parse the worker's `ok train-hello v1 <mode>` acknowledgement.
pub fn parse_hello_ack(line: &str) -> Result<WireMode, String> {
    match parse_response(line) {
        Response::Ok(body) => parse_hello(&body),
        Response::Busy { .. } => Err("worker busy".into()),
        Response::Err { code, msg } => Err(format!("handshake rejected: {code} {msg}")),
    }
}

// ---------------------------------------------------------------------------
// Binary *serve* protocol.
//
// Same `tag u8 | len u32 LE | payload` grammar as the train wire
// above — one frame reader, one set of bounds — but with its own tag
// space (0x10..) so a serve stream can never be confused with a train
// stream, negotiated per connection by a `serve-hello` line.  A client
// that never sends the hello speaks the text protocol unchanged; a
// hello requesting anything other than exactly `binary` falls back to
// text (forward compatibility: an old server answering a new client
// degrades to text instead of hanging).

const SERVE_HELLO_PREFIX: &str = "serve-hello v1";

/// The client's opening line: `serve-hello v1 <text|binary>`.
pub fn serve_hello_line(mode: WireMode) -> String {
    match mode {
        WireMode::Text => format!("{SERVE_HELLO_PREFIX} text"),
        WireMode::Binary => format!("{SERVE_HELLO_PREFIX} binary"),
    }
}

/// Server acknowledgement: `ok serve-hello v1 <mode>` — echoes the
/// *accepted* mode, which is what the stream speaks from then on.
pub fn serve_hello_ack(mode: WireMode) -> String {
    ok_msg(&serve_hello_line(mode))
}

/// Classify a first line from a serve connection.
///
/// - `None`: not a serve-hello at all — treat the line as a plain text
///   request (full backward compatibility with pre-hello clients).
/// - `Some(Binary)`: an exact `serve-hello v1 binary` request.
/// - `Some(Text)`: any other serve-hello — unknown modes and future
///   extensions fall back to text rather than erroring out.
pub fn negotiate_serve_hello(line: &str) -> Option<WireMode> {
    let rest = line.trim().strip_prefix(SERVE_HELLO_PREFIX)?;
    if !rest.is_empty() && !rest.starts_with(' ') {
        return None; // e.g. "serve-hello v12..." — not our version token
    }
    match rest.trim() {
        "binary" => Some(WireMode::Binary),
        _ => Some(WireMode::Text),
    }
}

/// Parse the server's `ok serve-hello v1 <mode>` acknowledgement
/// (client side).
pub fn parse_serve_hello_ack(line: &str) -> Result<WireMode, String> {
    match parse_response(line) {
        Response::Ok(body) => match negotiate_serve_hello(&body) {
            Some(mode) => Ok(mode),
            None => Err(format!("malformed serve-hello ack `{line}`")),
        },
        Response::Busy { .. } => Err("server busy".into()),
        Response::Err { code, msg } => Err(format!("handshake rejected: {code} {msg}")),
    }
}

/// Frame type tags of the binary serve protocol.  Deliberately
/// disjoint from [`FrameTag`] (1–5): a frame from the wrong plane is
/// an immediate `InvalidData`, not a misparse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ServeFrameTag {
    /// client → server: one predict request
    /// (`name_len u8 | name | dim u32 LE | n_rows u32 LE | n_rows*dim f32 LE`)
    Predict = 0x10,
    /// server → client: raw-LE f32 decision block, one value per row,
    /// request order preserved
    Decisions = 0x11,
    /// server → client: request-scoped error
    /// (`code_len u8 | code | msg`, both UTF-8); the connection stays up
    Err = 0x12,
    /// client → server: liveness probe (empty payload)
    Ping = 0x13,
    /// server → client: liveness answer (empty payload)
    Pong = 0x14,
    /// client → server: clean end of session (empty payload)
    Quit = 0x15,
    /// server → client: goodbye, connection closes after this frame
    Bye = 0x16,
}

impl ServeFrameTag {
    pub fn from_u8(b: u8) -> Option<ServeFrameTag> {
        Some(match b {
            0x10 => ServeFrameTag::Predict,
            0x11 => ServeFrameTag::Decisions,
            0x12 => ServeFrameTag::Err,
            0x13 => ServeFrameTag::Ping,
            0x14 => ServeFrameTag::Pong,
            0x15 => ServeFrameTag::Quit,
            0x16 => ServeFrameTag::Bye,
            _ => return None,
        })
    }
}

/// Nonblocking header peek over a partial receive buffer.
///
/// - `None`: fewer than 5 bytes buffered — read more.
/// - `Some(Err(_))`: unknown tag or oversized length prefix.  Decided
///   from the 5-byte header alone, **before any allocation** — the
///   event loop kills the connection without ever buffering the
///   claimed payload.
/// - `Some(Ok((tag, len)))`: a well-formed header; the frame is
///   complete once `5 + len` bytes are buffered.
pub fn peek_serve_frame(buf: &[u8]) -> Option<Result<(ServeFrameTag, usize), String>> {
    if buf.len() < 5 {
        return None;
    }
    let tag = match ServeFrameTag::from_u8(buf[0]) {
        Some(t) => t,
        None => return Some(Err(format!("unknown serve frame tag {}", buf[0]))),
    };
    let len = u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]) as usize;
    if len > FRAME_MAX {
        return Some(Err(format!(
            "frame length {len} exceeds FRAME_MAX {FRAME_MAX}"
        )));
    }
    Some(Ok((tag, len)))
}

/// Encode one serve frame.  Same bounds as [`encode_frame`].
pub fn encode_serve_frame(tag: ServeFrameTag, payload: &[u8]) -> Result<Vec<u8>, String> {
    if payload.len() > FRAME_MAX {
        return Err(format!(
            "frame payload {} exceeds FRAME_MAX {FRAME_MAX}",
            payload.len()
        ));
    }
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(tag as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Blocking serve-frame read (client side; the server never blocks on
/// a frame — it uses [`peek_serve_frame`] over its receive buffer).
/// Error taxonomy matches [`read_frame`]: truncation is
/// `UnexpectedEof`, unknown tag / oversized prefix is `InvalidData`
/// decided before any allocation.
pub fn read_serve_frame(r: &mut impl std::io::Read) -> std::io::Result<(ServeFrameTag, Vec<u8>)> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let (tag, len) = match peek_serve_frame(&head) {
        Some(Ok(hdr)) => hdr,
        Some(Err(e)) => return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e)),
        None => unreachable!("peek over a full 5-byte header"),
    };
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((tag, payload))
}

/// Decoded body of a [`ServeFrameTag::Predict`] frame: `rows × dim`
/// features, row-major, exactly as sent (bit-exact — no text
/// round-trip anywhere on the binary path).
#[derive(Clone, Debug, PartialEq)]
pub struct PredictFrame {
    pub model: String,
    pub dim: usize,
    pub rows: usize,
    /// `rows * dim` values, row-major.
    pub data: Vec<f32>,
}

/// Encode a Predict payload.  `data.len()` must equal `rows * dim`.
pub fn encode_predict_payload(
    model: &str,
    dim: usize,
    rows: usize,
    data: &[f32],
) -> Result<Vec<u8>, String> {
    if model.len() > u8::MAX as usize {
        return Err(format!("model name {} bytes exceeds 255", model.len()));
    }
    if rows > u32::MAX as usize || dim > u32::MAX as usize {
        return Err(format!("predict shape {rows}x{dim} exceeds u32"));
    }
    let expect = rows
        .checked_mul(dim)
        .ok_or_else(|| format!("predict shape {rows}x{dim} overflows"))?;
    if data.len() != expect {
        return Err(format!(
            "predict data {} values, shape says {rows}x{dim}={expect}",
            data.len()
        ));
    }
    let mut out = Vec::with_capacity(1 + model.len() + 8 + data.len() * 4);
    out.push(model.len() as u8);
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&f32s_to_bytes(data));
    Ok(out)
}

/// Decode a Predict payload.  Every length is cross-checked: the
/// feature block must be *exactly* `rows * dim * 4` bytes (computed
/// with overflow checks), so a lying header can neither over-read nor
/// leave trailing garbage unaccounted for.
pub fn decode_predict_payload(payload: &[u8]) -> Result<PredictFrame, String> {
    if payload.is_empty() {
        return Err("empty predict payload".into());
    }
    let name_len = payload[0] as usize;
    let head = 1 + name_len + 8;
    if payload.len() < head {
        return Err(format!(
            "predict payload {} bytes, header needs {head}",
            payload.len()
        ));
    }
    let model = std::str::from_utf8(&payload[1..1 + name_len])
        .map_err(|_| "model name is not UTF-8".to_string())?
        .to_string();
    let at = 1 + name_len;
    let dim = u32::from_le_bytes([payload[at], payload[at + 1], payload[at + 2], payload[at + 3]])
        as usize;
    let rows = u32::from_le_bytes([
        payload[at + 4],
        payload[at + 5],
        payload[at + 6],
        payload[at + 7],
    ]) as usize;
    let values = rows
        .checked_mul(dim)
        .ok_or_else(|| format!("predict shape {rows}x{dim} overflows"))?;
    let body_bytes = values
        .checked_mul(4)
        .ok_or_else(|| format!("predict shape {rows}x{dim} overflows"))?;
    if payload.len() - head != body_bytes {
        return Err(format!(
            "predict body {} bytes, shape {rows}x{dim} needs {body_bytes}",
            payload.len() - head
        ));
    }
    let data = bytes_to_f32s(&payload[head..])?;
    Ok(PredictFrame {
        model,
        dim,
        rows,
        data,
    })
}

/// Encode an Err payload (`code_len u8 | code | msg`).  Codes match
/// the text protocol (`busy`, `unknown-model`, `dim-mismatch`, ...).
pub fn encode_err_payload(code: &str, msg: &str) -> Vec<u8> {
    let code = &code.as_bytes()[..code.len().min(u8::MAX as usize)];
    let mut out = Vec::with_capacity(1 + code.len() + msg.len());
    out.push(code.len() as u8);
    out.extend_from_slice(code);
    out.extend_from_slice(msg.as_bytes());
    out
}

/// Decode an Err payload back into `(code, msg)`.
pub fn decode_err_payload(payload: &[u8]) -> Result<(String, String), String> {
    if payload.is_empty() {
        return Err("empty err payload".into());
    }
    let code_len = payload[0] as usize;
    if payload.len() < 1 + code_len {
        return Err(format!(
            "err payload {} bytes, code_len says {code_len}",
            payload.len()
        ));
    }
    let code = std::str::from_utf8(&payload[1..1 + code_len])
        .map_err(|_| "err code is not UTF-8".to_string())?
        .to_string();
    let msg = String::from_utf8_lossy(&payload[1 + code_len..]).into_owned();
    Ok((code, msg))
}

/// One prediction row off the wire: dense (`v1,v2,...`) or sparse
/// (`idx:val` pairs, 1-based like LIBSVM).  Sparse rows densify at the
/// server boundary against the target model's dimension — the serving
/// expansion is dense, so this is the documented densification
/// boundary of the serve path (DESIGN.md §Data-plane).
#[derive(Clone, Debug, PartialEq)]
pub enum PredictRow {
    Dense(Vec<f32>),
    /// 0-based (index, value) pairs, strictly increasing
    Sparse(Vec<(u32, f32)>),
}

impl PredictRow {
    /// The row's minimum viable dimension: dense length, or highest
    /// sparse index + 1.
    pub fn min_dim(&self) -> usize {
        match self {
            PredictRow::Dense(v) => v.len(),
            PredictRow::Sparse(p) => p.last().map_or(0, |&(j, _)| j as usize + 1),
        }
    }

    /// Densify to exactly `dim` features.  Errors when the row cannot
    /// fit (dense length mismatch is left to the caller's dim check;
    /// sparse indices past `dim` are rejected here).
    pub fn densify(self, dim: usize) -> Result<Vec<f32>, String> {
        match self {
            PredictRow::Dense(v) => Ok(v),
            PredictRow::Sparse(pairs) => {
                let mut out = vec![0.0f32; dim];
                for (j, v) in pairs {
                    if j as usize >= dim {
                        return Err(format!("sparse index {} exceeds model dim {dim}", j + 1));
                    }
                    out[j as usize] = v;
                }
                Ok(out)
            }
        }
    }
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Predict { model: String, rows: Vec<PredictRow> },
    Load { name: String, path: String },
    Unload { name: String },
    Stats,
    /// per-shard residency and hit counts of a sharded bundle
    Shards { name: String },
    /// metrics-registry snapshot: Prometheus text, or JSON with `json`
    Metrics { json: bool },
    Ping,
    Quit,
}

/// Parse one request line.  Errors are human-readable fragments that
/// the server echoes back as `err bad-request <msg>`.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim();
    if line.is_empty() {
        return Err("empty request".into());
    }
    if line.len() > MAX_LINE {
        return Err("request line too long".into());
    }
    let (cmd, rest) = match line.split_once(char::is_whitespace) {
        Some((c, r)) => (c, r.trim()),
        None => (line, ""),
    };
    match cmd {
        "predict" => {
            let (model, data) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "predict needs `<model> <rows>`".to_string())?;
            let rows = parse_rows(data.trim())?;
            Ok(Request::Predict { model: model.to_string(), rows })
        }
        "load" => {
            let (name, path) = rest
                .split_once(char::is_whitespace)
                .ok_or_else(|| "load needs `<name> <path>`".to_string())?;
            Ok(Request::Load { name: name.to_string(), path: path.trim().to_string() })
        }
        "unload" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("unload needs `<name>`".into());
            }
            Ok(Request::Unload { name: rest.to_string() })
        }
        "shards" => {
            if rest.is_empty() || rest.contains(char::is_whitespace) {
                return Err("shards needs `<name>`".into());
            }
            Ok(Request::Shards { name: rest.to_string() })
        }
        "stats" => Ok(Request::Stats),
        "metrics" => match rest {
            "" => Ok(Request::Metrics { json: false }),
            "json" => Ok(Request::Metrics { json: true }),
            other => Err(format!("metrics takes no argument or `json`, got `{other}`")),
        },
        "ping" => Ok(Request::Ping),
        "quit" => Ok(Request::Quit),
        other => Err(format!("unknown command `{other}`")),
    }
}

/// Parse `;`-separated rows of `,`-separated values.  A row whose
/// first token contains `:` is sparse (`idx:val` pairs, 1-based);
/// mixed tokens within one row are rejected, as are duplicate or
/// zero indices — the same strictness as the LIBSVM file reader.
pub fn parse_rows(text: &str) -> Result<Vec<PredictRow>, String> {
    if text.is_empty() {
        return Err("no feature rows".into());
    }
    let mut rows = Vec::new();
    for row in text.split(';') {
        let sparse = row.split(',').next().is_some_and(|t| t.contains(':'));
        if sparse {
            let mut pairs: Vec<(u32, f32)> = Vec::new();
            for t in row.split(',') {
                let t = t.trim();
                let (i, v) = t
                    .split_once(':')
                    .ok_or_else(|| format!("mixed sparse/dense row at `{t}`"))?;
                let i: u32 = i.parse().map_err(|_| format!("bad index `{i}`"))?;
                if i == 0 {
                    return Err("sparse indices are 1-based".into());
                }
                let v: f32 = v.parse().map_err(|_| format!("bad value `{v}`"))?;
                pairs.push((i - 1, v));
            }
            pairs.sort_unstable_by_key(|&(j, _)| j);
            if pairs.windows(2).any(|w| w[0].0 == w[1].0) {
                return Err("duplicate sparse index".into());
            }
            if pairs.is_empty() {
                return Err("empty feature row".into());
            }
            rows.push(PredictRow::Sparse(pairs));
        } else {
            let vals: Result<Vec<f32>, String> = row
                .split(',')
                .map(|t| {
                    let t = t.trim();
                    t.parse::<f32>().map_err(|_| format!("bad float `{t}`"))
                })
                .collect();
            let vals = vals?;
            if vals.is_empty() {
                return Err("empty feature row".into());
            }
            rows.push(PredictRow::Dense(vals));
        }
    }
    Ok(rows)
}

/// `ok v1;v2;...` for predict responses.
pub fn ok_values(vals: &[f32]) -> String {
    let body: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
    format!("ok {}", body.join(";"))
}

pub fn ok_msg(msg: &str) -> String {
    format!("ok {msg}")
}

pub fn err_msg(code: &str, msg: &str) -> String {
    format!("err {code} {msg}")
}

/// Backpressure rejection — the client should wait and retry.
pub fn err_busy(retry_after_ms: u64) -> String {
    format!("err busy retry_after_ms={retry_after_ms}")
}

/// Client-side classification of a response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok(String),
    Busy { retry_after_ms: u64 },
    Err { code: String, msg: String },
}

pub fn parse_response(line: &str) -> Response {
    let line = line.trim();
    if let Some(body) = line.strip_prefix("ok") {
        return Response::Ok(body.trim_start().to_string());
    }
    let body = line.strip_prefix("err").map(str::trim_start).unwrap_or(line);
    let (code, msg) = body.split_once(char::is_whitespace).unwrap_or((body, ""));
    if code == "busy" {
        let ms = msg
            .trim()
            .strip_prefix("retry_after_ms=")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        return Response::Busy { retry_after_ms: ms };
    }
    Response::Err { code: code.to_string(), msg: msg.trim().to_string() }
}

/// Parse the `v1;v2;...` payload of an `ok` predict response.
pub fn parse_values(body: &str) -> Result<Vec<f32>, String> {
    body.split(';')
        .map(|t| {
            let t = t.trim();
            t.parse::<f32>().map_err(|_| format!("bad value `{t}`"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_row_predict() {
        let r = parse_request("predict banana 0.5,-1.25").unwrap();
        assert_eq!(
            r,
            Request::Predict {
                model: "banana".into(),
                rows: vec![PredictRow::Dense(vec![0.5, -1.25])]
            }
        );
    }

    #[test]
    fn parses_multi_row_predict() {
        let r = parse_request("predict m 1,2;3,4;5,6").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        assert_eq!(
            rows,
            vec![
                PredictRow::Dense(vec![1.0, 2.0]),
                PredictRow::Dense(vec![3.0, 4.0]),
                PredictRow::Dense(vec![5.0, 6.0])
            ]
        );
    }

    #[test]
    fn parses_sparse_rows_and_densifies() {
        let r = parse_request("predict m 3:0.5,1:2;7:1").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        // indices sorted, 0-based
        assert_eq!(rows[0], PredictRow::Sparse(vec![(0, 2.0), (2, 0.5)]));
        assert_eq!(rows[1].min_dim(), 7);
        assert_eq!(rows[0].clone().densify(4).unwrap(), vec![2.0, 0.0, 0.5, 0.0]);
        // index past the model dim is a row error, not a panic
        assert!(rows[1].clone().densify(4).is_err());
        // dense and sparse rows may mix across (not within) a request
        let r = parse_request("predict m 1,2;2:5").unwrap();
        let Request::Predict { rows, .. } = r else { panic!() };
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn rejects_bad_sparse_rows() {
        assert!(parse_request("predict m 0:1").is_err()); // 1-based
        assert!(parse_request("predict m 2:1,2:3").is_err()); // duplicate
        assert!(parse_request("predict m 2:1,5").is_err()); // mixed row
        assert!(parse_request("predict m x:1").is_err());
    }

    #[test]
    fn parses_control_commands() {
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("ping").unwrap(), Request::Ping);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(
            parse_request("load m /tmp/m.sol").unwrap(),
            Request::Load { name: "m".into(), path: "/tmp/m.sol".into() }
        );
        assert_eq!(parse_request("unload m").unwrap(), Request::Unload { name: "m".into() });
        assert_eq!(parse_request("shards m").unwrap(), Request::Shards { name: "m".into() });
        assert_eq!(parse_request("metrics").unwrap(), Request::Metrics { json: false });
        assert_eq!(parse_request("metrics json").unwrap(), Request::Metrics { json: true });
        assert!(parse_request("metrics xml").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("").is_err());
        assert!(parse_request("predict m").is_err());
        assert!(parse_request("predict m 1,x").is_err());
        assert!(parse_request("load just-a-name").is_err());
        assert!(parse_request("unload").is_err());
        assert!(parse_request("shards").is_err());
        assert!(parse_request("shards a b").is_err());
        assert!(parse_request("frobnicate 1").is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = ok_values(&[1.0, -2.5]);
        let Response::Ok(body) = parse_response(&line) else { panic!() };
        assert_eq!(parse_values(&body).unwrap(), vec![1.0, -2.5]);

        assert_eq!(parse_response(&err_busy(7)), Response::Busy { retry_after_ms: 7 });
        assert_eq!(
            parse_response(&err_msg("unknown-model", "no `m`")),
            Response::Err { code: "unknown-model".into(), msg: "no `m`".into() }
        );
    }

    // ------------------------------------------ binary framing (fuzz/property)

    use crate::data::rng::Rng;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_all_tags() {
        for tag in [FrameTag::Cfg, FrameTag::Job, FrameTag::Shard, FrameTag::Done, FrameTag::Err] {
            let payload = b"hello shard".to_vec();
            let mut buf = Vec::new();
            write_frame(&mut buf, tag, &payload).unwrap();
            assert_eq!(buf, encode_frame(tag, &payload).unwrap());
            let (t, p) = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(t, tag);
            assert_eq!(p, payload);
        }
        // empty payload (Done's usual shape)
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameTag::Done, &[]).unwrap();
        assert_eq!(buf.len(), frame_overhead());
        let (t, p) = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!((t, p.len()), (FrameTag::Done, 0));
    }

    #[test]
    fn frame_roundtrip_random_payloads() {
        // property: write_frame ∘ read_frame is identity for arbitrary
        // payload bytes and lengths, including multi-frame streams
        let mut rng = Rng::new(0xf4a3);
        for round in 0..50 {
            let n_frames = 1 + (round % 4);
            let mut buf = Vec::new();
            let mut sent = Vec::new();
            for _ in 0..n_frames {
                let len = rng.below(4096);
                let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
                let tag = FrameTag::from_u8(1 + rng.below(5) as u8).unwrap();
                write_frame(&mut buf, tag, &payload).unwrap();
                sent.push((tag, payload));
            }
            let mut cur = Cursor::new(&buf);
            for (tag, payload) in &sent {
                let (t, p) = read_frame(&mut cur).unwrap();
                assert_eq!((&t, &p), (tag, payload));
            }
            // stream exhausted: next read is a clean EOF, not garbage
            assert_eq!(
                read_frame(&mut cur).unwrap_err().kind(),
                std::io::ErrorKind::UnexpectedEof
            );
        }
    }

    #[test]
    fn truncated_frames_are_unexpected_eof() {
        let full = encode_frame(FrameTag::Job, b"0123456789").unwrap();
        // cut at every possible byte boundary: header-truncated and
        // payload-truncated frames both surface as UnexpectedEof
        for cut in 0..full.len() {
            let err = read_frame(&mut Cursor::new(&full[..cut])).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut at {cut}");
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        // a 5-byte header claiming a u32::MAX payload must be rejected
        // from the header alone with a bounded InvalidData error — no
        // 4 GiB allocation, no read attempt past the header
        let mut head = vec![FrameTag::Shard as u8];
        head.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&head)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("FRAME_MAX"));

        // just past the limit is rejected too; writes enforce the same cap
        let mut head = vec![FrameTag::Cfg as u8];
        head.extend_from_slice(&((FRAME_MAX as u32) + 1).to_le_bytes());
        assert_eq!(
            read_frame(&mut Cursor::new(&head)).unwrap_err().kind(),
            std::io::ErrorKind::InvalidData
        );
        assert!(encode_frame(FrameTag::Cfg, &vec![0u8; FRAME_MAX + 1]).is_err());
    }

    #[test]
    fn unknown_tags_and_garbage_never_panic() {
        // unknown tag byte → InvalidData
        for bad in [0u8, 6, 7, 255] {
            let mut buf = vec![bad];
            buf.extend_from_slice(&0u32.to_le_bytes());
            let err = read_frame(&mut Cursor::new(&buf)).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "tag {bad}");
        }
        // fuzz: arbitrary byte soup either parses or errors — never panics
        let mut rng = Rng::new(0xbeef);
        for _ in 0..200 {
            let len = rng.below(64);
            let soup: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = read_frame(&mut Cursor::new(&soup));
        }
    }

    #[test]
    fn f32_blocks_roundtrip_bit_exact() {
        let vals = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, f32::MAX, f32::NEG_INFINITY, f32::NAN];
        let bytes = f32s_to_bytes(&vals);
        assert_eq!(bytes.len(), vals.len() * 4);
        let back = bytes_to_f32s(&bytes).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits()); // bit-exact, NaN included
        }
        // random floats, any bit pattern
        let mut rng = Rng::new(0x51ab);
        let vals: Vec<f32> = (0..1000).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let back = bytes_to_f32s(&f32s_to_bytes(&vals)).unwrap();
        assert!(vals.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        // misaligned block length is an error, not a silent truncation
        assert!(bytes_to_f32s(&[0, 0, 0]).is_err());
    }

    #[test]
    fn hello_negotiation() {
        assert_eq!(parse_hello(&hello_line(WireMode::Binary)).unwrap(), WireMode::Binary);
        assert_eq!(parse_hello(&hello_line(WireMode::Text)).unwrap(), WireMode::Text);
        assert_eq!(parse_hello("train-hello v1 binary\n").unwrap(), WireMode::Binary);
        assert!(parse_hello("train-hello v1 gzip").is_err());
        assert!(parse_hello("train-hello v2 binary").is_err());
        assert!(parse_hello("predict m 1,2").is_err());

        assert_eq!(parse_hello_ack(&hello_ack(WireMode::Binary)).unwrap(), WireMode::Binary);
        assert_eq!(parse_hello_ack(&hello_ack(WireMode::Text)).unwrap(), WireMode::Text);
        assert!(parse_hello_ack(&err_msg("bad-hello", "nope")).is_err());
        assert!(parse_hello_ack(&err_busy(5)).is_err());
    }

    // -------------------------------------- serve framing (fuzz/property)

    #[test]
    fn serve_frame_roundtrip_all_tags() {
        for tag in [
            ServeFrameTag::Predict,
            ServeFrameTag::Decisions,
            ServeFrameTag::Err,
            ServeFrameTag::Ping,
            ServeFrameTag::Pong,
            ServeFrameTag::Quit,
            ServeFrameTag::Bye,
        ] {
            let payload = b"serve bytes".to_vec();
            let buf = encode_serve_frame(tag, &payload).unwrap();
            let (t, len) = peek_serve_frame(&buf).unwrap().unwrap();
            assert_eq!((t, len), (tag, payload.len()));
            let (t, p) = read_serve_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!((t, p), (tag, payload));
        }
    }

    #[test]
    fn serve_frame_roundtrip_random_payloads() {
        // property: encode ∘ read is identity for arbitrary payloads,
        // including back-to-back frames on one stream (pipelining)
        let mut rng = Rng::new(0xace5);
        for round in 0..50 {
            let n_frames = 1 + (round % 4);
            let mut buf = Vec::new();
            let mut sent = Vec::new();
            for _ in 0..n_frames {
                let len = rng.below(4096);
                let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
                let tag = ServeFrameTag::from_u8(0x10 + rng.below(7) as u8).unwrap();
                buf.extend_from_slice(&encode_serve_frame(tag, &payload).unwrap());
                sent.push((tag, payload));
            }
            let mut cur = Cursor::new(&buf);
            for (tag, payload) in &sent {
                let (t, p) = read_serve_frame(&mut cur).unwrap();
                assert_eq!((&t, &p), (tag, payload));
            }
            assert_eq!(
                read_serve_frame(&mut cur).unwrap_err().kind(),
                std::io::ErrorKind::UnexpectedEof
            );
        }
    }

    #[test]
    fn serve_frames_truncation_and_peek() {
        let full = encode_serve_frame(ServeFrameTag::Predict, b"0123456789").unwrap();
        for cut in 0..full.len() {
            // blocking reader: truncation is a clean UnexpectedEof
            let err = read_serve_frame(&mut Cursor::new(&full[..cut])).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut at {cut}");
            // nonblocking peek: a short header asks for more bytes, a
            // full header parses even with a partial payload
            match peek_serve_frame(&full[..cut]) {
                None => assert!(cut < 5, "cut at {cut}"),
                Some(Ok((tag, len))) => {
                    assert!(cut >= 5);
                    assert_eq!((tag, len), (ServeFrameTag::Predict, 10));
                }
                Some(Err(e)) => panic!("well-formed header rejected: {e}"),
            }
        }
    }

    #[test]
    fn serve_oversized_prefix_rejected_before_allocation() {
        // decided from 5 bytes alone — no payload allocation happens
        let mut head = vec![ServeFrameTag::Predict as u8];
        head.extend_from_slice(&u32::MAX.to_le_bytes());
        let e = peek_serve_frame(&head).unwrap().unwrap_err();
        assert!(e.contains("FRAME_MAX"));
        assert_eq!(
            read_serve_frame(&mut Cursor::new(&head)).unwrap_err().kind(),
            std::io::ErrorKind::InvalidData
        );
        // the writer enforces the same cap
        assert!(encode_serve_frame(ServeFrameTag::Decisions, &vec![0u8; FRAME_MAX + 1]).is_err());
    }

    #[test]
    fn serve_garbage_tags_and_soup_never_panic() {
        // tags outside 0x10..=0x16 — including the *train* tags 1..=5,
        // which must not leak into the serve plane — are InvalidData
        for bad in [0u8, 1, 5, 0x0f, 0x17, 255] {
            let mut buf = vec![bad];
            buf.extend_from_slice(&0u32.to_le_bytes());
            assert!(matches!(peek_serve_frame(&buf), Some(Err(_))), "tag {bad}");
        }
        let mut rng = Rng::new(0xd00d);
        for _ in 0..200 {
            let len = rng.below(64);
            let soup: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = peek_serve_frame(&soup);
            let _ = read_serve_frame(&mut Cursor::new(&soup));
            let _ = decode_predict_payload(&soup);
            let _ = decode_err_payload(&soup);
        }
    }

    #[test]
    fn predict_payload_roundtrip_bit_exact() {
        let mut rng = Rng::new(0x7e57);
        for _ in 0..50 {
            let rows = 1 + rng.below(8);
            let dim = 1 + rng.below(16);
            let data: Vec<f32> = (0..rows * dim)
                .map(|_| f32::from_bits(rng.next_u64() as u32))
                .collect();
            let payload = encode_predict_payload("banana", dim, rows, &data).unwrap();
            let frame = decode_predict_payload(&payload).unwrap();
            assert_eq!(frame.model, "banana");
            assert_eq!((frame.rows, frame.dim), (rows, dim));
            assert!(frame
                .data
                .iter()
                .zip(&data)
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        // zero rows is legal (an empty predict gets an empty decision block)
        let payload = encode_predict_payload("m", 3, 0, &[]).unwrap();
        let frame = decode_predict_payload(&payload).unwrap();
        assert_eq!((frame.rows, frame.dim, frame.data.len()), (0, 3, 0));
    }

    #[test]
    fn predict_payload_lying_headers_rejected() {
        let good = encode_predict_payload("m", 2, 3, &[0.0; 6]).unwrap();
        assert!(decode_predict_payload(&good).is_ok());
        // truncated body: shape says 6 values, body has fewer
        assert!(decode_predict_payload(&good[..good.len() - 4]).is_err());
        // trailing garbage: body longer than the shape admits
        let mut long = good.clone();
        long.extend_from_slice(&[0; 4]);
        assert!(decode_predict_payload(&long).is_err());
        // rows*dim u32 overflow must not wrap into a small allocation
        let mut evil = vec![1u8, b'm'];
        evil.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
        evil.extend_from_slice(&u32::MAX.to_le_bytes()); // rows
        assert!(decode_predict_payload(&evil).is_err());
        // non-UTF-8 model name
        let mut bad_name = vec![1u8, 0xff];
        bad_name.extend_from_slice(&1u32.to_le_bytes());
        bad_name.extend_from_slice(&1u32.to_le_bytes());
        bad_name.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_predict_payload(&bad_name).is_err());
        // encoder cross-checks shape against data length
        assert!(encode_predict_payload("m", 2, 3, &[0.0; 5]).is_err());
    }

    #[test]
    fn err_payload_roundtrip() {
        let payload = encode_err_payload("busy", "retry_after_ms=4");
        assert_eq!(
            decode_err_payload(&payload).unwrap(),
            ("busy".into(), "retry_after_ms=4".into())
        );
        let payload = encode_err_payload("dim-mismatch", "");
        assert_eq!(decode_err_payload(&payload).unwrap().0, "dim-mismatch");
        assert!(decode_err_payload(&[]).is_err());
        assert!(decode_err_payload(&[200u8, b'x']).is_err()); // code_len lies
    }

    #[test]
    fn serve_hello_negotiation_falls_back_to_text() {
        // exact binary request upgrades; anything else serve-hello
        // shaped degrades to text; non-hello lines are plain requests
        assert_eq!(
            negotiate_serve_hello(&serve_hello_line(WireMode::Binary)),
            Some(WireMode::Binary)
        );
        assert_eq!(
            negotiate_serve_hello(&serve_hello_line(WireMode::Text)),
            Some(WireMode::Text)
        );
        assert_eq!(negotiate_serve_hello("serve-hello v1 gzip"), Some(WireMode::Text));
        assert_eq!(negotiate_serve_hello("serve-hello v1"), Some(WireMode::Text));
        assert_eq!(negotiate_serve_hello("serve-hello v12 binary"), None);
        assert_eq!(negotiate_serve_hello("ping"), None);
        assert_eq!(negotiate_serve_hello("predict m 1,2"), None);
        assert_eq!(negotiate_serve_hello("train-hello v1 binary"), None);

        assert_eq!(
            parse_serve_hello_ack(&serve_hello_ack(WireMode::Binary)).unwrap(),
            WireMode::Binary
        );
        assert_eq!(
            parse_serve_hello_ack(&serve_hello_ack(WireMode::Text)).unwrap(),
            WireMode::Text
        );
        assert!(parse_serve_hello_ack(&err_busy(3)).is_err());
        assert!(parse_serve_hello_ack(&err_msg("bad", "no")).is_err());
        assert!(parse_serve_hello_ack(&ok_msg("pong")).is_err());
    }
}
