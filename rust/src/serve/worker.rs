//! Worker pool: a bounded batch queue (the backpressure boundary) and
//! the threads that execute fused predict calls.
//!
//! Batches are padded up to power-of-two row buckets before the
//! predict call so a steady request stream hits a handful of shapes —
//! the same amortization trick as the runtime's artifact buckets
//! (`runtime/mod.rs` pads inputs to fixed shapes so PJRT executables
//! are compiled once), and on the XLA backend the two bucketing layers
//! line up so padding waste stays bounded instead of compounding.
//!
//! Every batch carries its routing target: monolithic batches run the
//! whole model, sharded-bundle batches run exactly one cell's
//! mini-model (loading the shard lazily on first touch — see
//! `registry`).  A shard-load failure fails only that batch's rows,
//! never the worker thread.
//!
//! Kernel evaluation under a fused predict goes through the Gram
//! plane's tiled cross-distance path (`kernel::plane`, via
//! `cv::predict_average`): one reusable tile buffer per call instead
//! of a per-row kernel loop or a full test×SV cross Gram, bounded by
//! the model config's `max_gram_mb` (see DESIGN.md §Compute-plane).

use std::collections::VecDeque;

use crate::sync::{Condvar, Mutex};

use crate::data::matrix::Matrix;

use super::batcher::{Batch, BatchItem};
use super::stats::ServeStats;

/// A fixed-capacity MPMC queue: `try_push` never blocks (full ⇒ the
/// caller applies backpressure), `pop` blocks until an item arrives or
/// the queue is closed.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_empty: Condvar,
    cap: usize,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue, or hand the item back if the queue is full/closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.items.len() >= self.cap {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking dequeue; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: pending items still drain, new pushes fail,
    /// blocked `pop`s wake with `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

/// Round `n` rows up to its shape bucket: the next power of two,
/// capped at `max_batch` (a full batch is its own bucket).
pub fn bucket_rows(n: usize, max_batch: usize) -> usize {
    if n == 0 {
        return 0;
    }
    if n >= max_batch {
        return n;
    }
    n.next_power_of_two().min(max_batch)
}

/// Execute one batch: pad to the row bucket, run the fused predict,
/// scatter per-row results to the waiting connections.
///
/// Rows whose dimension disagrees with the batch get an error reply
/// instead of poisoning the matrix — a hot-reload can change a model's
/// dim while validated rows are still pending, and a panicking worker
/// would permanently shrink the pool.
pub(crate) fn process_batch(batch: Batch, stats: &ServeStats) {
    if batch.items.is_empty() {
        return;
    }
    let dim = if batch.model.dim > 0 { batch.model.dim } else { batch.items[0].features.len() };
    let (items, stale): (Vec<BatchItem>, Vec<BatchItem>) =
        batch.items.into_iter().partition(|it| it.features.len() == dim);
    for item in stale {
        stats.errors.inc();
        let msg = format!("row dim {} != model dim {dim} (model reloaded?)", item.features.len());
        item.reply.send(Err(msg));
    }
    let n = items.len();
    if n == 0 {
        return;
    }
    let rows = bucket_rows(n, batch.bucket);
    let mut x = Matrix::zeros(rows, dim);
    for (i, item) in items.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&item.features);
    }
    // a panic inside predict must not kill the worker thread — fail the
    // batch's requests and keep draining the queue
    let model = &batch.model;
    let target = batch.target;
    let preds = {
        let mut sp = crate::obs::span("serve.predict");
        sp.add_bytes(4 * (rows * dim) as u64);
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.predict_routed(target, &x)
        }))
    };
    match preds {
        Ok(Ok(preds)) => {
            stats.batches.inc();
            stats.batched_rows.add(n as u64);
            stats.padded_rows.add((rows - n) as u64);
            // the slow log fires on enqueue→response latency (the time
            // a client actually experienced), once per offending batch
            let slow_us = stats.slow_log_us();
            let mut slow_max = 0u64;
            for (item, &p) in items.into_iter().zip(&preds) {
                let lat = item.enqueued.elapsed();
                if slow_us > 0 && lat.as_micros() as u64 >= slow_us {
                    stats.slow.inc();
                    slow_max = slow_max.max(lat.as_micros() as u64);
                }
                stats.latency.record(lat);
                // receiver gone = client disconnected mid-flight; drop silently
                item.reply.send(Ok(p));
            }
            if slow_max > 0 {
                eprintln!(
                    "slow-log: model={} rows={n} max_latency_us={slow_max} threshold_us={slow_us}",
                    model.name
                );
            }
        }
        Ok(Err(e)) => {
            // e.g. a shard file vanished or failed its checksum
            stats.errors.add(n as u64);
            for item in items {
                item.reply.send(Err(e.clone()));
            }
        }
        Err(_) => {
            stats.errors.add(n as u64);
            for item in items {
                item.reply.send(Err("predict panicked on this batch".into()));
            }
        }
    }
}

/// Body of one worker thread: drain the batch queue until it closes.
/// Spawned by the event loop's thread bootstrap (`eventloop.rs` is the
/// single spawn site in `serve/`, machine-enforced by
/// `scripts/check_invariants.py`).
pub(crate) fn worker_loop(queue: &BoundedQueue<Batch>, stats: &ServeStats) {
    while let Some(batch) = queue.pop() {
        process_batch(batch, stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::Arc;
    use std::thread;

    #[test]
    fn bucket_rounds_to_powers_of_two() {
        assert_eq!(bucket_rows(0, 64), 0);
        assert_eq!(bucket_rows(1, 64), 1);
        assert_eq!(bucket_rows(3, 64), 4);
        assert_eq!(bucket_rows(5, 64), 8);
        assert_eq!(bucket_rows(33, 64), 64);
        assert_eq!(bucket_rows(64, 64), 64);
        // cap below next power of two: never pad past a full batch
        assert_eq!(bucket_rows(40, 48), 48);
        assert_eq!(bucket_rows(48, 48), 48);
    }

    #[test]
    fn queue_pushes_until_cap() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn queue_pop_drains_in_order_then_none_after_close() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.try_push("b").unwrap();
        q.close();
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.try_push("c"), Err("c"));
    }

    #[test]
    fn queue_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(std::time::Duration::from_millis(20));
        q.try_push(7).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Arc<BoundedQueue<i32>> = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }
}
