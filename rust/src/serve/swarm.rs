//! Event-driven load generator: the client-side mirror of
//! [`super::eventloop`].  Where [`super::run_load`] spends one thread
//! per connection (fine up to a few hundred), `run_swarm` multiplexes
//! *all* its connections over a handful of poller threads — the same
//! readiness machinery the server uses ([`super::poll`]) — which is
//! what lets one bench process hold 10k+ sockets open against the
//! server and prove the c10k acceptance bar (`benches/table_serve.rs`
//! `async_c10k_*`, `scripts/serve_stress.sh`).
//!
//! Accounting is strict on purpose: every request written must come
//! back as a prediction, a busy (retried), or an error — a server
//! that closes a connection with requests still outstanding fails the
//! whole run.  "Zero dropped replies" is checked here, not eyeballed.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::poll::Poller;
use super::protocol::{self, ServeFrameTag, WireMode};
use super::{parse_retry_ms, LoadReport, LoadSpec};

/// Hard wall-clock bound on a swarm run; a wedged server must fail
/// the bench, not hang it.
const SWARM_DEADLINE: Duration = Duration::from_secs(300);

/// One multiplexed connection's state machine.
struct SwarmConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    /// row indices not yet written (busy retries come back here)
    to_send: VecDeque<usize>,
    /// requests written, replies pending — FIFO, the server answers
    /// in order
    inflight: VecDeque<(usize, Instant)>,
    /// binary mode: the hello ack line hasn't arrived yet
    awaiting_ack: bool,
    /// refused (busy / rate-limited): don't resend before this
    stall_until: Option<Instant>,
    quit_sent: bool,
    want_write: bool,
    done: bool,
}

/// Fire `connections × requests` single-row predicts using a few
/// event-loop threads instead of `connections` blocking threads
/// (`client --swarm`).  Semantics match [`super::run_load_mode`]:
/// busy responses are retried until answered, predictions are checked
/// against `expected` when given.
pub fn run_swarm(
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    expected: Option<&[f32]>,
    mode: WireMode,
) -> Result<LoadReport> {
    if rows.is_empty() {
        bail!("no feature rows to send");
    }
    if let Some(exp) = expected {
        if exp.len() != rows.len() {
            bail!("expected values misaligned: {} vs {} rows", exp.len(), rows.len());
        }
    }
    let connections = spec.connections.max(1);
    let threads = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 8)
        .min(connections);
    let t0 = Instant::now();
    let mut report = LoadReport::default();
    let results: Vec<Result<LoadReport>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                // connection c belongs to thread c % threads
                let conn_ids: Vec<usize> =
                    (0..connections).filter(|c| c % threads == t).collect();
                scope.spawn(move || swarm_thread(spec, rows, expected, mode, &conn_ids))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("swarm thread panicked")).collect()
    });
    for r in results {
        let r = r?;
        report.sent += r.sent;
        report.ok += r.ok;
        report.rejected += r.rejected;
        report.failed += r.failed;
        report.mismatches += r.mismatches;
        report.latency.merge(&r.latency);
    }
    report.elapsed = t0.elapsed();
    // the strict bar: nothing written may vanish — every request is
    // answered (ok/busy-retried/err), so ok + failed covers them all
    let answered = report.ok + report.failed;
    let expected_replies = connections * spec.requests;
    if answered != expected_replies {
        bail!(
            "dropped replies: {answered} answered of {expected_replies} requests ({})",
            report.report()
        );
    }
    Ok(report)
}

fn swarm_thread(
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    expected: Option<&[f32]>,
    mode: WireMode,
    conn_ids: &[usize],
) -> Result<LoadReport> {
    let mut poller = Poller::new().context("swarm poller")?;
    let mut st = LoadReport::default();
    let mut conns: Vec<SwarmConn> = Vec::with_capacity(conn_ids.len());
    for &c in conn_ids {
        let stream = connect_retry(&spec.addr)?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).context("nonblocking swarm socket")?;
        let mut conn = SwarmConn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            to_send: (0..spec.requests)
                .map(|k| (c * spec.requests + k) % rows.len())
                .collect(),
            inflight: VecDeque::new(),
            awaiting_ack: mode == WireMode::Binary,
            stall_until: None,
            quit_sent: false,
            want_write: true,
            done: false,
        };
        if mode == WireMode::Binary {
            conn.wbuf
                .extend_from_slice(format!("{}\n", protocol::serve_hello_line(mode)).as_bytes());
        }
        fill(&mut conn, spec, rows, mode, &mut st)?;
        let idx = conns.len();
        poller
            .register(conn.stream.as_raw_fd(), idx as u64, true, true, false)
            .context("registering swarm socket")?;
        conns.push(conn);
    }

    let deadline = Instant::now() + SWARM_DEADLINE;
    let mut events = Vec::new();
    let mut done = 0usize;
    while done < conns.len() {
        if Instant::now() >= deadline {
            bail!("swarm run exceeded {}s deadline ({})", SWARM_DEADLINE.as_secs(), st.report());
        }
        poller.wait(&mut events, 100).context("swarm poll wait")?;
        let readable: Vec<usize> = events
            .iter()
            .filter(|ev| ev.readable || ev.hangup)
            .map(|ev| ev.token as usize)
            .collect();
        for idx in readable {
            let conn = &mut conns[idx];
            if conn.done {
                continue;
            }
            if let Err(e) = drain_reads(conn, rows.len(), expected, mode, &mut st) {
                bail!("connection {idx}: {e:#}");
            }
        }
        // one cheap sweep per round advances every connection: expired
        // stalls refill, parsed replies free pipeline slots, buffered
        // bytes flush, write interest tracks the buffer
        for (idx, conn) in conns.iter_mut().enumerate() {
            if conn.done {
                continue;
            }
            fill(conn, spec, rows, mode, &mut st)?;
            flush_writes(conn)?;
            let unsent = conn.wpos < conn.wbuf.len();
            if unsent != conn.want_write {
                conn.want_write = unsent;
                let _ = poller.modify(conn.stream.as_raw_fd(), idx as u64, true, unsent, false);
            }
            if conn.quit_sent && !unsent && conn.inflight.is_empty() && conn.rbuf.is_empty() {
                poller.deregister(conn.stream.as_raw_fd()).ok();
                conn.done = true;
                done += 1;
            }
        }
    }
    Ok(st)
}

/// Connect with retries: a 10k-connection ramp can momentarily
/// overflow accept queues, which surfaces as transient refusals.
fn connect_retry(addr: &str) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                thread::sleep(Duration::from_millis(20));
            }
        }
    }
    Err(anyhow!("connecting {addr}: {}", last.expect("at least one attempt")))
}

/// Queue requests up to the pipeline budget; once everything is
/// answered, queue the quit.
fn fill(
    conn: &mut SwarmConn,
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    mode: WireMode,
    st: &mut LoadReport,
) -> Result<()> {
    if let Some(until) = conn.stall_until {
        if Instant::now() < until {
            return Ok(());
        }
        conn.stall_until = None;
    }
    let pipeline = spec.pipeline.max(1);
    while conn.inflight.len() < pipeline {
        let Some(ri) = conn.to_send.pop_front() else { break };
        match mode {
            WireMode::Text => {
                let row: Vec<String> = rows[ri].iter().map(|v| format!("{v}")).collect();
                conn.wbuf.extend_from_slice(
                    format!("predict {} {}\n", spec.model, row.join(",")).as_bytes(),
                );
            }
            WireMode::Binary => {
                let payload =
                    protocol::encode_predict_payload(&spec.model, rows[ri].len(), 1, &rows[ri])
                        .map_err(|e| anyhow!(e))?;
                conn.wbuf.extend_from_slice(
                    &protocol::encode_serve_frame(ServeFrameTag::Predict, &payload)
                        .map_err(|e| anyhow!(e))?,
                );
            }
        }
        conn.inflight.push_back((ri, Instant::now()));
        st.sent += 1;
    }
    if !conn.quit_sent && conn.to_send.is_empty() && conn.inflight.is_empty() {
        match mode {
            WireMode::Text => conn.wbuf.extend_from_slice(b"quit\n"),
            WireMode::Binary => conn.wbuf.extend_from_slice(
                &protocol::encode_serve_frame(ServeFrameTag::Quit, &[])
                    .map_err(|e| anyhow!(e))?,
            ),
        }
        conn.quit_sent = true;
    }
    Ok(())
}

/// Read everything the socket has, then parse replies out of the
/// buffer.  An EOF with work still outstanding is a dropped reply —
/// an error, not a statistic.
fn drain_reads(
    conn: &mut SwarmConn,
    n_rows: usize,
    expected: Option<&[f32]>,
    mode: WireMode,
    st: &mut LoadReport,
) -> Result<()> {
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match (&conn.stream).read(&mut tmp) {
            Ok(0) => {
                parse_replies(conn, n_rows, expected, mode, st)?;
                if conn.inflight.is_empty() && conn.to_send.is_empty() && conn.quit_sent {
                    return Ok(()); // orderly close after bye
                }
                bail!(
                    "server closed with {} in flight, {} unsent",
                    conn.inflight.len(),
                    conn.to_send.len()
                );
            }
            Ok(n) => conn.rbuf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    parse_replies(conn, n_rows, expected, mode, st)
}

fn parse_replies(
    conn: &mut SwarmConn,
    n_rows: usize,
    expected: Option<&[f32]>,
    mode: WireMode,
    st: &mut LoadReport,
) -> Result<()> {
    loop {
        // the hello ack is a text line even on binary connections
        if mode == WireMode::Text || conn.awaiting_ack {
            let Some(nl) = conn.rbuf.iter().position(|&b| b == b'\n') else { return Ok(()) };
            let line = String::from_utf8_lossy(&conn.rbuf[..nl]).trim().to_string();
            conn.rbuf.drain(..=nl);
            if line.is_empty() {
                continue;
            }
            if conn.awaiting_ack {
                let acked =
                    protocol::parse_serve_hello_ack(&line).map_err(|e| anyhow!(e))?;
                if acked != WireMode::Binary {
                    bail!("server refused binary mode (acked {acked:?})");
                }
                conn.awaiting_ack = false;
                continue;
            }
            match protocol::parse_response(&line) {
                protocol::Response::Ok(body) => {
                    let Some((ri, sent_at)) = conn.inflight.pop_front() else {
                        continue; // the bye reply to our quit
                    };
                    st.latency.record(sent_at.elapsed());
                    let vals = protocol::parse_values(&body).map_err(|e| anyhow!(e))?;
                    st.ok += 1;
                    if let Some(exp) = expected {
                        if vals.len() != 1 || vals[0] != exp[ri % n_rows] {
                            st.mismatches += 1;
                        }
                    }
                }
                protocol::Response::Busy { retry_after_ms } => {
                    let Some((ri, _)) = conn.inflight.pop_front() else {
                        bail!("busy response with nothing in flight");
                    };
                    st.rejected += 1;
                    conn.to_send.push_back(ri);
                    conn.stall_until =
                        Some(Instant::now() + Duration::from_millis(retry_after_ms.max(1)));
                }
                protocol::Response::Err { code, msg } => {
                    let Some((ri, _)) = conn.inflight.pop_front() else {
                        bail!("server error before any request: {code} {msg}");
                    };
                    if code == "rate-limited" {
                        st.rejected += 1;
                        conn.to_send.push_back(ri);
                        conn.stall_until = Some(
                            Instant::now() + Duration::from_millis(parse_retry_ms(&msg).max(1)),
                        );
                    } else {
                        st.failed += 1;
                    }
                }
            }
        } else {
            let (tag, len) = match protocol::peek_serve_frame(&conn.rbuf) {
                None => return Ok(()),
                Some(Err(e)) => bail!("bad reply frame: {e}"),
                Some(Ok(hdr)) => hdr,
            };
            let total = protocol::frame_overhead() + len;
            if conn.rbuf.len() < total {
                return Ok(());
            }
            let payload = conn.rbuf[protocol::frame_overhead()..total].to_vec();
            conn.rbuf.drain(..total);
            match tag {
                ServeFrameTag::Bye => continue,
                ServeFrameTag::Decisions => {
                    let Some((ri, sent_at)) = conn.inflight.pop_front() else {
                        bail!("decision frame with nothing in flight");
                    };
                    st.latency.record(sent_at.elapsed());
                    let vals = protocol::bytes_to_f32s(&payload).map_err(|e| anyhow!(e))?;
                    st.ok += 1;
                    if let Some(exp) = expected {
                        if vals.len() != 1 || vals[0] != exp[ri % n_rows] {
                            st.mismatches += 1;
                        }
                    }
                }
                ServeFrameTag::Err => {
                    let Some((ri, _)) = conn.inflight.pop_front() else {
                        bail!("error frame with nothing in flight");
                    };
                    let (code, msg) =
                        protocol::decode_err_payload(&payload).map_err(|e| anyhow!(e))?;
                    if code == "busy" || code == "rate-limited" {
                        st.rejected += 1;
                        conn.to_send.push_back(ri);
                        conn.stall_until = Some(
                            Instant::now() + Duration::from_millis(parse_retry_ms(&msg).max(1)),
                        );
                    } else {
                        st.failed += 1;
                    }
                }
                other => bail!("unexpected reply frame {other:?}"),
            }
        }
    }
}

/// Flush buffered output as far as the socket allows.
fn flush_writes(conn: &mut SwarmConn) -> Result<()> {
    while conn.wpos < conn.wbuf.len() {
        match (&conn.stream).write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => bail!("socket wrote zero"),
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
    Ok(())
}
