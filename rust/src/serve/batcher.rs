//! Micro-batching engine: coalesces concurrent prediction requests per
//! **(model, routing target)** into one fused predict call.
//!
//! Every accepted row is routed first — monolithic models batch as a
//! whole, sharded bundles batch per owning cell (or per "all cells"
//! for broadcast ensembles) — and then joins the pending batch of its
//! (model, target) key.  Keying by target means a fused call never
//! mixes rows bound for different shards, so the worker executes each
//! batch against exactly one resident mini-model and the power-of-two
//! shape buckets keep applying unchanged.  A batch flushes to the
//! worker queue on either trigger:
//!
//! * **size** — the batch reached `max_batch` rows (flushed inline by
//!   the submitting thread, zero added latency at saturation);
//! * **deadline** — the oldest pending row has waited `max_delay`
//!   (flushed by the server's flusher tick, bounding tail latency at
//!   low traffic).
//!
//! Backpressure is explicit: when a size-triggered flush finds the
//! worker queue full, the newest row is rejected with a retry-after
//! hint instead of buffering without bound — the queue capacity is the
//! server's whole memory budget for in-flight work.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::sync::{mpsc, Arc, Mutex};

use super::registry::{RouteTarget, ServedModel};
use super::worker::BoundedQueue;

/// Where a finished row's result goes: back to a blocking caller over
/// an mpsc channel (text connections, tests), or into a reactor
/// mailbox that wakes the owning event loop (the async serve plane,
/// DESIGN.md §Serving-async).
pub enum ReplySink {
    Channel(mpsc::Sender<Result<f32, String>>),
    Reactor(super::eventloop::ReactorSink),
}

impl ReplySink {
    /// Deliver the row's result.  Consuming `self` makes double-send
    /// unrepresentable; a sink dropped *unsent* still reports "worker
    /// dropped request" to its waiter (channel: sender drop unblocks
    /// the receiver; reactor: the sink's Drop pushes an error
    /// completion), so a discarded row can never strand a client.
    pub fn send(self, result: Result<f32, String>) {
        match self {
            // a vanished receiver is not the worker's problem
            ReplySink::Channel(tx) => drop(tx.send(result)),
            ReplySink::Reactor(sink) => sink.send(result),
        }
    }
}

/// One pending prediction row and its reply sink.
pub struct BatchItem {
    pub features: Vec<f32>,
    pub enqueued: Instant,
    pub reply: ReplySink,
}

/// A flushed batch awaiting a worker.
pub struct Batch {
    pub model: Arc<ServedModel>,
    /// where every row of this batch routes (one cell, all cells, or
    /// the whole monolithic model)
    pub target: RouteTarget,
    pub items: Vec<BatchItem>,
    /// shape-bucket cap (the batcher's `max_batch`)
    pub bucket: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// rows per fused predict call (size trigger)
    pub max_batch: usize,
    /// oldest-row wait bound (deadline trigger)
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, max_delay: Duration::from_millis(2) }
    }
}

/// Why a submit was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// worker queue full — retry after the hinted backoff
    Busy { retry_after_ms: u64 },
    /// the batcher was closed by shutdown — no flusher will run again,
    /// so accepting the row would strand its reply receiver forever
    Closed,
}

struct Pending {
    model: Arc<ServedModel>,
    target: RouteTarget,
    items: Vec<BatchItem>,
    oldest: Instant,
}

/// The pending map plus its lifecycle bit.  `closed` lives under the
/// same mutex as the map on purpose: a lone atomic flag would leave a
/// check-then-insert window in which a row lands in the map *after*
/// the shutdown drain emptied it — exactly the stranded-client race
/// `discard_pending` exists to prevent.
struct PendingState {
    map: HashMap<(String, RouteTarget), Pending>,
    closed: bool,
}

/// Per-(model, target) pending batches in front of the worker queue.
pub struct Batcher {
    cfg: BatcherConfig,
    pending: Mutex<PendingState>,
    queue: Arc<BoundedQueue<Batch>>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, queue: Arc<BoundedQueue<Batch>>) -> Batcher {
        let cfg = BatcherConfig { max_batch: cfg.max_batch.max(1), ..cfg };
        Batcher {
            cfg,
            pending: Mutex::new(PendingState { map: HashMap::new(), closed: false }),
            queue,
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Enqueue one row for `model`; the receiver yields the prediction
    /// once a worker has executed the row's batch.  The row is routed
    /// here — through the model's cell router for sharded bundles — so
    /// it coalesces only with rows bound for the same target.
    pub fn submit(
        &self,
        model: &Arc<ServedModel>,
        features: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<f32, String>>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(model, features, ReplySink::Channel(tx)).map(|()| rx)
    }

    /// [`submit`](Self::submit) with a caller-supplied reply sink — the
    /// async serve plane passes reactor sinks here so a worker
    /// completion wakes the owning event loop instead of a parked
    /// thread.  On error the sink is dropped, which is itself a
    /// delivery (see [`ReplySink::send`]); callers that want to answer
    /// the client differently (e.g. `err busy`) respond on their own
    /// connection state instead.
    pub fn submit_with(
        &self,
        model: &Arc<ServedModel>,
        features: Vec<f32>,
        reply: ReplySink,
    ) -> Result<(), SubmitError> {
        let _sp = crate::obs::span("serve.enqueue");
        let target = model.route(&features);
        let mut pending = self.pending.lock().unwrap();
        if pending.closed {
            return Err(SubmitError::Closed);
        }
        let p = pending
            .map
            .entry((model.name.clone(), target))
            .or_insert_with(|| Pending {
                model: model.clone(),
                target,
                items: Vec::with_capacity(self.cfg.max_batch),
                oldest: Instant::now(),
            });
        // a registry hot-reload may have swapped the Arc under this
        // name.  The pending rows were routed with the *old* model's
        // geometry — executing them against the new model's shard of
        // the same index would silently answer from the wrong cell —
        // so flush them as-is against the model that routed them, and
        // start a fresh batch for the new generation.
        if !Arc::ptr_eq(&p.model, model) {
            if !p.items.is_empty() {
                let stale = Batch {
                    model: p.model.clone(),
                    target: p.target,
                    items: std::mem::take(&mut p.items),
                    bucket: self.cfg.max_batch,
                };
                if let Err(rejected) = self.queue.try_push(stale) {
                    // queue full: keep the old rows pending under the
                    // old model and bounce only the new row
                    p.items = rejected.items;
                    return Err(SubmitError::Busy { retry_after_ms: self.retry_after_ms() });
                }
            }
            p.model = model.clone();
        }
        if p.items.is_empty() {
            p.oldest = Instant::now();
        }
        p.items.push(BatchItem { features, enqueued: Instant::now(), reply });
        if p.items.len() >= self.cfg.max_batch {
            let batch = Batch {
                model: p.model.clone(),
                target: p.target,
                items: std::mem::take(&mut p.items),
                bucket: self.cfg.max_batch,
            };
            if let Err(mut rejected) = self.queue.try_push(batch) {
                // queue full: restore the earlier rows (their deadline
                // is unchanged) and bounce only the newest one
                rejected.items.pop();
                p.items = rejected.items;
                return Err(SubmitError::Busy { retry_after_ms: self.retry_after_ms() });
            }
        }
        Ok(())
    }

    fn retry_after_ms(&self) -> u64 {
        (self.cfg.max_delay.as_millis() as u64).max(1) * 2
    }

    /// Flush every pending batch whose oldest row has waited past the
    /// deadline; called periodically by the server's flusher thread.
    /// Returns the number of batches moved to the worker queue.
    pub fn flush_expired(&self) -> usize {
        self.flush(|p| p.oldest.elapsed() >= self.cfg.max_delay)
    }

    /// Flush all pending batches regardless of age (shutdown drain).
    pub fn flush_all(&self) -> usize {
        self.flush(|_| true)
    }

    fn flush(&self, should: impl Fn(&Pending) -> bool) -> usize {
        let mut pending = self.pending.lock().unwrap();
        let mut flushed = 0;
        for p in pending.map.values_mut() {
            if p.items.is_empty() || !should(p) {
                continue;
            }
            let batch = Batch {
                model: p.model.clone(),
                target: p.target,
                items: std::mem::take(&mut p.items),
                bucket: self.cfg.max_batch,
            };
            match self.queue.try_push(batch) {
                Ok(()) => flushed += 1,
                Err(rejected) => {
                    // queue still full: put the rows back and let the
                    // next flusher tick retry
                    p.items = rejected.items;
                    break;
                }
            }
        }
        // drop drained entries: a (model, cell) key that stops seeing
        // traffic must not pin its ServedModel Arc — after a
        // hot-reload or unload that would keep a whole old generation
        // (and its resident shards) alive indefinitely
        pending.map.retain(|_, p| !p.items.is_empty());
        flushed
    }

    /// Rows currently pending (unflushed) for `model`, summed across
    /// its routing targets.
    pub fn pending_rows(&self, model: &str) -> usize {
        self.pending
            .lock()
            .unwrap()
            .map
            .iter()
            .filter(|((name, _), _)| name == model)
            .map(|(_, p)| p.items.len())
            .sum()
    }

    /// Any unflushed rows at all (shutdown drain check).
    pub fn has_pending(&self) -> bool {
        self.pending.lock().unwrap().map.values().any(|p| !p.items.is_empty())
    }

    /// Drop every pending row, failing its waiter (the reply senders
    /// are dropped, so blocked receivers error out instead of hanging),
    /// and close the batcher: any later `submit` fails with
    /// [`SubmitError::Closed`].  Closing under the pending lock is what
    /// makes the shutdown drain race-free — a connection thread that
    /// read its request before noticing the stop flag either lands its
    /// row in the map before this drain (and gets drained) or observes
    /// `closed` (and fails fast).  It can never park a row that no
    /// flusher will visit again.  Returns the number of discarded rows.
    pub fn discard_pending(&self) -> usize {
        let mut pending = self.pending.lock().unwrap();
        pending.closed = true;
        pending.map.values_mut().map(|p| std::mem::take(&mut p.items).len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::prelude::*;
    use crate::serve::stats::ServeStats;
    use crate::serve::worker::process_batch;

    fn served() -> Arc<ServedModel> {
        let d = synth::banana_binary(70, 21);
        let m = svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap();
        Arc::new(ServedModel::from_model("m", m))
    }

    fn batcher(max_batch: usize, queue_cap: usize) -> (Batcher, Arc<BoundedQueue<Batch>>) {
        let queue = Arc::new(BoundedQueue::new(queue_cap));
        let cfg = BatcherConfig { max_batch, max_delay: Duration::from_millis(1) };
        (Batcher::new(cfg, queue.clone()), queue)
    }

    #[test]
    fn flushes_by_size() {
        let model = served();
        let (b, queue) = batcher(4, 8);
        for _ in 0..3 {
            b.submit(&model, vec![0.1, 0.2]).unwrap();
        }
        assert!(queue.is_empty());
        assert_eq!(b.pending_rows("m"), 3);
        b.submit(&model, vec![0.3, 0.4]).unwrap();
        assert_eq!(queue.len(), 1);
        assert_eq!(b.pending_rows("m"), 0);
        assert_eq!(queue.pop().unwrap().items.len(), 4);
    }

    #[test]
    fn flushes_by_deadline() {
        let model = served();
        let (b, queue) = batcher(64, 8);
        b.submit(&model, vec![0.5, 0.5]).unwrap();
        assert_eq!(b.flush_expired(), 0); // deadline (1ms) not reached
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.flush_expired(), 1);
        assert_eq!(queue.pop().unwrap().items.len(), 1);
        assert_eq!(b.flush_expired(), 0); // nothing left
    }

    #[test]
    fn rejects_with_backpressure_when_queue_full() {
        let model = served();
        let (b, queue) = batcher(1, 1); // every row flushes; queue holds one batch
        b.submit(&model, vec![0.0, 0.0]).unwrap();
        assert_eq!(queue.len(), 1);
        let err = b.submit(&model, vec![1.0, 1.0]).unwrap_err();
        let SubmitError::Busy { retry_after_ms } = err else {
            panic!("expected Busy, got {err:?}");
        };
        assert!(retry_after_ms >= 1);
        // earlier rows were not lost: queue still has the first batch
        assert_eq!(queue.len(), 1);
    }

    #[test]
    fn backpressure_restores_pending_rows() {
        let model = served();
        let (b, queue) = batcher(2, 1);
        // fill the queue with a deadline flush of one row
        b.submit(&model, vec![0.0, 0.0]).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(b.flush_expired(), 1);
        // now two more rows force a size flush that cannot enqueue
        b.submit(&model, vec![0.1, 0.1]).unwrap();
        let err = b.submit(&model, vec![0.2, 0.2]);
        assert!(matches!(err, Err(SubmitError::Busy { .. })));
        // the first of the two stays pending for a later flush
        assert_eq!(b.pending_rows("m"), 1);
        let _ = queue.pop();
    }

    #[test]
    fn discard_closes_the_batcher() {
        let model = served();
        let (b, _queue) = batcher(4, 8);
        b.submit(&model, vec![0.1, 0.2]).unwrap();
        assert_eq!(b.discard_pending(), 1);
        // the shutdown drain ran: a late submit must fail fast instead
        // of parking a row no flusher will ever visit again
        assert_eq!(b.submit(&model, vec![0.3, 0.4]).unwrap_err(), SubmitError::Closed);
        assert!(!b.has_pending());
    }

    #[test]
    fn sharded_rows_batch_per_cell() {
        use crate::cells::CellStrategy;
        use crate::coordinator::persist::save_bundle;
        use crate::serve::registry::{Registry, RouteTarget};

        let d = synth::banana_binary(240, 22);
        let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 60 });
        let m = svm_binary(&d, 0.5, &cfg).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("lsvm-batcher-{}", std::process::id()))
            .join("b.sol.d");
        std::fs::create_dir_all(dir.parent().unwrap()).unwrap();
        save_bundle(&m, &dir).unwrap();
        let reg = Registry::new(Config::default(), 2);
        let served = reg.load("b", &dir).unwrap();

        // find two rows owned by different cells
        let first = served.route(d.x.row(0));
        let other = (1..d.len())
            .find(|&i| served.route(d.x.row(i)) != first)
            .expect("voronoi model should have >1 cell");

        let (b, queue) = batcher(64, 8);
        b.submit(&served, d.x.row(0).to_vec()).unwrap();
        b.submit(&served, d.x.row(other).to_vec()).unwrap();
        assert_eq!(b.pending_rows("b"), 2);
        // different cells ⇒ different pending batches ⇒ two flushes
        assert_eq!(b.flush_all(), 2);
        let (b1, b2) = (queue.pop().unwrap(), queue.pop().unwrap());
        assert_ne!(b1.target, b2.target);
        assert!(matches!(b1.target, RouteTarget::Cell(_)));
        assert_eq!(b1.items.len() + b2.items.len(), 2);
    }

    #[test]
    fn batched_predictions_match_direct_predict() {
        let model = served();
        let (b, queue) = batcher(8, 8);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|i| vec![-2.0 + i as f32, 1.0 - 0.4 * i as f32])
            .collect();
        let rxs: Vec<_> = rows.iter().map(|r| b.submit(&model, r.clone()).unwrap()).collect();
        assert_eq!(b.flush_all(), 1);
        let stats = ServeStats::new();
        process_batch(queue.pop().unwrap(), &stats);

        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let x = crate::data::matrix::Matrix::from_vec(flat, 5, 2);
        let expect = model.model.predict(&x);
        let got: Vec<f32> = rxs.iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        assert_eq!(got, expect);
        // 5 rows bucketed to 8: padding recorded
        assert_eq!(stats.batched_rows.get(), 5);
        assert_eq!(stats.padded_rows.get(), 3);
    }
}
