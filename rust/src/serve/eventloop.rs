//! The async serve plane: a small fixed pool of reactor threads
//! drives every connection through nonblocking readiness polling
//! ([`super::poll`]) — no thread-per-connection anywhere
//! (machine-enforced: `scripts/check_invariants.py` forbids
//! `thread::spawn` in `serve/` outside this file).
//!
//! ## Shape
//!
//! - Reactor 0 owns the nonblocking listener.  Every accept passes
//!   **admission control** ([`Admission`]): a `--max-conns` cap checked
//!   under one mutex (rejected connections get a clean
//!   `err conn-limit …` line, never an accept-queue stall) and a
//!   per-client token bucket (`--rate-limit`, rows/sec) charged per
//!   predict request.  Admitted sockets are handed round-robin to the
//!   reactors through their [`Mailbox`]es.
//! - Each reactor runs an edge-triggered poll loop over its
//!   connections: buffered partial reads/writes, a per-connection
//!   state machine ([`Conn`]) that speaks the text protocol by
//!   default and switches to length-prefixed binary frames when the
//!   client sends `serve-hello v1 binary` ([`super::protocol`]).
//! - Predict rows still flow through the shared [`Batcher`] and worker
//!   pool; a worker completion lands in the owning reactor's mailbox
//!   via a [`ReactorSink`] and wakes it through a self-pipe.  Replies
//!   are resolved strictly in request order per connection
//!   ([`ReplySlot`] queue), so pipelined requests batch in flight yet
//!   answer deterministically — same contract as the old
//!   thread-per-connection writer, minus the two threads.
//!
//! ## Wakeup discipline
//!
//! A sink pushes its completion to the mailbox **before** writing the
//! wake byte; the reactor drains the wake pipe **before** taking the
//! mailbox.  Any completion therefore either lands before the drain
//! (taken this round) or wrote a wake byte after it (taken next
//! round) — no lost wakeups, no busy polling.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::thread;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Mutex};

use super::batcher::{Batch, Batcher, ReplySink, SubmitError};
use super::poll::{Event, Poller, WakePipe};
use super::protocol::{
    self, ServeFrameTag, WireMode, FRAME_MAX, MAX_LINE,
};
use super::registry::{Registry, ServedModel};
use super::stats::ServeStats;
use super::worker::{worker_loop, BoundedQueue};
use super::{dispatch_request, Dispatch};

/// Reserved poll token: the listener (reactor 0 only).
const LISTENER_TOKEN: u64 = u64::MAX;
/// Reserved poll token: the reactor's wake pipe.
const WAKE_TOKEN: u64 = u64::MAX - 1;

// ---------------------------------------------------------------- admission

/// Connection-table and rate-limit seam, shared by the acceptor and
/// every reactor.  One mutex guards both the open-connection count and
/// the per-client token buckets, so `accept` racing `close` racing a
/// rate-limit charge cannot leak a slot or double-release one — the
/// loom model in `tests/loom_models.rs` (`admission_accept_close_spend`)
/// explores exactly that interleaving.
///
/// Time is passed in explicitly (`now_us`) so the bucket arithmetic is
/// deterministic under loom and in unit tests.
#[doc(hidden)]
#[derive(Debug)]
pub struct Admission {
    /// open-connection cap; 0 = unlimited
    max_conns: usize,
    /// token-bucket refill rate in rows/sec/client; 0.0 = off.  The
    /// burst is one second's budget.
    rate: f64,
    inner: Mutex<AdmissionInner>,
}

#[derive(Debug)]
struct AdmissionInner {
    open: usize,
    buckets: HashMap<IpAddr, TokenBucket>,
}

#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last_us: u64,
}

impl Admission {
    pub fn new(max_conns: usize, rate_limit: u64) -> Admission {
        Admission {
            max_conns,
            rate: rate_limit as f64,
            inner: Mutex::new(AdmissionInner { open: 0, buckets: HashMap::new() }),
        }
    }

    /// Claim a connection slot; `false` means the cap is reached and
    /// the caller must reject the socket (it holds no slot).
    pub fn try_accept(&self) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if self.max_conns > 0 && inner.open >= self.max_conns {
            return false;
        }
        inner.open += 1;
        true
    }

    /// Release a claimed slot.  Saturating: a stray double-release
    /// must not underflow the count and open the cap wide.
    pub fn release(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.open = inner.open.saturating_sub(1);
    }

    /// Currently claimed slots.
    pub fn open(&self) -> usize {
        self.inner.lock().unwrap().open
    }

    /// Charge `rows` against `peer`'s token bucket at time `now_us`
    /// (µs since server start).  `Err(retry_after_ms)` when the bucket
    /// is too empty.  A request larger than one second's budget costs
    /// a full bucket instead of being unpassable.
    pub fn try_spend(&self, peer: IpAddr, rows: u64, now_us: u64) -> Result<(), u64> {
        if self.rate <= 0.0 {
            return Ok(());
        }
        let burst = self.rate;
        let mut inner = self.inner.lock().unwrap();
        let b = inner
            .buckets
            .entry(peer)
            .or_insert(TokenBucket { tokens: burst, last_us: now_us });
        let dt_s = now_us.saturating_sub(b.last_us) as f64 / 1e6;
        b.tokens = (b.tokens + dt_s * self.rate).min(burst);
        b.last_us = now_us;
        let cost = (rows as f64).min(burst);
        if b.tokens + 1e-9 >= cost {
            b.tokens -= cost;
            Ok(())
        } else {
            let retry_ms = (((cost - b.tokens) / self.rate) * 1000.0).ceil() as u64;
            Err(retry_ms.max(1))
        }
    }

    /// Drop buckets idle for over a minute — a server facing churning
    /// clients must not grow the bucket map without bound.
    pub fn prune(&self, now_us: u64) {
        self.inner
            .lock()
            .unwrap()
            .buckets
            .retain(|_, b| now_us.saturating_sub(b.last_us) < 60_000_000);
    }

    /// Bucket-map size (tests).
    pub fn tracked_clients(&self) -> usize {
        self.inner.lock().unwrap().buckets.len()
    }
}

// ------------------------------------------------------------------ mailbox

/// One finished row, addressed back to (connection, request, row).
pub(crate) struct RowDone {
    token: u64,
    req: u64,
    row: u32,
    result: Result<f32, String>,
}

/// A reactor's inbox: worker completions and freshly admitted sockets,
/// each push followed by a self-pipe wake (see module doc for why this
/// ordering is lossless).
pub(crate) struct Mailbox {
    completions: Mutex<Vec<RowDone>>,
    incoming: Mutex<Vec<(TcpStream, IpAddr)>>,
    pipe: WakePipe,
}

impl Mailbox {
    pub(crate) fn new() -> std::io::Result<Mailbox> {
        Ok(Mailbox {
            completions: Mutex::new(Vec::new()),
            incoming: Mutex::new(Vec::new()),
            pipe: WakePipe::new()?,
        })
    }

    fn push_done(&self, done: RowDone) {
        self.completions.lock().unwrap().push(done);
        self.pipe.wake();
    }

    fn push_conn(&self, stream: TcpStream, peer: IpAddr) {
        self.incoming.lock().unwrap().push((stream, peer));
        self.pipe.wake();
    }

    /// Nudge the owning reactor (shutdown).
    pub(crate) fn wake(&self) {
        self.pipe.wake();
    }
}

/// Where a worker drops one row's result for an event-loop connection.
/// Consumed by [`ReplySink::send`]; if dropped unsent (a discarded
/// batch at shutdown, a vanished worker), its `Drop` still delivers a
/// "worker dropped request" completion so the reply slot resolves and
/// the client gets an answer instead of a hang.
pub struct ReactorSink {
    mailbox: Arc<Mailbox>,
    token: u64,
    req: u64,
    row: u32,
    sent: bool,
}

impl ReactorSink {
    fn new(mailbox: Arc<Mailbox>, token: u64, req: u64, row: u32) -> ReactorSink {
        ReactorSink { mailbox, token, req, row, sent: false }
    }

    pub(crate) fn send(mut self, result: Result<f32, String>) {
        self.sent = true;
        self.mailbox
            .push_done(RowDone { token: self.token, req: self.req, row: self.row, result });
    }
}

impl Drop for ReactorSink {
    fn drop(&mut self) {
        if !self.sent {
            self.mailbox.push_done(RowDone {
                token: self.token,
                req: self.req,
                row: self.row,
                result: Err("worker dropped request".into()),
            });
        }
    }
}

// ------------------------------------------------------------------- shared

/// Everything a reactor shares with the server handle and its peers.
pub(crate) struct Shared {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub stats: Arc<ServeStats>,
    pub admission: Arc<Admission>,
    /// stop accepting new connections (shutdown drain phase)
    pub stop: Arc<AtomicBool>,
    /// tear down: reactors flush best-effort and exit
    pub halt: Arc<AtomicBool>,
    pub mailboxes: Vec<Arc<Mailbox>>,
    /// time base for the token buckets
    pub epoch: Instant,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

// ------------------------------------------------------------ conn machine

/// One reply in a connection's ordered response stream.
enum ReplySlot {
    /// fully rendered bytes, ready to enter the write buffer
    Ready(Vec<u8>),
    /// a predict request waiting on its rows; `results[i]` fills as
    /// completions arrive, in any order
    Pending {
        req: u64,
        results: Vec<Option<Result<f32, String>>>,
        remaining: usize,
        binary: bool,
    },
}

/// Per-connection state machine: receive buffer, parser mode, ordered
/// reply queue, write buffer.
struct Conn {
    stream: TcpStream,
    peer: IpAddr,
    mode: WireMode,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    replies: VecDeque<ReplySlot>,
    next_req: u64,
    /// current poller write-interest (toggled via `modify` only on change)
    want_write: bool,
    /// flush what's buffered, then close (quit, EOF, protocol error)
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream, peer: IpAddr) -> Conn {
        Conn {
            stream,
            peer,
            mode: WireMode::Text,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            replies: VecDeque::new(),
            next_req: 0,
            want_write: false,
            closing: false,
        }
    }

    fn push_text(&mut self, line: String) {
        let mut bytes = line.into_bytes();
        bytes.push(b'\n');
        self.replies.push_back(ReplySlot::Ready(bytes));
    }

    fn push_frame(&mut self, tag: ServeFrameTag, payload: &[u8]) {
        // payloads we emit are bounded well below FRAME_MAX (decision
        // blocks are at most as large as the request's feature block)
        let bytes = protocol::encode_serve_frame(tag, payload)
            .expect("server-emitted frame within FRAME_MAX");
        self.replies.push_back(ReplySlot::Ready(bytes));
    }

    fn push_err(&mut self, code: &str, msg: &str) {
        match self.mode {
            WireMode::Text => self.push_text(protocol::err_msg(code, msg)),
            WireMode::Binary => {
                self.push_frame(ServeFrameTag::Err, &protocol::encode_err_payload(code, msg))
            }
        }
    }

    /// Render every resolved reply at the queue's front into the write
    /// buffer.  A pending slot with unfinished rows blocks everything
    /// behind it — this is what keeps pipelined responses in request
    /// order.
    fn render_ready(&mut self) {
        loop {
            match self.replies.front_mut() {
                Some(ReplySlot::Ready(bytes)) => {
                    self.wbuf.append(bytes);
                    self.replies.pop_front();
                }
                Some(ReplySlot::Pending { remaining, results, binary, .. }) => {
                    if *remaining > 0 {
                        break;
                    }
                    let bytes = render_predict_reply(results, *binary);
                    self.wbuf.extend_from_slice(&bytes);
                    self.replies.pop_front();
                }
                None => break,
            }
        }
    }

    fn has_unsent(&self) -> bool {
        self.wpos < self.wbuf.len() || !self.replies.is_empty()
    }
}

/// Resolve a completed predict request to wire bytes.  First row error
/// wins (matching the old text writer): a sink dropped unsent renders
/// as `internal`, an execution failure as `predict-failed`.
fn render_predict_reply(results: &[Option<Result<f32, String>>], binary: bool) -> Vec<u8> {
    let mut vals = Vec::with_capacity(results.len());
    for r in results {
        match r.as_ref().expect("render_predict_reply on complete slot") {
            Ok(v) => vals.push(*v),
            Err(e) => {
                let code =
                    if e == "worker dropped request" { "internal" } else { "predict-failed" };
                return match binary {
                    true => protocol::encode_serve_frame(
                        ServeFrameTag::Err,
                        &protocol::encode_err_payload(code, e),
                    )
                    .expect("error frame within FRAME_MAX"),
                    false => {
                        let mut s = protocol::err_msg(code, e);
                        s.push('\n');
                        s.into_bytes()
                    }
                };
            }
        }
    }
    match binary {
        true => protocol::encode_serve_frame(
            ServeFrameTag::Decisions,
            &protocol::f32s_to_bytes(&vals),
        )
        .expect("decision block no larger than its request"),
        false => {
            let mut s = protocol::ok_values(&vals);
            s.push('\n');
            s.into_bytes()
        }
    }
}

// -------------------------------------------------------------------- slab

/// Connection table with generation-tagged tokens: a token is
/// `slot | gen << 32`, so a completion addressed to a closed (and
/// possibly recycled) slot is recognized as stale and dropped instead
/// of answering the wrong client.
struct Slab {
    slots: Vec<Option<Conn>>,
    gens: Vec<u32>,
    free: Vec<usize>,
}

impl Slab {
    fn new() -> Slab {
        Slab { slots: Vec::new(), gens: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, conn: Conn) -> u64 {
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(conn);
                s
            }
            None => {
                self.slots.push(Some(conn));
                self.gens.push(0);
                self.slots.len() - 1
            }
        };
        (slot as u64) | ((self.gens[slot] as u64) << 32)
    }

    fn parts(token: u64) -> (usize, u32) {
        ((token & 0xffff_ffff) as usize, (token >> 32) as u32)
    }

    fn get_mut(&mut self, token: u64) -> Option<&mut Conn> {
        let (slot, gen) = Slab::parts(token);
        if slot >= self.slots.len() || self.gens[slot] != gen {
            return None;
        }
        self.slots[slot].as_mut()
    }

    fn remove(&mut self, token: u64) -> Option<Conn> {
        let (slot, gen) = Slab::parts(token);
        if slot >= self.slots.len() || self.gens[slot] != gen {
            return None;
        }
        let conn = self.slots[slot].take();
        if conn.is_some() {
            // stale tokens from this slot's previous life must miss
            self.gens[slot] = self.gens[slot].wrapping_add(1);
            self.free.push(slot);
        }
        conn
    }

    fn tokens(&self) -> Vec<u64> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_some())
            .map(|(i, _)| (i as u64) | ((self.gens[i] as u64) << 32))
            .collect()
    }

    fn len(&self) -> usize {
        self.slots.iter().filter(|c| c.is_some()).count()
    }
}

// ------------------------------------------------------------------ reactor

struct Reactor {
    idx: usize,
    poller: Poller,
    mailbox: Arc<Mailbox>,
    shared: Arc<Shared>,
    slab: Slab,
    /// reactor 0 only: the listening socket
    listener: Option<TcpListener>,
    /// reactor 0 only: round-robin cursor over mailboxes
    next_rr: usize,
    last_prune_us: u64,
}

impl Reactor {
    fn run(mut self) {
        self.poller
            .register(self.mailbox.pipe.read_fd(), WAKE_TOKEN, true, false, false)
            .expect("register wake pipe");
        if let Some(l) = &self.listener {
            // level-triggered: connections left in the backlog re-report
            self.poller
                .register(l.as_raw_fd(), LISTENER_TOKEN, true, false, false)
                .expect("register listener");
        }
        let mut events: Vec<Event> = Vec::new();
        loop {
            let _ = self.poller.wait(&mut events, 100);
            // `Event` is Copy; move them out so `self` is free again
            let batch: Vec<Event> = std::mem::take(&mut events);
            for ev in &batch {
                match ev.token {
                    WAKE_TOKEN => {} // drained in take_mail below
                    LISTENER_TOKEN => self.accept_ready(),
                    token => self.on_conn_event(token, *ev),
                }
            }
            events = batch;
            self.take_mail();
            if self.shared.halt.load(Ordering::Acquire) {
                self.teardown();
                return;
            }
            if self.idx == 0 {
                let now_us = self.shared.now_us();
                if now_us.saturating_sub(self.last_prune_us) > 10_000_000 {
                    self.shared.admission.prune(now_us);
                    self.last_prune_us = now_us;
                }
            }
        }
    }

    /// Reactor 0: drain the accept queue, apply admission control,
    /// distribute admitted sockets round-robin.
    fn accept_ready(&mut self) {
        let shared = self.shared.clone();
        let Some(listener) = &self.listener else { return };
        if shared.stop.load(Ordering::Acquire) {
            return; // drain phase: leave the backlog alone, accept no more
        }
        loop {
            match listener.accept() {
                Ok((stream, addr)) => {
                    if !shared.admission.try_accept() {
                        shared.stats.conns_rejected.inc();
                        // best-effort protocol error before the close —
                        // nonblocking, a full socket buffer just drops it
                        let _ = stream.set_nonblocking(true);
                        let line = format!(
                            "{}\n",
                            protocol::err_msg(
                                "conn-limit",
                                &format!(
                                    "max_conns={} retry_after_ms=100",
                                    shared.admission.max_conns
                                ),
                            )
                        );
                        let _ = (&stream).write(line.as_bytes());
                        continue;
                    }
                    shared.stats.conns_accepted.inc();
                    shared.stats.conn_opened();
                    let target = self.next_rr % shared.mailboxes.len();
                    self.next_rr = self.next_rr.wrapping_add(1);
                    shared.mailboxes[target].push_conn(stream, addr.ip());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // transient accept errors (EMFILE, ECONNABORTED):
                // stop this round, poll again
                Err(_) => break,
            }
        }
    }

    /// Adopt a mailbox-delivered socket into this reactor's table.
    fn adopt(&mut self, stream: TcpStream, peer: IpAddr) {
        stream.set_nodelay(true).ok();
        if stream.set_nonblocking(true).is_err() {
            self.shared.admission.release();
            self.shared.stats.conn_closed();
            return;
        }
        let fd = stream.as_raw_fd();
        let token = self.slab.insert(Conn::new(stream, peer));
        if self.poller.register(fd, token, true, false, true).is_err() {
            self.slab.remove(token);
            self.shared.admission.release();
            self.shared.stats.conn_closed();
            return;
        }
        // the socket may have carried data before registration; treat
        // adoption as a readable edge
        self.on_conn_event(
            token,
            Event { token, readable: true, writable: false, hangup: false },
        );
    }

    fn on_conn_event(&mut self, token: u64, ev: Event) {
        let shared = self.shared.clone();
        let mailbox = self.mailbox.clone();
        let Some(conn) = self.slab.get_mut(token) else { return };
        let mut dead = false;
        if ev.readable || ev.hangup {
            match read_some(conn) {
                Ok(eof) => {
                    process_input(conn, &shared, &mailbox, token);
                    if eof {
                        conn.closing = true;
                    }
                }
                Err(_) => dead = true,
            }
        }
        if dead || self.pump(token) {
            self.close_conn(token);
        }
    }

    /// Render resolved replies, flush the write buffer, maintain
    /// write interest.  Returns true when the connection should close.
    fn pump(&mut self, token: u64) -> bool {
        let Some(conn) = self.slab.get_mut(token) else { return false };
        conn.render_ready();
        if write_some(conn).is_err() {
            return true;
        }
        let unsent = conn.wpos < conn.wbuf.len();
        if conn.closing && !conn.has_unsent() {
            return true;
        }
        if unsent != conn.want_write {
            conn.want_write = unsent;
            let fd = conn.stream.as_raw_fd();
            let _ = self.poller.modify(fd, token, true, unsent, true);
        }
        false
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.slab.remove(token) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.shared.admission.release();
            self.shared.stats.conn_closed();
            // conn drops here; the socket closes with it
        }
    }

    /// Drain the wake pipe, then take the mailbox: adopted sockets and
    /// worker completions.  Pumps each touched connection once.
    fn take_mail(&mut self) {
        self.mailbox.pipe.drain();
        let incoming = std::mem::take(&mut *self.mailbox.incoming.lock().unwrap());
        for (stream, peer) in incoming {
            self.adopt(stream, peer);
        }
        let done = std::mem::take(&mut *self.mailbox.completions.lock().unwrap());
        let mut touched: Vec<u64> = Vec::new();
        for d in done {
            if !touched.contains(&d.token) {
                touched.push(d.token);
            }
            self.apply_done(d);
        }
        for token in touched {
            if self.pump(token) {
                self.close_conn(token);
            }
        }
    }

    /// Route one completion into its connection's pending reply slot.
    /// A missing connection (closed mid-flight) or missing slot
    /// (request already answered `err busy`) is not an error — the
    /// completion is simply dropped.
    fn apply_done(&mut self, done: RowDone) {
        let Some(conn) = self.slab.get_mut(done.token) else { return };
        for slot in conn.replies.iter_mut() {
            if let ReplySlot::Pending { req, results, remaining, .. } = slot {
                if *req == done.req {
                    let i = done.row as usize;
                    if i < results.len() && results[i].is_none() {
                        results[i] = Some(done.result);
                        *remaining -= 1;
                    }
                    return;
                }
            }
        }
    }

    /// Shutdown: workers are already joined (every pending row has
    /// completed or error-completed), so render everything, give the
    /// sockets a short best-effort flush window, and close.
    fn teardown(&mut self) {
        self.take_mail();
        let deadline = Instant::now() + Duration::from_millis(500);
        loop {
            let mut unsent = false;
            for token in self.slab.tokens() {
                if self.pump(token) {
                    self.close_conn(token);
                } else if self.slab.get_mut(token).is_some_and(|c| c.has_unsent()) {
                    unsent = true;
                }
            }
            if !unsent || Instant::now() >= deadline {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        for token in self.slab.tokens() {
            self.close_conn(token);
        }
        debug_assert_eq!(self.slab.len(), 0);
    }
}

// ----------------------------------------------------------- conn handlers

/// Drain the socket to `WouldBlock` (the edge-triggered contract).
/// `Ok(true)` = orderly EOF.  The receive buffer is capped one frame
/// above [`FRAME_MAX`]: a peer that streams more without completing a
/// frame is killed, not buffered.
fn read_some(conn: &mut Conn) -> std::io::Result<bool> {
    let mut sp = crate::obs::span("serve.io.read");
    let mut tmp = [0u8; 16 * 1024];
    loop {
        match (&conn.stream).read(&mut tmp) {
            Ok(0) => return Ok(true),
            Ok(n) => {
                sp.add_bytes(n as u64);
                conn.rbuf.extend_from_slice(&tmp[..n]);
                if conn.rbuf.len() > FRAME_MAX + protocol::frame_overhead() {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "receive buffer overrun",
                    ));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Flush the write buffer as far as the socket allows.
fn write_some(conn: &mut Conn) -> std::io::Result<()> {
    let mut sp = crate::obs::span("serve.io.write");
    while conn.wpos < conn.wbuf.len() {
        match (&conn.stream).write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket wrote zero",
                ))
            }
            Ok(n) => {
                sp.add_bytes(n as u64);
                conn.wpos += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    } else if conn.wpos > 64 * 1024 {
        // reclaim flushed prefix so a slow reader doesn't pin old bytes
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    Ok(())
}

/// Run the connection's parser over whatever is buffered, in its
/// current mode (a `serve-hello` can switch the mode mid-buffer —
/// pipelined frames right behind the hello line parse correctly).
fn process_input(conn: &mut Conn, shared: &Shared, mailbox: &Arc<Mailbox>, token: u64) {
    loop {
        if conn.closing {
            return;
        }
        let progressed = match conn.mode {
            WireMode::Text => step_text(conn, shared, mailbox, token),
            WireMode::Binary => step_binary(conn, shared, mailbox, token),
        };
        if !progressed {
            return;
        }
    }
}

/// Consume at most one text line.  Returns false when no full line is
/// buffered.
fn step_text(conn: &mut Conn, shared: &Shared, mailbox: &Arc<Mailbox>, token: u64) -> bool {
    let Some(nl) = conn.rbuf.iter().position(|&b| b == b'\n') else {
        if conn.rbuf.len() > MAX_LINE {
            conn.push_err("bad-request", "line too long");
            conn.closing = true;
        }
        return false;
    };
    if nl > MAX_LINE {
        conn.push_err("bad-request", "line too long");
        conn.closing = true;
        return false;
    }
    let line = String::from_utf8_lossy(&conn.rbuf[..nl]).trim().to_string();
    conn.rbuf.drain(..=nl);
    if line.is_empty() {
        return true;
    }
    if let Some(mode) = protocol::negotiate_serve_hello(&line) {
        conn.mode = mode;
        conn.push_text(protocol::serve_hello_ack(mode));
        return true;
    }
    match dispatch_request(&line, &shared.registry, &shared.stats) {
        Dispatch::Ready(reply) => conn.push_text(reply),
        Dispatch::Quit => {
            conn.push_text(protocol::ok_msg("bye"));
            conn.closing = true;
        }
        Dispatch::Predict { served, name, rows } => {
            submit_predict(conn, shared, mailbox, token, served, &name, rows, false);
        }
    }
    true
}

/// Consume at most one binary frame.  Returns false when no complete
/// frame is buffered.
fn step_binary(conn: &mut Conn, shared: &Shared, mailbox: &Arc<Mailbox>, token: u64) -> bool {
    let (tag, len) = match protocol::peek_serve_frame(&conn.rbuf) {
        None => return false,
        Some(Err(e)) => {
            // corrupt framing: after this no byte boundary can be
            // trusted — answer once and close
            conn.push_err("bad-frame", &e);
            conn.closing = true;
            return false;
        }
        Some(Ok(hdr)) => hdr,
    };
    let total = protocol::frame_overhead() + len;
    if conn.rbuf.len() < total {
        return false;
    }
    let payload = conn.rbuf[protocol::frame_overhead()..total].to_vec();
    conn.rbuf.drain(..total);
    match tag {
        ServeFrameTag::Ping => conn.push_frame(ServeFrameTag::Pong, &[]),
        ServeFrameTag::Quit => {
            conn.push_frame(ServeFrameTag::Bye, &[]);
            conn.closing = true;
        }
        ServeFrameTag::Predict => handle_binary_predict(conn, shared, mailbox, token, &payload),
        // server-to-client tags arriving at the server are a protocol
        // violation, not a crash
        ServeFrameTag::Decisions | ServeFrameTag::Err | ServeFrameTag::Pong
        | ServeFrameTag::Bye => {
            conn.push_err("bad-request", &format!("unexpected frame tag {:#04x}", tag as u8));
            conn.closing = true;
        }
    }
    true
}

fn handle_binary_predict(
    conn: &mut Conn,
    shared: &Shared,
    mailbox: &Arc<Mailbox>,
    token: u64,
    payload: &[u8],
) {
    let frame = {
        let _sp = crate::obs::span("serve.parse");
        match protocol::decode_predict_payload(payload) {
            Ok(f) => f,
            Err(e) => {
                conn.push_err("bad-request", &e);
                return;
            }
        }
    };
    shared.stats.requests.add(frame.rows as u64);
    if frame.dim == 0 {
        shared.stats.errors.add(frame.rows as u64);
        conn.push_err("bad-request", "predict frame with dim 0");
        return;
    }
    let served = match shared.registry.get(&frame.model) {
        Ok(m) => m,
        Err(e) => {
            shared.stats.errors.add(frame.rows as u64);
            conn.push_err("unknown-model", &format!("{e:#}"));
            return;
        }
    };
    if served.dim > 0 && frame.dim != served.dim {
        shared.stats.errors.add(frame.rows as u64);
        conn.push_err(
            "dim-mismatch",
            &format!("model `{}` expects dim {}, got {}", frame.model, served.dim, frame.dim),
        );
        return;
    }
    // the zero-copy-ish path: raw LE floats straight from the receive
    // buffer into batcher rows — no text parse, no per-value format
    let rows: Vec<Vec<f32>> =
        frame.data.chunks_exact(frame.dim).map(|c| c.to_vec()).collect();
    let model = frame.model;
    submit_predict(conn, shared, mailbox, token, served, &model, rows, true);
}

/// Common predict tail for both protocols: charge the rate limiter,
/// open an ordered reply slot, submit every row with a reactor sink.
#[allow(clippy::too_many_arguments)]
fn submit_predict(
    conn: &mut Conn,
    shared: &Shared,
    mailbox: &Arc<Mailbox>,
    token: u64,
    served: Arc<ServedModel>,
    name: &str,
    rows: Vec<Vec<f32>>,
    binary: bool,
) {
    let n = rows.len();
    if n == 0 {
        // only reachable from the binary path (text predicts always
        // carry at least one row): an empty request gets an empty block
        conn.push_frame(ServeFrameTag::Decisions, &[]);
        return;
    }
    if let Err(retry_ms) = shared.admission.try_spend(conn.peer, n as u64, shared.now_us()) {
        shared.stats.rate_limited.inc();
        conn.push_err("rate-limited", &format!("retry_after_ms={retry_ms}"));
        return;
    }
    let req = conn.next_req;
    conn.next_req += 1;
    conn.replies.push_back(ReplySlot::Pending {
        req,
        results: vec![None; n],
        remaining: n,
        binary,
    });
    for (i, row) in rows.into_iter().enumerate() {
        let sink = ReplySink::Reactor(ReactorSink::new(mailbox.clone(), token, req, i as u32));
        match shared.batcher.submit_with(&served, row, sink) {
            Ok(()) => {}
            Err(SubmitError::Busy { retry_after_ms }) => {
                shared.stats.rejected.inc();
                // rows already submitted stay in flight; their
                // completions find the slot replaced and are dropped
                let bytes = match binary {
                    true => protocol::encode_serve_frame(
                        ServeFrameTag::Err,
                        &protocol::encode_err_payload(
                            "busy",
                            &format!("retry_after_ms={retry_after_ms}"),
                        ),
                    )
                    .expect("busy frame within FRAME_MAX"),
                    false => {
                        let mut s = protocol::err_busy(retry_after_ms);
                        s.push('\n');
                        s.into_bytes()
                    }
                };
                replace_back_slot(conn, req, bytes);
                return;
            }
            Err(SubmitError::Closed) => {
                shared.stats.errors.add(n as u64);
                let bytes = match binary {
                    true => protocol::encode_serve_frame(
                        ServeFrameTag::Err,
                        &protocol::encode_err_payload("unavailable", "server shutting down"),
                    )
                    .expect("error frame within FRAME_MAX"),
                    false => {
                        let mut s = protocol::err_msg("unavailable", "server shutting down");
                        s.push('\n');
                        s.into_bytes()
                    }
                };
                replace_back_slot(conn, req, bytes);
                return;
            }
        }
    }
    shared.stats.note_model(name, n as u64);
}

/// Swap the just-opened pending slot (always the newest) for a ready
/// error reply.
fn replace_back_slot(conn: &mut Conn, req: u64, bytes: Vec<u8>) {
    if let Some(slot) = conn.replies.back_mut() {
        if matches!(slot, ReplySlot::Pending { req: r, .. } if *r == req) {
            *slot = ReplySlot::Ready(bytes);
            return;
        }
    }
    debug_assert!(false, "predict slot vanished before its error reply");
}

// ------------------------------------------------------------ thread pool

/// Spawn the reactor pool.  Reactor 0 owns the listener.  This
/// function (plus the worker/flusher bootstraps below) is the single
/// `thread::spawn` site in `serve/`.
pub(crate) fn spawn_reactors(
    listener: TcpListener,
    shared: Arc<Shared>,
) -> std::io::Result<Vec<thread::JoinHandle<()>>> {
    let mut handles = Vec::with_capacity(shared.mailboxes.len());
    let mut listener = Some(listener);
    for (idx, mailbox) in shared.mailboxes.iter().enumerate() {
        let reactor = Reactor {
            idx,
            poller: Poller::new()?,
            mailbox: mailbox.clone(),
            shared: shared.clone(),
            slab: Slab::new(),
            listener: if idx == 0 { listener.take() } else { None },
            next_rr: 0,
            last_prune_us: 0,
        };
        handles.push(
            thread::Builder::new()
                .name(format!("serve-io-{idx}"))
                .spawn(move || reactor.run())
                .expect("spawn reactor thread"),
        );
    }
    Ok(handles)
}

/// Spawn the predict worker pool (drains the batch queue).
pub(crate) fn spawn_workers(
    workers: usize,
    queue: Arc<BoundedQueue<Batch>>,
    stats: Arc<ServeStats>,
) -> Vec<thread::JoinHandle<()>> {
    (0..workers.max(1))
        .map(|i| {
            let queue = queue.clone();
            let stats = stats.clone();
            thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || worker_loop(&queue, &stats))
                .expect("spawn worker thread")
        })
        .collect()
}

/// Spawn the deadline flusher: ticks at a quarter of the delay bound
/// so a lone request waits at most ~1.25 × `max_delay`.
pub(crate) fn spawn_flusher(
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    tick: Duration,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("serve-flusher".into())
        .spawn(move || {
            // Acquire pairs with shutdown's Release store: everything
            // written before the stop was requested is visible here
            while !stop.load(Ordering::Acquire) {
                batcher.flush_expired();
                thread::sleep(tick);
            }
        })
        .expect("spawn flusher thread")
}

/// Fallback peer address when the OS can't report one.
pub(crate) fn unknown_peer() -> IpAddr {
    IpAddr::V4(Ipv4Addr::UNSPECIFIED)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ip(last: u8) -> IpAddr {
        IpAddr::V4(Ipv4Addr::new(10, 0, 0, last))
    }

    #[test]
    fn admission_caps_connections() {
        let a = Admission::new(2, 0);
        assert!(a.try_accept());
        assert!(a.try_accept());
        assert!(!a.try_accept());
        a.release();
        assert!(a.try_accept());
        assert_eq!(a.open(), 2);
        // zero cap = unlimited
        let a = Admission::new(0, 0);
        for _ in 0..100 {
            assert!(a.try_accept());
        }
    }

    #[test]
    fn admission_release_saturates() {
        let a = Admission::new(1, 0);
        a.release(); // stray release on an empty table
        assert_eq!(a.open(), 0);
        assert!(a.try_accept());
        assert!(!a.try_accept());
    }

    #[test]
    fn token_bucket_enforces_rate_and_refills() {
        let a = Admission::new(0, 100); // 100 rows/sec, burst 100
        // the full burst passes immediately
        assert!(a.try_spend(ip(1), 100, 0).is_ok());
        // the bucket is empty: the next row is refused with a hint
        let retry = a.try_spend(ip(1), 1, 0).unwrap_err();
        assert!(retry >= 1);
        // 10ms refills one row's worth at 100 rows/sec
        assert!(a.try_spend(ip(1), 1, 10_000).is_ok());
        assert!(a.try_spend(ip(1), 1, 10_000).is_err());
        // a full second refills the whole burst, never more
        assert!(a.try_spend(ip(1), 100, 1_500_000).is_ok());
    }

    #[test]
    fn token_bucket_is_per_client() {
        let a = Admission::new(0, 10);
        assert!(a.try_spend(ip(1), 10, 0).is_ok());
        assert!(a.try_spend(ip(1), 1, 0).is_err());
        // a different peer has its own full bucket
        assert!(a.try_spend(ip(2), 10, 0).is_ok());
        assert_eq!(a.tracked_clients(), 2);
    }

    #[test]
    fn oversized_request_costs_a_full_bucket() {
        let a = Admission::new(0, 10);
        // 50 rows > burst 10: passes when the bucket is full (costing
        // everything), rather than being forever unpassable
        assert!(a.try_spend(ip(1), 50, 0).is_ok());
        assert!(a.try_spend(ip(1), 1, 0).is_err());
        assert!(a.try_spend(ip(1), 50, 1_000_000).is_ok());
    }

    #[test]
    fn bucket_prune_drops_idle_clients() {
        let a = Admission::new(0, 10);
        let _ = a.try_spend(ip(1), 1, 0);
        let _ = a.try_spend(ip(2), 1, 30_000_000);
        a.prune(70_000_000); // ip(1) idle 70s, ip(2) idle 40s
        assert_eq!(a.tracked_clients(), 1);
        a.prune(120_000_000);
        assert_eq!(a.tracked_clients(), 0);
    }

    #[test]
    fn rate_limit_disabled_by_default() {
        let a = Admission::new(0, 0);
        assert!(a.try_spend(ip(1), u64::MAX, 0).is_ok());
        assert_eq!(a.tracked_clients(), 0);
    }

    #[test]
    fn slab_tokens_are_generation_tagged() {
        // fabricate conns over a loopback listener
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut slab = Slab::new();
        let c1 = TcpStream::connect(addr).unwrap();
        let t1 = slab.insert(Conn::new(c1, ip(1)));
        assert!(slab.get_mut(t1).is_some());
        assert!(slab.remove(t1).is_some());
        // the slot recycles under a new generation: the old token
        // must miss, the new one must hit
        let c2 = TcpStream::connect(addr).unwrap();
        let t2 = slab.insert(Conn::new(c2, ip(2)));
        assert_ne!(t1, t2);
        assert!(slab.get_mut(t1).is_none());
        assert!(slab.remove(t1).is_none());
        assert!(slab.get_mut(t2).is_some());
        assert_eq!(slab.len(), 1);
    }

    #[test]
    fn render_predict_reply_text_and_binary() {
        let done = vec![Some(Ok(1.5f32)), Some(Ok(-2.0))];
        assert_eq!(render_predict_reply(&done, false), b"ok 1.5;-2\n".to_vec());
        let frame = render_predict_reply(&done, true);
        let (tag, payload) =
            protocol::read_serve_frame(&mut std::io::Cursor::new(&frame)).unwrap();
        assert_eq!(tag, ServeFrameTag::Decisions);
        assert_eq!(protocol::bytes_to_f32s(&payload).unwrap(), vec![1.5, -2.0]);

        // first error wins; the dropped-sink sentinel maps to `internal`
        let failed = vec![Some(Ok(1.0f32)), Some(Err("worker dropped request".into()))];
        let line = String::from_utf8(render_predict_reply(&failed, false)).unwrap();
        assert!(line.starts_with("err internal "), "`{line}`");
        let failed = vec![Some(Err("shard gone".into()))];
        let frame = render_predict_reply(&failed, true);
        let (tag, payload) =
            protocol::read_serve_frame(&mut std::io::Cursor::new(&frame)).unwrap();
        assert_eq!(tag, ServeFrameTag::Err);
        let (code, msg) = protocol::decode_err_payload(&payload).unwrap();
        assert_eq!((code.as_str(), msg.as_str()), ("predict-failed", "shard gone"));
    }
}
