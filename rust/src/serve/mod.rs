//! `serve` — a batched, multi-model inference server with cell-routed
//! sharded bundles, driven by a fixed-size event-loop reactor pool.
//!
//! liquidSVM splits training from testing via persisted `.sol` models
//! precisely so prediction can run as its own fast process (paper §2);
//! this subsystem is that process, grown into a server.  Pipeline:
//!
//! ```text
//! 10k+ TCP conns ──► reactor pool (epoll/poll readiness,   ─► Batcher (per (model, cell),
//!                    nonblocking reads/writes, admission      size/deadline flush,
//!                    control, text or binary framing)          backpressure)
//!                          ▲                                       │  bounded queue
//!                          │ per-row completions             worker pool ─► fused predict
//!                          └────────── mailbox + wake ◄───────────┘     (one shard)
//! ```
//!
//! Connections do **not** get a thread each: `--io-threads` reactors
//! ([`eventloop`]) own every socket through nonblocking readiness
//! polling ([`poll`]), which is what makes 10k+ concurrent
//! connections a memory problem (one small state machine each)
//! instead of a scheduler problem (10k stacks).  Admission control
//! guards the door: a `--max-conns` cap refuses sockets cleanly at
//! accept time and a per-client token bucket (`--rate-limit`) refuses
//! predict rows with a `retry_after_ms` hint instead of queueing
//! without bound.
//!
//! Two wire formats share each connection: the line-oriented text
//! protocol (unchanged), and a length-prefixed binary framing
//! negotiated by `serve-hello v1 binary` that moves feature rows and
//! decisions as raw little-endian f32 blocks — no float formatting or
//! parsing on the hot path ([`protocol`] documents both grammars).
//!
//! Concurrent rows — across connections and pipelined within one —
//! coalesce into shape-bucketed batches before a single fused
//! `predict` call, so the per-call overhead (routing, kernel setup,
//! and on the XLA backend the padded artifact execution) is amortized
//! the same way the CV engine amortizes Gram work across the γ grid.
//!
//! For cell-decomposed models persisted as `.sol.d/` bundles, the
//! registry loads only the manifest; each incoming row is walked
//! through the model's `CellRouter` at submit time and batches
//! per (model, cell), so a fused call touches exactly one lazily
//! loaded shard.  Resident shards are bounded by a byte-budgeted LRU
//! (`max_shard_bytes`), which is what lets one server instance answer
//! traffic against a model trained on millions of samples without
//! ever holding it fully in memory (see DESIGN.md §Serving).
//!
//! The backpressure contract: the worker queue's capacity is the
//! server's entire memory budget for in-flight batches.  When a size
//! flush finds it full, the newest row is refused with
//! `err busy retry_after_ms=…` and everything previously accepted
//! stays queued — clients back off and retry; nothing buffers without
//! bound.
//!
//! [`protocol`] documents the wire formats; [`Server::start`] returns
//! a handle usable in-process (tests bind port 0); [`run_load`] is the
//! thread-per-connection load generator behind `liquidsvm client` and
//! [`swarm::run_swarm`] its event-driven sibling that holds tens of
//! thousands of sockets open from a handful of threads.

pub mod batcher;
pub mod eventloop;
pub mod poll;
pub mod protocol;
pub mod registry;
pub mod stats;
pub mod swarm;
pub mod worker;

pub use batcher::{Batch, Batcher, BatcherConfig, ReplySink, SubmitError};
pub use registry::{Registry, RouteTarget, ServedModel, ShardUsage};
pub use stats::ServeStats;
pub use swarm::run_swarm;
pub use worker::BoundedQueue;

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::thread;

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::config::Config;
use eventloop::{Admission, Mailbox, Shared};
use protocol::{Request, ServeFrameTag, WireMode};

/// Server configuration (`liquidsvm serve` flags map 1:1 onto this).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub host: String,
    /// 0 picks an ephemeral port (tests)
    pub port: u16,
    /// rows per fused predict call (size flush trigger)
    pub max_batch: usize,
    /// max wait of the oldest pending row (deadline flush trigger)
    pub max_delay: Duration,
    /// worker-queue capacity in batches (the backpressure bound)
    pub queue_cap: usize,
    /// predict worker threads
    pub workers: usize,
    /// LRU bound on resident models
    pub max_models: usize,
    /// per-bundle byte budget for lazily loaded shards
    pub max_shard_bytes: u64,
    /// log any request whose enqueue→response latency reaches this
    /// many µs (0 = off) — the serve-side slow log
    pub slow_log_us: u64,
    /// reactor (event-loop) threads; 0 = auto (up to 4, bounded by
    /// the machine's parallelism)
    pub io_threads: usize,
    /// open-connection cap enforced at accept time; 0 = unlimited
    pub max_conns: usize,
    /// per-client token-bucket rate limit in predict rows/sec (burst =
    /// one second's budget); 0 = off
    pub rate_limit: u64,
    /// runtime choices (backend, threads) applied to loaded models
    pub model_config: Config,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 4950,
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 128,
            workers: 2,
            max_models: 8,
            max_shard_bytes: registry::DEFAULT_SHARD_BUDGET,
            slow_log_us: 0,
            io_threads: 0,
            max_conns: 0,
            rate_limit: 0,
            model_config: Config::default(),
        }
    }
}

impl ServeConfig {
    /// Resolve `io_threads=0` to the auto default.
    fn resolved_io_threads(&self) -> usize {
        if self.io_threads > 0 {
            return self.io_threads;
        }
        thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(1, 4)
    }
}

/// A running server; dropping it does NOT stop the threads — call
/// [`Server::shutdown`].
pub struct Server {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub stats: Arc<ServeStats>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<BoundedQueue<Batch>>,
    shared: Arc<Shared>,
    /// workers + flusher
    threads: Vec<thread::JoinHandle<()>>,
    /// the reactor pool, joined last (after `halt`)
    reactors: Vec<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the reactor pool + flusher + workers, return
    /// immediately.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr()?;

        let stats = Arc::new(ServeStats::new());
        stats.set_slow_log_us(cfg.slow_log_us);
        let registry = Arc::new(
            Registry::new(cfg.model_config.clone(), cfg.max_models)
                .shard_budget(cfg.max_shard_bytes),
        );
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let batcher = Arc::new(Batcher::new(
            BatcherConfig { max_batch: cfg.max_batch, max_delay: cfg.max_delay },
            queue.clone(),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let halt = Arc::new(AtomicBool::new(false));

        let io_threads = cfg.resolved_io_threads();
        let mailboxes: Vec<Arc<Mailbox>> = (0..io_threads)
            .map(|_| Mailbox::new().map(Arc::new))
            .collect::<std::io::Result<_>>()
            .context("creating reactor wake pipes")?;
        let shared = Arc::new(Shared {
            registry: registry.clone(),
            batcher: batcher.clone(),
            stats: stats.clone(),
            admission: Arc::new(Admission::new(cfg.max_conns, cfg.rate_limit)),
            stop: stop.clone(),
            halt,
            mailboxes,
            epoch: Instant::now(),
        });

        let mut threads = eventloop::spawn_workers(cfg.workers, queue.clone(), stats.clone());
        let tick = (cfg.max_delay / 4).max(Duration::from_micros(250));
        threads.push(eventloop::spawn_flusher(batcher.clone(), stop.clone(), tick));
        let reactors =
            eventloop::spawn_reactors(listener, shared.clone()).context("spawning reactors")?;

        Ok(Server { registry, batcher, stats, addr, stop, queue, shared, threads, reactors })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight work, flush replies, join
    /// everything.
    pub fn shutdown(self) {
        // Release, paired with the Acquire loads in the flusher /
        // reactor loops.  With Relaxed on both sides a thread could
        // observe `stop` while missing writes sequenced before it
        // (loom catches this: see `stop_flag_publishes` in
        // tests/loom_models.rs); the flag is a publication edge, not a
        // mere counter.
        self.stop.store(true, Ordering::Release);
        // drain pending rows before closing so in-flight clients get
        // answers instead of hung receivers; the flush can find the
        // queue full under load, so keep retrying (bounded) while the
        // still-running workers make room
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            self.batcher.flush_all();
            if !self.batcher.has_pending() || Instant::now() >= deadline {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        // anything still pending after the deadline fails fast instead
        // of leaving its waiters blocked forever (a dropped reply sink
        // delivers a "worker dropped request" completion); this also
        // closes the batcher, so a reactor that parsed a request before
        // noticing `stop` cannot park a fresh row in a pending map no
        // flusher will ever visit again
        self.batcher.discard_pending();
        self.queue.close();
        for h in self.threads {
            let _ = h.join();
        }
        // workers are gone: every submitted row has a completion in
        // some mailbox.  Now halt the reactors — they apply those
        // completions, flush what the sockets will take, and exit.
        self.shared.halt.store(true, Ordering::Release);
        for mb in &self.shared.mailboxes {
            mb.wake();
        }
        for h in self.reactors {
            let _ = h.join();
        }
    }
}

/// One parsed request, resolved as far as the shared state allows —
/// the seam between protocol handling (this module) and connection
/// scheduling ([`eventloop`]).  `Predict` carries densified rows ready
/// for the batcher; submission itself is the caller's job because the
/// reply path differs per transport.
pub(crate) enum Dispatch {
    /// a complete response line (no trailing newline)
    Ready(String),
    Predict { served: Arc<ServedModel>, name: String, rows: Vec<Vec<f32>> },
    Quit,
}

/// Handle one text-protocol request line.
pub(crate) fn dispatch_request(line: &str, registry: &Registry, stats: &ServeStats) -> Dispatch {
    let req = {
        let _sp = crate::obs::span("serve.parse");
        match protocol::parse_request(line) {
            Ok(r) => r,
            Err(msg) => return Dispatch::Ready(protocol::err_msg("bad-request", &msg)),
        }
    };
    match req {
        Request::Quit => Dispatch::Quit,
        Request::Ping => Dispatch::Ready(protocol::ok_msg("pong")),
        Request::Stats => Dispatch::Ready(protocol::ok_msg(
            &stats.report(registry.len(), &registry.shard_usage()),
        )),
        Request::Metrics { json } => {
            let fams = metrics_families(registry, stats);
            if json {
                Dispatch::Ready(protocol::ok_msg(&crate::obs::registry::json_text(&fams)))
            } else {
                // the protocol's only multi-line response: the header
                // announces the payload line count so lockstep readers
                // know how much to consume (see `protocol` docs)
                let body = crate::obs::registry::prometheus_text(&fams);
                let body = body.trim_end_matches('\n');
                let n = body.lines().count();
                Dispatch::Ready(format!("ok metrics lines={n}\n{body}"))
            }
        }
        Request::Shards { name } => match registry.get(&name) {
            Ok(m) => match m.shard_info() {
                Some(info) => {
                    let bundle = m.bundle.as_ref().expect("shard_info implies bundle");
                    let per_cell: Vec<String> = info
                        .iter()
                        .map(|s| {
                            format!(
                                "{}:{}:{}",
                                s.cell,
                                s.hits,
                                if s.resident { 1 } else { 0 }
                            )
                        })
                        .collect();
                    Dispatch::Ready(protocol::ok_msg(&format!(
                        "name={} shards={} resident={} resident_bytes={} total_bytes={} \
                         cell:hits:resident {}",
                        name,
                        info.len(),
                        bundle.resident_shards(),
                        bundle.resident_bytes(),
                        bundle.manifest().total_bytes(),
                        per_cell.join(" ")
                    )))
                }
                None => Dispatch::Ready(protocol::err_msg(
                    "not-sharded",
                    &format!("model `{name}` is not a sharded bundle"),
                )),
            },
            Err(e) => Dispatch::Ready(protocol::err_msg("unknown-model", &format!("{e:#}"))),
        },
        Request::Load { name, path } => match registry.load(&name, Path::new(&path)) {
            Ok(m) => {
                let detail = match &m.bundle {
                    Some(b) => format!("shards={}", b.manifest().n_cells()),
                    None => format!("units={}", m.model.units.len()),
                };
                Dispatch::Ready(protocol::ok_msg(&format!(
                    "loaded {name} dim={} {detail}",
                    m.dim
                )))
            }
            Err(e) => Dispatch::Ready(protocol::err_msg("load-failed", &format!("{e:#}"))),
        },
        Request::Unload { name } => {
            if registry.unload(&name) {
                Dispatch::Ready(protocol::ok_msg(&format!("unloaded {name}")))
            } else {
                Dispatch::Ready(protocol::err_msg("unknown-model", &format!("no model `{name}`")))
            }
        }
        Request::Predict { model, rows } => {
            stats.requests.add(rows.len() as u64);
            let served = match registry.get(&model) {
                Ok(m) => m,
                Err(e) => {
                    stats.errors.add(rows.len() as u64);
                    return Dispatch::Ready(protocol::err_msg(
                        "unknown-model",
                        &format!("{e:#}"),
                    ));
                }
            };
            // resolve every wire row to a dense feature vector before
            // batching: dense rows must match the model dim exactly
            // (when known); sparse idx:val rows densify against it here
            // — the serve path's densification boundary (the shard
            // expansions are dense; see DESIGN.md §Data-plane)
            // a rejected request fails ALL its rows with one err reply,
            // so the error counter advances by the full row count —
            // keeping `requests - errors` = successful predictions
            let total_rows = rows.len() as u64;
            let mut dense_rows: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for row in rows {
                let err = match &row {
                    protocol::PredictRow::Dense(v) if served.dim > 0 && v.len() != served.dim => {
                        Some(format!(
                            "model `{model}` expects dim {}, got {}",
                            served.dim,
                            v.len()
                        ))
                    }
                    protocol::PredictRow::Sparse(_) if served.dim == 0 => Some(format!(
                        "model `{model}` has unknown dim; sparse rows need a known dim"
                    )),
                    _ => None,
                };
                if let Some(msg) = err {
                    stats.errors.add(total_rows);
                    return Dispatch::Ready(protocol::err_msg("dim-mismatch", &msg));
                }
                let dim = if served.dim > 0 { served.dim } else { row.min_dim() };
                match row.densify(dim) {
                    Ok(v) => dense_rows.push(v),
                    Err(msg) => {
                        stats.errors.add(total_rows);
                        return Dispatch::Ready(protocol::err_msg("dim-mismatch", &msg));
                    }
                }
            }
            Dispatch::Predict { served, name: model, rows: dense_rows }
        }
    }
}

/// Scrape-time metric families for this server: the process-global
/// registry (solver/Gram/cell counters) plus the server's own
/// instance-local counters, gauges, and the request-latency histogram
/// (see DESIGN.md §Observability for the exposition contract).
fn metrics_families(
    registry: &Registry,
    stats: &ServeStats,
) -> Vec<crate::obs::registry::Family> {
    use crate::obs::registry::Family;
    let shards = registry.shard_usage();
    let mut fams = crate::obs::registry::global().families();
    fams.push(Family::gauge(
        "liquidsvm_serve_uptime_seconds",
        "Seconds since this server started",
        stats.uptime_s() as f64,
    ));
    fams.push(Family::gauge(
        "liquidsvm_serve_models",
        "Models resident in the registry",
        registry.len() as f64,
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_requests",
        "Prediction rows accepted into the batcher",
        stats.requests.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_rejected",
        "Prediction rows rejected with backpressure",
        stats.rejected.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_errors",
        "Prediction rows that failed after acceptance",
        stats.errors.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_slow_requests",
        "Rows whose latency reached the slow-log threshold",
        stats.slow.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_batches",
        "Fused predict calls executed",
        stats.batches.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_batched_rows",
        "Real rows across all executed batches",
        stats.batched_rows.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_padded_rows",
        "Padding rows added to reach shape buckets",
        stats.padded_rows.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_conns_accepted",
        "Connections admitted by the event loop",
        stats.conns_accepted.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_conns_rejected",
        "Connections refused at accept time by the max-conns cap",
        stats.conns_rejected.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_conns_rate_limited",
        "Predict requests refused by the per-client token bucket",
        stats.rate_limited.get(),
    ));
    fams.push(Family::gauge(
        "liquidsvm_serve_conns_open",
        "Currently open connections",
        stats.conns_open() as f64,
    ));
    fams.push(Family::gauge(
        "liquidsvm_serve_shard_resident_bytes",
        "Bytes of lazily loaded bundle shards currently resident",
        shards.resident_bytes as f64,
    ));
    fams.push(Family::histogram(
        "liquidsvm_serve_request_latency_us",
        "Enqueue to response-ready latency per row (microseconds)",
        &stats.latency,
    ));
    fams
}

// ------------------------------------------------------------ client

/// Load-generation parameters (`liquidsvm client` flags).
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub addr: String,
    pub model: String,
    /// concurrent TCP connections
    pub connections: usize,
    /// single-row requests per connection
    pub requests: usize,
    /// requests written back-to-back before reading responses (1 = a
    /// strict request/response lockstep, i.e. no client-side batching)
    pub pipeline: usize,
}

/// Aggregated result of a load run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// request lines/frames written (including busy retries)
    pub sent: usize,
    /// successful predictions
    pub ok: usize,
    /// busy (backpressure) responses observed
    pub rejected: usize,
    /// non-busy error responses
    pub failed: usize,
    /// predictions that disagreed with the caller's expected values
    pub mismatches: usize,
    pub elapsed: Duration,
    /// round-trip latency of each pipelined chunk
    pub latency: crate::metrics::LatencyHistogram,
}

impl LoadReport {
    pub fn rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs <= 0.0 { 0.0 } else { self.ok as f64 / secs }
    }

    pub fn report(&self) -> String {
        format!(
            "sent={} ok={} rejected={} failed={} mismatches={} elapsed={:.2}s rps={:.1} {}",
            self.sent,
            self.ok,
            self.rejected,
            self.failed,
            self.mismatches,
            self.elapsed.as_secs_f64(),
            self.rps(),
            self.latency.report()
        )
    }
}

/// Fire `connections × requests` single-row predict requests at a
/// server over the text protocol, cycling through `rows`.  Busy
/// responses back off and retry until answered.  When `expected` is
/// given (aligned with `rows`), every prediction is checked against
/// it.
pub fn run_load(spec: &LoadSpec, rows: &[Vec<f32>], expected: Option<&[f32]>) -> Result<LoadReport> {
    run_load_mode(spec, rows, expected, WireMode::Text)
}

/// [`run_load`] with an explicit wire mode: `WireMode::Binary`
/// negotiates `serve-hello v1 binary` on every connection and moves
/// rows/decisions as length-prefixed f32 frames (`client --binary`).
pub fn run_load_mode(
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    expected: Option<&[f32]>,
    mode: WireMode,
) -> Result<LoadReport> {
    if rows.is_empty() {
        bail!("no feature rows to send");
    }
    if let Some(exp) = expected {
        if exp.len() != rows.len() {
            bail!("expected values misaligned: {} vs {} rows", exp.len(), rows.len());
        }
    }
    let connections = spec.connections.max(1);
    let pipeline = spec.pipeline.max(1);
    let t0 = Instant::now();
    let mut report = LoadReport::default();
    let results: Vec<Result<LoadReport>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                scope.spawn(move || {
                    run_connection(spec, rows, expected, c * spec.requests, pipeline, mode)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    for r in results {
        let r = r?;
        report.sent += r.sent;
        report.ok += r.ok;
        report.rejected += r.rejected;
        report.failed += r.failed;
        report.mismatches += r.mismatches;
        report.latency.merge(&r.latency);
    }
    report.elapsed = t0.elapsed();
    Ok(report)
}

/// Pull a `retry_after_ms=N` hint out of a busy/rate-limit message.
pub(crate) fn parse_retry_ms(msg: &str) -> u64 {
    msg.split("retry_after_ms=")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn run_connection(
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    expected: Option<&[f32]>,
    base_idx: usize,
    pipeline: usize,
    mode: WireMode,
) -> Result<LoadReport> {
    let stream = TcpStream::connect(&spec.addr)
        .with_context(|| format!("connecting {}", spec.addr))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut st = LoadReport::default();

    if mode == WireMode::Binary {
        writer.write_all(format!("{}\n", protocol::serve_hello_line(mode)).as_bytes())?;
        let mut ack = String::new();
        if reader.read_line(&mut ack)? == 0 {
            bail!("server closed connection during hello");
        }
        let acked = protocol::parse_serve_hello_ack(ack.trim()).map_err(|e| anyhow!(e))?;
        if acked != WireMode::Binary {
            bail!("server refused binary mode (acked {acked:?})");
        }
    }

    let mut done = 0usize;
    while done < spec.requests {
        let chunk = pipeline.min(spec.requests - done);
        let mut outstanding: Vec<usize> =
            (done..done + chunk).map(|k| (base_idx + k) % rows.len()).collect();
        let mut attempts = 0usize;
        while !outstanding.is_empty() {
            attempts += 1;
            if attempts > 500 {
                bail!("request rejected busy 500 times; server saturated");
            }
            let t0 = Instant::now();
            let mut msg: Vec<u8> = Vec::new();
            for &ri in &outstanding {
                match mode {
                    WireMode::Text => {
                        let row: Vec<String> =
                            rows[ri].iter().map(|v| format!("{v}")).collect();
                        msg.extend_from_slice(
                            format!("predict {} {}\n", spec.model, row.join(",")).as_bytes(),
                        );
                    }
                    WireMode::Binary => {
                        let payload = protocol::encode_predict_payload(
                            &spec.model,
                            rows[ri].len(),
                            1,
                            &rows[ri],
                        )
                        .map_err(|e| anyhow!(e))?;
                        msg.extend_from_slice(
                            &protocol::encode_serve_frame(ServeFrameTag::Predict, &payload)
                                .map_err(|e| anyhow!(e))?,
                        );
                    }
                }
            }
            writer.write_all(&msg)?;
            st.sent += outstanding.len();

            let mut retry = Vec::new();
            let mut backoff_ms = 0u64;
            let mut line = String::new();
            for &ri in &outstanding {
                match mode {
                    WireMode::Text => {
                        line.clear();
                        if reader.read_line(&mut line)? == 0 {
                            bail!("server closed connection");
                        }
                        match protocol::parse_response(&line) {
                            protocol::Response::Ok(body) => {
                                let vals =
                                    protocol::parse_values(&body).map_err(|e| anyhow!(e))?;
                                st.ok += 1;
                                if let Some(exp) = expected {
                                    if vals.len() != 1 || vals[0] != exp[ri] {
                                        st.mismatches += 1;
                                    }
                                }
                            }
                            protocol::Response::Busy { retry_after_ms } => {
                                st.rejected += 1;
                                backoff_ms = backoff_ms.max(retry_after_ms);
                                retry.push(ri);
                            }
                            protocol::Response::Err { .. } => st.failed += 1,
                        }
                    }
                    WireMode::Binary => {
                        let (tag, payload) = protocol::read_serve_frame(&mut reader)?;
                        match tag {
                            ServeFrameTag::Decisions => {
                                let vals = protocol::bytes_to_f32s(&payload)
                                    .map_err(|e| anyhow!(e))?;
                                st.ok += 1;
                                if let Some(exp) = expected {
                                    if vals.len() != 1 || vals[0] != exp[ri] {
                                        st.mismatches += 1;
                                    }
                                }
                            }
                            ServeFrameTag::Err => {
                                let (code, emsg) = protocol::decode_err_payload(&payload)
                                    .map_err(|e| anyhow!(e))?;
                                if code == "busy" {
                                    st.rejected += 1;
                                    backoff_ms = backoff_ms.max(parse_retry_ms(&emsg));
                                    retry.push(ri);
                                } else {
                                    st.failed += 1;
                                }
                            }
                            other => bail!("unexpected reply frame {other:?}"),
                        }
                    }
                }
            }
            st.latency.record(t0.elapsed());
            if !retry.is_empty() {
                thread::sleep(Duration::from_millis(backoff_ms.max(1)));
            }
            outstanding = retry;
        }
        done += chunk;
    }
    // polite teardown so the server releases the admission slot promptly
    match mode {
        WireMode::Text => {
            let _ = writer.write_all(b"quit\n");
        }
        WireMode::Binary => {
            if let Ok(frame) = protocol::encode_serve_frame(ServeFrameTag::Quit, &[]) {
                let _ = writer.write_all(&frame);
            }
        }
    }
    Ok(st)
}
