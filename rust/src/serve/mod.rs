//! `serve` — a batched, multi-model inference server with cell-routed
//! sharded bundles.
//!
//! liquidSVM splits training from testing via persisted `.sol` models
//! precisely so prediction can run as its own fast process (paper §2);
//! this subsystem is that process, grown into a server.  Pipeline:
//!
//! ```text
//! TCP conn ──┐
//! TCP conn ──┼─► Registry (LRU model cache,  ─► Batcher (per (model, cell),
//! TCP conn ──┘   .sol + .sol.d bundles,         size/deadline flush,
//!                hot-reload, shard LRU)          backpressure)
//!                                                     │  bounded queue
//!                                             WorkerPool ─► fused predict
//!                                                     │     (one shard)
//!                                             per-row replies, in order
//! ```
//!
//! Concurrent rows — across connections and pipelined within one —
//! coalesce into shape-bucketed batches before a single fused
//! `predict` call, so the per-call overhead (routing, kernel setup,
//! and on the XLA backend the padded artifact execution) is amortized
//! the same way the CV engine amortizes Gram work across the γ grid.
//!
//! For cell-decomposed models persisted as `.sol.d/` bundles, the
//! registry loads only the manifest; each incoming row is walked
//! through the model's `CellRouter` at submit time and batches
//! per (model, cell), so a fused call touches exactly one lazily
//! loaded shard.  Resident shards are bounded by a byte-budgeted LRU
//! (`max_shard_bytes`), which is what lets one server instance answer
//! traffic against a model trained on millions of samples without
//! ever holding it fully in memory (see DESIGN.md §Serving).
//!
//! The backpressure contract: the worker queue's capacity is the
//! server's entire memory budget for in-flight batches.  When a size
//! flush finds it full, the newest row is refused with
//! `err busy retry_after_ms=…` and everything previously accepted
//! stays queued — clients back off and retry; nothing buffers without
//! bound.
//!
//! [`protocol`] documents the wire format; [`Server::start`] returns a
//! handle usable in-process (tests bind port 0), and [`run_load`] is
//! the load generator behind `liquidsvm client`.

pub mod batcher;
pub mod protocol;
pub mod registry;
pub mod stats;
pub mod worker;

pub use batcher::{Batch, Batcher, BatcherConfig, SubmitError};
pub use registry::{Registry, RouteTarget, ServedModel, ShardUsage};
pub use stats::ServeStats;
pub use worker::{BoundedQueue, WorkerPool};

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::thread;

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::config::Config;
use protocol::Request;

/// Server configuration (`liquidsvm serve` flags map 1:1 onto this).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub host: String,
    /// 0 picks an ephemeral port (tests)
    pub port: u16,
    /// rows per fused predict call (size flush trigger)
    pub max_batch: usize,
    /// max wait of the oldest pending row (deadline flush trigger)
    pub max_delay: Duration,
    /// worker-queue capacity in batches (the backpressure bound)
    pub queue_cap: usize,
    /// predict worker threads
    pub workers: usize,
    /// LRU bound on resident models
    pub max_models: usize,
    /// per-bundle byte budget for lazily loaded shards
    pub max_shard_bytes: u64,
    /// log any request whose enqueue→response latency reaches this
    /// many µs (0 = off) — the serve-side slow log
    pub slow_log_us: u64,
    /// runtime choices (backend, threads) applied to loaded models
    pub model_config: Config,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 4950,
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 128,
            workers: 2,
            max_models: 8,
            max_shard_bytes: registry::DEFAULT_SHARD_BUDGET,
            slow_log_us: 0,
            model_config: Config::default(),
        }
    }
}

/// A running server; dropping it does NOT stop the threads — call
/// [`Server::shutdown`].
pub struct Server {
    pub registry: Arc<Registry>,
    pub batcher: Arc<Batcher>,
    pub stats: Arc<ServeStats>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<BoundedQueue<Batch>>,
    threads: Vec<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn acceptor + flusher + workers, return immediately.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr()?;

        let stats = Arc::new(ServeStats::new());
        stats.set_slow_log_us(cfg.slow_log_us);
        let registry = Arc::new(
            Registry::new(cfg.model_config.clone(), cfg.max_models)
                .shard_budget(cfg.max_shard_bytes),
        );
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let batcher = Arc::new(Batcher::new(
            BatcherConfig { max_batch: cfg.max_batch, max_delay: cfg.max_delay },
            queue.clone(),
        ));
        let stop = Arc::new(AtomicBool::new(false));

        let mut threads =
            WorkerPool::start(cfg.workers, queue.clone(), stats.clone()).into_handles();

        // deadline flusher: ticks at a quarter of the delay bound so a
        // lone request waits at most ~1.25 * max_delay
        {
            let batcher = batcher.clone();
            let stop = stop.clone();
            let tick = (cfg.max_delay / 4).max(Duration::from_micros(250));
            threads.push(thread::spawn(move || {
                // Acquire pairs with shutdown's Release store: everything
                // written before the stop was requested is visible here
                while !stop.load(Ordering::Acquire) {
                    batcher.flush_expired();
                    thread::sleep(tick);
                }
            }));
        }

        // acceptor: one thread per connection (batching happens behind
        // the shared batcher, so connection threads stay cheap readers)
        {
            let registry = registry.clone();
            let batcher = batcher.clone();
            let stats = stats.clone();
            let stop = stop.clone();
            threads.push(thread::spawn(move || {
                loop {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let registry = registry.clone();
                            let batcher = batcher.clone();
                            let stats = stats.clone();
                            let stop = stop.clone();
                            thread::spawn(move || {
                                let _ = handle_conn(stream, registry, batcher, stats, stop);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => thread::sleep(Duration::from_millis(5)),
                    }
                }
            }));
        }

        Ok(Server { registry, batcher, stats, addr, stop, queue, threads })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop acceptor/flusher/workers and join them.  Connection
    /// threads notice the stop flag on their next read timeout.
    pub fn shutdown(self) {
        // Release, paired with the Acquire loads in the flusher /
        // acceptor / connection loops.  With Relaxed on both sides a
        // thread could observe `stop` while missing writes sequenced
        // before it (loom catches this: see `stop_flag_publishes` in
        // tests/loom_models.rs); the flag is a publication edge, not a
        // mere counter.
        self.stop.store(true, Ordering::Release);
        // drain pending rows before closing so in-flight clients get
        // answers instead of hung receivers; the flush can find the
        // queue full under load, so keep retrying (bounded) while the
        // still-running workers make room
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            self.batcher.flush_all();
            if !self.batcher.has_pending() || Instant::now() >= deadline {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        // anything still pending after the deadline fails fast instead
        // of leaving its waiters blocked forever; this also closes the
        // batcher, so a connection thread that read a request before
        // noticing `stop` cannot park a fresh row in a pending map no
        // flusher will ever visit again (its client would block on the
        // reply receiver forever)
        self.batcher.discard_pending();
        self.queue.close();
        for h in self.threads {
            let _ = h.join();
        }
    }
}

/// One response slot in a connection's ordered reply stream.
enum Reply {
    Ready(String),
    /// one receiver per submitted row of a predict request
    Pending(Vec<mpsc::Receiver<Result<f32, String>>>),
}

fn handle_conn(
    stream: TcpStream,
    registry: Arc<Registry>,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_nonblocking(false).ok();
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let mut read_half = stream.try_clone().context("cloning stream")?;
    let mut write_half = stream;

    // writer thread: resolves replies strictly in request order, so
    // pipelined requests batch in flight yet answer deterministically
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let writer = thread::spawn(move || {
        let mut out = String::new();
        for reply in reply_rx {
            out.clear();
            match reply {
                Reply::Ready(line) => out.push_str(&line),
                Reply::Pending(rxs) => out.push_str(&collect_predictions(rxs)),
            }
            out.push('\n');
            if write_half.write_all(out.as_bytes()).is_err() {
                break;
            }
        }
    });

    // manual line framing: a read timeout must not drop a partial line
    // (BufReader::read_line discards its progress on error)
    let mut chunk = [0u8; 4096];
    let mut acc: Vec<u8> = Vec::new();
    'conn: loop {
        match read_half.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                acc.extend_from_slice(&chunk[..n]);
                while let Some(pos) = acc.iter().position(|&b| b == b'\n') {
                    let line_bytes: Vec<u8> = acc.drain(..=pos).collect();
                    let line = String::from_utf8_lossy(&line_bytes);
                    if line.trim().is_empty() {
                        continue;
                    }
                    match handle_request(line.trim(), &registry, &batcher, &stats) {
                        Some(reply) => {
                            if reply_tx.send(reply).is_err() {
                                break 'conn;
                            }
                        }
                        None => {
                            let _ = reply_tx.send(Reply::Ready(protocol::ok_msg("bye")));
                            break 'conn;
                        }
                    }
                }
                if acc.len() > protocol::MAX_LINE {
                    let _ = reply_tx
                        .send(Reply::Ready(protocol::err_msg("bad-request", "line too long")));
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Acquire) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    drop(reply_tx);
    let _ = writer.join();
    Ok(())
}

/// Dispatch one request; `None` means the client asked to quit.
fn handle_request(
    line: &str,
    registry: &Registry,
    batcher: &Batcher,
    stats: &ServeStats,
) -> Option<Reply> {
    let req = {
        let _sp = crate::obs::span("serve.parse");
        match protocol::parse_request(line) {
            Ok(r) => r,
            Err(msg) => return Some(Reply::Ready(protocol::err_msg("bad-request", &msg))),
        }
    };
    let reply = match req {
        Request::Quit => return None,
        Request::Ping => Reply::Ready(protocol::ok_msg("pong")),
        Request::Stats => Reply::Ready(protocol::ok_msg(
            &stats.report(registry.len(), &registry.shard_usage()),
        )),
        Request::Metrics { json } => {
            let fams = metrics_families(registry, stats);
            if json {
                Reply::Ready(protocol::ok_msg(&crate::obs::registry::json_text(&fams)))
            } else {
                // the protocol's only multi-line response: the header
                // announces the payload line count so lockstep readers
                // know how much to consume (see `protocol` docs)
                let body = crate::obs::registry::prometheus_text(&fams);
                let body = body.trim_end_matches('\n');
                let n = body.lines().count();
                Reply::Ready(format!("ok metrics lines={n}\n{body}"))
            }
        }
        Request::Shards { name } => match registry.get(&name) {
            Ok(m) => match m.shard_info() {
                Some(info) => {
                    let bundle = m.bundle.as_ref().expect("shard_info implies bundle");
                    let per_cell: Vec<String> = info
                        .iter()
                        .map(|s| {
                            format!(
                                "{}:{}:{}",
                                s.cell,
                                s.hits,
                                if s.resident { 1 } else { 0 }
                            )
                        })
                        .collect();
                    Reply::Ready(protocol::ok_msg(&format!(
                        "name={} shards={} resident={} resident_bytes={} total_bytes={} \
                         cell:hits:resident {}",
                        name,
                        info.len(),
                        bundle.resident_shards(),
                        bundle.resident_bytes(),
                        bundle.manifest().total_bytes(),
                        per_cell.join(" ")
                    )))
                }
                None => Reply::Ready(protocol::err_msg(
                    "not-sharded",
                    &format!("model `{name}` is not a sharded bundle"),
                )),
            },
            Err(e) => Reply::Ready(protocol::err_msg("unknown-model", &format!("{e:#}"))),
        },
        Request::Load { name, path } => match registry.load(&name, Path::new(&path)) {
            Ok(m) => {
                let detail = match &m.bundle {
                    Some(b) => format!("shards={}", b.manifest().n_cells()),
                    None => format!("units={}", m.model.units.len()),
                };
                Reply::Ready(protocol::ok_msg(&format!("loaded {name} dim={} {detail}", m.dim)))
            }
            Err(e) => Reply::Ready(protocol::err_msg("load-failed", &format!("{e:#}"))),
        },
        Request::Unload { name } => {
            if registry.unload(&name) {
                Reply::Ready(protocol::ok_msg(&format!("unloaded {name}")))
            } else {
                Reply::Ready(protocol::err_msg("unknown-model", &format!("no model `{name}`")))
            }
        }
        Request::Predict { model, rows } => {
            stats.requests.add(rows.len() as u64);
            let served = match registry.get(&model) {
                Ok(m) => m,
                Err(e) => {
                    stats.errors.add(rows.len() as u64);
                    return Some(Reply::Ready(protocol::err_msg(
                        "unknown-model",
                        &format!("{e:#}"),
                    )));
                }
            };
            // resolve every wire row to a dense feature vector before
            // batching: dense rows must match the model dim exactly
            // (when known); sparse idx:val rows densify against it here
            // — the serve path's densification boundary (the shard
            // expansions are dense; see DESIGN.md §Data-plane)
            // a rejected request fails ALL its rows with one err reply,
            // so the error counter advances by the full row count —
            // keeping `requests - errors` = successful predictions
            let total_rows = rows.len() as u64;
            let mut dense_rows: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for row in rows {
                let err = match &row {
                    protocol::PredictRow::Dense(v) if served.dim > 0 && v.len() != served.dim => {
                        Some(format!(
                            "model `{model}` expects dim {}, got {}",
                            served.dim,
                            v.len()
                        ))
                    }
                    protocol::PredictRow::Sparse(_) if served.dim == 0 => Some(format!(
                        "model `{model}` has unknown dim; sparse rows need a known dim"
                    )),
                    _ => None,
                };
                if let Some(msg) = err {
                    stats.errors.add(total_rows);
                    return Some(Reply::Ready(protocol::err_msg("dim-mismatch", &msg)));
                }
                let dim = if served.dim > 0 { served.dim } else { row.min_dim() };
                match row.densify(dim) {
                    Ok(v) => dense_rows.push(v),
                    Err(msg) => {
                        stats.errors.add(total_rows);
                        return Some(Reply::Ready(protocol::err_msg("dim-mismatch", &msg)));
                    }
                }
            }
            let mut rxs = Vec::with_capacity(dense_rows.len());
            for row in dense_rows {
                match batcher.submit(&served, row) {
                    Ok(rx) => rxs.push(rx),
                    Err(SubmitError::Busy { retry_after_ms }) => {
                        stats.rejected.inc();
                        // rows already submitted from this request stay
                        // in flight; their receivers are dropped here
                        // and the worker's sends fail silently
                        return Some(Reply::Ready(protocol::err_busy(retry_after_ms)));
                    }
                    Err(SubmitError::Closed) => {
                        stats.errors.add(total_rows);
                        return Some(Reply::Ready(protocol::err_msg(
                            "unavailable",
                            "server shutting down",
                        )));
                    }
                }
            }
            stats.note_model(&model, rxs.len() as u64);
            Reply::Pending(rxs)
        }
    };
    Some(reply)
}

/// Scrape-time metric families for this server: the process-global
/// registry (solver/Gram/cell counters) plus the server's own
/// instance-local counters, gauges, and the request-latency histogram
/// (see DESIGN.md §Observability for the exposition contract).
fn metrics_families(
    registry: &Registry,
    stats: &ServeStats,
) -> Vec<crate::obs::registry::Family> {
    use crate::obs::registry::Family;
    let shards = registry.shard_usage();
    let mut fams = crate::obs::registry::global().families();
    fams.push(Family::gauge(
        "liquidsvm_serve_uptime_seconds",
        "Seconds since this server started",
        stats.uptime_s() as f64,
    ));
    fams.push(Family::gauge(
        "liquidsvm_serve_models",
        "Models resident in the registry",
        registry.len() as f64,
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_requests",
        "Prediction rows accepted into the batcher",
        stats.requests.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_rejected",
        "Prediction rows rejected with backpressure",
        stats.rejected.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_errors",
        "Prediction rows that failed after acceptance",
        stats.errors.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_slow_requests",
        "Rows whose latency reached the slow-log threshold",
        stats.slow.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_batches",
        "Fused predict calls executed",
        stats.batches.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_batched_rows",
        "Real rows across all executed batches",
        stats.batched_rows.get(),
    ));
    fams.push(Family::counter(
        "liquidsvm_serve_padded_rows",
        "Padding rows added to reach shape buckets",
        stats.padded_rows.get(),
    ));
    fams.push(Family::gauge(
        "liquidsvm_serve_shard_resident_bytes",
        "Bytes of lazily loaded bundle shards currently resident",
        shards.resident_bytes as f64,
    ));
    fams.push(Family::histogram(
        "liquidsvm_serve_request_latency_us",
        "Enqueue to response-ready latency per row (microseconds)",
        &stats.latency,
    ));
    fams
}

fn collect_predictions(rxs: Vec<mpsc::Receiver<Result<f32, String>>>) -> String {
    let mut vals = Vec::with_capacity(rxs.len());
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(v)) => vals.push(v),
            Ok(Err(e)) => return protocol::err_msg("predict-failed", &e),
            Err(_) => return protocol::err_msg("internal", "worker dropped request"),
        }
    }
    protocol::ok_values(&vals)
}

// ------------------------------------------------------------ client

/// Load-generation parameters (`liquidsvm client` flags).
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub addr: String,
    pub model: String,
    /// concurrent TCP connections
    pub connections: usize,
    /// single-row requests per connection
    pub requests: usize,
    /// requests written back-to-back before reading responses (1 = a
    /// strict request/response lockstep, i.e. no client-side batching)
    pub pipeline: usize,
}

/// Aggregated result of a load run.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// request lines written (including busy retries)
    pub sent: usize,
    /// successful predictions
    pub ok: usize,
    /// busy (backpressure) responses observed
    pub rejected: usize,
    /// non-busy error responses
    pub failed: usize,
    /// predictions that disagreed with the caller's expected values
    pub mismatches: usize,
    pub elapsed: Duration,
    /// round-trip latency of each pipelined chunk
    pub latency: crate::metrics::LatencyHistogram,
}

impl LoadReport {
    pub fn rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs <= 0.0 { 0.0 } else { self.ok as f64 / secs }
    }

    pub fn report(&self) -> String {
        format!(
            "sent={} ok={} rejected={} failed={} mismatches={} elapsed={:.2}s rps={:.1} {}",
            self.sent,
            self.ok,
            self.rejected,
            self.failed,
            self.mismatches,
            self.elapsed.as_secs_f64(),
            self.rps(),
            self.latency.report()
        )
    }
}

/// Fire `connections × requests` single-row predict requests at a
/// server, cycling through `rows`.  Busy responses back off and retry
/// until answered.  When `expected` is given (aligned with `rows`),
/// every prediction is checked against it.
pub fn run_load(spec: &LoadSpec, rows: &[Vec<f32>], expected: Option<&[f32]>) -> Result<LoadReport> {
    if rows.is_empty() {
        bail!("no feature rows to send");
    }
    if let Some(exp) = expected {
        if exp.len() != rows.len() {
            bail!("expected values misaligned: {} vs {} rows", exp.len(), rows.len());
        }
    }
    let connections = spec.connections.max(1);
    let pipeline = spec.pipeline.max(1);
    let t0 = Instant::now();
    let mut report = LoadReport::default();
    let results: Vec<Result<LoadReport>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                scope.spawn(move || {
                    run_connection(spec, rows, expected, c * spec.requests, pipeline)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    for r in results {
        let r = r?;
        report.sent += r.sent;
        report.ok += r.ok;
        report.rejected += r.rejected;
        report.failed += r.failed;
        report.mismatches += r.mismatches;
        report.latency.merge(&r.latency);
    }
    report.elapsed = t0.elapsed();
    Ok(report)
}

fn run_connection(
    spec: &LoadSpec,
    rows: &[Vec<f32>],
    expected: Option<&[f32]>,
    base_idx: usize,
    pipeline: usize,
) -> Result<LoadReport> {
    let stream = TcpStream::connect(&spec.addr)
        .with_context(|| format!("connecting {}", spec.addr))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut st = LoadReport::default();

    let mut done = 0usize;
    while done < spec.requests {
        let chunk = pipeline.min(spec.requests - done);
        let mut outstanding: Vec<usize> =
            (done..done + chunk).map(|k| (base_idx + k) % rows.len()).collect();
        let mut attempts = 0usize;
        while !outstanding.is_empty() {
            attempts += 1;
            if attempts > 500 {
                bail!("request rejected busy 500 times; server saturated");
            }
            let t0 = Instant::now();
            let mut msg = String::new();
            for &ri in &outstanding {
                let row: Vec<String> = rows[ri].iter().map(|v| format!("{v}")).collect();
                msg.push_str(&format!("predict {} {}\n", spec.model, row.join(",")));
            }
            writer.write_all(msg.as_bytes())?;
            st.sent += outstanding.len();

            let mut retry = Vec::new();
            let mut backoff_ms = 0u64;
            let mut line = String::new();
            for &ri in &outstanding {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    bail!("server closed connection");
                }
                match protocol::parse_response(&line) {
                    protocol::Response::Ok(body) => {
                        let vals = protocol::parse_values(&body).map_err(|e| anyhow!(e))?;
                        st.ok += 1;
                        if let Some(exp) = expected {
                            if vals.len() != 1 || vals[0] != exp[ri] {
                                st.mismatches += 1;
                            }
                        }
                    }
                    protocol::Response::Busy { retry_after_ms } => {
                        st.rejected += 1;
                        backoff_ms = backoff_ms.max(retry_after_ms);
                        retry.push(ri);
                    }
                    protocol::Response::Err { .. } => st.failed += 1,
                }
            }
            st.latency.record(t0.elapsed());
            if !retry.is_empty() {
                thread::sleep(Duration::from_millis(backoff_ms.max(1)));
            }
            outstanding = retry;
        }
        done += chunk;
    }
    // polite teardown so the server thread exits promptly
    let _ = writer.write_all(b"quit\n");
    Ok(st)
}
