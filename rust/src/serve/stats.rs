//! Serving telemetry: request/batch counters plus the end-to-end
//! request latency histogram (enqueue → response ready), reported by
//! the protocol's `stats` command.  Kernel-cache and accelerator
//! counters come from the process-wide [`crate::metrics::counters`]
//! so serving and the CV engine report the same quantities; shard
//! residency/hit numbers come from the registry's per-bundle caches
//! ([`crate::serve::registry::ShardUsage`]), which is how a load test
//! verifies that a sharded bundle really is serving lazily (resident
//! bytes below total bundle size).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::sync::Mutex;
// the slow-log threshold is a config cell (armed once at startup,
// read with Relaxed), not a synchronization edge — always-std atomics
use crate::sync::static_atomic::{AtomicU64, Ordering};

use super::registry::ShardUsage;
use crate::metrics::counters::{self, Counter};
use crate::metrics::LatencyHistogram;

/// Shared server counters (all lock-free; one instance per server).
#[derive(Debug)]
pub struct ServeStats {
    /// prediction rows accepted into the batcher
    pub requests: Counter,
    /// prediction rows rejected with backpressure
    pub rejected: Counter,
    /// requests that failed after acceptance
    pub errors: Counter,
    /// fused predict calls executed
    pub batches: Counter,
    /// real rows across all executed batches
    pub batched_rows: Counter,
    /// padding rows added to reach shape buckets
    pub padded_rows: Counter,
    /// batches whose predict exceeded the slow-log threshold
    pub slow: Counter,
    /// connections admitted by the event loop
    pub conns_accepted: Counter,
    /// connections refused at the door (`--max-conns` cap)
    pub conns_rejected: Counter,
    /// predict requests refused by the per-client token bucket
    pub rate_limited: Counter,
    /// currently-open connections (gauge; inc on admit, dec on close).
    /// Telemetry only — the admission seam's own count, under its
    /// mutex, is what enforces the cap.
    conns_open: AtomicU64,
    /// enqueue → response-ready latency per row
    pub latency: LatencyHistogram,
    /// prediction rows routed per model name (BTreeMap: the `stats`
    /// line must render deterministically for the golden-parse test)
    per_model: Mutex<BTreeMap<String, u64>>,
    /// slow-log threshold in µs (0 = off); set once at server start
    slow_log_us: AtomicU64,
    started: Instant,
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            requests: Counter::new(),
            rejected: Counter::new(),
            errors: Counter::new(),
            batches: Counter::new(),
            batched_rows: Counter::new(),
            padded_rows: Counter::new(),
            slow: Counter::new(),
            conns_accepted: Counter::new(),
            conns_rejected: Counter::new(),
            rate_limited: Counter::new(),
            conns_open: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
            per_model: Mutex::new(BTreeMap::new()),
            slow_log_us: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Arm (or disarm, with 0) the slow-request log threshold.
    pub fn set_slow_log_us(&self, us: u64) {
        self.slow_log_us.store(us, Ordering::Relaxed);
    }

    /// Current slow-log threshold in µs (0 = off).
    pub fn slow_log_us(&self) -> u64 {
        self.slow_log_us.load(Ordering::Relaxed)
    }

    /// Event-loop bookkeeping: a connection was admitted.
    pub fn conn_opened(&self) {
        self.conns_open.fetch_add(1, Ordering::Relaxed);
    }

    /// Event-loop bookkeeping: an admitted connection closed.
    /// Saturating — a stray double-close must not wrap the gauge.
    pub fn conn_closed(&self) {
        let mut cur = self.conns_open.load(Ordering::Relaxed);
        while cur > 0 {
            match self.conns_open.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Currently-open connections (gauge).
    pub fn conns_open(&self) -> u64 {
        self.conns_open.load(Ordering::Relaxed)
    }

    /// Mean real rows per fused predict call.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 { 0.0 } else { self.batched_rows.get() as f64 / b as f64 }
    }

    /// Whole seconds since the server started.
    pub fn uptime_s(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Credit `rows` accepted prediction rows to `model`.
    pub fn note_model(&self, model: &str, rows: u64) {
        let mut map = self.per_model.lock().unwrap();
        *map.entry(model.to_string()).or_insert(0) += rows;
    }

    /// Per-model accepted row counts, sorted by model name.
    pub fn per_model(&self) -> Vec<(String, u64)> {
        self.per_model.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Completed rows per second since the server started.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 { 0.0 } else { self.latency.count() as f64 / secs }
    }

    /// One-line `key=value` report for the `stats` protocol command.
    /// `shards` carries the registry's aggregated shard-cache usage
    /// (all-zero when no bundle is resident).
    pub fn report(&self, n_models: usize, shards: &ShardUsage) -> String {
        let per_model = self.per_model();
        let model_rows = if per_model.is_empty() {
            String::from("-")
        } else {
            per_model
                .iter()
                .map(|(name, rows)| format!("{name}:{rows}"))
                .collect::<Vec<_>>()
                .join(",")
        };
        format!(
            "models={} uptime_s={} requests={} rejected={} errors={} slow={} \
             conns={} conns_accepted={} conns_rejected={} rate_limited={} batches={} \
             rows={} pad_rows={} mean_batch={:.1} rps={:.1} {} mean_us={} \
             shards={}/{} shard_bytes={}/{} shard_hits={} shard_loads={} shard_evictions={} \
             model_rows={} {}",
            n_models,
            self.uptime_s(),
            self.requests.get(),
            self.rejected.get(),
            self.errors.get(),
            self.slow.get(),
            self.conns_open(),
            self.conns_accepted.get(),
            self.conns_rejected.get(),
            self.rate_limited.get(),
            self.batches.get(),
            self.batched_rows.get(),
            self.padded_rows.get(),
            self.mean_batch(),
            self.throughput_rps(),
            self.latency.report(),
            self.latency.mean_us(),
            shards.resident_shards,
            shards.total_shards,
            shards.resident_bytes,
            shards.total_bytes,
            shards.hits,
            shards.loads,
            shards.evictions,
            model_rows,
            counters::snapshot().report(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn report_contains_all_sections() {
        let s = ServeStats::new();
        s.requests.add(10);
        s.batches.add(2);
        s.batched_rows.add(10);
        s.padded_rows.add(6);
        s.latency.record(Duration::from_micros(300));
        let usage = ShardUsage {
            bundles: 1,
            total_shards: 4,
            resident_shards: 2,
            total_bytes: 4000,
            resident_bytes: 2000,
            hits: 7,
            loads: 2,
            evictions: 1,
        };
        s.note_model("banana", 7);
        s.note_model("cov", 3);
        s.note_model("banana", 2);
        let r = s.report(3, &usage);
        s.conns_accepted.add(4);
        s.conns_rejected.inc();
        s.rate_limited.add(2);
        s.conn_opened();
        s.conn_opened();
        s.conn_closed();
        for key in [
            "models=3", "uptime_s=", "requests=10", "slow=0", "batches=2", "rows=10",
            "conns=1", "conns_accepted=4", "conns_rejected=1", "rate_limited=2",
            "pad_rows=6", "mean_batch=5.0",
            "p50_us=", "p95_us=", "p99_us=", "max_us=", "gram_hits=", "gram_allocs=",
            "xla_calls=", "solver_sweeps=", "shrink_active=", "unshrink_passes=",
            "shards=2/4", "shard_bytes=2000/4000", "shard_hits=7", "shard_loads=2",
            "shard_evictions=1", "model_rows=banana:9,cov:3",
        ] {
            assert!(r.contains(key), "missing {key} in `{r}`");
        }
    }

    #[test]
    fn empty_per_model_renders_dash() {
        let s = ServeStats::new();
        let r = s.report(0, &ShardUsage::default());
        assert!(r.contains("model_rows=- "), "`{r}`");
        assert!(s.per_model().is_empty());
    }

    #[test]
    fn mean_batch_handles_empty() {
        assert_eq!(ServeStats::new().mean_batch(), 0.0);
    }

    #[test]
    fn conn_gauge_saturates_at_zero() {
        let s = ServeStats::new();
        s.conn_closed(); // stray close on an empty gauge must not wrap
        assert_eq!(s.conns_open(), 0);
        s.conn_opened();
        assert_eq!(s.conns_open(), 1);
        s.conn_closed();
        s.conn_closed();
        assert_eq!(s.conns_open(), 0);
    }
}
