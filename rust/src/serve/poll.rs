//! Readiness polling for the async serve plane: a thin, safe wrapper
//! over the OS readiness syscall (`epoll` on Linux, POSIX `poll(2)`
//! elsewhere) plus the self-pipe used to wake a reactor from another
//! thread.
//!
//! Semantics exposed upward:
//! - [`Poller::register`] / [`Poller::modify`] express *interest*
//!   (readable / writable) for an fd under a caller-chosen token;
//!   [`Poller::wait`] reports readiness as [`Event`]s carrying that
//!   token back.
//! - On Linux the `edge` flag arms edge-triggered delivery (EPOLLET);
//!   the portable fallback is level-triggered and ignores the flag.
//!   Callers stay correct under both by always draining to
//!   `WouldBlock` and keeping write interest armed only while output
//!   is actually buffered (DESIGN.md §Serving-async).
//! - [`WakePipe`] is the classic self-pipe trick: `wake()` writes one
//!   byte (EAGAIN means a wake is already pending — exactly the
//!   coalescing we want), and the reactor drains the pipe before it
//!   takes its mailbox, so a completion pushed before the wake byte is
//!   never missed.

// One of the three modules allowed to opt back into `unsafe` (the
// crate root denies it): the readiness syscalls take raw pointers the
// type system cannot vouch for.  The surface is raw `extern "C"`
// declarations — the crate links no FFI helper crates; libc symbols
// come in via std — and every unsafe block carries a SAFETY contract
// (CI denies `clippy::undocumented_unsafe_blocks`); see DESIGN.md
// §Serving-async.
#![allow(unsafe_code)]

use std::io;
use std::os::unix::io::RawFd;

/// Upper bound on events returned by a single [`Poller::wait`] call.
/// Readiness is a level/edge signal, not a queue: anything not
/// reported this round is reported on the next call.
pub const MAX_EVENTS: usize = 256;

/// One readiness report from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hangup or socket error: the connection should be read to
    /// EOF and torn down.
    pub hangup: bool,
}

// ---------------------------------------------------------------- Linux: epoll

#[cfg(target_os = "linux")]
mod sys {
    //! Raw epoll + pipe syscall bindings.  Numeric constants are the
    //! stable Linux userspace ABI (uapi headers); they are identical
    //! on every Linux architecture this crate targets.

    /// Mirror of the kernel's `struct epoll_event`.  The x86-64 ABI
    /// declares it packed (a 12-byte struct); other architectures use
    /// natural alignment.  Fields must be copied to locals before
    /// use — references into a packed struct are UB.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLET: u32 = 1 << 31;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub const F_GETFL: i32 = 3;
    pub const F_SETFL: i32 = 4;
    pub const O_NONBLOCK: i32 = 0o4000;

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        // fcntl is variadic in C; the F_GETFL/F_SETFL commands we use
        // take at most one int argument, for which the fixed-arity
        // declaration matches the platform calling convention.
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }
}

/// Edge- or level-triggered readiness poller over one `epoll`
/// instance.  `register`/`modify`/`deregister` may be called from any
/// thread (the kernel serializes `epoll_ctl`); `wait` belongs to the
/// owning reactor.
#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: RawFd,
    buf: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        // SAFETY: epoll_create1 takes a flag word and returns a fresh
        // fd (or -1); no pointers cross the boundary.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller {
            epfd,
            buf: vec![sys::EpollEvent { events: 0, data: 0 }; MAX_EVENTS],
        })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        // SAFETY: `ev` is a live, correctly laid-out epoll_event for
        // the duration of the call; the kernel copies it out before
        // returning (it is also passed, ignored, for EPOLL_CTL_DEL to
        // stay compatible with pre-2.6.9 kernels that reject NULL).
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn interest_bits(readable: bool, writable: bool, edge: bool) -> u32 {
        let mut bits = 0u32;
        if readable {
            bits |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if writable {
            bits |= sys::EPOLLOUT;
        }
        if edge {
            bits |= sys::EPOLLET;
        }
        bits
    }

    pub fn register(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
        edge: bool,
    ) -> io::Result<()> {
        self.ctl(
            sys::EPOLL_CTL_ADD,
            fd,
            Self::interest_bits(readable, writable, edge),
            token,
        )
    }

    pub fn modify(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
        edge: bool,
    ) -> io::Result<()> {
        self.ctl(
            sys::EPOLL_CTL_MOD,
            fd,
            Self::interest_bits(readable, writable, edge),
            token,
        )
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (-1 = forever) and append readiness
    /// reports to `events` (cleared first).  EINTR is reported as
    /// zero events, never as an error.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
        events.clear();
        // SAFETY: `buf` points at MAX_EVENTS properly-sized
        // epoll_event slots owned by self; the kernel writes at most
        // `maxevents` entries and we read back only the first `n`.
        let n = unsafe {
            sys::epoll_wait(
                self.epfd,
                self.buf.as_mut_ptr(),
                self.buf.len() as i32,
                timeout_ms,
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        for slot in self.buf.iter().take(n as usize) {
            // Copy packed fields to locals before use: forming a
            // reference to them (e.g. in a format or comparison that
            // autorefs) would be UB on x86-64.
            let ev: sys::EpollEvent = *slot;
            let bits = ev.events;
            let token = ev.data;
            events.push(Event {
                token,
                readable: bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0,
                writable: bits & sys::EPOLLOUT != 0,
                hangup: bits & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0,
            });
        }
        Ok(n as usize)
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: epfd is a live fd owned exclusively by this Poller;
        // closing it is the last use.
        unsafe { sys::close(self.epfd) };
    }
}

// ------------------------------------------------- portable: POSIX poll(2)

#[cfg(not(target_os = "linux"))]
mod sys {
    //! POSIX `poll(2)` + pipe bindings for non-Linux unix targets.

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    pub const F_GETFL: i32 = 3;
    pub const F_SETFL: i32 = 4;
    pub const O_NONBLOCK: i32 = 0x0004;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        // fcntl is variadic in C; see the Linux binding for why the
        // fixed-arity declaration is sound for F_GETFL/F_SETFL.
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }
}

/// Level-triggered fallback poller over POSIX `poll(2)`.  The `edge`
/// flag is accepted and ignored: callers already drain to `WouldBlock`
/// and drop write interest once their buffers empty, which is correct
/// (if mildly chattier) under level-triggered delivery.
#[cfg(not(target_os = "linux"))]
pub struct Poller {
    interest: crate::sync::Mutex<std::collections::HashMap<RawFd, (u64, bool, bool)>>,
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller {
            interest: crate::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    pub fn register(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
        edge: bool,
    ) -> io::Result<()> {
        let _ = edge; // level-triggered fallback: see type-level doc
        self.interest
            .lock()
            .unwrap()
            .insert(fd, (token, readable, writable));
        Ok(())
    }

    pub fn modify(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
        edge: bool,
    ) -> io::Result<()> {
        self.register(fd, token, readable, writable, edge)
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.interest.lock().unwrap().remove(&fd);
        Ok(())
    }

    pub fn wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
        events.clear();
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let mut tokens: Vec<u64> = Vec::new();
        for (&fd, &(token, readable, writable)) in self.interest.lock().unwrap().iter() {
            let mut ev = 0i16;
            if readable {
                ev |= sys::POLLIN;
            }
            if writable {
                ev |= sys::POLLOUT;
            }
            fds.push(sys::PollFd {
                fd,
                events: ev,
                revents: 0,
            });
            tokens.push(token);
        }
        if fds.is_empty() {
            return Ok(0);
        }
        // SAFETY: `fds` is a live, contiguous pollfd array of exactly
        // `nfds` entries; the kernel writes only the revents fields.
        let n = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        for (pfd, &token) in fds.iter().zip(tokens.iter()) {
            let bits = pfd.revents;
            if bits == 0 {
                continue;
            }
            events.push(Event {
                token,
                readable: bits & POLLIN_HUP != 0,
                writable: bits & sys::POLLOUT != 0,
                hangup: bits & (sys::POLLERR | sys::POLLHUP) != 0,
            });
        }
        Ok(events.len())
    }
}

// A peer hangup surfaces as POLLHUP (possibly without POLLIN); treat
// it as readable so the state machine reads to EOF and tears down.
#[cfg(not(target_os = "linux"))]
const POLLIN_HUP: i16 = sys::POLLIN | sys::POLLHUP;

// ------------------------------------------------------------- wake pipe

/// Self-pipe used to interrupt a blocked [`Poller::wait`] from another
/// thread.  Both ends are nonblocking; the read end is registered with
/// the reactor's poller under a reserved token.
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0i32; 2];
        // SAFETY: pipe writes exactly two fds into the provided
        // 2-element array.
        let rc = unsafe { sys::pipe(fds.as_mut_ptr()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        let pipe = WakePipe {
            read_fd: fds[0],
            write_fd: fds[1],
        };
        set_nonblocking(pipe.read_fd)?;
        set_nonblocking(pipe.write_fd)?;
        Ok(pipe)
    }

    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Nudge the owning reactor.  Infallible by design: EAGAIN on a
    /// full pipe means a wake byte is already pending, which is all a
    /// waker needs.  Callers must publish their payload (push to the
    /// mailbox) *before* calling wake; the reactor drains the pipe
    /// before taking the mailbox, so the payload is never missed.
    pub fn wake(&self) {
        let b = [1u8];
        // SAFETY: writes one byte from a live stack buffer to a
        // nonblocking fd this pipe owns; short writes and EAGAIN are
        // both acceptable (see above).
        let _ = unsafe { sys::write(self.write_fd, b.as_ptr(), 1) };
    }

    /// Consume all pending wake bytes (called by the reactor before it
    /// takes its mailbox).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reads into a live 64-byte stack buffer from a
            // nonblocking fd this pipe owns.
            let n = unsafe { sys::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                break;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // SAFETY: both fds are live and owned exclusively by this
        // pipe; closing them is the last use.
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    // SAFETY: F_GETFL takes no argument (0 passed as the unused slot)
    // and F_SETFL takes one int; fd is live and owned by the caller.
    let flags = unsafe { sys::fcntl(fd, sys::F_GETFL, 0) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    // SAFETY: as above; setting O_NONBLOCK on a pipe end is always
    // valid.
    let rc = unsafe { sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

// Miri interprets no FFI, so the syscall-backed tests run natively only.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_roundtrip_and_coalescing() {
        let pipe = WakePipe::new().unwrap();
        // Many wakes coalesce into "some bytes pending" — drain never
        // blocks and leaves the pipe empty.
        for _ in 0..10_000 {
            pipe.wake();
        }
        pipe.drain();
        pipe.drain(); // idempotent on an empty pipe
    }

    #[test]
    fn poller_reports_wake_pipe_readable() {
        let mut poller = Poller::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        poller
            .register(pipe.read_fd(), 42, true, false, false)
            .unwrap();

        let mut events = Vec::new();
        // Nothing pending: a zero timeout returns no events.
        poller.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| e.token != 42 || !e.readable));

        pipe.wake();
        poller.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 42 && e.readable),
            "wake byte must surface as readability on the read end"
        );

        pipe.drain();
        poller.deregister(pipe.read_fd()).unwrap();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| e.token != 42));
    }

    #[test]
    fn edge_triggered_registration_fires_once_per_arrival() {
        let mut poller = Poller::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        poller
            .register(pipe.read_fd(), 7, true, false, true)
            .unwrap();
        pipe.wake();
        let mut events = Vec::new();
        poller.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
        // After draining, no further readiness is reported.
        pipe.drain();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));
    }

    #[test]
    fn modify_toggles_write_interest() {
        let mut poller = Poller::new().unwrap();
        let pipe = WakePipe::new().unwrap();
        // The write end of an empty pipe is always writable.
        poller
            .register(pipe.write_fd, 9, false, true, false)
            .unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.writable));
        // Drop write interest: no more reports for this fd.
        poller.modify(pipe.write_fd, 9, false, false, false).unwrap();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| e.token != 9 || !e.writable));
    }
}
