//! Simulated Spark mode (paper §4 Table 4 + Appendix B.3).
//!
//! The original setup: data on HDFS across 14 workers; the driver
//! samples the training set, finds coarse Voronoi centers (~20 000
//! samples per coarse cell), a Spark shuffle moves every cell to one
//! worker, and each worker then runs the single-node engine on its
//! coarse cells (which split further into fine cells of ≤ 2000).
//!
//! This image has no cluster, so the reproduction keeps the
//! *structure* honest: coarse cells really do train concurrently — one
//! OS thread per simulated worker, capped at the host's available
//! parallelism so time-slicing cannot inflate the timings, through the
//! parallel cell driver ([`crate::coordinator::driver`]) — while the
//! Table-4 numbers stay a model built from those per-cell times:
//! * the driver/center/shuffle phases run exactly as described;
//! * every coarse-cell training is timed individually by the driver;
//! * the distributed wall-clock is modelled as
//!   `max over workers(Σ cell times on that worker) + shuffle cost`,
//!   the single-node wall-clock as `Σ all cell times + retrain
//!   overhead` — the same accounting the paper's Table 4 compares —
//!   and the *measured* parallel wall-clock is reported alongside.
//! See DESIGN.md §Substitutions.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cells::CellStrategy;
use crate::coordinator::config::Config;
use crate::coordinator::driver::{lpt_assign, run_cell_grid_untracked};
use crate::coordinator::model::{train, SvmModel};
use crate::data::dataset::Dataset;
use crate::data::matrix::{sq_dist, Matrix};
use crate::data::rng::Rng;
use crate::tasks::TaskSpec;

/// Cluster shape.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub workers: usize,
    /// target coarse-cell size (paper: ~20 000)
    pub coarse_size: usize,
    /// fine-cell cap inside each coarse cell (paper: 2000)
    pub fine_size: usize,
    /// samples the driver draws to estimate centers (paper: 300–8000
    /// centers from a subset)
    pub driver_sample: usize,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec { workers: 14, coarse_size: 20_000, fine_size: 2000, driver_sample: 8000 }
    }
}

/// A trained distributed model.
pub struct DistributedModel {
    pub centers: Matrix,
    /// one single-node model per coarse cell
    pub cell_models: Vec<SvmModel>,
    /// worker that owned each coarse cell
    pub assignment: Vec<usize>,
    pub stats: DistStats,
}

/// Timing/accounting of a distributed run.
#[derive(Clone, Debug)]
pub struct DistStats {
    pub workers: usize,
    pub n_coarse_cells: usize,
    pub per_cell_time: Vec<Duration>,
    pub shuffle_time: Duration,
    pub driver_time: Duration,
    /// modelled distributed wall-clock (critical path)
    pub distributed_time: Duration,
    /// modelled single-node wall-clock (sequential sum + the extra
    /// disk/retrain overhead the CLI pays, cf. §B.3)
    pub single_node_time: Duration,
    /// *measured* wall-clock of the parallel cell-driver run (one
    /// thread per simulated worker, capped at host parallelism)
    pub measured_wall: Duration,
}

impl DistStats {
    pub fn speedup(&self) -> f64 {
        self.single_node_time.as_secs_f64() / self.distributed_time.as_secs_f64().max(1e-9)
    }
}

/// Phase 1+2: driver samples, finds centers, "shuffles" samples into
/// coarse cells.  Returns (centers, per-cell index lists).
pub fn coarse_partition(
    data: &Dataset,
    spec: &ClusterSpec,
    seed: u64,
) -> (Matrix, Vec<Vec<usize>>) {
    let n = data.len();
    let k = n.div_ceil(spec.coarse_size).max(1);
    let mut rng = Rng::new(seed ^ 0xd157);
    // driver sees only a sample (HDFS → master in the paper)
    let sample = rng.sample_indices(n, spec.driver_sample.min(n));
    let mut center_idx = Vec::with_capacity(k);
    // k-center-style greedy on the sample: spread centers out
    center_idx.push(sample[0]);
    while center_idx.len() < k.min(sample.len()) {
        let mut far = (sample[0], 0.0f32);
        for &i in &sample {
            let dmin = center_idx
                .iter()
                .map(|&c| sq_dist(data.x.row(i), data.x.row(c)))
                .fold(f32::INFINITY, f32::min);
            if dmin > far.1 {
                far = (i, dmin);
            }
        }
        center_idx.push(far.0);
    }
    let centers = data.x.select_rows(&center_idx);
    // workers assign their local samples to the nearest center
    let mut cells: Vec<Vec<usize>> = vec![Vec::new(); centers.rows()];
    for i in 0..n {
        let mut best = (0usize, f32::INFINITY);
        for c in 0..centers.rows() {
            let d = sq_dist(centers.row(c), data.x.row(i));
            if d < best.1 {
                best = (c, d);
            }
        }
        cells[best.0].push(i);
    }
    let keep: Vec<usize> = (0..cells.len()).filter(|&c| !cells[c].is_empty()).collect();
    let centers = centers.select_rows(&keep);
    let cells = keep.into_iter().map(|c| std::mem::take(&mut cells[c])).collect();
    (centers, cells)
}

/// Full distributed training run.
pub fn train_distributed(
    data: &Dataset,
    task: &TaskSpec,
    cfg: &Config,
    cluster: &ClusterSpec,
) -> Result<DistributedModel> {
    let t0 = Instant::now();
    let (centers, coarse_cells) = {
        let _sp = crate::obs::span("dist.driver");
        coarse_partition(data, cluster, cfg.seed)
    };
    let driver_time = t0.elapsed();

    // "shuffle": materialize every coarse cell (the bytes that would
    // cross the network in Spark)
    let t1 = Instant::now();
    let cell_data: Vec<Dataset> = {
        let mut sp = crate::obs::span("dist.shuffle");
        let cells: Vec<Dataset> = coarse_cells.iter().map(|idx| data.subset(idx)).collect();
        let rows: u64 = cells.iter().map(|d| d.len() as u64).sum();
        sp.add_bytes(rows * 4 * (data.x.cols() as u64 + 1));
        cells
    };
    let shuffle_time = t1.elapsed();

    // greedy longest-processing-time assignment of cells to workers
    let weights: Vec<u64> = cell_data.iter().map(|d| d.len() as u64).collect();
    let assignment = lpt_assign(&weights, cluster.workers);

    // each coarse cell trains with the single-node engine + fine
    // cells, genuinely in parallel: one thread per simulated worker,
    // capped at the host's parallelism — oversubscribing would let
    // time-slicing inflate the per-cell timings the Table-4 model is
    // built from.  Each simulated worker runs its engine
    // single-threaded (nested threading would both oversubscribe and
    // double-count the driver metrics), and the outer grid is the
    // untracked driver variant for the same reason.
    let mut cell_cfg = cfg.clone();
    cell_cfg.cells = CellStrategy::RecursiveTree { max_size: cluster.fine_size };
    cell_cfg.threads = 1;
    cell_cfg.jobs = Some(1);
    let jobs: Vec<(usize, _)> = cell_data
        .iter()
        .enumerate()
        .map(|(c, d)| {
            let cfg = cell_cfg.clone();
            let task = task.clone();
            (c, move || train(d, &task, &cfg))
        })
        .collect();
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let driver_threads = cluster.workers.min(host).max(1);
    let (trained, report) = {
        let _sp = crate::obs::span("dist.train");
        run_cell_grid_untracked(driver_threads, cell_data.len(), jobs)
    };

    let mut cell_models = Vec::with_capacity(trained.len());
    for m in trained {
        cell_models.push(m?);
    }
    let per_cell_time = report.per_cell.clone();

    // wall-clock accounting (see module docs)
    let mut worker_time = vec![Duration::ZERO; cluster.workers];
    for (c, &w) in assignment.iter().enumerate() {
        worker_time[w] += per_cell_time[c];
    }
    let critical = worker_time.into_iter().max().unwrap_or(Duration::ZERO);
    let distributed_time = critical + shuffle_time + driver_time;
    // single-node: strictly sequential, plus the CLI's extra I/O+retrain
    // overhead the paper points to for its super-linear speedups (§B.3);
    // modelled conservatively at 10%
    let total: Duration = per_cell_time.iter().sum();
    let single_node_time = total + total / 10;

    let stats = DistStats {
        workers: cluster.workers,
        n_coarse_cells: cell_models.len(),
        per_cell_time,
        shuffle_time,
        driver_time,
        distributed_time,
        single_node_time,
        measured_wall: report.wall,
    };
    Ok(DistributedModel { centers, cell_models, assignment, stats })
}

impl DistributedModel {
    /// Route each test row to its coarse cell and predict there.
    pub fn predict(&self, x: &Matrix) -> Vec<f32> {
        let mut routed: Vec<Vec<usize>> = vec![Vec::new(); self.cell_models.len()];
        for i in 0..x.rows() {
            let mut best = (0usize, f32::INFINITY);
            for c in 0..self.centers.rows() {
                let d = sq_dist(self.centers.row(c), x.row(i));
                if d < best.1 {
                    best = (c, d);
                }
            }
            routed[best.0].push(i);
        }
        let mut out = vec![0.0f32; x.rows()];
        for (c, idx) in routed.iter().enumerate() {
            if idx.is_empty() {
                continue;
            }
            let sub = x.select_rows(idx);
            let preds = self.cell_models[c].predict(&sub);
            for (j, &i) in idx.iter().enumerate() {
                out[i] = preds[j];
            }
        }
        out
    }

    /// Classification error on a test set.
    pub fn test_error(&self, test: &Dataset) -> f32 {
        let preds = self.predict(&test.x);
        crate::metrics::multiclass_error(&test.y, &preds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn cluster() -> ClusterSpec {
        ClusterSpec { workers: 4, coarse_size: 300, fine_size: 120, driver_sample: 400 }
    }

    #[test]
    fn coarse_partition_covers_everything() {
        let d = synth::by_name("covtype", 1000, 1).unwrap();
        let (centers, cells) = coarse_partition(&d, &cluster(), 3);
        assert!(centers.rows() >= 3);
        let total: usize = cells.iter().map(Vec::len).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn distributed_training_and_prediction() {
        let tt = synth::by_name("covtype", 1400, 2).unwrap().split(1000, 7);
        let cfg = Config::default().folds(3);
        let m = train_distributed(
            &tt.train,
            &TaskSpec::Binary { w: 0.5 },
            &cfg,
            &cluster(),
        )
        .unwrap();
        assert!(m.stats.n_coarse_cells >= 3);
        let err = m.test_error(&tt.test);
        assert!(err < 0.45, "distributed error {err}");
        // modelled speedup must be positive and ≤ worker count + overhead credit
        let s = m.stats.speedup();
        assert!(s > 1.0, "speedup {s}");
        // the driver really ran: measured parallel wall-clock exists and
        // is no larger than the sequential sum of cell times (plus slack)
        assert!(m.stats.measured_wall > Duration::ZERO);
    }

    #[test]
    fn assignment_is_balanced() {
        let d = synth::by_name("covtype", 1200, 3).unwrap();
        let cfg = Config::default().folds(3);
        let m = train_distributed(&d, &TaskSpec::Binary { w: 0.5 }, &cfg, &cluster()).unwrap();
        let mut load = vec![0usize; 4];
        for (c, &w) in m.assignment.iter().enumerate() {
            load[w] += m.cell_models[c].units.iter().map(|u| u.data.len()).sum::<usize>();
        }
        let (mx, mn) = (*load.iter().max().unwrap(), *load.iter().min().unwrap());
        assert!(mx <= mn * 3 + 400, "unbalanced: {load:?}");
    }
}
