//! Distributed training (paper §4 Table 4 + Appendix B.3).
//!
//! Two planes, one accounting story:
//!
//! * [`sim`] — the original single-process *simulation* of the paper's
//!   Spark mode: coarse cells train concurrently on threads, Table-4
//!   wall-clocks are modelled from the measured per-cell times.  It
//!   stays as the bit-exactness and accounting reference.
//! * [`wire`] — real multi-process training over TCP: a coordinator
//!   shards the model's cells to `liquidsvm worker` processes speaking
//!   the binary train protocol (`serve::protocol`, DESIGN.md
//!   §Distributed-wire), workers run the CV grid locally and stream
//!   solved shards back, and the coordinator assembles a `.sol.d`
//!   bundle byte-identical to the single-process one.  Its wall-clock
//!   is *measured* on sockets, with the simulation's modelled numbers
//!   reported alongside for comparison.

pub mod sim;
pub mod wire;

pub use sim::{
    coarse_partition, train_distributed, ClusterSpec, DistStats, DistributedModel,
};
pub use wire::{train_distributed_wire, WireOptions, WireReport, WireWorker, WorkerOptions};
