//! Real distributed training over TCP (DESIGN.md §Distributed-wire).
//!
//! The coordinator runs the exact same training front-end as the
//! in-process [`train`](crate::coordinator::model::train) path —
//! scale, class list, `make_cells`, the (cell × task) working-set
//! roster — then ships each cell's working sets to a worker process as
//! one binary `Job` frame (raw little-endian f32 row blocks; see
//! [`crate::serve::protocol`]).  Workers run the same per-unit CV grid
//! ([`train_unit`](crate::coordinator::model::train_unit)) with the
//! same per-unit seed mix and budget split, serialize the solved cell
//! with [`persist::encode_shard`] and stream the bytes back; the
//! coordinator writes them verbatim into a `.sol.d` bundle via
//! [`persist::BundleWriter`].  Because every stage reuses the
//! single-process code (front-end, solver, shard encoder, manifest
//! writer), the distributed bundle is **byte-identical** to
//! `save_bundle(train(...))` by construction — the integration tests
//! in `tests/dist_wire.rs` compare the files byte for byte.
//!
//! Fault handling: cells are LPT-assigned to workers up front
//! ([`lpt_assign`]); when a worker disconnects or times out, its
//! in-flight cell and its remaining queue move to a shared retry queue
//! that surviving workers drain — a lost worker costs one cell's
//! re-train, not the run.  A worker that *reports* a deterministic
//! failure (an `Err` frame) aborts the run instead: re-dispatching a
//! poison cell would just kill every worker in turn.
//!
//! Wall-clock: `measured_wall` in [`WireReport`] is the socket-level
//! elapsed time of the whole run — the number the Table-4 harness was
//! previously *modelling*.  The modelled figures (critical path over
//! the planned assignment; sequential sum + 10%) are computed from the
//! worker-reported per-cell train times and reported alongside.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::cells::CellStrategy;
use crate::coordinator::config::{BackendChoice, Config};
use crate::coordinator::driver::lpt_assign;
use crate::coordinator::model::{build_dense_units, make_backend, train_unit, TrainedUnit};
use crate::coordinator::persist::{self, BundleHeader, BundleWriter};
use crate::data::dataset::Dataset;
use crate::data::folds::FoldKind;
use crate::data::matrix::Matrix;
use crate::data::store::WorkingSet;
use crate::kernel::KernelKind;
use crate::metrics::counters::{
    DIST_BYTES_RX, DIST_BYTES_TX, DIST_CELLS_DISPATCHED, DIST_CELLS_REDISPATCHED,
};
use crate::metrics::Loss;
use crate::serve::protocol::{
    bytes_to_f32s, f32s_to_bytes, hello_ack, hello_line, parse_hello, parse_hello_ack,
    read_frame, write_frame, FrameTag, WireMode, MAX_LINE,
};
use crate::solver::SolverKind;
use crate::tasks::TaskSpec;

// ------------------------------------------------------------ wire codecs

fn solver_tag(s: &SolverKind) -> String {
    match s {
        SolverKind::Hinge { w } => format!("h:{w}"),
        SolverKind::LeastSquares => "ls".into(),
        SolverKind::Quantile { tau } => format!("q:{tau}"),
        SolverKind::Expectile { tau } => format!("e:{tau}"),
    }
}

fn parse_solver(tag: &str) -> Result<SolverKind> {
    let (kind, rest) = tag.split_once(':').unwrap_or((tag, ""));
    Ok(match kind {
        "h" => SolverKind::Hinge { w: rest.parse()? },
        "ls" => SolverKind::LeastSquares,
        "q" => SolverKind::Quantile { tau: rest.parse()? },
        "e" => SolverKind::Expectile { tau: rest.parse()? },
        other => bail!("unknown solver tag `{other}`"),
    })
}

fn loss_tag(l: &Loss) -> String {
    match l {
        Loss::Classification => "c".into(),
        Loss::WeightedClassification { w } => format!("wc:{w}"),
        Loss::LeastSquares => "ls".into(),
        Loss::Pinball { tau } => format!("p:{tau}"),
        Loss::Expectile { tau } => format!("ex:{tau}"),
        Loss::Hinge => "h".into(),
    }
}

fn parse_loss(tag: &str) -> Result<Loss> {
    let (kind, rest) = tag.split_once(':').unwrap_or((tag, ""));
    Ok(match kind {
        "c" => Loss::Classification,
        "wc" => Loss::WeightedClassification { w: rest.parse()? },
        "ls" => Loss::LeastSquares,
        "p" => Loss::Pinball { tau: rest.parse()? },
        "ex" => Loss::Expectile { tau: rest.parse()? },
        "h" => Loss::Hinge,
        other => bail!("unknown loss tag `{other}`"),
    })
}

fn backend_tag(b: BackendChoice) -> &'static str {
    match b {
        BackendChoice::Scalar => "scalar",
        BackendChoice::Blocked => "blocked",
        BackendChoice::Simd => "simd",
        BackendChoice::SimdAvx2 => "avx2",
        BackendChoice::SimdAvx512 => "avx512",
        BackendChoice::SimdF32 => "simd-f32",
        BackendChoice::Xla => "xla",
    }
}

fn parse_backend(tag: &str) -> Result<BackendChoice> {
    Ok(match tag {
        "scalar" => BackendChoice::Scalar,
        "blocked" => BackendChoice::Blocked,
        "simd" => BackendChoice::Simd,
        "avx2" => BackendChoice::SimdAvx2,
        "avx512" => BackendChoice::SimdAvx512,
        "simd-f32" => BackendChoice::SimdF32,
        "xla" => BackendChoice::Xla,
        other => bail!("unknown backend tag `{other}`"),
    })
}

fn field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    line.strip_prefix(key)
        .map(str::trim)
        .ok_or_else(|| anyhow!("expected `{key} ...`, got `{line}`"))
}

/// Encode the session config the worker trains under.  Only the
/// fields [`train_unit`] reads travel; everything a worker must not
/// second-guess (scaling, cells) already happened on the coordinator.
fn encode_cfg(cfg: &Config) -> Vec<u8> {
    let p = cfg.solver_params;
    let mut s = String::new();
    s.push_str("cfg v1\n");
    s.push_str(&format!("seed {}\n", cfg.seed));
    s.push_str(&format!("folds {}\n", cfg.folds));
    s.push_str(&format!("fold_kind {:?}\n", cfg.fold_kind));
    s.push_str(&format!("grid_choice {}\n", cfg.grid_choice));
    s.push_str(&format!("libsvm_grid {}\n", cfg.use_libsvm_grid));
    s.push_str(&format!("adaptivity {}\n", cfg.adaptivity_control));
    s.push_str(&format!("kernel {:?}\n", cfg.kernel));
    s.push_str(&format!("select {:?}\n", cfg.select));
    s.push_str(&format!("solver {} {} {}\n", p.eps, p.max_iter, p.shrink_every));
    s.push_str(&format!("backend {}\n", backend_tag(cfg.backend)));
    s.into_bytes()
}

/// Decode a `Cfg` payload into a worker-side [`Config`].  Starts from
/// defaults with the coordinator-only knobs neutralized.
fn decode_cfg(payload: &[u8]) -> Result<Config> {
    let text = std::str::from_utf8(payload).context("cfg payload not UTF-8")?;
    let mut lines = text.lines();
    let mut next = || lines.next().ok_or_else(|| anyhow!("truncated cfg payload"));
    if next()? != "cfg v1" {
        bail!("not a cfg v1 payload");
    }
    let mut cfg = Config::default().display(0).threads(1);
    cfg.scale = None; // rows arrive already scaled
    cfg.cells = CellStrategy::None; // cells were cut on the coordinator
    cfg.seed = field(next()?, "seed")?.parse()?;
    cfg.folds = field(next()?, "folds")?.parse()?;
    cfg.fold_kind = match field(next()?, "fold_kind")? {
        "Random" => FoldKind::Random,
        "Stratified" => FoldKind::Stratified,
        "Block" => FoldKind::Block,
        "Alternating" => FoldKind::Alternating,
        other => bail!("unknown fold kind `{other}`"),
    };
    cfg.grid_choice = field(next()?, "grid_choice")?.parse()?;
    cfg.use_libsvm_grid = field(next()?, "libsvm_grid")?.parse()?;
    cfg.adaptivity_control = field(next()?, "adaptivity")?.parse()?;
    cfg.kernel = match field(next()?, "kernel")? {
        "Gauss" => KernelKind::Gauss,
        "Laplace" => KernelKind::Laplace,
        other => bail!("unknown kernel `{other}`"),
    };
    cfg.select = match field(next()?, "select")? {
        "FoldAverage" => crate::cv::SelectMethod::FoldAverage,
        "RetrainOnFull" => crate::cv::SelectMethod::RetrainOnFull,
        other => bail!("unknown select method `{other}`"),
    };
    let toks: Vec<&str> = field(next()?, "solver")?.split_whitespace().collect();
    if toks.len() != 3 {
        bail!("solver line arity");
    }
    cfg.solver_params.eps = toks[0].parse()?;
    cfg.solver_params.max_iter = toks[1].parse()?;
    cfg.solver_params.shrink_every = toks[2].parse()?;
    cfg.backend = parse_backend(field(next()?, "backend")?)?;
    Ok(cfg)
}

/// One cell's training job as it travels the wire.
struct WireJob {
    cell: usize,
    cv_jobs: usize,
    cv_gram_mb: Option<usize>,
    /// the cell's training indices (recorded in the shard)
    indices: Vec<usize>,
    /// (task index, working set, solver, validation loss)
    units: Vec<(usize, WorkingSet, SolverKind, Loss)>,
}

/// `Job` payload: a `u32` header length, a UTF-8 header describing the
/// cell and its unit roster, then one raw little-endian f32 block pair
/// (x rows, then y) per unit.
fn encode_job(
    cell: usize,
    cv_jobs: usize,
    cv_gram_mb: Option<usize>,
    indices: &[usize],
    units: &[(usize, &WorkingSet, SolverKind, Loss)],
) -> Result<Vec<u8>> {
    let mut h = String::new();
    h.push_str("job v1\n");
    h.push_str(&format!("cell {cell}\n"));
    h.push_str(&format!("budget {} {}\n", cv_jobs, cv_gram_mb.unwrap_or(0)));
    h.push_str(&format!(
        "indices {}\n",
        indices.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
    ));
    h.push_str(&format!("units {}\n", units.len()));
    for (t, ws, solver, loss) in units {
        h.push_str(&format!(
            "unit {t} {} {} {} {}\n",
            ws.len(),
            ws.dim(),
            solver_tag(solver),
            loss_tag(loss)
        ));
    }
    let header = h.into_bytes();
    let mut out = Vec::with_capacity(4 + header.len());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(&header);
    for (_, ws, _, _) in units {
        let crate::data::store::Store::Dense(x) = &ws.x else {
            bail!("wire training is dense-only (sparse cells never reach encode_job)");
        };
        out.extend_from_slice(&f32s_to_bytes(x.as_slice()));
        out.extend_from_slice(&f32s_to_bytes(&ws.y));
    }
    Ok(out)
}

fn decode_job(payload: &[u8]) -> Result<WireJob> {
    if payload.len() < 4 {
        bail!("job payload truncated");
    }
    let hlen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    let body = payload
        .get(4..4 + hlen)
        .ok_or_else(|| anyhow!("job header length {hlen} exceeds payload"))?;
    let text = std::str::from_utf8(body).context("job header not UTF-8")?;
    let mut lines = text.lines();
    let mut next = || lines.next().ok_or_else(|| anyhow!("truncated job header"));
    if next()? != "job v1" {
        bail!("not a job v1 payload");
    }
    let cell: usize = field(next()?, "cell")?.parse()?;
    let toks: Vec<&str> = field(next()?, "budget")?.split_whitespace().collect();
    if toks.len() != 2 {
        bail!("budget line arity");
    }
    let cv_jobs: usize = toks[0].parse()?;
    let gram: usize = toks[1].parse()?;
    let cv_gram_mb = if gram == 0 { None } else { Some(gram) };
    let indices: Vec<usize> = field(next()?, "indices")?
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| anyhow!("bad index `{t}`")))
        .collect::<Result<_>>()?;
    let n_units: usize = field(next()?, "units")?.parse()?;
    let mut roster = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        let toks: Vec<&str> = field(next()?, "unit")?.split_whitespace().collect();
        if toks.len() != 5 {
            bail!("unit line arity");
        }
        let t: usize = toks[0].parse()?;
        let rows: usize = toks[1].parse()?;
        let dim: usize = toks[2].parse()?;
        roster.push((t, rows, dim, parse_solver(toks[3])?, parse_loss(toks[4])?));
    }
    // the f32 blocks follow the header, one (x, y) pair per unit
    let mut at = 4 + hlen;
    let mut units = Vec::with_capacity(n_units);
    for (t, rows, dim, solver, loss) in roster {
        let xb = rows * dim * 4;
        let yb = rows * 4;
        let x_bytes = payload
            .get(at..at + xb)
            .ok_or_else(|| anyhow!("job payload truncated in unit {t} x block"))?;
        let y_bytes = payload
            .get(at + xb..at + xb + yb)
            .ok_or_else(|| anyhow!("job payload truncated in unit {t} y block"))?;
        at += xb + yb;
        let x = bytes_to_f32s(x_bytes).map_err(|e| anyhow!(e))?;
        let y = bytes_to_f32s(y_bytes).map_err(|e| anyhow!(e))?;
        let ws = WorkingSet::dense(Matrix::from_vec(x, rows, dim), y);
        units.push((t, ws, solver, loss));
    }
    if at != payload.len() {
        bail!("job payload has {} trailing bytes", payload.len() - at);
    }
    Ok(WireJob { cell, cv_jobs, cv_gram_mb, indices, units })
}

/// `Shard` payload: `u32` cell, `u64` worker-measured train µs, then
/// the exact shard-file bytes ([`persist::encode_shard`]).
fn encode_shard_reply(cell: usize, train_us: u64, shard: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + shard.len());
    out.extend_from_slice(&(cell as u32).to_le_bytes());
    out.extend_from_slice(&train_us.to_le_bytes());
    out.extend_from_slice(shard);
    out
}

fn decode_shard_reply(payload: &[u8]) -> Result<(usize, u64, &[u8])> {
    if payload.len() < 12 {
        bail!("shard payload truncated");
    }
    let cell = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    let train_us = u64::from_le_bytes(payload[4..12].try_into().unwrap());
    Ok((cell, train_us, &payload[12..]))
}

// ------------------------------------------------------------- worker side

/// Worker-process knobs (the `liquidsvm worker` subcommand).
#[derive(Clone, Debug, Default)]
pub struct WorkerOptions {
    /// override the coordinator-shipped CV job budget (None = obey it)
    pub jobs: Option<usize>,
    /// chaos knob for fault-tolerance tests: exit(3) after streaming
    /// this many shards
    pub fail_after: Option<usize>,
    pub display: u8,
}

/// Serve one coordinator connection: text handshake, then either a
/// text debug session (`ping`/`quit`) or the binary train session
/// (`Cfg`, then `Job` → `Shard` until `Done`).
fn handle_coordinator(
    stream: TcpStream,
    opts: &WorkerOptions,
    shards_sent: &AtomicUsize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut writer = BufWriter::new(stream);

    // ---- text handshake
    let mut line = String::new();
    reader.by_ref().take(MAX_LINE as u64).read_line(&mut line)?;
    let mode = match parse_hello(&line) {
        Ok(m) => m,
        Err(e) => {
            writeln!(writer, "{}", crate::serve::protocol::err_msg("bad-hello", &e))?;
            writer.flush()?;
            return Ok(());
        }
    };
    writeln!(writer, "{}", hello_ack(mode))?;
    writer.flush()?;

    if mode == WireMode::Text {
        // debug session: line in, line out
        loop {
            let mut line = String::new();
            if reader.by_ref().take(MAX_LINE as u64).read_line(&mut line)? == 0 {
                return Ok(());
            }
            match line.trim() {
                "ping" => writeln!(writer, "{}", crate::serve::protocol::ok_msg("pong"))?,
                "quit" => {
                    writeln!(writer, "{}", crate::serve::protocol::ok_msg("bye"))?;
                    writer.flush()?;
                    return Ok(());
                }
                other => writeln!(
                    writer,
                    "{}",
                    crate::serve::protocol::err_msg("bad-request", other)
                )?,
            }
            writer.flush()?;
        }
    }

    // ---- binary train session
    let (tag, payload) = read_frame(&mut reader)?;
    if tag != FrameTag::Cfg {
        bail!("expected Cfg frame, got {tag:?}");
    }
    let mut cfg = decode_cfg(&payload)?;
    cfg.display = opts.display;
    let backend = make_backend(&cfg).map_err(|e| anyhow!("backend: {e}"))?;

    loop {
        let (tag, payload) = {
            let mut sp = crate::obs::span("dist.rpc.recv");
            let got = read_frame(&mut reader)?;
            sp.add_bytes(got.1.len() as u64 + 5);
            got
        };
        match tag {
            FrameTag::Job => {
                let job = match decode_job(&payload) {
                    Ok(j) => j,
                    Err(e) => {
                        // malformed job is deterministic: report, don't die
                        write_frame(&mut writer, FrameTag::Err, e.to_string().as_bytes())?;
                        continue;
                    }
                };
                let cv_jobs = opts.jobs.unwrap_or(job.cv_jobs).max(1);
                let t0 = Instant::now();
                let mut trained = Vec::with_capacity(job.units.len());
                for (t, ws, solver, loss) in job.units {
                    // the exact per-unit seed mix of the in-process driver
                    let seed = cfg.seed ^ ((job.cell as u64) << 20) ^ t as u64;
                    let cv = train_unit(
                        &ws,
                        solver,
                        loss,
                        &cfg,
                        backend.clone(),
                        seed,
                        cv_jobs,
                        job.cv_gram_mb,
                    );
                    trained.push(TrainedUnit { cell: job.cell, task: t, data: ws, cv });
                }
                let train_us = t0.elapsed().as_micros() as u64;
                let refs: Vec<&TrainedUnit> = trained.iter().collect();
                let shard = persist::encode_shard(job.cell, &job.indices, &refs)?;
                let reply = encode_shard_reply(job.cell, train_us, &shard);
                {
                    let mut sp = crate::obs::span("dist.rpc.send");
                    write_frame(&mut writer, FrameTag::Shard, &reply)?;
                    sp.add_bytes(reply.len() as u64 + 5);
                }
                if opts.display > 0 {
                    eprintln!(
                        "[worker] cell {} done: {} units, {} shard bytes, {:.2}s",
                        job.cell,
                        refs.len(),
                        shard.len(),
                        train_us as f64 / 1e6
                    );
                }
                let sent = shards_sent.fetch_add(1, Ordering::SeqCst) + 1;
                if let Some(limit) = opts.fail_after {
                    if sent >= limit {
                        // chaos: die abruptly mid-run, like a lost node
                        eprintln!("[worker] --fail-after {limit} reached, exiting");
                        std::process::exit(3);
                    }
                }
            }
            FrameTag::Done => return Ok(()),
            FrameTag::Err => {
                let msg = String::from_utf8_lossy(&payload).into_owned();
                bail!("coordinator error: {msg}");
            }
            other => bail!("unexpected frame {other:?} in train session"),
        }
    }
}

/// Accept-and-serve loop of a worker process.  Connections are served
/// one at a time (a worker is one training engine); `stop` ends the
/// loop between connections — [`WireWorker`] uses it, the CLI passes
/// `None` and serves forever.
pub fn worker_listen(
    listener: TcpListener,
    opts: &WorkerOptions,
    stop: Option<&AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let shards_sent = AtomicUsize::new(0);
    loop {
        if stop.map(|s| s.load(Ordering::SeqCst)).unwrap_or(false) {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nonblocking(false).ok();
                if opts.display > 0 {
                    eprintln!("[worker] coordinator connected from {peer}");
                }
                if let Err(e) = handle_coordinator(stream, opts, &shards_sent) {
                    // a dropped coordinator is routine; log and re-accept
                    if opts.display > 0 {
                        eprintln!("[worker] session ended: {e}");
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accepting"),
        }
    }
}

/// An in-process worker on an ephemeral loopback port — the bench and
/// unit tests use this to exercise the *real* socket path without
/// spawning processes.  (The fault-tolerance tests spawn real
/// `liquidsvm worker` processes instead: `--fail-after` has to kill a
/// process, not a thread.)
pub struct WireWorker {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl WireWorker {
    pub fn spawn_local(opts: WorkerOptions) -> Result<WireWorker> {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding loopback")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let _ = worker_listen(listener, &opts, Some(&*stop2));
        });
        Ok(WireWorker { addr, stop, handle: Some(handle) })
    }

    /// `host:port` string to pass as a `--workers` entry.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }
}

impl Drop for WireWorker {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// -------------------------------------------------------- coordinator side

/// Coordinator-side socket knobs.
#[derive(Clone, Copy, Debug)]
pub struct WireOptions {
    pub connect_timeout: Duration,
    /// per-reply read timeout; a worker silent for this long is
    /// declared lost and its cells re-dispatched (None = wait forever)
    pub io_timeout: Option<Duration>,
}

impl Default for WireOptions {
    fn default() -> Self {
        WireOptions {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Some(Duration::from_secs(600)),
        }
    }
}

/// Outcome and accounting of a wire training run.
#[derive(Clone, Debug)]
pub struct WireReport {
    /// worker addresses given
    pub workers: usize,
    /// workers still connected when the run finished
    pub live_workers: usize,
    pub n_cells: usize,
    /// worker-reported train time per cell (re-dispatches keep the
    /// successful attempt's time)
    pub per_cell_train: Vec<Duration>,
    /// socket-level wall-clock of the whole run — genuinely measured
    pub measured_wall: Duration,
    pub bytes_tx: u64,
    pub bytes_rx: u64,
    /// Job frames sent (≥ n_cells when cells were re-dispatched)
    pub dispatched: u64,
    /// cells moved to the retry queue after a worker loss
    pub redispatched: u64,
    /// modelled distributed wall (critical path over the planned LPT
    /// assignment) — the simulation's accounting, for comparison
    pub modelled_distributed: Duration,
    /// modelled single-node wall (sequential sum + 10% overhead)
    pub modelled_single_node: Duration,
}

impl WireReport {
    pub fn modelled_speedup(&self) -> f64 {
        self.modelled_single_node.as_secs_f64() / self.modelled_distributed.as_secs_f64().max(1e-9)
    }
}

/// Shared dispatch state across the per-worker coordinator threads.
/// `#[doc(hidden)] pub` (fields included) so the loom models in
/// `tests/loom_models.rs` can drive the claim / complete /
/// worker-death transitions directly and assert the no-lost-cell,
/// no-double-dispatch invariants; not a public API.
#[doc(hidden)]
pub struct DispatchState {
    /// per-worker cell queues (the planned LPT assignment)
    pub queues: Vec<VecDeque<usize>>,
    /// cells orphaned by a lost worker, drained by survivors
    pub retry: VecDeque<usize>,
    pub in_flight: usize,
    /// per-cell (shard bytes, train µs) as they arrive
    pub done: Vec<Option<(Vec<u8>, u64)>>,
    pub n_done: usize,
    pub live_workers: usize,
    /// deterministic failure reported by a worker — abort, don't retry
    pub failed: Option<String>,
    pub redispatched: u64,
}

#[doc(hidden)]
pub struct Shared {
    pub state: Mutex<DispatchState>,
    pub cv: Condvar,
}

/// What [`Shared::claim`] handed a worker thread.
#[doc(hidden)]
#[derive(Debug, PartialEq, Eq)]
pub enum Claim {
    /// train this cell (the claim is exclusive; `in_flight` was bumped)
    Cell(usize),
    /// the run is over — all cells done, or someone failed
    Finished,
}

impl Shared {
    pub fn new(
        queues: Vec<VecDeque<usize>>,
        retry: VecDeque<usize>,
        n_cells: usize,
        live_workers: usize,
    ) -> Shared {
        Shared {
            state: Mutex::new(DispatchState {
                queues,
                retry,
                in_flight: 0,
                done: vec![None; n_cells],
                n_done: 0,
                live_workers,
                failed: None,
                redispatched: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Claim the next cell for worker `w`: its own queue first, then
    /// the retry queue of orphaned cells.  Blocks on the condvar while
    /// other workers still have cells in flight (one of them may die
    /// and orphan work for us); returns [`Claim::Finished`] once every
    /// cell is done or the run failed.
    pub fn claim(&self, w: usize) -> Claim {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.failed.is_some() || st.n_done == st.done.len() {
                return Claim::Finished;
            }
            if let Some(c) = st.queues[w].pop_front().or_else(|| st.retry.pop_front()) {
                st.in_flight += 1;
                return Claim::Cell(c);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Record a trained shard for a claimed cell.  First writer wins:
    /// a re-dispatched cell whose original worker turns out to have
    /// answered after all does not overwrite (or double-count) the
    /// finished result.
    pub fn complete(&self, cell: usize, shard: Vec<u8>, train_us: u64) {
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        if st.done[cell].is_none() {
            st.done[cell] = Some((shard, train_us));
            st.n_done += 1;
        }
        self.cv.notify_all();
    }

    /// Abort the run with a deterministic failure while holding a
    /// claimed cell (releases the in-flight slot so waiters can see a
    /// quiescent final state).
    pub fn fail_in_flight(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        st.failed = Some(msg);
        self.cv.notify_all();
    }

    /// Requeue a lost worker's cells (its in-flight claim plus
    /// everything still assigned to it) and retire it from the pool.
    /// Returns how many cells moved to the retry queue.  When the last
    /// worker dies with work remaining the run is failed — nobody is
    /// left to drain the retry queue, and without this the surviving
    /// claim loops would block forever.
    pub fn worker_dead(&self, w: usize, in_flight_cell: Option<usize>) -> u64 {
        let mut st = self.state.lock().unwrap();
        let mut moved = 0u64;
        if let Some(c) = in_flight_cell {
            st.in_flight -= 1;
            st.retry.push_back(c);
            moved += 1;
        }
        while let Some(c) = st.queues[w].pop_front() {
            st.retry.push_back(c);
            moved += 1;
        }
        st.redispatched += moved;
        st.live_workers -= 1;
        if st.live_workers == 0 && st.n_done < st.done.len() {
            st.failed = Some("all workers lost".into());
        }
        self.cv.notify_all();
        moved
    }
}

/// One worker connection's dispatch loop.  Returns when all cells are
/// done, the run failed, or this worker died (in which case its cells
/// have been moved to the retry queue).
#[allow(clippy::too_many_arguments)]
fn worker_thread(
    w: usize,
    stream: TcpStream,
    shared: &Shared,
    payloads: &[Vec<u8>],
    opts: &WireOptions,
    bytes_tx: &AtomicU64,
    bytes_rx: &AtomicU64,
    dispatched: &AtomicU64,
) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(opts.io_timeout).ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return mark_worker_dead(w, shared, None),
    });
    let mut writer = BufWriter::new(stream);

    loop {
        let cell = match shared.claim(w) {
            Claim::Finished => {
                // clean end: tell the worker the session is over
                let _ = write_frame(&mut writer, FrameTag::Done, &[]);
                return;
            }
            Claim::Cell(c) => c,
        };

        // send the job, wait for the shard
        let send = {
            let mut sp = crate::obs::span("dist.rpc.send");
            let r = write_frame(&mut writer, FrameTag::Job, &payloads[cell]);
            sp.add_bytes(payloads[cell].len() as u64 + 5);
            r
        };
        if send.is_ok() {
            let n = payloads[cell].len() as u64 + 5;
            DIST_BYTES_TX.add(n);
            bytes_tx.fetch_add(n, Ordering::Relaxed);
            DIST_CELLS_DISPATCHED.inc();
            dispatched.fetch_add(1, Ordering::Relaxed);
        }
        let reply = send.and_then(|_| {
            let mut sp = crate::obs::span("dist.rpc.recv");
            let got = read_frame(&mut reader)?;
            sp.add_bytes(got.1.len() as u64 + 5);
            Ok(got)
        });

        match reply {
            Ok((FrameTag::Shard, payload)) => {
                let n = payload.len() as u64 + 5;
                DIST_BYTES_RX.add(n);
                bytes_rx.fetch_add(n, Ordering::Relaxed);
                match decode_shard_reply(&payload) {
                    Ok((got_cell, train_us, shard)) if got_cell == cell => {
                        shared.complete(cell, shard.to_vec(), train_us);
                    }
                    Ok((got_cell, _, _)) => {
                        shared.fail_in_flight(format!(
                            "worker {w} answered cell {got_cell} for cell {cell}"
                        ));
                        return;
                    }
                    Err(e) => {
                        shared.fail_in_flight(format!("worker {w} shard reply: {e}"));
                        return;
                    }
                }
            }
            Ok((FrameTag::Err, payload)) => {
                // deterministic failure — re-dispatching would poison
                // the next worker too
                let msg = String::from_utf8_lossy(&payload).into_owned();
                shared.fail_in_flight(format!("worker {w} failed on cell {cell}: {msg}"));
                return;
            }
            Ok((tag, _)) => {
                shared.fail_in_flight(format!("worker {w}: unexpected {tag:?} frame"));
                return;
            }
            Err(_) => {
                // disconnect or timeout: this worker is lost — requeue
                // its in-flight cell plus everything still assigned to it
                return mark_worker_dead(w, shared, Some(cell));
            }
        }
    }
}

/// Requeue a lost worker's cells and retire it from the pool,
/// crediting the process-wide re-dispatch counter (kept out of
/// [`Shared::worker_dead`] so the loom models exercise the transition
/// without mutating global metrics).
fn mark_worker_dead(w: usize, shared: &Shared, in_flight_cell: Option<usize>) {
    let moved = shared.worker_dead(w, in_flight_cell);
    DIST_CELLS_REDISPATCHED.add(moved);
}

/// Open a train session to one worker: connect, text handshake in
/// binary mode, ship the session config.
fn connect_worker(addr: &str, cfg_payload: &[u8], opts: &WireOptions) -> Result<TcpStream> {
    let sock_addr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr}: no address"))?;
    let stream = TcpStream::connect_timeout(&sock_addr, opts.connect_timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(opts.connect_timeout)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream.try_clone()?);
    writeln!(writer, "{}", hello_line(WireMode::Binary))?;
    writer.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mode = parse_hello_ack(&line).map_err(|e| anyhow!("{addr}: {e}"))?;
    if mode != WireMode::Binary {
        bail!("{addr}: worker negotiated {mode:?}, wanted Binary");
    }
    write_frame(&mut writer, FrameTag::Cfg, cfg_payload)?;
    Ok(stream)
}

/// Distributed training over real sockets.  Shards the model's cells
/// (the `cfg.cells` strategy — the same partition `train` would cut)
/// across the given workers and assembles the streamed-back shards
/// into a `.sol.d` bundle at `out`, byte-identical to
/// `save_bundle(train(data, spec, cfg))`.
pub fn train_distributed_wire(
    data: &Dataset,
    spec: &TaskSpec,
    cfg: &Config,
    workers: &[String],
    out: &Path,
    opts: &WireOptions,
) -> Result<WireReport> {
    let _sp = crate::obs::span("dist.wire");
    if workers.is_empty() {
        bail!("no workers given");
    }
    let t0 = Instant::now();

    // the exact front-end of the in-process train() path
    let fe = build_dense_units(data, spec, cfg)?;
    let n_cells = fe.partition.n_cells();
    // ship the same per-unit budget shares the in-process driver computes
    let (driver_threads, cv_jobs) = cfg.split_jobs(fe.units.len());
    let cv_gram_mb = cfg.max_gram_mb.map(|mb| (mb / driver_threads.max(1)).max(1));

    // group the unit roster by cell and pre-encode every Job frame
    let mut by_cell: Vec<Vec<(usize, &WorkingSet, SolverKind, Loss)>> = vec![Vec::new(); n_cells];
    for (c, t, ws, task) in &fe.units {
        by_cell[*c].push((*t, ws, task.solver, task.val_loss));
    }
    let mut payloads = Vec::with_capacity(n_cells);
    for (c, units) in by_cell.iter().enumerate() {
        payloads.push(encode_job(c, cv_jobs, cv_gram_mb, &fe.partition.cells[c], units)?);
    }

    // LPT-plan cells onto workers by training-row weight
    let weights: Vec<u64> = by_cell
        .iter()
        .map(|units| units.iter().map(|(_, ws, _, _)| ws.len() as u64).sum::<u64>().max(1))
        .collect();
    let assignment = lpt_assign(&weights, workers.len());

    // connect everyone up front; a worker that never answers is simply
    // not part of the pool (its planned cells start on the retry queue)
    let cfg_payload = encode_cfg(cfg);
    let mut streams: Vec<Option<TcpStream>> = Vec::with_capacity(workers.len());
    for addr in workers {
        match connect_worker(addr, &cfg_payload, opts) {
            Ok(s) => {
                s.set_read_timeout(opts.io_timeout).ok();
                streams.push(Some(s));
            }
            Err(e) => {
                if cfg.display > 0 {
                    eprintln!("[dist] worker {addr} unavailable: {e}");
                }
                streams.push(None);
            }
        }
    }
    let live = streams.iter().filter(|s| s.is_some()).count();
    if live == 0 {
        bail!("none of the {} workers are reachable", workers.len());
    }

    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); workers.len()];
    let mut retry = VecDeque::new();
    for (c, &w) in assignment.iter().enumerate() {
        if streams[w].is_some() {
            queues[w].push_back(c);
        } else {
            retry.push_back(c);
        }
    }
    let shared = Arc::new(Shared::new(queues, retry, n_cells, live));
    let payloads = Arc::new(payloads);
    let bytes_tx = Arc::new(AtomicU64::new(0));
    let bytes_rx = Arc::new(AtomicU64::new(0));
    let dispatched = Arc::new(AtomicU64::new(0));

    std::thread::scope(|scope| {
        for (w, slot) in streams.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            let shared = Arc::clone(&shared);
            let payloads = Arc::clone(&payloads);
            let bytes_tx = Arc::clone(&bytes_tx);
            let bytes_rx = Arc::clone(&bytes_rx);
            let dispatched = Arc::clone(&dispatched);
            let opts = *opts;
            scope.spawn(move || {
                worker_thread(
                    w, stream, &shared, &payloads, &opts, &bytes_tx, &bytes_rx, &dispatched,
                )
            });
        }
    });

    let st = shared.state.lock().unwrap();
    if let Some(msg) = &st.failed {
        bail!("distributed train failed: {msg}");
    }
    if st.n_done != n_cells {
        bail!("distributed train incomplete: {}/{} cells", st.n_done, n_cells);
    }

    // stream the shards into the bundle, manifest in cell order
    let mut writer = BundleWriter::create(out, n_cells)?;
    let mut per_cell_train = Vec::with_capacity(n_cells);
    for (c, slot) in st.done.iter().enumerate() {
        let (bytes, train_us) = slot.as_ref().expect("n_done == n_cells");
        writer.put_shard(c, bytes)?;
        per_cell_train.push(Duration::from_micros(*train_us));
    }
    writer.finish(&BundleHeader {
        spec: spec.clone(),
        kernel: cfg.kernel,
        classes: fe.classes.clone(),
        n_tasks: fe.n_tasks,
        scaler: fe.scaler.clone(),
        dim: fe.input_dim(),
        strategy: cfg.cells.clone(),
        router: fe.partition.router.clone(),
    })?;

    // modelled accounting (the simulation's formulas) for comparison
    let mut worker_time = vec![Duration::ZERO; workers.len()];
    for (c, &w) in assignment.iter().enumerate() {
        worker_time[w] += per_cell_train[c];
    }
    let modelled_distributed =
        worker_time.into_iter().max().unwrap_or(Duration::ZERO).max(Duration::from_micros(1));
    let total: Duration = per_cell_train.iter().sum();
    let modelled_single_node = total + total / 10;

    Ok(WireReport {
        workers: workers.len(),
        live_workers: st.live_workers,
        n_cells,
        per_cell_train,
        measured_wall: t0.elapsed(),
        bytes_tx: bytes_tx.load(Ordering::Relaxed),
        bytes_rx: bytes_rx.load(Ordering::Relaxed),
        dispatched: dispatched.load(Ordering::Relaxed),
        redispatched: st.redispatched,
        modelled_distributed,
        modelled_single_node,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn cfg_payload_roundtrip() {
        let cfg = Config::default()
            .folds(4)
            .seed(7)
            .grid_choice(1)
            .libsvm_grid(true)
            .solver_eps(5e-4);
        let back = decode_cfg(&encode_cfg(&cfg)).unwrap();
        assert_eq!(back.folds, 4);
        assert_eq!(back.seed, 7);
        assert_eq!(back.grid_choice, 1);
        assert!(back.use_libsvm_grid);
        assert_eq!(back.solver_params.eps.to_bits(), 5e-4f32.to_bits());
        assert_eq!(back.kernel, cfg.kernel);
        assert_eq!(back.backend, cfg.backend);
        assert!(decode_cfg(b"not a cfg").is_err());
    }

    #[test]
    fn job_payload_roundtrip_bit_exact() {
        let d = synth::banana_binary(40, 9);
        let ws = WorkingSet::dense(d.x.clone(), d.y.clone());
        let units = vec![(0usize, &ws, SolverKind::Hinge { w: 0.5 }, Loss::Classification)];
        let indices: Vec<usize> = (0..40).collect();
        let payload = encode_job(3, 2, Some(64), &indices, &units).unwrap();
        let job = decode_job(&payload).unwrap();
        assert_eq!(job.cell, 3);
        assert_eq!(job.cv_jobs, 2);
        assert_eq!(job.cv_gram_mb, Some(64));
        assert_eq!(job.indices, indices);
        assert_eq!(job.units.len(), 1);
        let (t, back, solver, loss) = &job.units[0];
        assert_eq!(*t, 0);
        assert_eq!(*solver, SolverKind::Hinge { w: 0.5 });
        assert_eq!(*loss, Loss::Classification);
        let crate::data::store::Store::Dense(x) = &back.x else { panic!() };
        // bit-exact: the wire never converts floats through text
        assert!(x
            .as_slice()
            .iter()
            .zip(d.x.as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(back.y, d.y);
        // truncation is an error, not a panic
        assert!(decode_job(&payload[..payload.len() - 8]).is_err());
        assert!(decode_job(&payload[..2]).is_err());
    }

    #[test]
    fn shard_reply_roundtrip() {
        let reply = encode_shard_reply(12, 34_567, b"shard-bytes");
        let (cell, us, bytes) = decode_shard_reply(&reply).unwrap();
        assert_eq!((cell, us), (12, 34_567));
        assert_eq!(bytes, b"shard-bytes");
        assert!(decode_shard_reply(&reply[..7]).is_err());
    }

    #[test]
    fn solver_and_loss_tags_roundtrip() {
        for s in [
            SolverKind::Hinge { w: 0.31 },
            SolverKind::LeastSquares,
            SolverKind::Quantile { tau: 0.05 },
            SolverKind::Expectile { tau: 0.95 },
        ] {
            assert_eq!(parse_solver(&solver_tag(&s)).unwrap(), s);
        }
        for l in [
            Loss::Classification,
            Loss::WeightedClassification { w: 0.7 },
            Loss::LeastSquares,
            Loss::Pinball { tau: 0.1 },
            Loss::Expectile { tau: 0.9 },
            Loss::Hinge,
        ] {
            assert_eq!(parse_loss(&loss_tag(&l)).unwrap(), l);
        }
        assert!(parse_solver("zz").is_err());
        assert!(parse_loss("zz").is_err());
    }

    #[test]
    fn loopback_wire_matches_single_process_bundle() {
        use crate::coordinator::model::train;
        use crate::coordinator::persist::save_bundle;

        let d = synth::by_name("covtype", 500, 21).unwrap();
        let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 120 });
        let spec = TaskSpec::Binary { w: 0.5 };

        let dir = std::env::temp_dir().join(format!("lsvm-wire-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mono = dir.join("mono.sol.d");
        let dist = dir.join("dist.sol.d");

        let model = train(&d, &spec, &cfg).unwrap();
        save_bundle(&model, &mono).unwrap();

        let w1 = WireWorker::spawn_local(WorkerOptions::default()).unwrap();
        let w2 = WireWorker::spawn_local(WorkerOptions::default()).unwrap();
        let report = train_distributed_wire(
            &d,
            &spec,
            &cfg,
            &[w1.addr(), w2.addr()],
            &dist,
            &WireOptions::default(),
        )
        .unwrap();
        assert_eq!(report.live_workers, 2);
        assert_eq!(report.redispatched, 0);
        assert!(report.n_cells >= 2, "want a real multi-cell run");
        assert!(report.bytes_tx > 0 && report.bytes_rx > 0);
        assert!(report.measured_wall > Duration::ZERO);

        // byte identity: manifest and every shard file
        let m1 = std::fs::read(mono.join(persist::MANIFEST_FILE)).unwrap();
        let m2 = std::fs::read(dist.join(persist::MANIFEST_FILE)).unwrap();
        assert_eq!(m1, m2, "MANIFEST differs");
        for c in 0..report.n_cells {
            let f = format!("shard-{c:05}.sol");
            let a = std::fs::read(mono.join(&f)).unwrap();
            let b = std::fs::read(dist.join(&f)).unwrap();
            assert_eq!(a, b, "shard {c} differs");
        }
    }

    #[test]
    fn unreachable_workers_fail_cleanly() {
        let d = synth::banana_binary(60, 3);
        let cfg = Config::default().folds(2);
        let out = std::env::temp_dir().join("lsvm-wire-unreachable.sol.d");
        let opts = WireOptions { connect_timeout: Duration::from_millis(200), io_timeout: None };
        let err = train_distributed_wire(
            &d,
            &TaskSpec::Binary { w: 0.5 },
            &cfg,
            &["127.0.0.1:1".into()],
            &out,
            &opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
    }
}
