//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time — the artifacts are HLO *text*
//! (see aot.py for why text, not serialized protos), compiled once per
//! process by the PJRT CPU client and cached.  Inputs are zero-padded
//! up to the artifact's shape bucket (exact for every graph we lower;
//! see python/compile/kernels/*.py) and outputs sliced back.

// One of the two modules allowed to opt back into `unsafe` (the crate
// root denies it): the `unsafe impl Send/Sync for XlaRuntime` below is
// an FFI thread-safety contract the compiler cannot check.  Every
// unsafe item must carry a SAFETY comment (CI denies
// `clippy::undocumented_unsafe_blocks`); see DESIGN.md
// §Static-analysis.
#![cfg_attr(feature = "xla", allow(unsafe_code))]

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
use crate::sync::Mutex;
// always-std (sync.rs §static_atomic): a plain call tally for perf
// reports, not a synchronization edge
use crate::sync::static_atomic::AtomicUsize;
#[cfg(feature = "xla")]
use crate::sync::static_atomic::Ordering;

use anyhow::{anyhow, Context, Result};

use crate::data::matrix::Matrix;

/// Artifact descriptor from `artifacts/manifest.tsv`.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub op: String,
    pub rows: usize,
    pub cols: usize,
    pub dim: usize,
    pub gammas: usize,
    pub t_cols: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub gamma_chunk: usize,
    pub t_cols: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Parse the TSV manifest written by aot.py:
    /// first line `gamma_chunk\t<G>\tt_cols\t<T>`, then one artifact
    /// per line: `name\top\trows\tcols\tdim\tgammas\tt_cols`.
    pub fn parse_tsv(text: &str) -> Result<Manifest> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let head = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        let h: Vec<&str> = head.split('\t').collect();
        if h.len() != 4 || h[0] != "gamma_chunk" || h[2] != "t_cols" {
            return Err(anyhow!("bad manifest header: {head}"));
        }
        let gamma_chunk: usize = h[1].parse().context("gamma_chunk")?;
        let t_cols: usize = h[3].parse().context("t_cols")?;
        let mut artifacts = Vec::new();
        for line in lines {
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 7 {
                return Err(anyhow!("bad manifest row: {line}"));
            }
            artifacts.push(ArtifactInfo {
                name: f[0].to_string(),
                op: f[1].to_string(),
                rows: f[2].parse().context("rows")?,
                cols: f[3].parse().context("cols")?,
                dim: f[4].parse().context("dim")?,
                gammas: f[5].parse().context("gammas")?,
                t_cols: f[6].parse().context("t_cols")?,
            });
        }
        Ok(Manifest { gamma_chunk, t_cols, artifacts })
    }
}

#[cfg(feature = "xla")]
struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Handle to the PJRT CPU client + compiled-artifact cache.
///
/// The PJRT CPU client is internally thread-safe; all calls here are
/// nonetheless serialized behind one mutex because a single in-flight
/// executable already saturates this machine.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    dir: PathBuf,
    manifest: Manifest,
    inner: Mutex<Inner>,
    /// executions served, for perf reporting
    pub calls: AtomicUsize,
}

// SAFETY: `XlaRuntime` is not auto-Send/Sync only because the xla
// crate's `PjRtClient` / `PjRtLoadedExecutable` wrap raw pointers to
// C++ PJRT objects.  The contract justifying the impls:
//
// 1. *Ownership* — the wrapped pointers are uniquely owned by `Inner`
//    (they are not borrowed from elsewhere and nothing else frees
//    them), so moving the struct to another thread (`Send`) transfers
//    ownership without aliasing.
// 2. *Synchronized access* — every use of the pointers goes through
//    `self.inner.lock()` ([`XlaRuntime::run`] is the only call site),
//    so `&XlaRuntime` shared across threads (`Sync`) never yields
//    concurrent access to the C++ objects, even if the plugin's own
//    thread-safety documentation were wrong.
// 3. *No thread affinity* — the PJRT CPU plugin does not require
//    calls to come from the thread that created the client (it is
//    documented thread-safe and thread-agnostic), so crossing threads
//    between calls is permitted.
//
// The remaining fields (`PathBuf`, `Manifest`, atomic counter) are
// ordinarily Send + Sync.  Any new field holding FFI state MUST go
// inside `Inner`, behind the mutex, or this contract is void.
#[cfg(feature = "xla")]
unsafe impl Send for XlaRuntime {}
// SAFETY: see the Send contract above — points 2 and 3 are exactly
// the shared-reference guarantees `Sync` requires.
#[cfg(feature = "xla")]
unsafe impl Sync for XlaRuntime {}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = Manifest::parse_tsv(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaRuntime {
            dir,
            manifest,
            inner: Mutex::new(Inner { client, executables: HashMap::new() }),
            calls: AtomicUsize::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Largest Gram bucket rows available (callers tile above this).
    pub fn max_gram_rows(&self) -> usize {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.op == "gram_multi")
            .map(|a| a.rows)
            .max()
            .unwrap_or(0)
    }

    /// Pick the smallest bucket that fits (rows, cols, dim) for `op`.
    fn pick_bucket(&self, op: &str, rows: usize, cols: usize, dim: usize) -> Result<ArtifactInfo> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.op == op && a.rows >= rows && a.cols >= cols && a.dim >= dim)
            .min_by_key(|a| a.rows * a.cols * a.dim)
            .cloned()
            .ok_or_else(|| anyhow!("no `{op}` artifact bucket fits ({rows}x{cols}x{dim})"))
    }

    /// Execute an artifact by name with the given literals, returning
    /// the single tuple-wrapped output literal.
    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.executables.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            inner.executables.insert(name.to_string(), exe);
        }
        let exe = &inner.executables[name];
        self.calls.fetch_add(1, Ordering::Relaxed);
        crate::metrics::counters::XLA_CALLS.inc();
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result.to_tuple1().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    fn mat_literal(m: &Matrix) -> Result<xla::Literal> {
        xla::Literal::vec1(m.as_slice())
            .reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| anyhow!("literal reshape: {e:?}"))
    }

    /// Multi-γ Gaussian Gram stack `[G]` matrices of shape
    /// `[x.rows × y.rows]`, via the `gram10` artifact (liquidSVM γ
    /// parameterization).  γ grids longer than the artifact chunk are
    /// tiled transparently.
    pub fn gram_multi(&self, x: &Matrix, y: &Matrix, gammas: &[f32]) -> Result<Vec<Matrix>> {
        let chunk = self.manifest.gamma_chunk;
        let (m, n, d) = (x.rows(), y.rows(), x.cols());
        let art = self.pick_bucket("gram_multi", m, n, d)?;
        let xpad = x.pad_to(art.rows, art.dim);
        let ypad = y.pad_to(art.cols, art.dim);
        let mut out = Vec::with_capacity(gammas.len());
        for gs in gammas.chunks(chunk) {
            let mut gpad: Vec<f32> = gs.to_vec();
            gpad.resize(chunk, 1.0); // padding gammas, outputs ignored
            let glit = xla::Literal::vec1(&gpad);
            let res = self.run(
                &art.name,
                &[Self::mat_literal(&xpad)?, Self::mat_literal(&ypad)?, glit],
            )?;
            let flat: Vec<f32> = res.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            // layout [chunk, art.rows, art.cols] -> slice [m, n] per γ
            for (gi, _) in gs.iter().enumerate() {
                let mut mat = Matrix::zeros(m, n);
                let base = gi * art.rows * art.cols;
                for i in 0..m {
                    let row = &flat[base + i * art.cols..base + i * art.cols + n];
                    mat.row_mut(i).copy_from_slice(row);
                }
                out.push(mat);
            }
        }
        Ok(out)
    }

    /// Fused prediction `K_γ(x, sv) · alpha` via the `predict` artifact;
    /// alpha is `[n × t]`, result `[m × t]`.
    pub fn predict(&self, x: &Matrix, sv: &Matrix, alpha: &Matrix, gamma: f32) -> Result<Matrix> {
        let (m, n, d, t) = (x.rows(), sv.rows(), x.cols(), alpha.cols());
        let tcap = self.manifest.t_cols;
        let art = self.pick_bucket("predict", m, n, d)?;
        let xpad = x.pad_to(art.rows, art.dim);
        let svpad = sv.pad_to(art.cols, art.dim);
        let mut out = Matrix::zeros(m, t);
        for t0 in (0..t).step_by(tcap) {
            let t1 = (t0 + tcap).min(t);
            // column block of alpha, zero-padded to (art.cols, tcap)
            let mut ablock = Matrix::zeros(art.cols, tcap);
            for i in 0..n {
                for (jj, j) in (t0..t1).enumerate() {
                    ablock.set(i, jj, alpha.get(i, j));
                }
            }
            let alit = Self::mat_literal(&ablock)?;
            let res = self.run(
                &art.name,
                &[
                    Self::mat_literal(&xpad)?,
                    Self::mat_literal(&svpad)?,
                    alit,
                    xla::Literal::scalar(gamma),
                ],
            )?;
            let flat: Vec<f32> = res.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            for i in 0..m {
                for (jj, j) in (t0..t1).enumerate() {
                    out.set(i, j, flat[i * tcap + jj]);
                }
            }
        }
        Ok(out)
    }
}

/// Stub compiled when the `xla` feature is off: [`XlaRuntime::open`]
/// always fails, so `BackendChoice::Xla` resolves to an error, the
/// CPU fallbacks take over, and the artifact-gated tests/benches skip
/// — no caller ever holds an instance, the other methods exist only
/// to keep the API surface identical.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    manifest: Manifest,
    /// executions served, for perf reporting
    pub calls: AtomicUsize,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    pub fn open(_dir: impl AsRef<Path>) -> Result<Self> {
        Err(anyhow!(
            "built without the `xla` feature — rebuild with `--features xla` \
             (needs the PJRT/xla_extension toolchain) to execute AOT artifacts"
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn max_gram_rows(&self) -> usize {
        0
    }

    pub fn gram_multi(&self, _x: &Matrix, _y: &Matrix, _gammas: &[f32]) -> Result<Vec<Matrix>> {
        Err(anyhow!("xla feature disabled"))
    }

    pub fn predict(&self, _x: &Matrix, _sv: &Matrix, _alpha: &Matrix, _gamma: f32) -> Result<Matrix> {
        Err(anyhow!("xla feature disabled"))
    }
}

/// Locate the artifacts directory relative to the workspace root
/// (works from `cargo test`, benches, and installed binaries run from
/// the repo).
pub fn default_artifact_dir() -> PathBuf {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.tsv").exists() {
            return c.clone();
        }
    }
    PathBuf::from("artifacts")
}
