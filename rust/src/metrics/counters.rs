//! Process-wide performance counters.
//!
//! The CV engine and the serving subsystem report the same underlying
//! quantities — kernel-cache effectiveness and accelerator call volume
//! — so both read from one set of global monotonic counters instead of
//! threading per-component tallies through every layer.  Counters only
//! ever increase; consumers diff two [`snapshot`]s to scope a window.

// Always-std atomics (sync.rs §static_atomic): the global counter
// statics need `const fn new`, which loom's atomics don't provide, and
// telemetry tallies are never used as synchronization edges — exactly
// the carve-out the shim documents.
use crate::sync::static_atomic::{AtomicU64, Ordering};

/// A monotonic, thread-safe event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gram requests answered by an already-resident exponentiation in a
/// [`crate::kernel::plane::GramBuffer`] (no work needed) — the λ-chain
/// reuse pattern of the CV grid.
pub static GRAM_CACHE_HITS: Counter = Counter::new();

/// Gram requests that required an exponentiation pass over distances.
pub static GRAM_CACHE_MISSES: Counter = Counter::new();

/// Gram-plane buffer (re)allocations: incremented only when a
/// [`crate::kernel::plane::GramBuffer`] / `TileBuffer` must grow its
/// storage.  In steady state this stays flat while `gram_misses`
/// advances — the observable proof that per-γ Gram matrices are
/// exponentiated into reusable buffers instead of freshly allocated
/// (the CV hot-loop contract; see DESIGN.md §Compute-plane).
pub static GRAM_ALLOCS: Counter = Counter::new();

/// Artifact executions on the PJRT runtime
/// ([`crate::runtime::XlaRuntime`]).
pub static XLA_CALLS: Counter = Counter::new();

/// Gradient/state entries written by the solver engine's sweeps — the
/// O(n·iterations) core cost of coordinate descent, and the quantity
/// shrinking reduces: a shrunk sweep writes |active| entries instead
/// of n (selection-only scans are not counted).  Compare shrink-on vs
/// shrink-off at fixed accuracy via `benches/table_solver.rs`.
pub static SOLVER_SWEEPS: Counter = Counter::new();

/// Sum of active-set sizes recorded at each shrink refresh; divided by
/// the number of refreshes it gives the mean surviving active-set
/// size (see DESIGN.md §Solver-core).
pub static SOLVER_SHRINK_ACTIVE: Counter = Counter::new();

/// Stale-gradient reconstruction passes: the mandatory full unshrink
/// verification before any termination, plus forced rebuilds on
/// `max_iter` exits while shrunk.
pub static SOLVER_UNSHRINK_PASSES: Counter = Counter::new();

/// (cell × task) working sets trained through the parallel cell
/// driver ([`crate::coordinator::driver`]).
pub static CELL_UNITS_TRAINED: Counter = Counter::new();

/// Accumulated wall-clock spent training those working sets, in
/// microseconds (per-unit times summed across driver runs).
pub static CELL_TRAIN_US: Counter = Counter::new();

/// Kernel entries produced through the streaming sources' per-pair
/// `gather` overrides (the shrunk-sweep access path; see DESIGN.md
/// §Compute-plane).  Advanced once per gather call (by `idx.len()`)
/// and only while tracing is enabled, so the cap-respecting hot path
/// pays a single branch when observability is off.  Surfaced through
/// the metrics registry rather than [`CounterSnapshot`]: it is a
/// volume diagnostic, not part of the stable CV report line.
pub static GRAM_GATHER_ENTRIES: Counter = Counter::new();

/// Cells dispatched to wire workers as binary `Job` frames by the
/// distributed coordinator (`distributed::wire`; DESIGN.md
/// §Distributed-wire).  Counts every send, so re-dispatched cells
/// advance it more than once.  Like [`GRAM_GATHER_ENTRIES`], the four
/// `DIST_*` counters surface through the metrics registry (Prometheus
/// exposition + `--trace`), not [`CounterSnapshot`]: they describe a
/// distributed run, not the per-process CV report line.
pub static DIST_CELLS_DISPATCHED: Counter = Counter::new();

/// Cells moved to the coordinator's retry queue after a worker
/// disconnect or timeout — the fault-tolerance path.  Zero on a
/// healthy run.
pub static DIST_CELLS_REDISPATCHED: Counter = Counter::new();

/// Bytes sent to workers over the train wire (frame headers included).
pub static DIST_BYTES_TX: Counter = Counter::new();

/// Bytes received from workers over the train wire (frame headers
/// included) — dominated by the solved shard payloads.
pub static DIST_BYTES_RX: Counter = Counter::new();

/// Point-in-time view of the global counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub gram_cache_hits: u64,
    pub gram_cache_misses: u64,
    pub gram_allocs: u64,
    pub xla_calls: u64,
    pub solver_sweeps: u64,
    pub solver_shrink_active: u64,
    pub solver_unshrink_passes: u64,
    pub cell_units_trained: u64,
    pub cell_train_us: u64,
}

impl CounterSnapshot {
    /// Per-field saturating difference `self − earlier`: the counter
    /// activity inside a window bounded by two snapshots.  Counters
    /// are monotonic, so with correctly ordered snapshots the
    /// saturation never fires; it exists so a misordered pair degrades
    /// to zeros instead of wrapping into astronomical deltas.
    pub fn diff(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            gram_cache_hits: self.gram_cache_hits.saturating_sub(earlier.gram_cache_hits),
            gram_cache_misses: self.gram_cache_misses.saturating_sub(earlier.gram_cache_misses),
            gram_allocs: self.gram_allocs.saturating_sub(earlier.gram_allocs),
            xla_calls: self.xla_calls.saturating_sub(earlier.xla_calls),
            solver_sweeps: self.solver_sweeps.saturating_sub(earlier.solver_sweeps),
            solver_shrink_active: self
                .solver_shrink_active
                .saturating_sub(earlier.solver_shrink_active),
            solver_unshrink_passes: self
                .solver_unshrink_passes
                .saturating_sub(earlier.solver_unshrink_passes),
            cell_units_trained: self.cell_units_trained.saturating_sub(earlier.cell_units_trained),
            cell_train_us: self.cell_train_us.saturating_sub(earlier.cell_train_us),
        }
    }

    /// `key=value` report fragment shared by `liquidsvm serve`'s
    /// `stats` command and the CV engine's display output.
    pub fn report(&self) -> String {
        format!(
            "gram_hits={} gram_misses={} gram_allocs={} xla_calls={} solver_sweeps={} \
             shrink_active={} unshrink_passes={} cell_units={} cell_train_us={}",
            self.gram_cache_hits,
            self.gram_cache_misses,
            self.gram_allocs,
            self.xla_calls,
            self.solver_sweeps,
            self.solver_shrink_active,
            self.solver_unshrink_passes,
            self.cell_units_trained,
            self.cell_train_us
        )
    }
}

pub fn snapshot() -> CounterSnapshot {
    CounterSnapshot {
        gram_cache_hits: GRAM_CACHE_HITS.get(),
        gram_cache_misses: GRAM_CACHE_MISSES.get(),
        gram_allocs: GRAM_ALLOCS.get(),
        xla_calls: XLA_CALLS.get(),
        solver_sweeps: SOLVER_SWEEPS.get(),
        solver_shrink_active: SOLVER_SHRINK_ACTIVE.get(),
        solver_unshrink_passes: SOLVER_UNSHRINK_PASSES.get(),
        cell_units_trained: CELL_UNITS_TRAINED.get(),
        cell_train_us: CELL_TRAIN_US.get(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn snapshot_reports_all_keys() {
        let r = snapshot().report();
        for key in [
            "gram_hits=", "gram_misses=", "gram_allocs=", "xla_calls=", "solver_sweeps=",
            "shrink_active=", "unshrink_passes=", "cell_units=", "cell_train_us=",
        ] {
            assert!(r.contains(key), "missing {key} in {r}");
        }
    }

    #[test]
    fn diff_scopes_nested_windows() {
        // Two windows, the inner strictly contained in the outer: the
        // outer delta must include the inner's activity plus whatever
        // happened outside it.  Counters are process-global (other
        // tests may advance them concurrently), so the assertions are
        // one-sided: deltas are at least what this test contributed.
        let outer0 = snapshot();
        XLA_CALLS.add(2);
        let inner0 = snapshot();
        XLA_CALLS.add(3);
        let inner1 = snapshot();
        XLA_CALLS.add(1);
        let outer1 = snapshot();

        let inner = inner1.diff(&inner0);
        let outer = outer1.diff(&outer0);
        assert!(inner.xla_calls >= 3, "inner window lost increments: {inner:?}");
        assert!(outer.xla_calls >= 6, "outer window lost increments: {outer:?}");
        assert!(outer.xla_calls >= inner.xla_calls, "nested window larger than enclosing");
        // untouched fields diff to zero-or-more, never wrap
        assert!(outer.cell_train_us < u64::MAX / 2);
    }

    #[test]
    fn diff_under_concurrent_increments_loses_nothing() {
        let threads = 4u64;
        let per_thread = 1000u64;
        let before = snapshot();
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    for _ in 0..per_thread {
                        SOLVER_UNSHRINK_PASSES.inc();
                    }
                });
            }
        });
        let delta = snapshot().diff(&before);
        assert!(
            delta.solver_unshrink_passes >= threads * per_thread,
            "dropped increments: {} < {}",
            delta.solver_unshrink_passes,
            threads * per_thread
        );
    }

    #[test]
    fn diff_saturates_on_misordered_snapshots() {
        let a = CounterSnapshot { xla_calls: 5, ..Default::default() };
        let b = CounterSnapshot { xla_calls: 9, ..Default::default() };
        assert_eq!(a.diff(&b).xla_calls, 0);
        assert_eq!(b.diff(&a).xla_calls, 4);
    }
}
