//! Process-wide performance counters.
//!
//! The CV engine and the serving subsystem report the same underlying
//! quantities — kernel-cache effectiveness and accelerator call volume
//! — so both read from one set of global monotonic counters instead of
//! threading per-component tallies through every layer.  Counters only
//! ever increase; consumers diff two [`snapshot`]s to scope a window.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonic, thread-safe event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gram requests served from [`crate::kernel::DistanceCache`]'s held
/// kernel matrix (no exponentiation pass needed).
pub static GRAM_CACHE_HITS: Counter = Counter::new();

/// Gram requests that required an exponentiation pass over distances.
pub static GRAM_CACHE_MISSES: Counter = Counter::new();

/// Artifact executions on the PJRT runtime
/// ([`crate::runtime::XlaRuntime`]).
pub static XLA_CALLS: Counter = Counter::new();

/// Point-in-time view of the global counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub gram_cache_hits: u64,
    pub gram_cache_misses: u64,
    pub xla_calls: u64,
}

impl CounterSnapshot {
    /// `key=value` report fragment shared by `liquidsvm serve`'s
    /// `stats` command and the CV engine's display output.
    pub fn report(&self) -> String {
        format!(
            "gram_hits={} gram_misses={} xla_calls={}",
            self.gram_cache_hits, self.gram_cache_misses, self.xla_calls
        )
    }
}

pub fn snapshot() -> CounterSnapshot {
    CounterSnapshot {
        gram_cache_hits: GRAM_CACHE_HITS.get(),
        gram_cache_misses: GRAM_CACHE_MISSES.get(),
        xla_calls: XLA_CALLS.get(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn snapshot_reports_all_keys() {
        let r = snapshot().report();
        for key in ["gram_hits=", "gram_misses=", "xla_calls="] {
            assert!(r.contains(key), "missing {key} in {r}");
        }
    }
}
