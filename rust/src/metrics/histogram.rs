//! Lock-free latency histogram for the serving hot path.
//!
//! Power-of-two microsecond buckets: recording is one atomic add (safe
//! to call from every worker/connection thread), percentiles are read
//! by walking the cumulative counts.  Bucket `i` covers
//! `[2^i, 2^(i+1))` µs and a percentile reports the bucket's upper
//! bound, so quantiles are conservative (never under-reported) with at
//! most 2× resolution error — plenty for p50/p95/p99 serving stats.
//! The exact observed maximum is tracked separately (`max_us`), so the
//! true tail sits next to the ≤2×-resolution p99 in every report, and
//! `sum_us` accumulates with saturating adds so a long-lived process
//! can never wrap the mean into nonsense silently.

// Always-std atomics (sync.rs §static_atomic): pure telemetry (no
// synchronization edges), and `record` leans on fetch_max/fetch_update,
// which the loom twin does not model.
use crate::sync::static_atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40; // 2^40 µs ≈ 12.7 days; saturates above

/// Concurrent log₂-bucketed histogram of durations.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

/// Saturating add on an atomic (Relaxed): once the accumulator hits
/// `u64::MAX` it stays there instead of wrapping.
fn saturating_fetch_add(a: &AtomicU64, n: u64) {
    if n == 0 {
        return;
    }
    let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_add(n)));
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_index(us: u64) -> usize {
        // floor(log2(us)) via leading_zeros; us=0 maps to bucket 0
        let v = us.max(1);
        ((63 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Record one observation.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        saturating_fetch_add(&self.sum_us, us);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded durations in µs (saturating at `u64::MAX`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Largest single observation in µs (exact, not bucket-rounded).
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 if empty).
    pub fn mean_us(&self) -> u64 {
        let n = self.count();
        if n == 0 { 0 } else { self.sum_us.load(Ordering::Relaxed) / n }
    }

    /// Per-bucket `(upper_bound_us, count)` pairs, low to high — the
    /// raw series Prometheus histogram exposition is built from.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| ((1u64 << (i + 1)).saturating_sub(1), b.load(Ordering::Relaxed)))
            .collect()
    }

    /// Upper bound (µs) of the bucket containing quantile `q ∈ (0, 1]`.
    pub fn percentile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)).saturating_sub(1);
            }
        }
        (1u64 << BUCKETS).saturating_sub(1)
    }

    /// Fold another histogram's counts into this one (client threads
    /// aggregate per-thread histograms this way).
    pub fn merge(&self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        saturating_fetch_add(&self.sum_us, other.sum_us.load(Ordering::Relaxed));
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// `p50_us=… p95_us=… p99_us=… max_us=…` report fragment.
    pub fn report(&self) -> String {
        format!(
            "p50_us={} p95_us={} p99_us={} max_us={}",
            self.percentile_us(0.50),
            self.percentile_us(0.95),
            self.percentile_us(0.99),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(v: u64) -> Duration {
        Duration::from_micros(v)
    }

    #[test]
    fn counts_and_mean() {
        let h = LatencyHistogram::new();
        h.record(us(10));
        h.record(us(30));
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean_us(), 20);
    }

    #[test]
    fn percentiles_are_bucket_upper_bounds() {
        let h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(us(100)); // bucket [64, 128)
        }
        h.record(us(10_000)); // bucket [8192, 16384)
        assert_eq!(h.percentile_us(0.50), 127);
        assert_eq!(h.percentile_us(0.95), 127);
        assert_eq!(h.percentile_us(1.0), 16_383);
    }

    #[test]
    fn percentiles_monotone() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(us(i));
        }
        let (p50, p95, p99) = (h.percentile_us(0.5), h.percentile_us(0.95), h.percentile_us(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 >= 500 && p99 >= 990, "{p50} {p99}");
    }

    #[test]
    fn merge_adds_counts() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(us(5));
        b.record(us(7));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 7);
    }

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0);
        assert!(h.report().contains("p99_us=0"));
        assert!(h.report().contains("max_us=0"));
    }

    #[test]
    fn zero_duration_lands_in_first_bucket() {
        let h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile_us(1.0), 1);
    }

    #[test]
    fn max_tracks_exact_tail() {
        let h = LatencyHistogram::new();
        h.record(us(100));
        h.record(us(9_321));
        h.record(us(50));
        // p100 is the bucket upper bound (2x-resolution)...
        assert_eq!(h.percentile_us(1.0), 16_383);
        // ...but max is the exact observation
        assert_eq!(h.max_us(), 9_321);
    }

    #[test]
    fn sum_saturates_instead_of_wrapping() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(u64::MAX));
        h.record(Duration::from_micros(u64::MAX));
        assert_eq!(h.sum_us(), u64::MAX, "sum wrapped");
        assert_eq!(h.count(), 2);
        // mean stays a sane (saturated) figure rather than ~0
        assert_eq!(h.mean_us(), u64::MAX / 2);

        // merge saturates the same way
        let other = LatencyHistogram::new();
        other.record(Duration::from_micros(u64::MAX));
        h.merge(&other);
        assert_eq!(h.sum_us(), u64::MAX);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn buckets_expose_upper_bounds_and_counts() {
        let h = LatencyHistogram::new();
        h.record(us(100)); // [64, 128) -> upper bound 127
        let b = h.buckets();
        assert_eq!(b.len(), 40);
        assert_eq!(b[0].0, 1);
        let total: u64 = b.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 1);
        assert_eq!(b[6], (127, 1));
    }
}
