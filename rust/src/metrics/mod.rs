//! Losses, error metrics, and timing instrumentation.
//!
//! The validation loss used during the selection phase is configurable
//! (paper §2: "the user can ... determine ... the loss function used on
//! the validation fold"); these are the choices liquidSVM ships.

pub mod counters;
pub mod histogram;

pub use counters::{snapshot, Counter, CounterSnapshot};
pub use histogram::LatencyHistogram;

use std::time::{Duration, Instant};

/// Validation / test losses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Loss {
    /// 0-1 classification error on sign(f)
    Classification,
    /// weighted 0-1: false positives cost `w`, false negatives `1-w`
    WeightedClassification { w: f32 },
    /// mean squared error
    LeastSquares,
    /// pinball loss at quantile `tau`
    Pinball { tau: f32 },
    /// asymmetric least squares at expectile `tau`
    Expectile { tau: f32 },
    /// hinge loss (margin-based validation for classification)
    Hinge,
}

impl Loss {
    /// Pointwise loss of prediction `t` against truth `y`.
    #[inline]
    pub fn eval(&self, y: f32, t: f32) -> f32 {
        match *self {
            Loss::Classification => {
                if (t >= 0.0) == (y >= 0.0) { 0.0 } else { 1.0 }
            }
            Loss::WeightedClassification { w } => {
                if (t >= 0.0) == (y >= 0.0) {
                    0.0
                } else if y < 0.0 {
                    // false positive
                    w
                } else {
                    1.0 - w
                }
            }
            Loss::LeastSquares => (y - t) * (y - t),
            Loss::Pinball { tau } => {
                let r = y - t;
                if r >= 0.0 { tau * r } else { (tau - 1.0) * r }
            }
            Loss::Expectile { tau } => {
                let r = y - t;
                if r >= 0.0 { tau * r * r } else { (1.0 - tau) * r * r }
            }
            Loss::Hinge => (1.0 - y * t).max(0.0),
        }
    }

    /// Mean loss over slices.
    pub fn mean(&self, y: &[f32], t: &[f32]) -> f32 {
        assert_eq!(y.len(), t.len());
        if y.is_empty() {
            return 0.0;
        }
        let s: f32 = y.iter().zip(t).map(|(&a, &b)| self.eval(a, b)).sum();
        s / y.len() as f32
    }
}

/// Binary confusion counts from decision values.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub tn: usize,
    pub fp: usize,
    pub fn_: usize,
}

impl Confusion {
    pub fn from_scores(y: &[f32], t: &[f32]) -> Confusion {
        let mut c = Confusion::default();
        for (&yi, &ti) in y.iter().zip(t) {
            match (yi >= 0.0, ti >= 0.0) {
                (true, true) => c.tp += 1,
                (false, false) => c.tn += 1,
                (false, true) => c.fp += 1,
                (true, false) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn error(&self) -> f32 {
        let n = self.tp + self.tn + self.fp + self.fn_;
        if n == 0 { 0.0 } else { (self.fp + self.fn_) as f32 / n as f32 }
    }

    /// False-alarm rate (fraction of true negatives classified +).
    pub fn false_alarm_rate(&self) -> f32 {
        let n = self.fp + self.tn;
        if n == 0 { 0.0 } else { self.fp as f32 / n as f32 }
    }

    /// Detection rate on the positive class.
    pub fn detection_rate(&self) -> f32 {
        let n = self.tp + self.fn_;
        if n == 0 { 0.0 } else { self.tp as f32 / n as f32 }
    }
}

/// Multiclass 0-1 error from integer-ish float labels.
pub fn multiclass_error(y: &[f32], pred: &[f32]) -> f32 {
    assert_eq!(y.len(), pred.len());
    if y.is_empty() {
        return 0.0;
    }
    let wrong = y.iter().zip(pred).filter(|(a, b)| a != b).count();
    wrong as f32 / y.len() as f32
}

/// Lightweight accumulating timer registry used by the coordinator to
/// report per-phase wall-clock (train/select/test) like the CLI does.
#[derive(Debug, Default)]
pub struct Timers {
    entries: std::collections::BTreeMap<&'static str, Duration>,
}

impl Timers {
    pub fn time<T>(&mut self, key: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.entries.entry(key).or_default() += t0.elapsed();
        out
    }

    pub fn add(&mut self, key: &'static str, d: Duration) {
        *self.entries.entry(key).or_default() += d;
    }

    pub fn get(&self, key: &str) -> Duration {
        self.entries.get(key).copied().unwrap_or_default()
    }

    pub fn report(&self) -> String {
        self.entries
            .iter()
            .map(|(k, v)| format!("{k}: {:.3}s", v.as_secs_f64()))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_loss() {
        let l = Loss::Classification;
        assert_eq!(l.eval(1.0, 0.3), 0.0);
        assert_eq!(l.eval(-1.0, 0.3), 1.0);
        assert_eq!(l.mean(&[1.0, -1.0], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn weighted_classification_asymmetry() {
        let l = Loss::WeightedClassification { w: 0.8 };
        assert_eq!(l.eval(-1.0, 1.0), 0.8); // FP
        assert!((l.eval(1.0, -1.0) - 0.2).abs() < 1e-6); // FN
    }

    #[test]
    fn pinball_tilts() {
        let l = Loss::Pinball { tau: 0.9 };
        assert!((l.eval(1.0, 0.0) - 0.9).abs() < 1e-6); // under-predict
        assert!((l.eval(0.0, 1.0) - 0.1).abs() < 1e-6); // over-predict
    }

    #[test]
    fn expectile_asymmetric_square() {
        let l = Loss::Expectile { tau: 0.25 };
        assert!((l.eval(2.0, 0.0) - 1.0).abs() < 1e-6); // 0.25*4
        assert!((l.eval(0.0, 2.0) - 3.0).abs() < 1e-6); // 0.75*4
    }

    #[test]
    fn confusion_rates() {
        let c = Confusion::from_scores(&[1.0, 1.0, -1.0, -1.0], &[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(c, Confusion { tp: 1, tn: 1, fp: 1, fn_: 1 });
        assert_eq!(c.error(), 0.5);
        assert_eq!(c.false_alarm_rate(), 0.5);
        assert_eq!(c.detection_rate(), 0.5);
    }

    #[test]
    fn timers_accumulate() {
        let mut t = Timers::default();
        t.time("x", || std::thread::sleep(Duration::from_millis(2)));
        t.time("x", || ());
        assert!(t.get("x") >= Duration::from_millis(2));
        assert!(t.report().contains("x:"));
    }
}
