//! `liquidsvm` CLI — the command-line interface of the reproduction
//! (liquidSVM ships `svm-train`-style tools plus scenario scripts like
//! `mc-svm.sh`; this binary folds them into subcommands).
//!
//! ```text
//! liquidsvm train --data banana-mc --n 2000 --scenario mc --threads 2 --display 1
//! liquidsvm bench --table 1
//! liquidsvm list-datasets
//! ```
//!
//! Hand-rolled argument parsing: this image's offline crate registry
//! has no clap.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use liquid_svm::coordinator::config::BackendChoice;
use liquid_svm::coordinator::scenarios;
use liquid_svm::data::{synth, Dataset};
use liquid_svm::distributed::{train_distributed, ClusterSpec};
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--key value` / `--key=value` / `--flag` argument bag.  A key may
/// appear only once, whichever spelling is used — `--n 5 --n=6` is a
/// duplicate just like `--n 5 --n 6`.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        Args::parse_from(std::env::args().skip(1))
    }

    fn parse_from(tokens: impl IntoIterator<Item = String>) -> Result<Args> {
        fn insert_unique(kv: &mut HashMap<String, String>, k: String, v: String) -> Result<()> {
            if kv.insert(k.clone(), v).is_some() {
                bail!("duplicate option `--{k}`");
            }
            Ok(())
        }
        let mut it = tokens.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        let mut key: Option<String> = None;
        for tok in it {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(k) = key.take() {
                    insert_unique(&mut kv, k, "true".into())?; // bare flag
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    if k.is_empty() {
                        bail!("empty option name in `{tok}`");
                    }
                    insert_unique(&mut kv, k.to_string(), v.to_string())?;
                } else {
                    key = Some(stripped.to_string());
                }
            } else if let Some(k) = key.take() {
                insert_unique(&mut kv, k, tok)?;
            } else {
                bail!("unexpected positional argument `{tok}`");
            }
        }
        if let Some(k) = key.take() {
            insert_unique(&mut kv, k, "true".into())?;
        }
        Ok(Args { cmd, kv })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: cannot parse `{v}`")),
        }
    }
}

/// `--trace` / `--trace-json PATH` turn on the observability plane's
/// phase tracing (see DESIGN.md §Observability).  Returns (enabled,
/// json path); call [`trace_report`] with them after the command ran.
fn trace_setup(args: &Args) -> (bool, Option<String>) {
    let json = args.get("trace-json").map(str::to_string);
    let on = args.get("trace").is_some() || json.is_some();
    if on {
        liquid_svm::obs::set_enabled(true);
    }
    (on, json)
}

/// End-of-run side of `--trace`: phase table to stderr (keeps stdout
/// machine-parsable) plus the optional JSON dump.
fn trace_report(on: bool, json: Option<&str>) -> Result<()> {
    if !on {
        return Ok(());
    }
    eprint!("{}", liquid_svm::obs::render_table());
    if let Some(path) = json {
        std::fs::write(path, liquid_svm::obs::render_json())
            .with_context(|| format!("writing --trace-json to {path}"))?;
        eprintln!("trace written to {path}");
    }
    Ok(())
}

fn load_dataset(args: &Args) -> Result<(Dataset, Dataset)> {
    let n: usize = args.num("n", 2000)?;
    let n_test: usize = args.num("n-test", n / 2)?;
    let seed: u64 = args.num("seed", 42)?;
    if let Some(path) = args.get("file") {
        let d = if path.ends_with(".csv") {
            liquid_svm::data::io::read_csv(std::path::Path::new(path), 0)?
        } else {
            liquid_svm::data::io::read_libsvm(std::path::Path::new(path), 0)?
        };
        let tt = d.split(d.len() * 4 / 5, seed);
        return Ok((tt.train, tt.test));
    }
    let name = args.get("data").unwrap_or("banana-mc");
    if name == "banana-mc" {
        let tt = synth::banana_mc(n, n_test, seed);
        return Ok((tt.train, tt.test));
    }
    if name == "banana" {
        return Ok((synth::banana_binary(n, seed), synth::banana_binary(n_test, seed ^ 1)));
    }
    if name == "sinc" {
        return Ok((synth::sinc_hetero(n, seed), synth::sinc_hetero(n_test, seed ^ 1)));
    }
    let train = synth::by_name(name, n, seed)
        .ok_or_else(|| anyhow!("unknown dataset `{name}` (try list-datasets)"))?;
    let test = synth::by_name(name, n_test, seed ^ 0xdead).unwrap();
    Ok((train, test))
}

fn build_config(args: &Args) -> Result<Config> {
    let mut cfg = Config::default()
        .display(args.num("display", 0u8)?)
        .threads(args.num("threads", 1usize)?)
        .grid_choice(args.num("grid-choice", 0u8)?)
        .adaptivity(args.num("adaptivity", 0u8)?)
        .folds(args.num("folds", 5usize)?)
        .seed(args.num("seed", 42u64)?);
    cfg.use_libsvm_grid = args.get("libsvm-grid").is_some();
    if let Some(j) = args.get("jobs") {
        cfg = cfg.jobs(j.parse().map_err(|_| anyhow!("--jobs: cannot parse `{j}`"))?);
    }
    // Gram-state budget for the CV engine (0 = unlimited)
    if let Some(mb) = args.get("max-gram-mb") {
        let mb: usize = mb.parse().map_err(|_| anyhow!("--max-gram-mb: cannot parse `{mb}`"))?;
        cfg = cfg.max_gram_mb(mb);
    }
    // solver tolerances: CLI → Config → every CV/driver call site (no
    // more hard-coded SolverParams::default() anywhere on the path)
    let eps: f32 = args.num("solver-eps", cfg.solver_params.eps)?;
    if !eps.is_finite() || eps <= 0.0 {
        bail!("--solver-eps must be positive, got `{eps}`");
    }
    let max_iter: usize = args.num("max-iter", cfg.solver_params.max_iter)?;
    if max_iter == 0 {
        bail!("--max-iter must be at least 1 (0 does not mean unlimited; the default is 200000)");
    }
    cfg = cfg
        .solver_eps(eps)
        .max_iter(max_iter)
        .shrink_every(args.num("shrink-every", cfg.solver_params.shrink_every)?);
    // --cells is the readable alias of the paper's --voronoi syntax
    match (args.get("voronoi"), args.get("cells")) {
        (Some(_), Some(_)) => bail!("--voronoi and --cells are aliases; give only one"),
        (Some(v), None) | (None, Some(v)) => {
            cfg.cells = Config::parse_voronoi(v)
                .ok_or_else(|| anyhow!("--voronoi/--cells: bad spec `{v}`"))?;
        }
        (None, None) => {}
    }
    cfg.backend = match args.get("backend").unwrap_or("blocked") {
        "scalar" => BackendChoice::Scalar,
        "blocked" => BackendChoice::Blocked,
        "simd" => BackendChoice::Simd,
        "avx2" => BackendChoice::SimdAvx2,
        "avx512" => BackendChoice::SimdAvx512,
        "simd-f32" => BackendChoice::SimdF32,
        "xla" => BackendChoice::Xla,
        other => bail!(
            "--backend: unknown `{other}` (scalar|blocked|simd|avx2|avx512|simd-f32|xla)"
        ),
    };
    // sparse data plane: explicit --sparse, or auto-detected from a
    // `.csr` file extension (LIBSVM text read straight into CSR)
    cfg.sparse = args.get("sparse").is_some()
        || args.get("file").is_some_and(|f| f.ends_with(".csr"));
    Ok(cfg)
}

/// Load a CSR train/test pair for the sparse pipeline: a LIBSVM file
/// (streamed, bounded memory) or the synthetic sparse generator.
/// `dim_hint > 0` pins the dimension (predict-time: the loaded model's
/// `input_dim`, so an over-wide test file fails with the parser's
/// line-numbered error instead of a shape panic in the kernel layer).
fn load_sparse_dataset(
    args: &Args,
    dim_hint: usize,
) -> Result<(liquid_svm::data::SparseDataset, liquid_svm::data::SparseDataset)> {
    let seed: u64 = args.num("seed", 42)?;
    if let Some(path) = args.get("file") {
        let dim = if dim_hint > 0 { dim_hint } else { args.num("dim", 0usize)? };
        let d = liquid_svm::data::io::read_libsvm_csr(std::path::Path::new(path), dim)?;
        let n_train = d.len() * 4 / 5;
        return Ok(d.split(n_train, seed));
    }
    // synthetic sparse set: --n/--dim/--density knobs
    let n: usize = args.num("n", 2000)?;
    let n_test: usize = args.num("n-test", n / 2)?;
    let dim = if dim_hint > 0 { dim_hint } else { args.num("dim", 10_000)? };
    let density: f32 = args.num("density", 0.005f32)?;
    Ok((
        synth::sparse_binary(n, dim, density, seed),
        synth::sparse_binary(n_test, dim, density, seed ^ 0xdead),
    ))
}

/// Sparse training: single-cell (or chunked) pipeline over CSR data.
fn cmd_train_sparse(args: &Args, cfg: &Config) -> Result<()> {
    let (train_d, test_d) = load_sparse_dataset(args, 0)?;
    let scenario = args.get("scenario").unwrap_or("binary");
    let spec = match scenario {
        "binary" => TaskSpec::Binary { w: args.num("weight", 0.5f32)? },
        "mc" => TaskSpec::MultiClassOvA,
        "mc-ava" => TaskSpec::MultiClassAvA,
        "ls" => TaskSpec::LeastSquares,
        "qt" => TaskSpec::MultiQuantile { taus: vec![0.05, 0.5, 0.95] },
        "ex" => TaskSpec::MultiExpectile { taus: vec![0.05, 0.5, 0.95] },
        other => bail!("scenario `{other}` not supported with --sparse"),
    };
    let t0 = std::time::Instant::now();
    let model = liquid_svm::coordinator::train_sparse(&train_d, &spec, cfg)?;
    let train_time = t0.elapsed();
    let res = model.test_sparse(&test_d);
    println!(
        "scenario={scenario} sparse=1 n={} d={} nnz={} tasks={} train={:.2}s test={:.2}s error={:.4}",
        train_d.len(),
        train_d.dim(),
        train_d.x.nnz(),
        model.n_tasks,
        train_time.as_secs_f64(),
        res.test_time.as_secs_f64(),
        res.error
    );
    if let Some(path) = args.get("save") {
        if path.ends_with(".sol.d") {
            liquid_svm::coordinator::persist::save_bundle(&model, std::path::Path::new(path))?;
            println!("saved sharded bundle to {path} ({} shards)", model.partition.n_cells());
        } else {
            liquid_svm::coordinator::persist::save_model(&model, std::path::Path::new(path))?;
            println!("saved model to {path}");
        }
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "convert" => cmd_convert(&args),
        "distributed" => cmd_distributed(&args),
        "worker" => cmd_worker(&args),
        "list-datasets" => {
            println!("banana-mc banana sinc {}", synth::names().join(" "));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command `{other}` (see `liquidsvm help`)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let (trace, trace_json) = trace_setup(args);
    let cfg = build_config(args)?;
    let out = if cfg.sparse {
        cmd_train_sparse(args, &cfg)
    } else {
        cmd_train_dense(args, &cfg)
    };
    trace_report(trace, trace_json.as_deref())?;
    out
}

fn cmd_train_dense(args: &Args, cfg: &Config) -> Result<()> {
    let (train_d, test_d) = load_dataset(args)?;
    let scenario = args.get("scenario").unwrap_or("mc");
    let t0 = std::time::Instant::now();
    let model = match scenario {
        "binary" => scenarios::svm_binary(&train_d, args.num("weight", 0.5f32)?, cfg)?,
        "mc" => scenarios::mc_svm(&train_d, cfg)?,
        "mc-ava" => scenarios::mc_svm_type(&train_d, false, cfg)?,
        "ls" => scenarios::ls_svm(&train_d, cfg)?,
        "qt" => scenarios::qt_svm(&train_d, &[0.05, 0.5, 0.95], cfg)?,
        "ex" => scenarios::ex_svm(&train_d, &[0.05, 0.5, 0.95], cfg)?,
        "npl" => scenarios::npl_svm(&train_d, args.num("alpha", 0.05f32)?, cfg)?,
        "roc" => scenarios::roc_svm(&train_d, args.num("points", 6usize)?, cfg)?,
        other => bail!("unknown scenario `{other}`"),
    };
    let train_time = t0.elapsed();
    let res = model.test(&test_d);
    println!(
        "scenario={scenario} n={} d={} cells={} tasks={} train={:.2}s test={:.2}s error={:.4}",
        train_d.len(),
        train_d.dim(),
        model.partition.n_cells(),
        model.n_tasks,
        train_time.as_secs_f64(),
        res.test_time.as_secs_f64(),
        res.error
    );
    if let Some(path) = args.get("save") {
        // a `.sol.d` path selects the sharded bundle layout (one shard
        // per cell, lazily loadable by `liquidsvm serve`)
        if path.ends_with(".sol.d") {
            liquid_svm::coordinator::persist::save_bundle(&model, std::path::Path::new(path))?;
            println!("saved sharded bundle to {path} ({} shards)", model.partition.n_cells());
        } else {
            liquid_svm::coordinator::persist::save_model(&model, std::path::Path::new(path))?;
            println!("saved model to {path}");
        }
    }
    Ok(())
}

/// Test phase in a separate process: load a `.sol` file and predict —
/// mirrors liquidSVM's svm-test tool.
fn cmd_predict(args: &Args) -> Result<()> {
    let (trace, trace_json) = trace_setup(args);
    let out = cmd_predict_inner(args);
    trace_report(trace, trace_json.as_deref())?;
    out
}

fn cmd_predict_inner(args: &Args) -> Result<()> {
    let model_path = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let cfg = build_config(args)?;
    let model =
        liquid_svm::coordinator::persist::load_model(std::path::Path::new(model_path), cfg)?;
    if cfg.sparse {
        let (_, test_d) = load_sparse_dataset(args, model.input_dim())?;
        let res = model.test_sparse(&test_d);
        println!(
            "model={model_path} sparse=1 n_test={} tasks={} test={:.2}s error={:.4}",
            test_d.len(),
            model.n_tasks,
            res.test_time.as_secs_f64(),
            res.error
        );
        if let Some(out) = args.get("out") {
            let mut text = String::new();
            for p in &res.predictions {
                text.push_str(&format!("{p}\n"));
            }
            std::fs::write(out, text)?;
            println!("wrote predictions to {out}");
        }
        return Ok(());
    }
    let (_, test_d) = load_dataset(args)?;
    let res = model.test(&test_d);
    println!(
        "model={model_path} n_test={} tasks={} test={:.2}s error={:.4}",
        test_d.len(),
        model.n_tasks,
        res.test_time.as_secs_f64(),
        res.error
    );
    if let Some(out) = args.get("out") {
        let mut text = String::new();
        for p in &res.predictions {
            text.push_str(&format!("{p}\n"));
        }
        std::fs::write(out, text)?;
        println!("wrote predictions to {out}");
    }
    Ok(())
}

/// Batched multi-model inference server over persisted `.sol` models.
fn cmd_serve(args: &Args) -> Result<()> {
    use liquid_svm::serve::{ServeConfig, Server};
    let scfg = ServeConfig {
        host: args.get("host").unwrap_or("127.0.0.1").to_string(),
        port: args.num("port", 4950u16)?,
        max_batch: args.num("max-batch", 64usize)?,
        max_delay: std::time::Duration::from_millis(args.num("max-delay-ms", 2u64)?),
        queue_cap: args.num("queue-cap", 128usize)?,
        workers: args.num("workers", 2usize)?,
        max_models: args.num("max-models", 8usize)?,
        max_shard_bytes: args.num("max-shard-mb", 256u64)? << 20,
        slow_log_us: args.num("slow-log-us", 0u64)?,
        io_threads: args.num("io-threads", 0usize)?,
        max_conns: args.num("max-conns", 0usize)?,
        rate_limit: args.num("rate-limit", 0u64)?,
        model_config: build_config(args)?,
    };
    let server = Server::start(scfg)?;
    println!("serving on {}", server.addr());
    if let Some(spec) = args.get("models") {
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (name, path) = part.split_once('=').ok_or_else(|| {
                anyhow!("--models: expected `name=path.sol` or `name=path.sol.d`, got `{part}`")
            })?;
            let m = server.registry.load(name, std::path::Path::new(path))?;
            match &m.bundle {
                Some(b) => println!(
                    "loaded {name} from {path} (dim={} shards={}, lazy)",
                    m.dim,
                    b.manifest().n_cells()
                ),
                None => println!(
                    "loaded {name} from {path} (dim={} units={})",
                    m.dim,
                    m.model.units.len()
                ),
            }
        }
    }
    println!("protocol: predict/load/unload/stats/shards/metrics/ping/quit — see README");
    loop {
        std::thread::park(); // run until killed; requests drive the threads
    }
}

/// Load generator against a running server (the demo/bench client).
/// `--binary` negotiates the length-prefixed f32 framing; `--swarm`
/// multiplexes all connections over a few event-loop threads instead
/// of one thread each (the c10k mode).
fn cmd_client(args: &Args) -> Result<()> {
    use liquid_svm::serve::{protocol::WireMode, run_load_mode, run_swarm, LoadSpec};
    let addr = args.get("addr").ok_or_else(|| anyhow!("--addr host:port required"))?;
    let connections: usize = args.num("connections", 16)?;
    let total: usize = args.num("n", 1000)?;
    let spec = LoadSpec {
        addr: addr.to_string(),
        model: args.get("model").unwrap_or("default").to_string(),
        connections,
        requests: (total + connections.max(1) - 1) / connections.max(1),
        pipeline: args.num("pipeline", 32usize)?,
    };
    let mode =
        if args.get("binary").is_some() { WireMode::Binary } else { WireMode::Text };
    let (_, test_d) = load_dataset(args)?;
    let rows: Vec<Vec<f32>> = (0..test_d.len()).map(|i| test_d.x.row(i).to_vec()).collect();
    let report = if args.get("swarm").is_some() {
        run_swarm(&spec, &rows, None, mode)?
    } else {
        run_load_mode(&spec, &rows, None, mode)?
    };
    println!(
        "connections={} requests_per_conn={} pipeline={} mode={}",
        spec.connections,
        spec.requests,
        spec.pipeline,
        match mode {
            WireMode::Binary => "binary",
            WireMode::Text => "text",
        }
    );
    println!("{}", report.report());
    Ok(())
}

/// Format conversion tool (liquidSVM ships CLI data tools, paper §3c).
fn cmd_convert(args: &Args) -> Result<()> {
    let input = args.get("in").ok_or_else(|| anyhow!("--in required"))?;
    let output = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let d = if input.ends_with(".csv") {
        liquid_svm::data::io::read_csv(std::path::Path::new(input), 0)?
    } else {
        liquid_svm::data::io::read_libsvm(std::path::Path::new(input), 0)?
    };
    if output.ends_with(".csv") {
        liquid_svm::data::io::write_csv(std::path::Path::new(output), &d)?;
    } else {
        liquid_svm::data::io::write_libsvm(std::path::Path::new(output), &d)?;
    }
    println!("converted {} samples x {} dims: {input} -> {output}", d.len(), d.dim());
    Ok(())
}

/// Wire-protocol training worker: bind a TCP port, print the bound
/// address (scripts and the dist-smoke CI job parse the first stdout
/// line), then serve coordinator connections until killed.
fn cmd_worker(args: &Args) -> Result<()> {
    use liquid_svm::distributed::{wire, WorkerOptions};
    let host = args.get("host").unwrap_or("127.0.0.1");
    let port: u16 = args.num("port", 0u16)?;
    let listener = std::net::TcpListener::bind((host, port))
        .with_context(|| format!("worker: cannot bind {host}:{port}"))?;
    let opts = WorkerOptions {
        jobs: match args.get("jobs") {
            Some(j) => Some(j.parse().map_err(|_| anyhow!("--jobs: cannot parse `{j}`"))?),
            None => None,
        },
        fail_after: match args.get("fail-after") {
            Some(f) => {
                Some(f.parse().map_err(|_| anyhow!("--fail-after: cannot parse `{f}`"))?)
            }
            None => None,
        },
        display: args.num("display", 0u8)?,
    };
    // the parseable contract: first line is `worker listening on ADDR`
    println!("worker listening on {}", listener.local_addr()?);
    use std::io::Write as _;
    std::io::stdout().flush()?;
    wire::worker_listen(listener, &opts, None)
}

/// Distributed training over real sockets: shard cells to the worker
/// processes named in `--workers host:port,...`, assemble the returned
/// shards into a `.sol.d` bundle byte-identical to a single-process
/// `train --save`, and report the socket-measured wall next to the
/// simulation's modelled numbers.
fn cmd_distributed_wire(args: &Args, spec: &str) -> Result<()> {
    use liquid_svm::distributed::{train_distributed_wire, WireOptions};
    let (trace, trace_json) = trace_setup(args);
    let (train_d, test_d) = load_dataset(args)?;
    let cfg = build_config(args)?;
    let workers: Vec<String> =
        spec.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
    let out = args
        .get("save")
        .ok_or_else(|| anyhow!("--save PATH.sol.d required with --workers host:port,..."))?;
    if !out.ends_with(".sol.d") {
        bail!("--save must name a `.sol.d` bundle in wire mode, got `{out}`");
    }
    let opts = WireOptions {
        connect_timeout: std::time::Duration::from_millis(args.num("connect-timeout-ms", 5000u64)?),
        io_timeout: match args.num("io-timeout-ms", 600_000u64)? {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
    };
    let out_path = std::path::Path::new(out);
    let report = train_distributed_wire(
        &train_d,
        &TaskSpec::Binary { w: args.num("weight", 0.5f32)? },
        &cfg,
        &workers,
        out_path,
        &opts,
    )
    .context("distributed wire training")?;
    println!(
        "workers={} live={} cells={} measured_wall={:.2}s modelled_distributed={:.2}s \
         modelled_single_node={:.2}s modelled_speedup={:.1}x dispatched={} redispatched={} \
         tx_bytes={} rx_bytes={}",
        report.workers,
        report.live_workers,
        report.n_cells,
        report.measured_wall.as_secs_f64(),
        report.modelled_distributed.as_secs_f64(),
        report.modelled_single_node.as_secs_f64(),
        report.modelled_speedup(),
        report.dispatched,
        report.redispatched,
        report.bytes_tx,
        report.bytes_rx,
    );
    // prove the bundle is loadable and report generalisation like the
    // other train paths do
    let model = liquid_svm::coordinator::persist::load_model(out_path, cfg)?;
    let res = model.test(&test_d);
    println!(
        "saved sharded bundle to {out} ({} shards) test={:.2}s error={:.4}",
        report.n_cells,
        res.test_time.as_secs_f64(),
        res.error
    );
    trace_report(trace, trace_json.as_deref())?;
    Ok(())
}

fn cmd_distributed(args: &Args) -> Result<()> {
    // `--workers host:port,...` selects the real multi-process wire
    // path; a bare worker *count* keeps the original single-process
    // simulation (the Table-4 accounting reference) unchanged.
    if let Some(spec) = args.get("workers") {
        if spec.contains(':') {
            return cmd_distributed_wire(args, spec);
        }
    }
    let (trace, trace_json) = trace_setup(args);
    let (train_d, test_d) = load_dataset(args)?;
    let cfg = build_config(args)?;
    let cluster = ClusterSpec {
        workers: args.num("workers", 4usize)?,
        coarse_size: args.num("coarse-size", 2000usize)?,
        fine_size: args.num("fine-size", 500usize)?,
        driver_sample: args.num("driver-sample", 4000usize)?,
    };
    let m = train_distributed(&train_d, &TaskSpec::Binary { w: 0.5 }, &cfg, &cluster)
        .context("distributed training")?;
    let err = m.test_error(&test_d);
    println!(
        "workers={} coarse_cells={} distributed={:.2}s single_node={:.2}s speedup={:.1}x error={:.4}",
        cluster.workers,
        m.stats.n_coarse_cells,
        m.stats.distributed_time.as_secs_f64(),
        m.stats.single_node_time.as_secs_f64(),
        m.stats.speedup(),
        err
    );
    trace_report(trace, trace_json.as_deref())?;
    Ok(())
}

fn print_help() {
    println!(
        "liquidsvm — liquidSVM reproduction (Rust + JAX/Pallas)

USAGE:
  liquidsvm train [--data NAME|--file PATH] [--scenario binary|mc|mc-ava|ls|qt|ex|npl|roc]
                  [--n N] [--threads T] [--jobs J] [--max-gram-mb MB] [--display D]
                  [--grid-choice 0|1|2] [--adaptivity 0|1|2] [--cells SPEC|--voronoi SPEC]
                  [--libsvm-grid] [--backend scalar|blocked|simd|avx2|avx512|simd-f32|xla]
                  [--folds K] [--seed S]
                  [--solver-eps E] [--max-iter N] [--shrink-every N]
                  [--sparse] [--dim D] [--density P]
                  [--trace] [--trace-json PATH.json]
                  [--save MODEL.sol | --save MODEL.sol.d]
  liquidsvm predict --model MODEL.sol[.d] [--data NAME|--file PATH] [--sparse]
                  [--out PREDICTIONS.txt] [--trace] [--trace-json PATH.json]
  liquidsvm serve [--port P] [--host H] [--models name=a.sol,name2=b.sol.d]
                  [--max-batch B] [--max-delay-ms MS] [--workers W] [--queue-cap Q]
                  [--max-models M] [--max-shard-mb MB] [--backend scalar|blocked|simd|...]
                  [--slow-log-us US] [--io-threads N] [--max-conns C] [--rate-limit R]
  liquidsvm client --addr HOST:PORT --model NAME [--data NAME|--file PATH] [--n N]
                   [--connections C] [--pipeline P] [--binary] [--swarm]
  liquidsvm convert --in DATA.[csv|libsvm] --out DATA.[csv|libsvm]
  liquidsvm distributed [--data NAME] [--workers W] [--coarse-size N] [--fine-size N]
                  [--trace] [--trace-json PATH.json]
  liquidsvm distributed --workers HOST:PORT,HOST:PORT,... --save BUNDLE.sol.d
                  [--data NAME|--file PATH] [--cells SPEC] [--jobs J]
                  [--connect-timeout-ms MS] [--io-timeout-ms MS]
                  [--trace] [--trace-json PATH.json]
  liquidsvm worker [--host H] [--port P] [--jobs J] [--display D]
  liquidsvm list-datasets

Options take `--key value` or `--key=value`; each key at most once.
`--cells`/`--voronoi` specs: 0 (off), chunks,SIZE, 1,SIZE (Voronoi),
5,SIZE (overlapping Voronoi), 6,SIZE (recursive tree).  `--jobs` is
the shared worker budget (defaults to --threads), split between the
cell driver and each unit's parallel per-fold CV chain grid.  `--max-gram-mb`
caps resident distance/Gram memory per CV run (default 1024, 0 =
unlimited); past the cap the engine streams kernel row-tiles.
`--solver-eps` (default 1e-3) is the KKT stopping threshold,
`--max-iter` (default 200000) the per-solve coordinate-update cap
(the ls scenario's CG solver reads it as a CG-round cap), and
`--shrink-every` (default 1000, 0 = off) the cadence of the solver
engine's shrinking: every N coordinate updates it drops coordinates
pinned at a box bound, and a mandatory unshrink pass before
termination re-checks the full KKT criterion, so accuracy is
unchanged — see the README solver-tuning playbook.
Saving to a `.sol.d` path writes a sharded bundle (one shard per cell)
that `liquidsvm serve` loads lazily under --max-shard-mb.
`--sparse` (auto-detected for `.csr` files) reads LIBSVM data straight
into CSR and trains through the sparse data plane: no n x d
densification anywhere, no scaling, cells limited to 0/chunks — the
path for d in the tens of thousands at sub-percent density.  Without
--file it generates a synthetic sparse set (--dim, --density).
`--backend simd` switches the Gram hot loop onto the explicit-SIMD
dispatch seam: the instruction level (scalar fallback / AVX2 / AVX-512)
is detected once at startup and can be pinned with `--backend avx2`,
`--backend avx512`, or the `LIQUIDSVM_SIMD=scalar|avx2|avx512` env
escape hatch (env beats CLI beats auto-detect; requests the CPU or
build cannot run are clamped down, which never changes results — all
levels are bit-identical).  `--backend simd-f32` adds the opt-in f32
mixed-precision Gram fill (ULP-bounded, not bit-exact) — see the
README SIMD playbook.
`--trace` turns on phase tracing and prints the per-phase wall-time
table to stderr when the run finishes; `--trace-json PATH` additionally
writes the same breakdown as JSON (implies --trace).  `serve
--slow-log-us N` logs any request whose enqueue-to-response latency
reaches N microseconds, and the serve protocol's `metrics` command
exposes every registered counter/gauge/histogram as Prometheus text
(`metrics json` for JSON) — see the README observability playbook.

`serve` runs connections on a fixed pool of nonblocking reactor
threads (`--io-threads`, default min(cores, 4)), so 10k idle
connections cost 10k slab slots, not 10k threads.  `--max-conns C`
caps concurrently open connections (excess accepts get one
`err conn-limit ...` line and a close); `--rate-limit R` grants each
client IP a token bucket of R predict rows/s with a 1-second burst
(refusals carry `retry_after_ms`).  `client --binary` negotiates the
length-prefixed f32 wire format (`tag u8 | len u32 LE | payload`, raw
little-endian rows/decisions — same predictions as text, no float
formatting on the hot path); `client --swarm` drives all connections
from one event-loop thread per core instead of a thread per
connection, the harness for c10k-scale sweeps — see the README
serving playbook.

`distributed` with a worker *count* runs the single-process simulation
of the paper's Spark mode (modelled Table-4 wall-clocks).  With
`--workers host:port,...` it instead trains over real sockets: start
`liquidsvm worker` processes (port 0 picks an ephemeral port, printed
as `worker listening on ADDR`), point the coordinator at them, and it
shards the Voronoi cells over the binary train protocol, re-dispatches
on worker loss, and writes a `.sol.d` bundle byte-identical to a
single-process `train --save` — the reported `measured_wall` is
genuinely socket-measured, with the modelled numbers alongside.  See
the README distributed playbook and DESIGN.md §Distributed-wire.

EXAMPLES (sparse):
  liquidsvm train --sparse --dim 50000 --density 0.005 --n 2000 --scenario binary
  liquidsvm train --file rcv1.csr --scenario binary --save rcv1.sol
  liquidsvm predict --model rcv1.sol --file rcv1-test.csr

EXAMPLES:
  liquidsvm train --data banana-mc --n 2000 --scenario mc --display 1 --threads 2
  liquidsvm train --data covtype --n 10000 --cells 6,1000 --jobs 8 --scenario binary
  liquidsvm train --data banana --scenario binary --save banana.sol
  liquidsvm train --data covtype --n 50000 --cells 1,2000 --jobs 8 \\
      --scenario binary --save covtype.sol.d
  liquidsvm serve --port 4950 --models banana=banana.sol,cov=covtype.sol.d --max-shard-mb 64
  liquidsvm client --addr 127.0.0.1:4950 --model banana --data banana --n 1000
  liquidsvm distributed --data covtype --n 20000 --workers 8
  liquidsvm worker --port 5151 &
  liquidsvm worker --port 5152 &
  liquidsvm distributed --data covtype --n 4000 --cells 1,500 \\
      --workers 127.0.0.1:5151,127.0.0.1:5152 --save covtype-dist.sol.d"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Result<Args> {
        Args::parse_from(tokens.iter().map(|t| t.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["train", "--data", "banana", "--n", "500"]).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("data"), Some("banana"));
        assert_eq!(a.num("n", 0usize).unwrap(), 500);
    }

    #[test]
    fn bare_flags_become_true() {
        let a = parse(&["train", "--libsvm-grid", "--n", "100", "--verbose"]).unwrap();
        assert_eq!(a.get("libsvm-grid"), Some("true"));
        assert_eq!(a.get("verbose"), Some("true")); // trailing bare flag
        assert_eq!(a.get("n"), Some("100"));
    }

    #[test]
    fn duplicate_key_rejected() {
        let err = parse(&["train", "--n", "100", "--n", "200"]).unwrap_err();
        assert!(err.to_string().contains("duplicate option `--n`"), "{err}");
    }

    #[test]
    fn equals_syntax_parses() {
        let a = parse(&["train", "--n=500", "--data=banana", "--verbose"]).unwrap();
        assert_eq!(a.num("n", 0usize).unwrap(), 500);
        assert_eq!(a.get("data"), Some("banana"));
        assert_eq!(a.get("verbose"), Some("true"));
        // value containing '=' splits only on the first one
        let a = parse(&["serve", "--models=banana=banana.sol"]).unwrap();
        assert_eq!(a.get("models"), Some("banana=banana.sol"));
    }

    #[test]
    fn equals_vs_space_collision_rejected() {
        let err = parse(&["train", "--n=100", "--n", "200"]).unwrap_err();
        assert!(err.to_string().contains("duplicate option `--n`"), "{err}");
        let err = parse(&["train", "--n", "100", "--n=200"]).unwrap_err();
        assert!(err.to_string().contains("duplicate option `--n`"), "{err}");
        let err = parse(&["train", "--n=100", "--n=200"]).unwrap_err();
        assert!(err.to_string().contains("duplicate option `--n`"), "{err}");
        // bare flag vs = form collides too
        assert!(parse(&["train", "--verbose", "--verbose=true"]).is_err());
    }

    #[test]
    fn empty_equals_key_rejected() {
        let err = parse(&["train", "--=5"]).unwrap_err();
        assert!(err.to_string().contains("empty option name"), "{err}");
    }

    #[test]
    fn duplicate_bare_flag_rejected() {
        let err = parse(&["train", "--verbose", "--verbose"]).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn duplicate_mixed_flag_then_value_rejected() {
        assert!(parse(&["train", "--x", "--x", "1"]).is_err());
    }

    #[test]
    fn positional_rejected() {
        let err = parse(&["train", "stray"]).unwrap_err();
        assert!(err.to_string().contains("unexpected positional"), "{err}");
    }

    #[test]
    fn empty_args_default_to_help() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.cmd, "help");
    }

    #[test]
    fn num_parse_errors_are_reported() {
        let a = parse(&["train", "--n", "many"]).unwrap();
        assert!(a.num("n", 0usize).is_err());
        assert_eq!(a.num("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn trace_and_slow_log_flags_parse() {
        let a = parse(&["train", "--trace", "--trace-json", "t.json"]).unwrap();
        assert_eq!(a.get("trace"), Some("true"));
        assert_eq!(a.get("trace-json"), Some("t.json"));
        // --trace-json alone must also select tracing (checked without
        // calling trace_setup: it flips process-global state)
        let a = parse(&["train", "--trace-json=t.json"]).unwrap();
        assert!(a.get("trace").is_some() || a.get("trace-json").is_some());
        let a = parse(&["serve", "--slow-log-us", "5000"]).unwrap();
        assert_eq!(a.num("slow-log-us", 0u64).unwrap(), 5000);
    }

    #[test]
    fn serve_admission_flags_parse() {
        let a = parse(&[
            "serve", "--io-threads", "3", "--max-conns", "5000", "--rate-limit", "200",
        ])
        .unwrap();
        assert_eq!(a.num("io-threads", 0usize).unwrap(), 3);
        assert_eq!(a.num("max-conns", 0usize).unwrap(), 5000);
        assert_eq!(a.num("rate-limit", 0u64).unwrap(), 200);
        // all three default to 0 = auto/unlimited/off
        let a = parse(&["serve"]).unwrap();
        assert_eq!(a.num("io-threads", 0usize).unwrap(), 0);
        assert_eq!(a.num("max-conns", 0usize).unwrap(), 0);
        assert_eq!(a.num("rate-limit", 0u64).unwrap(), 0);
    }

    #[test]
    fn client_mode_flags_parse() {
        let a = parse(&["client", "--addr", "h:1", "--model", "m", "--binary", "--swarm"]).unwrap();
        assert!(a.get("binary").is_some());
        assert!(a.get("swarm").is_some());
        let a = parse(&["client", "--addr", "h:1", "--model", "m"]).unwrap();
        assert!(a.get("binary").is_none());
        assert!(a.get("swarm").is_none());
    }

    #[test]
    fn solver_knobs_parse_into_config() {
        let a = parse(&[
            "train", "--solver-eps", "1e-4", "--max-iter", "5000", "--shrink-every", "0",
        ])
        .unwrap();
        let cfg = build_config(&a).unwrap();
        assert_eq!(cfg.solver_params.eps, 1e-4);
        assert_eq!(cfg.solver_params.max_iter, 5000);
        assert_eq!(cfg.solver_params.shrink_every, 0);
        // defaults flow through untouched
        let d = build_config(&parse(&["train"]).unwrap()).unwrap();
        assert_eq!(d.solver_params.eps, 1e-3);
        assert!(d.solver_params.shrink_every > 0);
        // nonsense values are rejected with flag-specific errors
        let bad = parse(&["train", "--solver-eps", "-1"]).unwrap();
        assert!(build_config(&bad).unwrap_err().to_string().contains("solver-eps"));
        let bad = parse(&["train", "--max-iter", "0"]).unwrap();
        assert!(build_config(&bad).unwrap_err().to_string().contains("max-iter"));
    }
}
