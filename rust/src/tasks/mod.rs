//! Working-set **tasks** (paper §2 "Managing Working Sets"): a task is
//! a sub-problem of the full learning problem — one binary machine of
//! an OvA/AvA decomposition, one weighted machine of an NPL sweep, one
//! quantile/expectile level — carrying its own sample subset, label
//! transformation, solver, and validation loss.  Tasks are crossed with
//! cells by the coordinator, and hyper-parameter selection runs on each
//! resulting (cell × task) working set independently.

use crate::data::dataset::Dataset;
use crate::metrics::Loss;
use crate::solver::SolverKind;

/// Learning-scenario specification (the routines the CLI/bindings
/// expose: mcSVM, lsSVM, qtSVM, exSVM, nplSVM, rocSVM ...).
#[derive(Clone, Debug)]
pub enum TaskSpec {
    /// binary classification with hinge loss; `w` = positive-class
    /// weight (0.5 ⇒ unweighted)
    Binary { w: f32 },
    /// one-versus-all multiclass (one hinge task per class)
    MultiClassOvA,
    /// all-versus-all multiclass (one task per unordered class pair)
    MultiClassAvA,
    /// least-squares regression (also the OvA-LS mode of Table 2 when
    /// combined with multiclass data via `ova_ls`)
    LeastSquares,
    /// OvA with least-squares machines (GURLS comparison mode)
    MultiClassOvALs,
    /// weighted-binary sweep for Neyman-Pearson-type control of the
    /// false-alarm rate
    NeymanPearson { weights: Vec<f32> },
    /// quantile regression at several levels simultaneously
    MultiQuantile { taus: Vec<f32> },
    /// expectile regression at several levels
    MultiExpectile { taus: Vec<f32> },
}

/// A concrete task: subset + transformed labels + solver + val loss.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    /// indices into the working set this task trains on
    pub indices: Vec<usize>,
    /// transformed labels, parallel to `indices`
    pub y: Vec<f32>,
    pub solver: SolverKind,
    pub val_loss: Loss,
}

/// Materialize the tasks of a spec over a working set, using the
/// working set's own label set.
pub fn create_tasks(data: &Dataset, spec: &TaskSpec) -> Vec<Task> {
    create_tasks_for_classes(&data.y, spec, &data.classes())
}

/// Materialize tasks against a *global* class list — needed when the
/// working set is one cell of a decomposition: every cell must carry
/// the same task roster so predictions can be combined across cells,
/// even if some class is absent locally (those tasks get empty index
/// sets and are skipped by the trainer).  Tasks are a pure label
/// transformation, so this takes labels only — the dense and sparse
/// training paths share it (see DESIGN.md §Data-plane).
pub fn create_tasks_for_classes(y: &[f32], spec: &TaskSpec, classes: &[f32]) -> Vec<Task> {
    let all: Vec<usize> = (0..y.len()).collect();
    match spec {
        TaskSpec::Binary { w } => vec![Task {
            name: "binary".into(),
            indices: all,
            y: y.to_vec(),
            solver: SolverKind::Hinge { w: *w },
            val_loss: if *w == 0.5 {
                Loss::Classification
            } else {
                Loss::WeightedClassification { w: *w }
            },
        }],
        TaskSpec::LeastSquares => vec![Task {
            name: "ls".into(),
            indices: all,
            y: y.to_vec(),
            solver: SolverKind::LeastSquares,
            val_loss: Loss::LeastSquares,
        }],
        TaskSpec::MultiClassOvA | TaskSpec::MultiClassOvALs => {
            let ls = matches!(spec, TaskSpec::MultiClassOvALs);
            classes
                .iter()
                .map(|&c| Task {
                    name: format!("ova-{c}"),
                    indices: all.clone(),
                    y: y.iter().map(|&v| if v == c { 1.0 } else { -1.0 }).collect(),
                    solver: if ls {
                        SolverKind::LeastSquares
                    } else {
                        SolverKind::Hinge { w: 0.5 }
                    },
                    val_loss: if ls { Loss::LeastSquares } else { Loss::Classification },
                })
                .collect()
        }
        TaskSpec::MultiClassAvA => {
            let mut tasks = Vec::new();
            for a in 0..classes.len() {
                for b in a + 1..classes.len() {
                    let (ca, cb) = (classes[a], classes[b]);
                    let indices: Vec<usize> =
                        (0..y.len()).filter(|&i| y[i] == ca || y[i] == cb).collect();
                    let ty: Vec<f32> = indices
                        .iter()
                        .map(|&i| if y[i] == ca { -1.0 } else { 1.0 })
                        .collect();
                    tasks.push(Task {
                        name: format!("ava-{ca}v{cb}"),
                        indices,
                        y: ty,
                        solver: SolverKind::Hinge { w: 0.5 },
                        val_loss: Loss::Classification,
                    });
                }
            }
            tasks
        }
        TaskSpec::NeymanPearson { weights } => weights
            .iter()
            .map(|&w| Task {
                name: format!("npl-w{w:.3}"),
                indices: all.clone(),
                y: y.to_vec(),
                solver: SolverKind::Hinge { w },
                val_loss: Loss::WeightedClassification { w },
            })
            .collect(),
        TaskSpec::MultiQuantile { taus } => taus
            .iter()
            .map(|&tau| Task {
                name: format!("qt-{tau:.2}"),
                indices: all.clone(),
                y: y.to_vec(),
                solver: SolverKind::Quantile { tau },
                val_loss: Loss::Pinball { tau },
            })
            .collect(),
        TaskSpec::MultiExpectile { taus } => taus
            .iter()
            .map(|&tau| Task {
                name: format!("ex-{tau:.2}"),
                indices: all.clone(),
                y: y.to_vec(),
                solver: SolverKind::Expectile { tau },
                val_loss: Loss::Expectile { tau },
            })
            .collect(),
    }
}

/// Combine per-task decision values into final predictions.
/// `scores[t][i]` = task `t`'s decision value on test sample `i`.
pub fn combine_predictions(spec: &TaskSpec, classes: &[f32], scores: &[Vec<f32>]) -> Vec<f32> {
    match spec {
        TaskSpec::Binary { .. } => {
            scores[0].iter().map(|&s| if s >= 0.0 { 1.0 } else { -1.0 }).collect()
        }
        TaskSpec::LeastSquares => scores[0].clone(),
        TaskSpec::MultiClassOvA | TaskSpec::MultiClassOvALs => {
            // argmax over the per-class machines
            let n = scores[0].len();
            (0..n)
                .map(|i| {
                    let mut best = (0usize, f32::NEG_INFINITY);
                    for (t, sc) in scores.iter().enumerate() {
                        if sc[i] > best.1 {
                            best = (t, sc[i]);
                        }
                    }
                    classes[best.0]
                })
                .collect()
        }
        TaskSpec::MultiClassAvA => {
            // pairwise voting; task order matches create_tasks pair order
            let n = scores[0].len();
            let k = classes.len();
            (0..n)
                .map(|i| {
                    let mut votes = vec![0usize; k];
                    let mut t = 0usize;
                    for a in 0..k {
                        for b in a + 1..k {
                            if scores[t][i] >= 0.0 {
                                votes[b] += 1;
                            } else {
                                votes[a] += 1;
                            }
                            t += 1;
                        }
                    }
                    let best = votes
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, &v)| v)
                        .map(|(c, _)| c)
                        .unwrap_or(0);
                    classes[best]
                })
                .collect()
        }
        // NPL / quantile / expectile produce one curve per task; the
        // "combined" prediction defaults to the first task (callers
        // usually inspect per-task outputs instead)
        _ => scores[0].clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    fn mc_data() -> Dataset {
        Dataset::new(
            Matrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0], &[4.0], &[5.0]]),
            vec![0.0, 1.0, 2.0, 0.0, 1.0, 2.0],
        )
    }

    #[test]
    fn ova_creates_one_task_per_class() {
        let tasks = create_tasks(&mc_data(), &TaskSpec::MultiClassOvA);
        assert_eq!(tasks.len(), 3);
        // class-1 task labels: +1 where y==1
        assert_eq!(tasks[1].y, vec![-1.0, 1.0, -1.0, -1.0, 1.0, -1.0]);
        assert!(matches!(tasks[0].solver, SolverKind::Hinge { .. }));
    }

    #[test]
    fn ava_pairs_and_subsets() {
        let tasks = create_tasks(&mc_data(), &TaskSpec::MultiClassAvA);
        assert_eq!(tasks.len(), 3); // 3 choose 2
        // pair (0,1): only samples of class 0/1 included
        assert_eq!(tasks[0].indices, vec![0, 1, 3, 4]);
        assert_eq!(tasks[0].y, vec![-1.0, 1.0, -1.0, 1.0]);
    }

    #[test]
    fn ova_argmax_combination() {
        let classes = [0.0, 1.0, 2.0];
        let scores = vec![vec![0.1, -1.0], vec![0.9, -0.2], vec![-0.5, -0.1]];
        let pred = combine_predictions(&TaskSpec::MultiClassOvA, &classes, &scores);
        assert_eq!(pred, vec![1.0, 2.0]);
    }

    #[test]
    fn ava_voting_combination() {
        let classes = [0.0, 1.0, 2.0];
        // tasks: (0v1), (0v2), (1v2); sample where 1 beats 0, 2 beats 0,
        // 1 beats 2 => votes 0:0, 1:2, 2:1 -> class 1
        let scores = vec![vec![1.0], vec![1.0], vec![-1.0]];
        let pred = combine_predictions(&TaskSpec::MultiClassAvA, &classes, &scores);
        assert_eq!(pred, vec![1.0]);
    }

    #[test]
    fn quantile_tasks_one_per_tau() {
        let d = Dataset::new(Matrix::from_rows(&[&[0.0], &[1.0]]), vec![0.3, 0.7]);
        let tasks =
            create_tasks(&d, &TaskSpec::MultiQuantile { taus: vec![0.1, 0.5, 0.9] });
        assert_eq!(tasks.len(), 3);
        assert!(matches!(tasks[2].solver, SolverKind::Quantile { tau } if tau == 0.9));
    }

    #[test]
    fn npl_weight_sweep() {
        let d = Dataset::new(Matrix::from_rows(&[&[0.0], &[1.0]]), vec![-1.0, 1.0]);
        let tasks = create_tasks(&d, &TaskSpec::NeymanPearson { weights: vec![0.7, 0.9] });
        assert_eq!(tasks.len(), 2);
        assert!(matches!(tasks[1].val_loss, Loss::WeightedClassification { w } if w == 0.9));
    }

    #[test]
    fn binary_sign_combination() {
        let pred = combine_predictions(
            &TaskSpec::Binary { w: 0.5 },
            &[-1.0, 1.0],
            &[vec![0.2, -0.3]],
        );
        assert_eq!(pred, vec![1.0, -1.0]);
    }
}
