//! k-fold generation (paper §2: "The user can choose between different
//! fold generation methods").  liquidSVM offers random, stratified
//! (class-balanced), block (contiguous), and alternating assignment.

use super::dataset::Dataset;
use super::rng::Rng;

/// Fold assignment strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FoldKind {
    /// uniform random permutation split
    Random,
    /// class proportions preserved in every fold (classification default)
    Stratified,
    /// contiguous blocks in input order (time-series friendly)
    Block,
    /// round-robin i mod k (liquidSVM's "alternating")
    Alternating,
}

/// The index sets of one CV split: `folds[f]` are the *validation*
/// indices of fold `f`; training indices are the complement.
#[derive(Clone, Debug)]
pub struct Folds {
    pub folds: Vec<Vec<usize>>,
}

impl Folds {
    pub fn k(&self) -> usize {
        self.folds.len()
    }

    /// Training indices for fold `f` (complement of the validation set).
    pub fn train_indices(&self, f: usize) -> Vec<usize> {
        let n: usize = self.folds.iter().map(|v| v.len()).sum();
        let mut in_val = vec![false; n];
        for &i in &self.folds[f] {
            in_val[i] = true;
        }
        (0..n).filter(|&i| !in_val[i]).collect()
    }

    pub fn val_indices(&self, f: usize) -> &[usize] {
        &self.folds[f]
    }
}

/// Generate k folds over `d` with the given strategy and seed.
pub fn make_folds(d: &Dataset, k: usize, kind: FoldKind, seed: u64) -> Folds {
    make_folds_y(&d.y, k, kind, seed)
}

/// Label-only fold generation — the strategies never look at features,
/// so sparse datasets share this path.
pub fn make_folds_y(y: &[f32], k: usize, kind: FoldKind, seed: u64) -> Folds {
    let n = y.len();
    assert!(k >= 2, "need at least 2 folds");
    assert!(n >= k, "fewer samples than folds");
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    match kind {
        FoldKind::Random => {
            let mut idx: Vec<usize> = (0..n).collect();
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut idx);
            for (pos, &i) in idx.iter().enumerate() {
                folds[pos % k].push(i);
            }
        }
        FoldKind::Stratified => {
            let mut rng = Rng::new(seed);
            // ONE round-robin cursor carried across classes: restarting
            // at fold 0 per class (`pos % k` with a class-local `pos`)
            // would pile every class's remainder onto the low-index
            // folds — with c classes, fold 0 could end up c samples
            // bigger than fold k-1.  Carrying the cursor keeps overall
            // fold sizes within 1 for any class mix, while each class
            // still spreads over k consecutive slots (per-class counts
            // within 1 too).
            let mut cursor = 0usize;
            for class in crate::data::dataset::distinct_labels(y) {
                let mut idx: Vec<usize> =
                    (0..n).filter(|&i| y[i] == class).collect();
                rng.shuffle(&mut idx);
                for &i in &idx {
                    folds[cursor % k].push(i);
                    cursor += 1;
                }
            }
        }
        FoldKind::Block => {
            let base = n / k;
            let extra = n % k;
            let mut start = 0;
            for (f, fold) in folds.iter_mut().enumerate() {
                let len = base + usize::from(f < extra);
                fold.extend(start..start + len);
                start += len;
            }
        }
        FoldKind::Alternating => {
            for i in 0..n {
                folds[i % k].push(i);
            }
        }
    }
    for fold in &mut folds {
        fold.sort_unstable();
    }
    Folds { folds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    fn toy(n: usize) -> Dataset {
        let x = Matrix::from_vec((0..n).map(|i| i as f32).collect(), n, 1);
        let y = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new(x, y)
    }

    fn check_partition(f: &Folds, n: usize) {
        let mut seen = vec![0u8; n];
        for fold in &f.folds {
            for &i in fold {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "not a partition");
    }

    #[test]
    fn all_kinds_partition() {
        let d = toy(103);
        for kind in [FoldKind::Random, FoldKind::Stratified, FoldKind::Block, FoldKind::Alternating] {
            let f = make_folds(&d, 5, kind, 9);
            check_partition(&f, 103);
            let sizes: Vec<usize> = f.folds.iter().map(|v| v.len()).collect();
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 2, "{kind:?}: {sizes:?}");
        }
    }

    #[test]
    fn stratified_balances_classes() {
        let d = toy(90);
        let f = make_folds(&d, 5, FoldKind::Stratified, 1);
        for fold in &f.folds {
            let pos = fold.iter().filter(|&&i| d.y[i] == 1.0).count();
            // 30 positives over 5 folds => 6 each
            assert_eq!(pos, 6);
        }
    }

    #[test]
    fn stratified_carries_cursor_across_classes() {
        // regression: many small odd-sized classes.  With the old
        // class-local `pos % k`, every class dropped its remainder on
        // fold 0: 11 classes x 3 samples over 5 folds gave fold sizes
        // [11, 11, 11, 0, 0].  The carried cursor keeps the spread <= 1
        // overall AND <= 1 within every class.
        let n_classes = 11usize;
        let per_class = 3usize;
        let n = n_classes * per_class;
        let x = Matrix::from_vec((0..n).map(|i| i as f32).collect(), n, 1);
        let y: Vec<f32> = (0..n).map(|i| (i % n_classes) as f32).collect();
        let d = Dataset::new(x, y);
        let k = 5;
        let f = make_folds(&d, k, FoldKind::Stratified, 3);
        check_partition(&f, n);
        let sizes: Vec<usize> = f.folds.iter().map(Vec::len).collect();
        let (lo, hi) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(hi - lo <= 1, "fold sizes unbalanced: {sizes:?}");
        for class in d.classes() {
            let counts: Vec<usize> = f
                .folds
                .iter()
                .map(|fold| fold.iter().filter(|&&i| d.y[i] == class).count())
                .collect();
            let (lo, hi) = (*counts.iter().min().unwrap(), *counts.iter().max().unwrap());
            assert!(hi - lo <= 1, "class {class} unbalanced: {counts:?}");
        }
    }

    #[test]
    fn stratified_balances_uneven_binary_mix() {
        // 7 positives + 46 negatives over 4 folds: overall sizes must
        // differ by at most 1 even though both classes leave remainders
        let n = 53usize;
        let x = Matrix::from_vec((0..n).map(|i| i as f32).collect(), n, 1);
        let y: Vec<f32> = (0..n).map(|i| if i < 7 { 1.0 } else { -1.0 }).collect();
        let d = Dataset::new(x, y);
        let f = make_folds(&d, 4, FoldKind::Stratified, 9);
        check_partition(&f, n);
        let sizes: Vec<usize> = f.folds.iter().map(Vec::len).collect();
        assert!(
            sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1,
            "{sizes:?}"
        );
    }

    #[test]
    fn train_indices_complement() {
        let d = toy(20);
        let f = make_folds(&d, 4, FoldKind::Random, 3);
        let tr = f.train_indices(2);
        assert_eq!(tr.len() + f.val_indices(2).len(), 20);
        for i in &tr {
            assert!(!f.val_indices(2).contains(i));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let d = toy(50);
        let a = make_folds(&d, 5, FoldKind::Random, 42);
        let b = make_folds(&d, 5, FoldKind::Random, 42);
        assert_eq!(a.folds, b.folds);
    }
}
