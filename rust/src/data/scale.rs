//! Train-set-determined normalization (paper §B.1: "Based on the
//! training a scaling was determined and both training and test set
//! were normalized by that").

use super::matrix::Matrix;

/// Per-column affine scaler fitted on a training set.
#[derive(Clone, Debug)]
pub struct Scaler {
    shift: Vec<f32>,
    scale: Vec<f32>,
}

/// Which scaling statistic to fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleKind {
    /// map [min,max] -> [0,1]
    MinMax,
    /// zero mean, unit variance
    Standard,
}

impl Scaler {
    pub fn fit(x: &Matrix, kind: ScaleKind) -> Scaler {
        let (r, c) = (x.rows(), x.cols());
        let mut shift = vec![0.0f32; c];
        let mut scale = vec![1.0f32; c];
        match kind {
            ScaleKind::MinMax => {
                let mut lo = vec![f32::INFINITY; c];
                let mut hi = vec![f32::NEG_INFINITY; c];
                for i in 0..r {
                    for (j, &v) in x.row(i).iter().enumerate() {
                        lo[j] = lo[j].min(v);
                        hi[j] = hi[j].max(v);
                    }
                }
                for j in 0..c {
                    shift[j] = lo[j];
                    let span = hi[j] - lo[j];
                    scale[j] = if span > 0.0 { 1.0 / span } else { 1.0 };
                }
            }
            ScaleKind::Standard => {
                let mut mean = vec![0.0f64; c];
                let mut m2 = vec![0.0f64; c];
                for i in 0..r {
                    for (j, &v) in x.row(i).iter().enumerate() {
                        mean[j] += v as f64;
                        m2[j] += (v as f64) * (v as f64);
                    }
                }
                for j in 0..c {
                    let mu = mean[j] / r.max(1) as f64;
                    let var = (m2[j] / r.max(1) as f64 - mu * mu).max(0.0);
                    shift[j] = mu as f32;
                    scale[j] = if var > 0.0 { (1.0 / var.sqrt()) as f32 } else { 1.0 };
                }
            }
        }
        Scaler { shift, scale }
    }

    /// Apply in place.
    pub fn apply(&self, x: &mut Matrix) {
        let c = x.cols();
        assert_eq!(c, self.shift.len());
        for i in 0..x.rows() {
            let row = x.row_mut(i);
            for j in 0..c {
                row[j] = (row[j] - self.shift[j]) * self.scale[j];
            }
        }
    }

    /// Apply to a copy.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        self.apply(&mut out);
        out
    }

    /// Apply to a single row (one allocation; the serving router's
    /// per-request path, where building a 1×d `Matrix` would cost two
    /// extra copies per row).
    pub fn transform_row(&self, row: &[f32]) -> Vec<f32> {
        assert_eq!(row.len(), self.shift.len());
        row.iter()
            .zip(self.shift.iter().zip(&self.scale))
            .map(|(&v, (&sh, &sc))| (v - sh) * sc)
            .collect()
    }

    /// Rebuild from serialized (shift, scale) columns (persistence).
    pub fn from_parts(shift: Vec<f32>, scale: Vec<f32>) -> Scaler {
        assert_eq!(shift.len(), scale.len());
        Scaler { shift, scale }
    }

    /// Serialized (shift, scale) columns (persistence).
    pub fn parts(&self) -> (Vec<f32>, Vec<f32>) {
        (self.shift.clone(), self.scale.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_maps_to_unit_interval() {
        let x = Matrix::from_rows(&[&[0.0, 10.0], &[4.0, 30.0], &[2.0, 20.0]]);
        let s = Scaler::fit(&x, ScaleKind::MinMax);
        let t = s.transform(&x);
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.get(1, 0), 1.0);
        assert_eq!(t.get(2, 1), 0.5);
    }

    #[test]
    fn standard_zero_mean_unit_var() {
        let x = Matrix::from_rows(&[&[1.0], &[3.0], &[5.0]]);
        let s = Scaler::fit(&x, ScaleKind::Standard);
        let t = s.transform(&x);
        let mean: f32 = t.as_slice().iter().sum::<f32>() / 3.0;
        assert!(mean.abs() < 1e-6);
        let var: f32 = t.as_slice().iter().map(|v| v * v).sum::<f32>() / 3.0;
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn constant_column_is_noop() {
        let x = Matrix::from_rows(&[&[7.0], &[7.0]]);
        let s = Scaler::fit(&x, ScaleKind::MinMax);
        let t = s.transform(&x);
        assert_eq!(t.get(0, 0), 0.0); // shifted by min, scale 1
    }

    #[test]
    fn transform_row_matches_matrix_transform() {
        let x = Matrix::from_rows(&[&[0.0, 10.0], &[4.0, 30.0], &[2.0, 20.0]]);
        let s = Scaler::fit(&x, ScaleKind::MinMax);
        let t = s.transform(&x);
        for i in 0..x.rows() {
            assert_eq!(s.transform_row(x.row(i)), t.row(i).to_vec(), "row {i}");
        }
    }
}
