//! Labeled dataset container + train/test split bundles.

use super::matrix::Matrix;

/// A labeled sample set. Labels are `f32`: ±1 for binary classification,
/// {0..k-1} (stored as floats) for multiclass, reals for regression —
/// matching liquidSVM's untyped label column.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(x: Matrix, y: Vec<f32>) -> Self {
        assert_eq!(x.rows(), y.len(), "label/sample count mismatch");
        Dataset { x, y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Subset by row indices (order preserved).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Distinct labels in sorted order (exact float comparison, as
    /// labels are small integers or quantile levels set by us).
    pub fn classes(&self) -> Vec<f32> {
        distinct_labels(&self.y)
    }

    /// Indices of samples with the given label.
    pub fn indices_of(&self, label: f32) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.y[i] == label).collect()
    }

    /// Deterministic split into train/test by shuffled indices.
    pub fn split(&self, n_train: usize, seed: u64) -> TrainTest {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = super::rng::Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = n_train.min(self.len());
        TrainTest {
            train: self.subset(&idx[..n_train]),
            test: self.subset(&idx[n_train..]),
        }
    }
}

/// Distinct labels of `y` in sorted order — the label-only core of
/// [`Dataset::classes`], shared with the sparse containers and the
/// label-driven fold/task machinery.
pub fn distinct_labels(y: &[f32]) -> Vec<f32> {
    let mut c: Vec<f32> = Vec::new();
    for &v in y {
        if !c.iter().any(|&u| u == v) {
            c.push(v);
        }
    }
    c.sort_by(|a, b| a.partial_cmp(b).unwrap());
    c
}

/// A train/test bundle (what `liquidData` returns in the R binding).
#[derive(Clone, Debug)]
pub struct TrainTest {
    pub train: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            Matrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0]]),
            vec![1.0, -1.0, 1.0, -1.0],
        )
    }

    #[test]
    fn classes_sorted_unique() {
        assert_eq!(toy().classes(), vec![-1.0, 1.0]);
    }

    #[test]
    fn subset_preserves_pairing() {
        let s = toy().subset(&[2, 0]);
        assert_eq!(s.x.as_slice(), &[2.0, 0.0]);
        assert_eq!(s.y, vec![1.0, 1.0]);
    }

    #[test]
    fn split_partitions_all_samples() {
        let tt = toy().split(3, 7);
        assert_eq!(tt.train.len(), 3);
        assert_eq!(tt.test.len(), 1);
    }

    #[test]
    fn indices_of_label() {
        assert_eq!(toy().indices_of(-1.0), vec![1, 3]);
    }
}
