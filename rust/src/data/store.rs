//! The sample-storage abstraction of the data plane (see DESIGN.md
//! §Data-plane): one enum over the two physical layouts — dense
//! row-major [`Matrix`] and [`CsrMatrix`] — so the CV engine, the
//! trained units, and the predict path carry either without caring
//! which.  Kernel math on a `Store` lives in `kernel::backend` /
//! `kernel::plane` (the data module stays dependency-free); this
//! module only owns the data operations: row selection, norms, and the
//! explicit densification boundaries.

use super::csr::CsrMatrix;
use super::matrix::Matrix;

/// Owned sample storage: dense or CSR.
#[derive(Clone, Debug)]
pub enum Store {
    Dense(Matrix),
    Sparse(CsrMatrix),
}

/// Borrowed view of a [`Store`] — what the CV engine and predict path
/// take, so callers holding a bare `&Matrix` or `&CsrMatrix` never
/// clone into an owned `Store` just to call in.
#[derive(Clone, Copy, Debug)]
pub enum StoreRef<'a> {
    Dense(&'a Matrix),
    Sparse(&'a CsrMatrix),
}

impl Store {
    /// Borrowed view (not the `AsRef` trait: the target is an enum of
    /// references, not a reference).
    #[allow(clippy::should_implement_trait)]
    pub fn as_ref(&self) -> StoreRef<'_> {
        match self {
            Store::Dense(m) => StoreRef::Dense(m),
            Store::Sparse(m) => StoreRef::Sparse(m),
        }
    }

    pub fn rows(&self) -> usize {
        self.as_ref().rows()
    }

    pub fn cols(&self) -> usize {
        self.as_ref().cols()
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Store::Sparse(_))
    }
}

impl StoreRef<'_> {
    pub fn rows(&self) -> usize {
        match self {
            StoreRef::Dense(m) => m.rows(),
            StoreRef::Sparse(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            StoreRef::Dense(m) => m.cols(),
            StoreRef::Sparse(m) => m.cols(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, StoreRef::Sparse(_))
    }

    /// Owned subset in the same layout (order preserved, repeats
    /// allowed) — fold subsets and cell working sets never change
    /// flavor.
    pub fn select_rows(&self, idx: &[usize]) -> Store {
        match self {
            StoreRef::Dense(m) => Store::Dense(m.select_rows(idx)),
            StoreRef::Sparse(m) => Store::Sparse(m.select_rows(idx)),
        }
    }

    /// Squared row norms — bit-identical across layouts (see
    /// [`CsrMatrix::row_sq_norms`]).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        match self {
            StoreRef::Dense(m) => m.row_sq_norms(),
            StoreRef::Sparse(m) => m.row_sq_norms(),
        }
    }

    /// Densify row `i` into caller scratch of length `cols` — the
    /// per-row densification boundary (geometric routing, dense-model
    /// predict on sparse inputs).  For dense stores this is a plain
    /// copy.
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            StoreRef::Dense(m) => out.copy_from_slice(m.row(i)),
            StoreRef::Sparse(m) => m.densify_row_into(i, out),
        }
    }

    /// Fully densify (tests / explicit boundaries only — never the
    /// sparse hot path).
    pub fn to_dense(&self) -> Matrix {
        match self {
            StoreRef::Dense(m) => (*m).clone(),
            StoreRef::Sparse(m) => m.to_dense(),
        }
    }
}

/// A labeled working set over either storage layout — what a trained
/// (cell × task) unit carries as its expansion data.
#[derive(Clone, Debug)]
pub struct WorkingSet {
    pub x: Store,
    pub y: Vec<f32>,
}

impl WorkingSet {
    pub fn dense(x: Matrix, y: Vec<f32>) -> WorkingSet {
        assert_eq!(x.rows(), y.len(), "label/sample count mismatch");
        WorkingSet { x: Store::Dense(x), y }
    }

    pub fn sparse(x: CsrMatrix, y: Vec<f32>) -> WorkingSet {
        assert_eq!(x.rows(), y.len(), "label/sample count mismatch");
        WorkingSet { x: Store::Sparse(x), y }
    }

    pub fn from_dataset(d: super::dataset::Dataset) -> WorkingSet {
        WorkingSet { x: Store::Dense(d.x), y: d.y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_preserves_flavor() {
        let dense = StoreRef::Dense(&Matrix::from_rows(&[&[1.0], &[2.0]])).select_rows(&[1]);
        assert!(!dense.is_sparse());
        let csr = CsrMatrix::from_dense(&Matrix::from_rows(&[&[0.0, 1.0], &[2.0, 0.0]]));
        let sparse = StoreRef::Sparse(&csr).select_rows(&[0]);
        assert!(sparse.is_sparse());
        assert_eq!(sparse.as_ref().to_dense().row(0), &[0.0, 1.0]);
    }

    #[test]
    fn densify_row_matches_dense_copy() {
        let m = Matrix::from_rows(&[&[0.0, 3.0, 0.0], &[1.0, 0.0, 2.0]]);
        let csr = CsrMatrix::from_dense(&m);
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        for i in 0..2 {
            StoreRef::Dense(&m).densify_row_into(i, &mut a);
            StoreRef::Sparse(&csr).densify_row_into(i, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn working_set_accessors() {
        let ws = WorkingSet::dense(Matrix::from_rows(&[&[1.0, 2.0]]), vec![1.0]);
        assert_eq!((ws.len(), ws.dim()), (1, 2));
        assert!(!ws.is_empty());
        assert!(!ws.x.is_sparse());
    }
}
