//! Data substrate: sample containers, file formats, scaling, fold
//! generation, and the synthetic stand-ins for the paper's datasets.

pub mod csr;
pub mod dataset;
pub mod folds;
pub mod io;
pub mod matrix;
pub mod rng;
pub mod scale;
pub mod store;
pub mod synth;

pub use csr::{CsrMatrix, SparseDataset};
pub use dataset::{Dataset, TrainTest};
pub use matrix::Matrix;
pub use store::{Store, StoreRef, WorkingSet};
