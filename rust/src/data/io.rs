//! Dataset file formats: LIBSVM sparse text and CSV (the two formats
//! liquidSVM reads, Table 5 "Data Format" column), plus writers — the
//! writers are also what the SVMlight-style `disk_wrapper` baseline
//! uses to pay its per-grid-point disk penalty honestly.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::csr::{CsrMatrix, SparseDataset};
use super::dataset::Dataset;
use super::matrix::Matrix;

/// Incremental LIBSVM parser building a [`CsrMatrix`] directly — the
/// sparse data plane's ingest path (see DESIGN.md §Data-plane).  Feed
/// lines one at a time; memory stays bounded by the CSR triplet being
/// built (plus one row's scratch), never by the text.
///
/// Strictness (all errors carry the 1-based line number):
/// * indices are 1-based; `0:` is rejected;
/// * duplicate indices within a row are rejected — last-write-wins
///   silently changes norms and distances far from the cause;
/// * with a declared `dim != 0`, an index past `dim` is rejected
///   instead of silently widening the matrix — predict-time rows wider
///   than the trained model's `dim` used to surface as shape-mismatch
///   panics deep in the kernel layer.
pub struct LibsvmParser {
    /// declared dimension; 0 = infer from the max index seen
    dim: usize,
    max_idx: usize,
    line_no: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    labels: Vec<f32>,
    row_buf: Vec<(u32, f32)>,
}

impl LibsvmParser {
    pub fn new(dim: usize) -> LibsvmParser {
        LibsvmParser {
            dim,
            max_idx: 0,
            line_no: 0,
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            row_buf: Vec::new(),
        }
    }

    /// Parse one input line (blank lines and `#` comments are skipped).
    pub fn push_line(&mut self, line: &str) -> Result<()> {
        self.line_no += 1;
        let ln = self.line_no;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(());
        }
        let mut parts = line.split_whitespace();
        let lab: f32 = parts
            .next()
            .ok_or_else(|| anyhow!("line {ln}: empty"))?
            .parse()
            .with_context(|| format!("line {ln}: bad label"))?;
        self.row_buf.clear();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("line {ln}: token `{tok}` not idx:val"))?;
            let i: usize = i.parse().with_context(|| format!("line {ln}: bad index"))?;
            if i == 0 {
                return Err(anyhow!("line {ln}: libsvm indices are 1-based"));
            }
            if self.dim != 0 && i > self.dim {
                return Err(anyhow!(
                    "line {ln}: index {i} exceeds declared dim {} — refusing to widen",
                    self.dim
                ));
            }
            if i > u32::MAX as usize {
                return Err(anyhow!("line {ln}: index {i} exceeds u32 range"));
            }
            let v: f32 = v.parse().with_context(|| format!("line {ln}: bad value"))?;
            self.max_idx = self.max_idx.max(i);
            self.row_buf.push((i as u32 - 1, v));
        }
        // files are usually sorted already; sort defensively, then a
        // single adjacent scan catches duplicates (before zero-dropping,
        // so `2:0 2:5` is still a duplicate)
        self.row_buf.sort_unstable_by_key(|&(j, _)| j);
        for w in self.row_buf.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(anyhow!(
                    "line {ln}: duplicate index {} (libsvm rows must list each index once)",
                    w[0].0 + 1
                ));
            }
        }
        for &(j, v) in &self.row_buf {
            // explicit zeros are dropped: they change no kernel value
            // (exact ±0.0 contributions) and would bloat the triplet
            if v != 0.0 {
                self.indices.push(j);
                self.values.push(v);
            }
        }
        self.indptr.push(self.indices.len());
        self.labels.push(lab);
        Ok(())
    }

    /// Finish parsing: the CSR dataset with `cols = dim` (declared) or
    /// the max index seen (inferred).
    pub fn finish(self) -> SparseDataset {
        let cols = if self.dim != 0 { self.dim } else { self.max_idx };
        SparseDataset::new(
            CsrMatrix::from_parts(self.indptr, self.indices, self.values, cols),
            self.labels,
        )
    }
}

/// Parse LIBSVM text into a [`SparseDataset`] (CSR, no densification).
/// `dim` may be 0 to infer the max index.
pub fn parse_libsvm_csr(text: &str, dim: usize) -> Result<SparseDataset> {
    let mut p = LibsvmParser::new(dim);
    for line in text.lines() {
        p.push_line(line)?;
    }
    Ok(p.finish())
}

/// Parse LIBSVM format into a dense [`Dataset`]: `label idx:val ...`
/// (1-based indices).  `dim` may be 0 to infer the max index.  Built
/// on the CSR parser, so strictness (duplicate indices, index > dim)
/// is identical across the dense and sparse ingest paths.
pub fn parse_libsvm(text: &str, dim: usize) -> Result<Dataset> {
    Ok(parse_libsvm_csr(text, dim)?.to_dense())
}

/// Parse CSV with the label in the given column (no header).
pub fn parse_csv(text: &str, label_col: usize) -> Result<Dataset> {
    let mut feats: Vec<f32> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut width: Option<usize> = None;
    let mut n = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split(',')
            .map(|t| t.trim().parse::<f32>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: bad number", ln + 1))?;
        if label_col >= vals.len() {
            return Err(anyhow!("line {}: label column {} out of range", ln + 1, label_col));
        }
        let w = vals.len() - 1;
        if *width.get_or_insert(w) != w {
            return Err(anyhow!("line {}: ragged row", ln + 1));
        }
        labels.push(vals[label_col]);
        feats.extend(vals.iter().enumerate().filter(|(j, _)| *j != label_col).map(|(_, v)| *v));
        n += 1;
    }
    Ok(Dataset::new(Matrix::from_vec(feats, n, width.unwrap_or(0)), labels))
}

pub fn read_libsvm(path: &Path, dim: usize) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).context("reading libsvm file")?;
    parse_libsvm(&text, dim)
}

/// Stream a LIBSVM file into a [`SparseDataset`] line-by-line: resident
/// memory is the growing CSR triplet plus one line buffer — never the
/// whole text, never an n×d dense matrix.  This is the ingest path for
/// `--sparse` training.
pub fn read_libsvm_csr(path: &Path, dim: usize) -> Result<SparseDataset> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    read_libsvm_buffered_csr(std::io::BufReader::new(f), dim)
}

/// [`read_libsvm_csr`] over any buffered reader.
pub fn read_libsvm_buffered_csr<R: BufRead>(r: R, dim: usize) -> Result<SparseDataset> {
    let mut p = LibsvmParser::new(dim);
    let mut line = String::new();
    let mut r = r;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        p.push_line(&line)?;
    }
    Ok(p.finish())
}

pub fn read_csv(path: &Path, label_col: usize) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).context("reading csv file")?;
    parse_csv(&text, label_col)
}

/// Write LIBSVM format (dense; zeros skipped like the original tools).
pub fn write_libsvm(path: &Path, d: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..d.len() {
        write!(w, "{}", d.y[i])?;
        for (j, &v) in d.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write CSV, label first.
pub fn write_csv(path: &Path, d: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..d.len() {
        write!(w, "{}", d.y[i])?;
        for &v in d.x.row(i) {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Stream a libsvm file line-by-line (for large files): genuinely
/// bounded memory — one line buffer plus the CSR triplet under
/// construction (the seed version slurped the whole text with
/// `read_to_string` despite this doc line), densified only at the end.
/// Parity with [`parse_libsvm`] is tested below; callers that can stay
/// sparse should use [`read_libsvm_buffered_csr`] and skip the
/// densification entirely.
pub fn read_libsvm_buffered<R: BufRead>(r: R, dim: usize) -> Result<Dataset> {
    Ok(read_libsvm_buffered_csr(r, dim)?.to_dense())
}

/// Write a [`SparseDataset`] in LIBSVM format (stored entries only).
pub fn write_libsvm_csr(path: &Path, d: &SparseDataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..d.len() {
        write!(w, "{}", d.y[i])?;
        let (idx, val) = d.x.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn libsvm_roundtrip_via_text() {
        let d = parse_libsvm("+1 1:0.5 3:2\n-1 2:1\n", 0).unwrap();
        assert_eq!(d.dim(), 3);
        assert_eq!(d.x.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(d.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn libsvm_rejects_zero_index() {
        assert!(parse_libsvm("1 0:1\n", 0).is_err());
    }

    #[test]
    fn libsvm_rejects_duplicate_index_with_line_number() {
        let err = parse_libsvm("1 1:0.5\n-1 2:1 3:4 2:9\n", 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate index 2"), "{msg}");
        // unsorted but distinct indices are fine (sorted internally)
        let d = parse_libsvm("1 3:3 1:1\n", 0).unwrap();
        assert_eq!(d.x.row(0), &[1.0, 0.0, 3.0]);
    }

    #[test]
    fn libsvm_rejects_index_past_declared_dim() {
        let err = parse_libsvm("1 2:1\n1 5:2\n", 3).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("exceeds declared dim 3"), "{msg}");
        // dim == 0 still infers
        assert_eq!(parse_libsvm("1 5:2\n", 0).unwrap().dim(), 5);
        // declared dim wider than the data pads
        assert_eq!(parse_libsvm("1 2:1\n", 6).unwrap().dim(), 6);
    }

    #[test]
    fn buffered_reader_parity_with_parse() {
        let text = "+1 1:0.5 3:2\n\n# comment\n-1 2:1\n3 1:-1 4:0.25\n";
        let a = parse_libsvm(text, 0).unwrap();
        let b = read_libsvm_buffered(std::io::Cursor::new(text.as_bytes()), 0).unwrap();
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
        // and the CSR path densifies to the same bytes
        let c = parse_libsvm_csr(text, 0).unwrap();
        assert_eq!(c.to_dense().x.as_slice(), a.x.as_slice());
        assert_eq!(c.dim(), 4);
        assert_eq!(c.x.nnz(), 5);
    }

    #[test]
    fn csr_roundtrip_via_file() {
        let dir = std::env::temp_dir().join(format!("liquidsvm-io-csr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = parse_libsvm_csr("1 2:0.5 9:1\n-1 4:2\n", 0).unwrap();
        let p = dir.join("d.libsvm");
        write_libsvm_csr(&p, &d).unwrap();
        let back = read_libsvm_csr(&p, d.dim()).unwrap();
        assert_eq!(back.y, d.y);
        assert_eq!(back.x, d.x);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn explicit_zero_values_are_dropped() {
        let d = parse_libsvm_csr("1 1:0 3:2\n", 0).unwrap();
        assert_eq!(d.x.nnz(), 1);
        assert_eq!(d.dim(), 3);
        assert_eq!(d.to_dense().x.row(0), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn csv_label_first() {
        let d = parse_csv("1,0.5,2\n-1, 1.5, 3\n", 0).unwrap();
        assert_eq!(d.dim(), 2);
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.x.row(1), &[1.5, 3.0]);
    }

    #[test]
    fn csv_ragged_errors() {
        assert!(parse_csv("1,2\n1,2,3\n", 0).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("liquidsvm-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = parse_csv("1,0.5\n-1,1.5\n", 0).unwrap();
        let p = dir.join("d.libsvm");
        write_libsvm(&p, &d).unwrap();
        let back = read_libsvm(&p, d.dim()).unwrap();
        assert_eq!(back.y, d.y);
        assert_eq!(back.x.as_slice(), d.x.as_slice());
        let pc = dir.join("d.csv");
        write_csv(&pc, &d).unwrap();
        let back = read_csv(&pc, 0).unwrap();
        assert_eq!(back.x.as_slice(), d.x.as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }
}
