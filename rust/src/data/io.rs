//! Dataset file formats: LIBSVM sparse text and CSV (the two formats
//! liquidSVM reads, Table 5 "Data Format" column), plus writers — the
//! writers are also what the SVMlight-style `disk_wrapper` baseline
//! uses to pay its per-grid-point disk penalty honestly.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::dataset::Dataset;
use super::matrix::Matrix;

/// Parse LIBSVM format: `label idx:val idx:val ...` (1-based indices).
/// `dim` may be 0 to infer the max index.
pub fn parse_libsvm(text: &str, dim: usize) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_idx = dim;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let lab: f32 = parts
            .next()
            .ok_or_else(|| anyhow!("line {}: empty", ln + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", ln + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("line {}: token `{tok}` not idx:val", ln + 1))?;
            let i: usize = i.parse().with_context(|| format!("line {}: bad index", ln + 1))?;
            if i == 0 {
                return Err(anyhow!("line {}: libsvm indices are 1-based", ln + 1));
            }
            let v: f32 = v.parse().with_context(|| format!("line {}: bad value", ln + 1))?;
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        labels.push(lab);
        rows.push(feats);
    }
    let mut x = Matrix::zeros(rows.len(), max_idx);
    for (r, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x.set(r, j, v);
        }
    }
    Ok(Dataset::new(x, labels))
}

/// Parse CSV with the label in the given column (no header).
pub fn parse_csv(text: &str, label_col: usize) -> Result<Dataset> {
    let mut feats: Vec<f32> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut width: Option<usize> = None;
    let mut n = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split(',')
            .map(|t| t.trim().parse::<f32>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: bad number", ln + 1))?;
        if label_col >= vals.len() {
            return Err(anyhow!("line {}: label column {} out of range", ln + 1, label_col));
        }
        let w = vals.len() - 1;
        if *width.get_or_insert(w) != w {
            return Err(anyhow!("line {}: ragged row", ln + 1));
        }
        labels.push(vals[label_col]);
        feats.extend(vals.iter().enumerate().filter(|(j, _)| *j != label_col).map(|(_, v)| *v));
        n += 1;
    }
    Ok(Dataset::new(Matrix::from_vec(feats, n, width.unwrap_or(0)), labels))
}

pub fn read_libsvm(path: &Path, dim: usize) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).context("reading libsvm file")?;
    parse_libsvm(&text, dim)
}

pub fn read_csv(path: &Path, label_col: usize) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).context("reading csv file")?;
    parse_csv(&text, label_col)
}

/// Write LIBSVM format (dense; zeros skipped like the original tools).
pub fn write_libsvm(path: &Path, d: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..d.len() {
        write!(w, "{}", d.y[i])?;
        for (j, &v) in d.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write CSV, label first.
pub fn write_csv(path: &Path, d: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..d.len() {
        write!(w, "{}", d.y[i])?;
        for &v in d.x.row(i) {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Stream a libsvm file line-by-line (for large files).
pub fn read_libsvm_buffered<R: BufRead>(mut r: R, dim: usize) -> Result<Dataset> {
    let mut text = String::new();
    r.read_to_string(&mut text)?;
    parse_libsvm(&text, dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn libsvm_roundtrip_via_text() {
        let d = parse_libsvm("+1 1:0.5 3:2\n-1 2:1\n", 0).unwrap();
        assert_eq!(d.dim(), 3);
        assert_eq!(d.x.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(d.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn libsvm_rejects_zero_index() {
        assert!(parse_libsvm("1 0:1\n", 0).is_err());
    }

    #[test]
    fn csv_label_first() {
        let d = parse_csv("1,0.5,2\n-1, 1.5, 3\n", 0).unwrap();
        assert_eq!(d.dim(), 2);
        assert_eq!(d.y, vec![1.0, -1.0]);
        assert_eq!(d.x.row(1), &[1.5, 3.0]);
    }

    #[test]
    fn csv_ragged_errors() {
        assert!(parse_csv("1,2\n1,2,3\n", 0).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("liquidsvm-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = parse_csv("1,0.5\n-1,1.5\n", 0).unwrap();
        let p = dir.join("d.libsvm");
        write_libsvm(&p, &d).unwrap();
        let back = read_libsvm(&p, d.dim()).unwrap();
        assert_eq!(back.y, d.y);
        assert_eq!(back.x.as_slice(), d.x.as_slice());
        let pc = dir.join("d.csv");
        write_csv(&pc, &d).unwrap();
        let back = read_csv(&pc, 0).unwrap();
        assert_eq!(back.x.as_slice(), d.x.as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }
}
