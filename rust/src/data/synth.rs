//! Synthetic stand-ins for the paper's benchmark datasets.
//!
//! The paper evaluates on UCI/LIBSVM sets (BANK-MARKETING, COD-RNA,
//! COVTYPE, THYROID-ANN, IJCNN1, WEBSPAM, SUSY, HEPMASS, HIGGS, ECBDL,
//! OPTDIGIT, LANDSAT, PENDIGIT) that are not available in this image.
//! Each generator below matches its dataset's *shape parameters* —
//! dimension, number of classes, class balance — and sets an
//! approximate Bayes-error floor via label noise, with boundary
//! complexity (Gaussian clusters per class) controlling how quickly
//! the error approaches that floor as n grows.  All comparisons in the
//! benchmarks are *relative* between methods on identical data, which
//! is what the paper's tables measure (see DESIGN.md §Substitutions).
//!
//! Deterministic: same (name, n, seed) → identical bytes.

use super::csr::{CsrMatrix, SparseDataset};
use super::dataset::{Dataset, TrainTest};
use super::rng::Rng;
use super::matrix::Matrix;

/// Specification of a Gaussian-mixture classification problem.
#[derive(Clone, Debug)]
pub struct GmmSpec {
    pub name: &'static str,
    pub dim: usize,
    pub classes: usize,
    /// sampling weight per class (normalized internally)
    pub class_weights: Vec<f32>,
    /// clusters per class — more clusters = more complex boundary
    pub clusters_per_class: usize,
    /// cluster standard deviation (overlap knob)
    pub spread: f32,
    /// label-flip probability = approximate Bayes error floor
    pub label_noise: f32,
}

fn sample_gauss(rng: &mut Rng, dim: usize, center: &[f32], spread: f32, out: &mut [f32]) {
    for j in 0..dim {
        out[j] = center[j] + spread * rng.normal();
    }
}

impl GmmSpec {
    /// Draw `n` labeled samples.  Binary problems are labeled ±1,
    /// multiclass 0..k-1 (matching liquidSVM's conventions).
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x5eed_11d5 ^ fxhash(self.name));
        // class cluster centers drawn once from a wider Gaussian, with a
        // deterministic per-class offset so classes are separable up to
        // the intended overlap.
        let mut centers: Vec<Vec<Vec<f32>>> = Vec::with_capacity(self.classes);
        let mut crng = Rng::new(seed.wrapping_mul(0x9e3779b97f4a7c15) ^ fxhash(self.name));
        for c in 0..self.classes {
            let mut class_centers = Vec::with_capacity(self.clusters_per_class);
            for _ in 0..self.clusters_per_class {
                let mut ctr = vec![0.0f32; self.dim];
                sample_gauss(&mut crng, self.dim, &vec![0.0; self.dim], 1.0, &mut ctr);
                // push class c along a rotating direction pattern so no
                // single linear projection separates the classes
                for (j, v) in ctr.iter_mut().enumerate() {
                    let phase = (c as f32 + 1.0) * (j as f32 + 1.0) * 0.7;
                    *v += phase.sin() * 1.2;
                }
                class_centers.push(ctr);
            }
            centers.push(class_centers);
        }

        let wsum: f32 = self.class_weights.iter().sum();
        let mut x = Matrix::zeros(n, self.dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            // class by weight
            let mut t = rng.range(0.0, wsum);
            let mut cls = self.classes - 1;
            for (c, &w) in self.class_weights.iter().enumerate() {
                if t < w {
                    cls = c;
                    break;
                }
                t -= w;
            }
            let k = rng.below(self.clusters_per_class);
            let center = centers[cls][k].clone();
            sample_gauss(&mut rng, self.dim, &center, self.spread, x.row_mut(i));
            // label noise = error floor
            let observed = if rng.uniform() < self.label_noise {
                let mut other = rng.below(self.classes);
                if other == cls {
                    other = (other + 1) % self.classes;
                }
                other
            } else {
                cls
            };
            y.push(encode_label(observed, self.classes));
        }
        Dataset::new(x, y)
    }
}

/// ±1 for binary, 0..k-1 as floats otherwise.
fn encode_label(c: usize, classes: usize) -> f32 {
    if classes == 2 {
        if c == 0 { -1.0 } else { 1.0 }
    } else {
        c as f32
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Dataset catalogue: paper dataset name -> generator spec.
/// Dim / #classes / balance follow the paper's tables; noise floors are
/// tuned near the best errors the paper reports.
pub fn spec(name: &str) -> Option<GmmSpec> {
    let s = match name {
        "bank-marketing" => GmmSpec {
            name: "bank-marketing", dim: 16, classes: 2,
            class_weights: vec![0.884, 0.116], clusters_per_class: 6,
            spread: 1.1, label_noise: 0.095,
        },
        "cod-rna" => GmmSpec {
            name: "cod-rna", dim: 8, classes: 2,
            class_weights: vec![0.667, 0.333], clusters_per_class: 4,
            spread: 0.8, label_noise: 0.035,
        },
        "covtype" => GmmSpec {
            name: "covtype", dim: 54, classes: 2,
            class_weights: vec![0.51, 0.49], clusters_per_class: 48,
            spread: 1.0, label_noise: 0.03,
        },
        "thyroid-ann" => GmmSpec {
            name: "thyroid-ann", dim: 21, classes: 2,
            class_weights: vec![0.92, 0.08], clusters_per_class: 4,
            spread: 0.9, label_noise: 0.04,
        },
        "ijcnn1" => GmmSpec {
            name: "ijcnn1", dim: 22, classes: 2,
            class_weights: vec![0.90, 0.10], clusters_per_class: 10,
            spread: 0.7, label_noise: 0.012,
        },
        "webspam" => GmmSpec {
            name: "webspam", dim: 254, classes: 2,
            class_weights: vec![0.61, 0.39], clusters_per_class: 12,
            spread: 0.9, label_noise: 0.009,
        },
        "susy" => GmmSpec {
            name: "susy", dim: 18, classes: 2,
            class_weights: vec![0.54, 0.46], clusters_per_class: 8,
            spread: 1.6, label_noise: 0.19,
        },
        "hepmass" => GmmSpec {
            name: "hepmass", dim: 28, classes: 2,
            class_weights: vec![0.5, 0.5], clusters_per_class: 8,
            spread: 1.4, label_noise: 0.13,
        },
        "higgs" => GmmSpec {
            name: "higgs", dim: 28, classes: 2,
            class_weights: vec![0.53, 0.47], clusters_per_class: 10,
            spread: 2.0, label_noise: 0.28,
        },
        "ecbdl" => GmmSpec {
            name: "ecbdl", dim: 631, classes: 2,
            class_weights: vec![0.98, 0.02], clusters_per_class: 6,
            spread: 1.0, label_noise: 0.015,
        },
        "optdigit" => GmmSpec {
            name: "optdigit", dim: 64, classes: 10,
            class_weights: vec![1.0; 10], clusters_per_class: 3,
            spread: 0.75, label_noise: 0.008,
        },
        "landsat" => GmmSpec {
            name: "landsat", dim: 36, classes: 6,
            class_weights: vec![1.0; 6], clusters_per_class: 4,
            spread: 1.15, label_noise: 0.06,
        },
        "pendigit" => GmmSpec {
            name: "pendigit", dim: 16, classes: 10,
            class_weights: vec![1.0; 10], clusters_per_class: 3,
            spread: 0.8, label_noise: 0.01,
        },
        _ => return None,
    };
    Some(s)
}

/// Generate a named paper-dataset stand-in.
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    spec(name).map(|s| s.generate(n, seed))
}

/// All catalogue names (for CLI listing / sweeps).
pub fn names() -> Vec<&'static str> {
    vec![
        "bank-marketing", "cod-rna", "covtype", "thyroid-ann", "ijcnn1",
        "webspam", "susy", "hepmass", "higgs", "ecbdl", "optdigit",
        "landsat", "pendigit",
    ]
}

/// The banana-mc demo set used throughout liquidSVM's docs: 2-d,
/// 4 classes — two interleaved banana arcs plus two Gaussian blobs.
pub fn banana_mc(n_train: usize, n_test: usize, seed: u64) -> TrainTest {
    fn gen(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = rng.below(4);
            let (px, py) = match cls {
                0 | 1 => {
                    // banana arcs, mirrored
                    let t: f32 = rng.range(-1.2, 1.2);
                    let r = 2.0f32;
                    let sign = if cls == 0 { 1.0 } else { -1.0 };
                    let cx = sign * (r * t.sin());
                    let cy = sign * (r * t.cos() - 1.0);
                    (cx + rng.range(-0.35, 0.35), cy + rng.range(-0.35, 0.35))
                }
                2 => (2.6 + rng.range(-0.4, 0.4), 2.2 + rng.range(-0.4, 0.4)),
                _ => (-2.6 + rng.range(-0.4, 0.4), -2.2 + rng.range(-0.4, 0.4)),
            };
            x.set(i, 0, px);
            x.set(i, 1, py);
            y.push(cls as f32);
        }
        Dataset::new(x, y)
    }
    TrainTest { train: gen(n_train, seed), test: gen(n_test, seed ^ 0xdead) }
}

/// Binary banana (for the binary quickstart paths).
pub fn banana_binary(n: usize, seed: u64) -> Dataset {
    let tt = banana_mc(n, 0, seed);
    let mut d = tt.train;
    for v in &mut d.y {
        *v = if *v < 2.0 { -1.0 } else { 1.0 };
    }
    d
}

/// Synthetic high-dimensional sparse binary set — the stand-in for the
/// rcv1/url/webspam-class style LIBSVM benchmarks (d in the tens of
/// thousands, sub-percent density) that the sparse data plane exists
/// for.  Each row draws `max(1, round(dim·density))` distinct indices
/// with values in [-1, 1]; the label is the sign of a fixed sparse
/// hyperplane (sign pattern hashed from the column index), so the
/// problem is learnable at any dimension.  Deterministic: same
/// `(n, dim, density, seed)` → identical bytes, and the CSR bytes are
/// `O(n·nnz)` — the generator never allocates an n×d matrix.
pub fn sparse_binary(n: usize, dim: usize, density: f32, seed: u64) -> SparseDataset {
    assert!(dim > 0 && density > 0.0);
    let nnz_row = ((dim as f32 * density).round() as usize).clamp(1, dim);
    let mut rng = Rng::new(seed ^ 0x5aa7_5e3d_0bad_cafe);
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices: Vec<u32> = Vec::with_capacity(n * nnz_row);
    let mut values: Vec<f32> = Vec::with_capacity(n * nnz_row);
    let mut y = Vec::with_capacity(n);
    indptr.push(0);
    let mut row: Vec<u32> = Vec::with_capacity(nnz_row);
    for _ in 0..n {
        row.clear();
        while row.len() < nnz_row {
            let j = rng.below(dim) as u32;
            row.push(j);
            if row.len() == nnz_row {
                row.sort_unstable();
                row.dedup();
            }
        }
        let mut score = 0.0f32;
        for &j in row.iter() {
            let mut v = rng.range(-1.0, 1.0);
            if v == 0.0 {
                // CSR stores no explicit zeros; nudge the (measure-zero
                // but reachable) exact hit
                v = 0.5;
            }
            indices.push(j);
            values.push(v);
            score += v * plane_sign(j);
        }
        indptr.push(indices.len());
        y.push(if score >= 0.0 { 1.0 } else { -1.0 });
    }
    SparseDataset::new(CsrMatrix::from_parts(indptr, indices, values, dim), y)
}

/// Fixed ±1 hyperplane weight for column `j` (splitmix-style hash).
fn plane_sign(j: u32) -> f32 {
    let mut z = (j as u64).wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    if z & 1 == 0 { 1.0 } else { -1.0 }
}

/// 1-d heteroscedastic regression set for quantile/expectile scenarios:
/// y = sinc-like trend + noise whose scale grows with x, so the true
/// conditional quantile curves fan out (visible in the example output).
pub fn sinc_hetero(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::zeros(n, 1);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let t: f32 = rng.range(-3.0, 3.0);
        let trend = if t.abs() < 1e-6 { 1.0 } else { (std::f32::consts::PI * t).sin() / (std::f32::consts::PI * t) };
        let scale = 0.1 + 0.15 * (t + 3.0) / 6.0;
        x.set(i, 0, t);
        y.push(trend + scale * rng.normal());
    }
    Dataset::new(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = by_name("cod-rna", 200, 3).unwrap();
        let b = by_name("cod-rna", 200, 3).unwrap();
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn catalogue_shapes_match_paper() {
        for name in names() {
            let s = spec(name).unwrap();
            let d = s.generate(64, 1);
            assert_eq!(d.dim(), s.dim, "{name}");
            assert!(d.classes().len() <= s.classes);
        }
    }

    #[test]
    fn binary_labels_are_pm1() {
        let d = by_name("covtype", 500, 2).unwrap();
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn multiclass_labels_in_range() {
        let d = by_name("optdigit", 800, 2).unwrap();
        for &v in &d.y {
            assert!((0.0..10.0).contains(&v) && v.fract() == 0.0);
        }
        assert_eq!(d.classes().len(), 10);
    }

    #[test]
    fn class_imbalance_respected() {
        let d = by_name("bank-marketing", 8000, 5).unwrap();
        let pos = d.y.iter().filter(|&&v| v == 1.0).count() as f32 / 8000.0;
        assert!((0.08..0.22).contains(&pos), "positive rate {pos}");
    }

    #[test]
    fn banana_mc_has_four_classes() {
        let tt = banana_mc(400, 100, 7);
        assert_eq!(tt.train.classes().len(), 4);
        assert_eq!(tt.train.dim(), 2);
        assert_eq!(tt.test.len(), 100);
    }

    #[test]
    fn sparse_binary_shape_and_determinism() {
        let a = sparse_binary(50, 5000, 0.002, 9);
        let b = sparse_binary(50, 5000, 0.002, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.dim(), 5000);
        assert_eq!(a.len(), 50);
        // ~10 nnz per row, never more
        assert!(a.x.nnz() <= 50 * 10);
        assert!(a.x.nnz() >= 50); // at least one per row
        assert!(a.y.iter().all(|&v| v == 1.0 || v == -1.0));
        // both classes present
        assert!(a.classes().len() == 2);
    }

    #[test]
    fn sinc_hetero_regression_targets() {
        let d = sinc_hetero(300, 11);
        assert_eq!(d.dim(), 1);
        // targets are continuous, not just labels
        assert!(d.classes().len() > 50);
    }
}
