//! Deterministic PRNG (SplitMix64) with the handful of distributions
//! the library needs.  Hand-rolled because this image's crate registry
//! carries no `rand`; SplitMix64 passes BigCrush and is more than
//! adequate for data synthesis, fold shuffles, and cell seeding.

/// SplitMix64 PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high-quality bits -> f32 mantissa
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style bounded reduction on 64-bit
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: only the first k positions need fixing
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }
}
