//! Compressed-sparse-row sample storage — the sparse half of the data
//! plane (see DESIGN.md §Data-plane).
//!
//! The paper's large-scale benchmarks are LIBSVM-format *sparse* sets
//! (rcv1/url/webspam-class style: d in the tens of thousands, a few
//! hundred non-zeros per row).  Densifying such data costs `n·d` floats
//! before a single kernel value is computed; `CsrMatrix` stores the
//! `indptr/indices/values` triplet instead, so resident bytes scale
//! with `nnz`, not `n·d`.
//!
//! Bit-identity contract: every derived quantity (row norms, dot
//! products, squared distances) is computed by walking stored entries
//! in increasing column order, which produces the same f32 bits as the
//! dense loops walking all `d` columns — the skipped terms are exact
//! `±0.0` contributions that cannot change an IEEE accumulator that is
//! never `-0.0`.  The sparse kernels in `kernel::backend` build on this
//! (property-tested in `tests/property_tests.rs`).

use super::matrix::Matrix;

/// Compressed-sparse-row `f32` matrix.  Column indices are `u32`
/// (halving index memory vs `usize`; d is bounded by `u32::MAX`) and
/// strictly increasing within each row — the invariant every sparse
/// kernel's merge-join relies on.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// row `i` occupies `indices[indptr[i]..indptr[i+1]]`
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    cols: usize,
}

impl CsrMatrix {
    /// Empty matrix with no rows.
    pub fn empty(cols: usize) -> CsrMatrix {
        CsrMatrix { indptr: vec![0], indices: Vec::new(), values: Vec::new(), cols }
    }

    /// Build from raw parts.  Panics when the triplet is inconsistent
    /// or a row's indices are not strictly increasing and `< cols`.
    pub fn from_parts(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
        cols: usize,
    ) -> CsrMatrix {
        assert!(!indptr.is_empty() && indptr[0] == 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr/indices mismatch");
        assert_eq!(indices.len(), values.len(), "indices/values mismatch");
        for w in indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be non-decreasing");
            for k in w[0] + 1..w[1] {
                assert!(indices[k - 1] < indices[k], "row indices must strictly increase");
            }
        }
        assert!(indices.iter().all(|&j| (j as usize) < cols.max(1)), "index out of range");
        CsrMatrix { indptr, indices, values, cols }
    }

    /// Convert a dense matrix, dropping exact zeros.
    pub fn from_dense(x: &Matrix) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(x.rows() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..x.rows() {
            for (j, &v) in x.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix { indptr, indices, values, cols: x.cols() }
    }

    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Row `i` as parallel (indices, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Raw parts view (persistence).
    pub fn parts(&self) -> (&[usize], &[u32], &[f32]) {
        (&self.indptr, &self.indices, &self.values)
    }

    /// Densify into an `n × cols` matrix.
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows(), self.cols);
        for i in 0..self.rows() {
            let (idx, val) = self.row(i);
            let row = out.row_mut(i);
            for (&j, &v) in idx.iter().zip(val) {
                row[j as usize] = v;
            }
        }
        out
    }

    /// Densify row `i` into `out` (caller-provided scratch of length
    /// `cols`, zeroed here) — the per-row densification boundary used
    /// by geometric routers and dense-expansion predict tiles.
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let (idx, val) = self.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            out[j as usize] = v;
        }
    }

    /// New matrix containing the given rows (in order, repeats allowed).
    pub fn select_rows(&self, sel: &[usize]) -> CsrMatrix {
        let nnz: usize = sel.iter().map(|&i| self.indptr[i + 1] - self.indptr[i]).sum();
        let mut indptr = Vec::with_capacity(sel.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for &i in sel {
            let (idx, val) = self.row(i);
            indices.extend_from_slice(idx);
            values.extend_from_slice(val);
            indptr.push(indices.len());
        }
        CsrMatrix { indptr, indices, values, cols: self.cols }
    }

    /// Squared Euclidean norm of every row — bit-identical to
    /// [`Matrix::row_sq_norms`] of the densified matrix (skipped zeros
    /// contribute exact `+0.0` to the in-order accumulation).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows())
            .map(|i| self.row(i).1.iter().map(|v| v * v).sum())
            .collect()
    }

    /// Resident bytes of the triplet storage (the number the dense
    /// path's `rows · cols · 4` is compared against in
    /// `benches/table_sparse.rs`).
    pub fn bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f32>()
    }
}

/// A labeled sparse sample set — the CSR twin of
/// [`super::dataset::Dataset`], produced by the streaming LIBSVM
/// reader (`data::io::read_libsvm_csr`).
#[derive(Clone, Debug)]
pub struct SparseDataset {
    pub x: CsrMatrix,
    pub y: Vec<f32>,
}

impl SparseDataset {
    pub fn new(x: CsrMatrix, y: Vec<f32>) -> SparseDataset {
        assert_eq!(x.rows(), y.len(), "label/sample count mismatch");
        SparseDataset { x, y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Subset by row indices (order preserved).
    pub fn subset(&self, idx: &[usize]) -> SparseDataset {
        SparseDataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Distinct labels in sorted order.
    pub fn classes(&self) -> Vec<f32> {
        super::dataset::distinct_labels(&self.y)
    }

    /// Densify into a [`super::dataset::Dataset`] (tests/benches; the
    /// training path never does this).
    pub fn to_dense(&self) -> super::dataset::Dataset {
        super::dataset::Dataset::new(self.x.to_dense(), self.y.clone())
    }

    /// Deterministic split into train/test by shuffled indices —
    /// mirrors [`super::dataset::Dataset::split`].
    pub fn split(&self, n_train: usize, seed: u64) -> (SparseDataset, SparseDataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = super::rng::Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = n_train.min(self.len());
        (self.subset(&idx[..n_train]), self.subset(&idx[n_train..]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> CsrMatrix {
        // [[0, 1.5, 0, 2], [0, 0, 0, 0], [3, 0, -1, 0]]
        CsrMatrix::from_parts(
            vec![0, 2, 2, 4],
            vec![1, 3, 0, 2],
            vec![1.5, 2.0, 3.0, -1.0],
            4,
        )
    }

    #[test]
    fn roundtrip_dense() {
        let c = toy();
        assert_eq!((c.rows(), c.cols(), c.nnz()), (3, 4, 4));
        let d = c.to_dense();
        assert_eq!(d.row(0), &[0.0, 1.5, 0.0, 2.0]);
        assert_eq!(d.row(1), &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(d.row(2), &[3.0, 0.0, -1.0, 0.0]);
        assert_eq!(CsrMatrix::from_dense(&d), c);
    }

    #[test]
    fn norms_match_dense_bitwise() {
        let c = toy();
        let dense = c.to_dense().row_sq_norms();
        let sparse = c.row_sq_norms();
        for (a, b) in dense.iter().zip(&sparse) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn select_rows_orders_and_repeats() {
        let c = toy();
        let s = c.select_rows(&[2, 0, 2]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.to_dense().row(0), c.to_dense().row(2));
        assert_eq!(s.to_dense().row(1), c.to_dense().row(0));
        assert_eq!(s.to_dense().row(2), c.to_dense().row(2));
    }

    #[test]
    fn densify_row_into_zeroes_scratch() {
        let c = toy();
        let mut scratch = vec![9.0f32; 4];
        c.densify_row_into(1, &mut scratch);
        assert_eq!(scratch, vec![0.0; 4]);
        c.densify_row_into(0, &mut scratch);
        assert_eq!(scratch, vec![0.0, 1.5, 0.0, 2.0]);
    }

    #[test]
    fn bytes_track_nnz_not_area() {
        let c = toy();
        assert!(c.bytes() < 3 * 1000 * 4);
        let wide = CsrMatrix::from_parts(vec![0, 1], vec![999], vec![1.0], 1000);
        assert!(wide.bytes() < 1000);
    }

    #[test]
    #[should_panic]
    fn unsorted_row_rejected() {
        CsrMatrix::from_parts(vec![0, 2], vec![3, 1], vec![1.0, 2.0], 4);
    }

    #[test]
    fn sparse_dataset_subset_split() {
        let d = SparseDataset::new(toy(), vec![1.0, -1.0, 1.0]);
        assert_eq!(d.classes(), vec![-1.0, 1.0]);
        let s = d.subset(&[2, 0]);
        assert_eq!(s.y, vec![1.0, 1.0]);
        let (tr, te) = d.split(2, 7);
        assert_eq!(tr.len() + te.len(), 3);
    }
}
