//! Dense row-major `f32` matrix — the sample container used everywhere.
//!
//! liquidSVM stores samples as contiguous rows so the Gram hot loop
//! streams cache lines; we keep the same layout (and it is also the
//! layout the XLA artifacts expect, so marshalling is a straight copy).

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer (must have `rows * cols` elements).
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { data, rows, cols }
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { data, rows: r, cols: c }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Whole buffer, row-major.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// New matrix containing the given rows (in order, repeats allowed).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        Matrix::from_vec(out, idx.len(), self.cols)
    }

    /// Zero-pad to at least (rows, cols) — used to fit artifact buckets.
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols);
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Squared Euclidean norm of every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|v| v * v).sum())
            .collect()
    }

    /// Append another matrix's rows (same width).
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix::from_vec(data, self.rows + other.rows, self.cols)
    }
}

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn select_rows_orders_and_repeats() {
        let m = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let s = m.select_rows(&[2, 0, 2]);
        assert_eq!(s.as_slice(), &[3.0, 1.0, 3.0]);
    }

    #[test]
    fn pad_to_keeps_content_zero_fills() {
        let m = Matrix::from_rows(&[&[1.0, 2.0]]);
        let p = m.pad_to(2, 3);
        assert_eq!(p.row(0), &[1.0, 2.0, 0.0]);
        assert_eq!(p.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn sq_norms_and_dist() {
        let m = Matrix::from_rows(&[&[3.0, 4.0], &[0.0, 0.0]]);
        assert_eq!(m.row_sq_norms(), vec![25.0, 0.0]);
        assert_eq!(sq_dist(m.row(0), m.row(1)), 25.0);
    }

    #[test]
    fn vstack() {
        let a = Matrix::from_rows(&[&[1.0]]);
        let b = Matrix::from_rows(&[&[2.0]]);
        assert_eq!(a.vstack(&b).as_slice(), &[1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        Matrix::from_rows(&[&[1.0, 2.0], &[3.0]]);
    }
}
