//! Spatial data decomposition into **cells** (paper §2, Bottou & Vapnik
//! 1992, Thomann et al. 2016): the strategy that makes liquidSVM scale
//! to millions of samples.  Training cost on a cell of size k is
//! O(k²)–O(k³); splitting n samples into n/k cells turns a hopeless
//! O(n²) problem into (n/k)·O(k²) = O(nk) — two orders of magnitude for
//! the paper's mid-size benchmarks (Table 3).
//!
//! Strategies (Appendix C `voronoi=` parameter):
//! * random chunks              — the BudgetedSVM/EnsembleSVM-style baseline
//! * Voronoi partition          — sampled centers, nearest-center cells
//! * overlapping Voronoi (=5)   — cells grow into their neighbours;
//!                                prediction still routes to the owner
//! * recursive partition (=6)   — median splits on the widest dimension
//!                                until cells fit `max_size`

use crate::data::dataset::Dataset;
use crate::data::matrix::{sq_dist, Matrix};
use crate::data::rng::Rng;
use crate::data::store::StoreRef;

/// Cell creation strategy.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStrategy {
    /// single cell = no decomposition
    None,
    /// random partition into chunks of ~`size`
    RandomChunks { size: usize },
    /// Voronoi partition from ~n/size sampled centers
    Voronoi { size: usize },
    /// voronoi=5: Voronoi cells enlarged by `overlap`·size of the
    /// nearest foreign samples
    OverlappingVoronoi { size: usize, overlap: f32 },
    /// voronoi=6: recursive median splits until ≤ `max_size`
    RecursiveTree { max_size: usize },
}

/// Routing structure mapping a test point to its cell(s).
#[derive(Clone, Debug)]
pub enum CellRouter {
    /// everything goes to cell 0
    Single,
    /// nearest of the stored centers
    Centers(Matrix),
    /// recursive split tree
    Tree(Box<TreeNode>),
    /// random chunks have no geometry: every cell predicts and the
    /// ensemble averages (stored: number of cells)
    Broadcast(usize),
}

/// Node of the recursive-partition tree.
#[derive(Clone, Debug)]
pub enum TreeNode {
    Leaf { cell: usize },
    Split { dim: usize, threshold: f32, left: Box<TreeNode>, right: Box<TreeNode> },
}

/// A materialized decomposition of a working set.
#[derive(Clone, Debug)]
pub struct CellPartition {
    /// training indices per cell (may overlap for voronoi=5)
    pub cells: Vec<Vec<usize>>,
    pub router: CellRouter,
}

impl CellPartition {
    /// The trivial one-cell partition over `n` samples.
    pub fn single(n: usize) -> CellPartition {
        CellPartition { cells: vec![(0..n).collect()], router: CellRouter::Single }
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Cells a test point should be evaluated in.
    pub fn route(&self, x: &[f32]) -> Vec<usize> {
        match &self.router {
            CellRouter::Single => vec![0],
            CellRouter::Broadcast(k) => (0..*k).collect(),
            CellRouter::Centers(centers) => vec![nearest_center(centers, x)],
            CellRouter::Tree(root) => vec![walk_tree(root, x)],
        }
    }

    /// Group a whole batch of rows by destination cell:
    /// `result[c]` = indices of `x` rows that evaluate in cell `c`
    /// (every row in every cell for broadcast routers).  The batched
    /// predict path feeds each group through one tiled cross-Gram pass
    /// instead of routing row-by-row at the call site.
    pub fn route_batch(&self, x: &Matrix) -> Vec<Vec<usize>> {
        self.route_batch_x(StoreRef::Dense(x))
    }

    /// [`CellPartition::route_batch`] over either sample layout.
    /// Routerless strategies (single cell, broadcast) never touch
    /// features; geometric routers (centers, tree) walk dense rows, so
    /// sparse inputs densify one reusable scratch row at a time — the
    /// routing densification boundary (DESIGN.md §Data-plane).  Sparse
    /// training only builds routerless partitions, so its hot path
    /// never takes the scratch branch.
    pub fn route_batch_x(&self, x: StoreRef) -> Vec<Vec<usize>> {
        let n = x.rows();
        let mut routed: Vec<Vec<usize>> = vec![Vec::new(); self.n_cells()];
        match (&self.router, x) {
            (CellRouter::Single, _) => routed[0] = (0..n).collect(),
            (CellRouter::Broadcast(k), _) => {
                for cell in routed.iter_mut().take(*k) {
                    *cell = (0..n).collect();
                }
            }
            (_, StoreRef::Dense(m)) => {
                for i in 0..n {
                    for c in self.route(m.row(i)) {
                        routed[c].push(i);
                    }
                }
            }
            (_, StoreRef::Sparse(m)) => {
                let mut scratch = vec![0.0f32; m.cols()];
                for i in 0..n {
                    m.densify_row_into(i, &mut scratch);
                    for c in self.route(&scratch) {
                        routed[c].push(i);
                    }
                }
            }
        }
        routed
    }
}

fn nearest_center(centers: &Matrix, x: &[f32]) -> usize {
    let mut best = (0usize, f32::INFINITY);
    for c in 0..centers.rows() {
        let d = sq_dist(centers.row(c), x);
        if d < best.1 {
            best = (c, d);
        }
    }
    best.0
}

fn walk_tree(node: &TreeNode, x: &[f32]) -> usize {
    match node {
        TreeNode::Leaf { cell } => *cell,
        TreeNode::Split { dim, threshold, left, right } => {
            if x[*dim] <= *threshold {
                walk_tree(left, x)
            } else {
                walk_tree(right, x)
            }
        }
    }
}

/// Build the decomposition of `data` for a strategy.
pub fn make_cells(data: &Dataset, strategy: &CellStrategy, seed: u64) -> CellPartition {
    let n = data.len();
    match strategy {
        CellStrategy::None => CellPartition::single(n),
        CellStrategy::RandomChunks { size } => random_chunks(n, *size, seed),
        CellStrategy::Voronoi { size } => {
            let (cells, centers) = voronoi_cells(data, *size, seed);
            CellPartition { cells, router: CellRouter::Centers(centers) }
        }
        CellStrategy::OverlappingVoronoi { size, overlap } => {
            let (mut cells, centers) = voronoi_cells(data, *size, seed);
            // enlarge every cell by its nearest foreign samples
            for c in 0..cells.len() {
                let extra = ((*size as f32) * overlap) as usize;
                if extra == 0 {
                    continue;
                }
                let member: std::collections::HashSet<usize> =
                    cells[c].iter().copied().collect();
                let mut foreign: Vec<(f32, usize)> = (0..n)
                    .filter(|i| !member.contains(i))
                    .map(|i| (sq_dist(centers.row(c), data.x.row(i)), i))
                    .collect();
                foreign.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                cells[c].extend(foreign.iter().take(extra).map(|&(_, i)| i));
            }
            CellPartition { cells, router: CellRouter::Centers(centers) }
        }
        CellStrategy::RecursiveTree { max_size } => {
            let mut cells: Vec<Vec<usize>> = Vec::new();
            let idx: Vec<usize> = (0..n).collect();
            let root = build_tree(data, idx, (*max_size).max(8), &mut cells);
            CellPartition { cells, router: CellRouter::Tree(Box::new(root)) }
        }
    }
}

/// Label/geometry-free random-chunk partition with broadcast routing —
/// the one strategy besides `None` that never reads features, shared
/// by [`make_cells`] and sparse training (which cannot route on dense
/// geometry; see DESIGN.md §Data-plane).
pub fn random_chunks(n: usize, size: usize, seed: u64) -> CellPartition {
    let k = n.div_ceil(size.max(1)).max(1);
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(seed).shuffle(&mut idx);
    let mut cells = vec![Vec::new(); k];
    for (pos, &i) in idx.iter().enumerate() {
        cells[pos % k].push(i);
    }
    CellPartition { cells, router: CellRouter::Broadcast(k) }
}

/// Sample ~n/size centers, assign every sample to the nearest center,
/// drop empty cells (re-indexing the center matrix accordingly).
fn voronoi_cells(data: &Dataset, size: usize, seed: u64) -> (Vec<Vec<usize>>, Matrix) {
    let n = data.len();
    let k = n.div_ceil(size.max(1)).max(1);
    let mut rng = Rng::new(seed ^ 0xce11);
    let picks = rng.sample_indices(n, k.min(n));
    let centers = data.x.select_rows(&picks);
    let mut cells: Vec<Vec<usize>> = vec![Vec::new(); centers.rows()];
    for i in 0..n {
        cells[nearest_center(&centers, data.x.row(i))].push(i);
    }
    // drop empties
    let keep: Vec<usize> = (0..cells.len()).filter(|&c| !cells[c].is_empty()).collect();
    let centers = centers.select_rows(&keep);
    let cells: Vec<Vec<usize>> = keep.into_iter().map(|c| std::mem::take(&mut cells[c])).collect();
    (cells, centers)
}

/// Recursive median split on the dimension with the largest spread.
fn build_tree(
    data: &Dataset,
    idx: Vec<usize>,
    max_size: usize,
    cells: &mut Vec<Vec<usize>>,
) -> TreeNode {
    if idx.len() <= max_size {
        let cell = cells.len();
        cells.push(idx);
        return TreeNode::Leaf { cell };
    }
    let d = data.dim();
    // widest dimension by range
    let mut best = (0usize, f32::NEG_INFINITY);
    for j in 0..d {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &i in &idx {
            let v = data.x.get(i, j);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi - lo > best.1 {
            best = (j, hi - lo);
        }
    }
    let dim = best.0;
    let mut vals: Vec<f32> = idx.iter().map(|&i| data.x.get(i, dim)).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = vals[vals.len() / 2];
    let (mut left, mut right): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| data.x.get(i, dim) <= threshold);
    // degenerate split (all values equal): cut by count instead
    if left.is_empty() || right.is_empty() {
        let mid = idx.len() / 2;
        left = idx[..mid].to_vec();
        right = idx[mid..].to_vec();
    }
    TreeNode::Split {
        dim,
        threshold,
        left: Box::new(build_tree(data, left, max_size, cells)),
        right: Box::new(build_tree(data, right, max_size, cells)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn data(n: usize) -> Dataset {
        synth::by_name("cod-rna", n, 3).unwrap()
    }

    fn assert_partition(cells: &[Vec<usize>], n: usize) {
        let mut seen = vec![0u8; n];
        for cell in cells {
            for &i in cell {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "not a disjoint cover");
    }

    #[test]
    fn none_is_single_cell() {
        let d = data(50);
        let p = make_cells(&d, &CellStrategy::None, 0);
        assert_eq!(p.n_cells(), 1);
        assert_eq!(p.route(d.x.row(3)), vec![0]);
    }

    #[test]
    fn random_chunks_partition_and_broadcast() {
        let d = data(250);
        let p = make_cells(&d, &CellStrategy::RandomChunks { size: 64 }, 1);
        assert_partition(&p.cells, 250);
        assert_eq!(p.n_cells(), 4);
        assert_eq!(p.route(d.x.row(0)).len(), 4);
    }

    #[test]
    fn voronoi_partitions_and_routes_members_home() {
        let d = data(400);
        let p = make_cells(&d, &CellStrategy::Voronoi { size: 100 }, 2);
        assert_partition(&p.cells, 400);
        // every training sample routes to the cell that contains it
        for (c, cell) in p.cells.iter().enumerate() {
            for &i in cell.iter().take(5) {
                assert_eq!(p.route(d.x.row(i)), vec![c]);
            }
        }
    }

    #[test]
    fn overlapping_cells_grow() {
        let d = data(300);
        let base = make_cells(&d, &CellStrategy::Voronoi { size: 100 }, 3);
        let over = make_cells(
            &d,
            &CellStrategy::OverlappingVoronoi { size: 100, overlap: 0.5 },
            3,
        );
        assert_eq!(base.n_cells(), over.n_cells());
        let total_base: usize = base.cells.iter().map(Vec::len).sum();
        let total_over: usize = over.cells.iter().map(Vec::len).sum();
        assert!(total_over > total_base, "{total_over} <= {total_base}");
    }

    #[test]
    fn tree_cells_respect_max_size() {
        let d = data(500);
        let p = make_cells(&d, &CellStrategy::RecursiveTree { max_size: 80 }, 4);
        assert_partition(&p.cells, 500);
        for cell in &p.cells {
            assert!(cell.len() <= 80);
        }
        // routing lands every training point in its own cell
        for (c, cell) in p.cells.iter().enumerate() {
            for &i in cell.iter().take(3) {
                assert_eq!(p.route(d.x.row(i)), vec![c]);
            }
        }
    }

    #[test]
    fn route_batch_groups_rows_like_row_routing() {
        let d = data(200);
        for strategy in [
            CellStrategy::Voronoi { size: 50 },
            CellStrategy::RandomChunks { size: 50 },
            CellStrategy::RecursiveTree { max_size: 60 },
        ] {
            let p = make_cells(&d, &strategy, 6);
            let routed = p.route_batch(&d.x);
            let mut seen = vec![0usize; 200];
            for (c, rows) in routed.iter().enumerate() {
                for &i in rows {
                    assert!(p.route(d.x.row(i)).contains(&c));
                    seen[i] += 1;
                }
            }
            let per_row = if matches!(p.router, CellRouter::Broadcast(_)) { p.n_cells() } else { 1 };
            assert!(seen.iter().all(|&c| c == per_row), "{strategy:?}");
        }
    }

    #[test]
    fn tree_handles_duplicate_points() {
        use crate::data::matrix::Matrix;
        // 40 identical points: median split degenerates, count-split saves it
        let x = Matrix::from_vec(vec![1.0; 40 * 2], 40, 2);
        let d = Dataset::new(x, vec![1.0; 40]);
        let p = make_cells(&d, &CellStrategy::RecursiveTree { max_size: 16 }, 5);
        assert_partition(&p.cells, 40);
    }
}
