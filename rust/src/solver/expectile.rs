//! Expectile plugin (asymmetric least squares), after Farooq &
//! Steinwart (2017) — the solver the paper notes needed "more care".
//!
//! Loss: ℓ_τ(r) = τ r² for r ≥ 0, (1−τ) r² for r < 0 (r = y − f(x)).
//! Stationarity of the offset-free problem gives, with f = Σ β_j k_j,
//!
//!   β_i = C · ℓ'_τ(y_i − f(x_i)),   C = 1/(2λn),  ℓ'_τ(r) = 2τ' r,
//!
//! where τ' = τ on positive residuals and 1−τ on negatives.  Each
//! coordinate therefore has an *exact* piecewise-linear 1-d solve:
//! try both sign cases, keep the consistent one (exactly one is, by
//! monotonicity) — that solve is this plugin's [`Loss::prox`].  The
//! cyclic sweeps, the incremental `f = Kβ` state, shrinking of
//! barely-moving coordinates, and the largest-move stopping rule are
//! the shared engine's ([`Mode::Cyclic`] in [`crate::solver::core`]).

use super::core::{Loss, Mode};
use super::box_c;

/// The expectile [`Loss`] plugin: the piecewise 1-d solve and the
/// primal objective.
pub struct ExpectileLoss<'a> {
    y: &'a [f32],
    lambda: f32,
    tau: f32,
    c: f32,
    scale: f32,
}

impl<'a> ExpectileLoss<'a> {
    pub fn new(y: &'a [f32], lambda: f32, tau: f32) -> ExpectileLoss<'a> {
        assert!((0.0..=1.0).contains(&tau));
        let c = box_c(lambda, y.len());
        let scale = y.iter().map(|v| v.abs()).fold(0.0, f32::max).max(1.0);
        ExpectileLoss { y, lambda, tau, c, scale }
    }
}

impl Loss for ExpectileLoss<'_> {
    #[inline]
    fn n(&self) -> usize {
        self.y.len()
    }

    #[inline]
    fn mode(&self) -> Mode {
        Mode::Cyclic
    }

    #[inline]
    fn bounds(&self, _i: usize) -> (f32, f32) {
        (f32::NEG_INFINITY, f32::INFINITY)
    }

    #[inline]
    fn init_state(&self, _i: usize) -> f32 {
        0.0
    }

    #[inline]
    fn stop_scale(&self) -> f32 {
        self.scale
    }

    /// Exact piecewise 1-d solve: residual with β_i's own contribution
    /// removed is r_i(β_i) = y_i − (f_i − k_ii β_i) − k_ii β_i; case
    /// r ≥ 0 (τ' = τ) gives β = 2Cτ·rest / (1 + 2Cτ·k_ii), consistent
    /// iff r ≥ 0, and symmetrically for the negative branch.
    #[inline]
    fn prox(&self, i: usize, x: f32, state: f32, q: f32) -> f32 {
        let rest = self.y[i] - (state - q * x);
        let mut new_b = x;
        let bp = 2.0 * self.c * self.tau * rest / (1.0 + 2.0 * self.c * self.tau * q);
        if rest - q * bp >= 0.0 {
            new_b = bp;
        } else {
            let tn = 1.0 - self.tau;
            let bn = 2.0 * self.c * tn * rest / (1.0 + 2.0 * self.c * tn * q);
            if rest - q * bn <= 0.0 {
                new_b = bn;
            }
        }
        new_b
    }

    /// Primal objective (for selection comparisons): λ‖f‖² + mean
    /// loss; `state` carries the final `f = Kβ`.
    fn objective(&self, x: &[f32], state: &[f32]) -> f32 {
        let reg: f32 = x.iter().zip(state).map(|(&b, &fi)| b * fi).sum();
        let loss: f32 = self
            .y
            .iter()
            .zip(state)
            .map(|(&yi, &fi)| {
                let r = yi - fi;
                if r >= 0.0 { self.tau * r * r } else { (1.0 - self.tau) * r * r }
            })
            .sum::<f32>()
            / self.y.len() as f32;
        self.lambda * reg + loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::solver::{Solution, SolverKind, SolverParams};

    fn solve(
        k: &mut DenseGram,
        y: &[f32],
        lambda: f32,
        tau: f32,
        params: &SolverParams,
        warm: Option<&[f32]>,
    ) -> Solution {
        crate::solver::solve(SolverKind::Expectile { tau }, k, y, lambda, params, warm)
    }

    fn setup(n: usize, seed: u64) -> (Matrix, Vec<f32>) {
        let d = crate::data::synth::sinc_hetero(n, seed);
        let k = GramBackend::Blocked.gram(&d.x, &d.x, 0.8, KernelKind::Gauss);
        (k, d.y)
    }

    #[test]
    fn half_expectile_equals_ls() {
        // τ = 0.5 reduces to (half-scaled) least squares — compare fits
        let (k, y) = setup(100, 1);
        let p = SolverParams { eps: 1e-5, ..Default::default() };
        let ex = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.5, &p, None).decision_values(&k);
        // ℓ_{0.5}(r) = r²/2, so expectile λ matches LS λ at half weight:
        let ls = crate::solver::solve(
            SolverKind::LeastSquares,
            &mut DenseGram::new(&k),
            &y,
            2e-3,
            &p,
            None,
        )
        .decision_values(&k);
        let diff: f32 =
            ex.iter().zip(&ls).map(|(a, b)| (a - b).abs()).sum::<f32>() / y.len() as f32;
        assert!(diff < 0.05, "mean |expectile - ls| = {diff}");
    }

    #[test]
    fn high_expectile_sits_above_low() {
        let (k, y) = setup(150, 2);
        let p = SolverParams::default();
        let hi = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.9, &p, None).decision_values(&k);
        let lo = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.1, &p, None).decision_values(&k);
        let gap: f32 = hi.iter().zip(&lo).map(|(a, b)| a - b).sum::<f32>() / y.len() as f32;
        assert!(gap > 0.0, "expectile ordering violated, gap {gap}");
    }

    #[test]
    fn stationarity_holds() {
        let (k, y) = setup(60, 3);
        let lambda = 1e-3;
        let tau = 0.7;
        let sol = solve(
            &mut DenseGram::new(&k),
            &y,
            lambda,
            tau,
            &SolverParams { eps: 1e-6, ..Default::default() },
            None,
        );
        let f = sol.decision_values(&k);
        let c = box_c(lambda, y.len());
        for i in 0..y.len() {
            let r = y[i] - f[i];
            let tp = if r >= 0.0 { tau } else { 1.0 - tau };
            let should = 2.0 * c * tp * r;
            assert!(
                (sol.coef[i] - should).abs() < 2e-3 * (1.0 + should.abs()),
                "beta[{i}]={} vs {}",
                sol.coef[i],
                should
            );
        }
    }

    #[test]
    fn warm_start_converges() {
        let (k, y) = setup(80, 4);
        let p = SolverParams::default();
        let a = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.8, &p, None);
        let b = solve(&mut DenseGram::new(&k), &y, 8e-4, 0.8, &p, Some(&a.coef));
        assert!(b.iterations <= a.iterations * 2);
    }

    #[test]
    fn shrinking_preserves_objective() {
        let (k, y) = setup(90, 5);
        let off = SolverParams { shrink_every: 0, ..Default::default() };
        let on = SolverParams { shrink_every: 90, ..Default::default() };
        let a = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.8, &off, None);
        let b = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.8, &on, None);
        assert!(
            (a.objective - b.objective).abs() < 1e-2 * (1.0 + a.objective.abs()),
            "{} vs {}",
            a.objective,
            b.objective
        );
    }
}
