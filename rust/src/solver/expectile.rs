//! Expectile solver (asymmetric least squares), after Farooq &
//! Steinwart (2017) — the solver the paper notes needed "more care".
//!
//! Loss: ℓ_τ(r) = τ r² for r ≥ 0, (1−τ) r² for r < 0 (r = y − f(x)).
//! Stationarity of the offset-free problem gives, with f = Σ β_j k_j,
//!
//!   β_i = C · ℓ'_τ(y_i − f(x_i)),   C = 1/(2λn),  ℓ'_τ(r) = 2τ' r,
//!
//! where τ' = τ on positive residuals and 1−τ on negatives.  Each
//! coordinate therefore has an *exact* piecewise-linear 1-d solve: try
//! both sign cases, keep the consistent one (exactly one is, by
//! monotonicity).  Cyclic sweeps with incremental f-updates until the
//! largest coordinate move falls below eps.

use crate::kernel::plane::GramSource;

use super::{box_c, Solution, SolverParams};

pub fn solve<K: GramSource + ?Sized>(
    k: &mut K,
    y: &[f32],
    lambda: f32,
    tau: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = y.len();
    assert_eq!(k.rows(), n);
    assert!((0.0..=1.0).contains(&tau));
    let c = box_c(lambda, n);

    let mut beta: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    // f_i = (Kβ)_i maintained incrementally
    let mut f = vec![0.0f32; n];
    for j in 0..n {
        if beta[j] != 0.0 {
            let bj = beta[j];
            let krow = k.row(j);
            for i in 0..n {
                f[i] += bj * krow[i];
            }
        }
    }

    let scale: f32 = y.iter().map(|v| v.abs()).fold(0.0, f32::max).max(1.0);
    let mut iters = 0usize;
    let mut sweep_max = f32::INFINITY;
    while sweep_max > params.eps * scale && iters < params.max_iter {
        sweep_max = 0.0;
        for i in 0..n {
            let kii = k.diag(i).max(1e-12);
            // residual with β_i's own contribution removed:
            // r_i(β_i) = y_i − (f_i − k_ii β_i) − k_ii β_i
            let rest = y[i] - (f[i] - kii * beta[i]);
            // case r >= 0 (τ' = τ):   β = 2Cτ (rest − k_ii β)
            //   ⇒ β = 2Cτ·rest / (1 + 2Cτ·k_ii), consistent iff r >= 0
            let mut new_b = beta[i];
            let bp = 2.0 * c * tau * rest / (1.0 + 2.0 * c * tau * kii);
            if rest - kii * bp >= 0.0 {
                new_b = bp;
            } else {
                let tn = 1.0 - tau;
                let bn = 2.0 * c * tn * rest / (1.0 + 2.0 * c * tn * kii);
                if rest - kii * bn <= 0.0 {
                    new_b = bn;
                }
            }
            let d = new_b - beta[i];
            if d != 0.0 {
                beta[i] = new_b;
                let krow = k.row(i);
                for (j, fj) in f.iter_mut().enumerate() {
                    *fj += d * krow[j];
                }
                sweep_max = sweep_max.max(d.abs() * kii);
            }
            iters += 1;
            if iters >= params.max_iter {
                break;
            }
        }
    }

    // primal objective (for selection comparisons): λ‖f‖² + mean loss
    let reg: f32 = beta.iter().zip(&f).map(|(&b, &fi)| b * fi).sum();
    let loss: f32 = y
        .iter()
        .zip(&f)
        .map(|(&yi, &fi)| {
            let r = yi - fi;
            if r >= 0.0 { tau * r * r } else { (1.0 - tau) * r * r }
        })
        .sum::<f32>()
        / n as f32;
    let obj = lambda * reg + loss;
    Solution::from_coef(beta, obj, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};

    fn setup(n: usize, seed: u64) -> (Matrix, Vec<f32>) {
        let d = crate::data::synth::sinc_hetero(n, seed);
        let k = GramBackend::Blocked.gram(&d.x, &d.x, 0.8, KernelKind::Gauss);
        (k, d.y)
    }

    #[test]
    fn half_expectile_equals_ls() {
        // τ = 0.5 reduces to (half-scaled) least squares — compare fits
        let (k, y) = setup(100, 1);
        let p = SolverParams { eps: 1e-5, ..Default::default() };
        let ex = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.5, &p, None).decision_values(&k);
        // ℓ_{0.5}(r) = r²/2, so expectile λ matches LS λ at half weight:
        let ls = crate::solver::ls::solve(&mut DenseGram::new(&k), &y, 2e-3, &p, None).decision_values(&k);
        let diff: f32 =
            ex.iter().zip(&ls).map(|(a, b)| (a - b).abs()).sum::<f32>() / y.len() as f32;
        assert!(diff < 0.05, "mean |expectile - ls| = {diff}");
    }

    #[test]
    fn high_expectile_sits_above_low() {
        let (k, y) = setup(150, 2);
        let p = SolverParams::default();
        let hi = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.9, &p, None).decision_values(&k);
        let lo = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.1, &p, None).decision_values(&k);
        let gap: f32 = hi.iter().zip(&lo).map(|(a, b)| a - b).sum::<f32>() / y.len() as f32;
        assert!(gap > 0.0, "expectile ordering violated, gap {gap}");
    }

    #[test]
    fn stationarity_holds() {
        let (k, y) = setup(60, 3);
        let lambda = 1e-3;
        let tau = 0.7;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, tau, &SolverParams { eps: 1e-6, ..Default::default() }, None);
        let f = sol.decision_values(&k);
        let c = box_c(lambda, y.len());
        for i in 0..y.len() {
            let r = y[i] - f[i];
            let tp = if r >= 0.0 { tau } else { 1.0 - tau };
            let should = 2.0 * c * tp * r;
            assert!(
                (sol.coef[i] - should).abs() < 2e-3 * (1.0 + should.abs()),
                "beta[{i}]={} vs {}",
                sol.coef[i],
                should
            );
        }
    }

    #[test]
    fn warm_start_converges() {
        let (k, y) = setup(80, 4);
        let p = SolverParams::default();
        let a = solve(&mut DenseGram::new(&k), &y, 1e-3, 0.8, &p, None);
        let b = solve(&mut DenseGram::new(&k), &y, 8e-4, 0.8, &p, Some(&a.coef));
        assert!(b.iterations <= a.iterations * 2);
    }
}
