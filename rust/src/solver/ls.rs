//! Least-squares solver (mean regression and the OvA-LS multiclass
//! path used in the GURLS comparison, Table 2).
//!
//! With the representer expansion f = Σ β_j k(x_j, ·), the offset-free
//! regularized LS problem reduces to the linear system
//!
//!   (K + nλ I) β = y,
//!
//! which we solve by conjugate gradients.  CG warm-starts from the
//! previous λ's solution, which is exactly the "straightforward
//! modification" of the hinge machinery the paper describes — matvecs
//! are the cost, and the Gram matrix is the one already cached for the
//! γ at hand.

use crate::kernel::plane::GramSource;

use super::{Solution, SolverParams};

/// y ← (K + nλ I)·x  (fused matvec + shift)
fn matvec_shifted<K: GramSource + ?Sized>(k: &mut K, shift: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    for i in 0..n {
        let row = k.row(i);
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        out[i] = s + shift * x[i];
    }
}

pub fn solve<K: GramSource + ?Sized>(
    k: &mut K,
    y: &[f32],
    lambda: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = y.len();
    assert_eq!(k.rows(), n);
    let shift = lambda * n as f32;

    let mut beta: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    let mut tmp = vec![0.0f32; n];

    // r = y − (K + nλI)β
    matvec_shifted(k, shift, &beta, &mut tmp);
    let mut r: Vec<f32> = y.iter().zip(&tmp).map(|(&a, &b)| a - b).collect();
    let mut p = r.clone();
    let mut rs: f32 = r.iter().map(|v| v * v).sum();
    let y_norm: f32 = y.iter().map(|v| v * v).sum::<f32>().max(1e-12);
    let tol2 = (params.eps * params.eps) * y_norm;

    let mut iters = 0usize;
    let max_cg = params.max_iter.min(4 * n + 50);
    while rs > tol2 && iters < max_cg {
        matvec_shifted(k, shift, &p, &mut tmp);
        let pap: f32 = p.iter().zip(&tmp).map(|(&a, &b)| a * b).sum();
        if pap <= 0.0 {
            break; // K + nλI is SPD; this only trips on round-off
        }
        let a = rs / pap;
        for i in 0..n {
            beta[i] += a * p[i];
            r[i] -= a * tmp[i];
        }
        let rs_new: f32 = r.iter().map(|v| v * v).sum();
        let b = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + b * p[i];
        }
        rs = rs_new;
        iters += 1;
    }

    // dual-ish objective: ½βᵀ(K+nλI)β − yᵀβ (monotone in the residual)
    matvec_shifted(k, shift, &beta, &mut tmp);
    let obj: f32 = beta
        .iter()
        .zip(&tmp)
        .zip(y)
        .map(|((&bi, &ti), &yi)| 0.5 * bi * ti - yi * bi)
        .sum();
    Solution::from_coef(beta, obj, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};

    fn gram_1d(xs: &[f32], gamma: f32) -> (Matrix, Matrix) {
        let rows: Vec<Vec<f32>> = xs.iter().map(|&v| vec![v]).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let x = Matrix::from_rows(&refs);
        let k = GramBackend::Blocked.gram(&x, &x, gamma, KernelKind::Gauss);
        (x, k)
    }

    #[test]
    fn solves_linear_system() {
        let (_, k) = gram_1d(&[0.0, 0.5, 1.0, 1.5, 2.0], 1.0);
        let y = vec![0.0, 0.25, 1.0, 2.25, 4.0];
        let lambda = 0.01;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, &SolverParams { eps: 1e-5, ..Default::default() }, None);
        // residual check: (K + nλI)β ≈ y
        let n = y.len();
        let mut out = vec![0.0; n];
        matvec_shifted(&mut DenseGram::new(&k), lambda * n as f32, &sol.coef, &mut out);
        for (o, yi) in out.iter().zip(&y) {
            assert!((o - yi).abs() < 1e-2, "{o} vs {yi}");
        }
    }

    #[test]
    fn fits_smooth_function() {
        let xs: Vec<f32> = (0..50).map(|i| i as f32 / 10.0).collect();
        let (x, k) = gram_1d(&xs, 0.7);
        let y: Vec<f32> = xs.iter().map(|&v| (v).sin()).collect();
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, &SolverParams { eps: 1e-5, ..Default::default() }, None);
        let kx = GramBackend::Blocked.gram(&x, &x, 0.7, KernelKind::Gauss);
        let f = sol.decision_values(&kx);
        let mse: f32 =
            f.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / y.len() as f32;
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn warm_start_converges_faster() {
        let xs: Vec<f32> = (0..80).map(|i| i as f32 / 8.0).collect();
        let (_, k) = gram_1d(&xs, 1.0);
        let y: Vec<f32> = xs.iter().map(|&v| v.cos()).collect();
        let p = SolverParams { eps: 1e-5, ..Default::default() };
        let first = solve(&mut DenseGram::new(&k), &y, 1e-3, &p, None);
        let warm = solve(&mut DenseGram::new(&k), &y, 8e-4, &p, Some(&first.coef));
        let cold = solve(&mut DenseGram::new(&k), &y, 8e-4, &p, None);
        assert!(warm.iterations <= cold.iterations);
    }

    #[test]
    fn heavy_regularization_shrinks() {
        let (_, k) = gram_1d(&[0.0, 1.0, 2.0], 1.0);
        let y = vec![1.0, 1.0, 1.0];
        let sol = solve(&mut DenseGram::new(&k), &y, 100.0, &SolverParams::default(), None);
        let norm: f32 = sol.coef.iter().map(|v| v.abs()).sum();
        assert!(norm < 0.02, "coef norm {norm}");
    }
}
