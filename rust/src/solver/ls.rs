//! Least-squares plugin (mean regression and the OvA-LS multiclass
//! path used in the GURLS comparison, Table 2).
//!
//! With the representer expansion f = Σ β_j k(x_j, ·), the offset-free
//! regularized LS problem reduces to the linear system
//!
//!   (K + nλ I) β = y,
//!
//! which the shared engine solves by conjugate gradients
//! ([`Mode::ConjugateGradient`] in [`crate::solver::core`]).  This
//! plugin contributes only the diagonal shift `nλ`, the right-hand
//! side, and the objective; CG warm-starts from the previous (γ, λ)
//! solution, which is exactly the "straightforward modification" of
//! the hinge machinery the paper describes.  No box ⇒ nothing to
//! shrink, so shrink-on and shrink-off runs are identical by
//! construction.

use super::core::{Loss, Mode};

/// The least-squares [`Loss`] plugin: unconstrained, shifted-diagonal.
pub struct LsLoss<'a> {
    y: &'a [f32],
    shift: f32,
}

impl<'a> LsLoss<'a> {
    pub fn new(y: &'a [f32], lambda: f32) -> LsLoss<'a> {
        LsLoss { y, shift: lambda * y.len() as f32 }
    }
}

impl Loss for LsLoss<'_> {
    #[inline]
    fn n(&self) -> usize {
        self.y.len()
    }

    #[inline]
    fn mode(&self) -> Mode {
        Mode::ConjugateGradient
    }

    #[inline]
    fn bounds(&self, _i: usize) -> (f32, f32) {
        (f32::NEG_INFINITY, f32::INFINITY)
    }

    #[inline]
    fn init_state(&self, i: usize) -> f32 {
        -self.y[i]
    }

    #[inline]
    fn diag_shift(&self) -> f32 {
        self.shift
    }

    /// Dual-ish objective ½βᵀ(K+nλI)β − yᵀβ (monotone in the
    /// residual); `state` carries the final `(K+nλI)β` matvec.
    fn objective(&self, x: &[f32], state: &[f32]) -> f32 {
        x.iter()
            .zip(state)
            .zip(self.y)
            .map(|((&bi, &ti), &yi)| 0.5 * bi * ti - yi * bi)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::solver::core::matvec_shifted;
    use crate::solver::{Solution, SolverKind, SolverParams};

    fn solve(
        k: &mut DenseGram,
        y: &[f32],
        lambda: f32,
        params: &SolverParams,
        warm: Option<&[f32]>,
    ) -> Solution {
        crate::solver::solve(SolverKind::LeastSquares, k, y, lambda, params, warm)
    }

    fn gram_1d(xs: &[f32], gamma: f32) -> (Matrix, Matrix) {
        let rows: Vec<Vec<f32>> = xs.iter().map(|&v| vec![v]).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let x = Matrix::from_rows(&refs);
        let k = GramBackend::Blocked.gram(&x, &x, gamma, KernelKind::Gauss);
        (x, k)
    }

    #[test]
    fn solves_linear_system() {
        let (_, k) = gram_1d(&[0.0, 0.5, 1.0, 1.5, 2.0], 1.0);
        let y = vec![0.0, 0.25, 1.0, 2.25, 4.0];
        let lambda = 0.01;
        let sol = solve(
            &mut DenseGram::new(&k),
            &y,
            lambda,
            &SolverParams { eps: 1e-5, ..Default::default() },
            None,
        );
        // residual check: (K + nλI)β ≈ y
        let n = y.len();
        let mut out = vec![0.0; n];
        matvec_shifted(&mut DenseGram::new(&k), lambda * n as f32, &sol.coef, &mut out);
        for (o, yi) in out.iter().zip(&y) {
            assert!((o - yi).abs() < 1e-2, "{o} vs {yi}");
        }
    }

    #[test]
    fn fits_smooth_function() {
        let xs: Vec<f32> = (0..50).map(|i| i as f32 / 10.0).collect();
        let (x, k) = gram_1d(&xs, 0.7);
        let y: Vec<f32> = xs.iter().map(|&v| (v).sin()).collect();
        let sol = solve(
            &mut DenseGram::new(&k),
            &y,
            1e-4,
            &SolverParams { eps: 1e-5, ..Default::default() },
            None,
        );
        let kx = GramBackend::Blocked.gram(&x, &x, 0.7, KernelKind::Gauss);
        let f = sol.decision_values(&kx);
        let mse: f32 =
            f.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / y.len() as f32;
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn warm_start_converges_faster() {
        let xs: Vec<f32> = (0..80).map(|i| i as f32 / 8.0).collect();
        let (_, k) = gram_1d(&xs, 1.0);
        let y: Vec<f32> = xs.iter().map(|&v| v.cos()).collect();
        let p = SolverParams { eps: 1e-5, ..Default::default() };
        let first = solve(&mut DenseGram::new(&k), &y, 1e-3, &p, None);
        let warm = solve(&mut DenseGram::new(&k), &y, 8e-4, &p, Some(&first.coef));
        let cold = solve(&mut DenseGram::new(&k), &y, 8e-4, &p, None);
        assert!(warm.iterations <= cold.iterations);
    }

    #[test]
    fn heavy_regularization_shrinks() {
        let (_, k) = gram_1d(&[0.0, 1.0, 2.0], 1.0);
        let y = vec![1.0, 1.0, 1.0];
        let sol = solve(&mut DenseGram::new(&k), &y, 100.0, &SolverParams::default(), None);
        let norm: f32 = sol.coef.iter().map(|v| v.abs()).sum();
        assert!(norm < 0.02, "coef norm {norm}");
    }

    #[test]
    fn shrink_setting_is_a_no_op_for_cg() {
        // no box ⇒ nothing to shrink: bit-identical either way
        let (_, k) = gram_1d(&[0.0, 0.4, 0.9, 1.7, 2.2, 3.0], 0.8);
        let y = vec![0.1, 0.5, 0.9, 0.4, -0.2, -0.7];
        let off = SolverParams { shrink_every: 0, ..Default::default() };
        let on = SolverParams { shrink_every: 8, ..Default::default() };
        let a = solve(&mut DenseGram::new(&k), &y, 1e-3, &off, None);
        let b = solve(&mut DenseGram::new(&k), &y, 1e-3, &on, None);
        let bits_a: Vec<u32> = a.coef.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = b.coef.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b);
        assert_eq!(a.iterations, b.iterations);
    }
}
