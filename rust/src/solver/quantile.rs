//! Pinball-loss solver for quantile regression.
//!
//! Dual of the offset-free pinball problem at quantile τ:
//!
//!   min_β ½ βᵀKβ − yᵀβ,    C(τ−1) ≤ β_i ≤ Cτ,    C = 1/(2λn).
//!
//! Same greedy coordinate-descent skeleton as the hinge solver — the
//! "straightforward modification" the paper mentions for the quantile
//! case: only the box bounds and the linear term change.  The gradient
//! g = Kβ − y is maintained incrementally; KKT-violation stopping.

use crate::kernel::plane::GramSource;

use super::{box_c, Solution, SolverParams};

#[inline]
fn violation(beta: f32, g: f32, lo: f32, hi: f32) -> f32 {
    let mut v: f32 = 0.0;
    if beta < hi {
        v = v.max(-g);
    }
    if beta > lo {
        v = v.max(g);
    }
    v
}

pub fn solve<K: GramSource + ?Sized>(
    k: &mut K,
    y: &[f32],
    lambda: f32,
    tau: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = y.len();
    assert_eq!(k.rows(), n);
    assert!((0.0..=1.0).contains(&tau), "quantile level in (0,1)");
    let c = box_c(lambda, n);
    let lo = c * (tau - 1.0);
    let hi = c * tau;

    let mut beta: Vec<f32> = match warm {
        Some(prev) => prev.iter().map(|&b| b.clamp(lo, hi)).collect(),
        None => vec![0.0; n],
    };

    // g = Kβ − y, built sparsely from the warm start
    let mut g: Vec<f32> = y.iter().map(|&v| -v).collect();
    for j in 0..n {
        if beta[j] != 0.0 {
            let bj = beta[j];
            let krow = k.row(j);
            for i in 0..n {
                g[i] += bj * krow[i];
            }
        }
    }

    // initial greedy pick; afterwards the next pick is fused into the
    // gradient-update sweep (one O(n) pass per iteration)
    let mut best = (usize::MAX, 0.0f32);
    for i in 0..n {
        let v = violation(beta[i], g[i], lo, hi);
        if v > best.1 {
            best = (i, v);
        }
    }

    let mut iters = 0usize;
    while iters < params.max_iter {
        if best.0 == usize::MAX || best.1 <= params.eps {
            break;
        }
        let i = best.0;
        let qii = k.diag(i).max(1e-12);
        let d = (beta[i] - g[i] / qii).clamp(lo, hi) - beta[i];
        beta[i] += d;
        let krow = k.row(i);
        best = (usize::MAX, 0.0f32);
        for j in 0..n {
            let gj = g[j] + d * krow[j];
            g[j] = gj;
            let v = violation(beta[j], gj, lo, hi);
            if v > best.1 {
                best = (j, v);
            }
        }
        iters += 1;
    }

    // ½βᵀKβ − yᵀβ = ½βᵀ(g+y) − yᵀβ = ½βᵀg − ½yᵀβ
    let obj: f32 = beta
        .iter()
        .zip(&g)
        .zip(y)
        .map(|((&b, &gi), &yi)| 0.5 * b * gi - 0.5 * yi * b)
        .sum();
    Solution::from_coef(beta, obj, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::metrics::Loss;

    fn setup(n: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let d = crate::data::synth::sinc_hetero(n, seed);
        let k = GramBackend::Blocked.gram(&d.x, &d.x, 0.8, KernelKind::Gauss);
        (d.x.clone(), k, d.y)
    }

    #[test]
    fn median_splits_residuals() {
        let (_, k, y) = setup(150, 3);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.5, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let above = f.iter().zip(&y).filter(|(fi, yi)| *yi > *fi).count();
        let frac = above as f32 / y.len() as f32;
        assert!((0.35..=0.65).contains(&frac), "above-fraction {frac}");
    }

    #[test]
    fn upper_quantile_sits_higher() {
        let (_, k, y) = setup(150, 4);
        let p = SolverParams::default();
        let q10 = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.1, &p, None).decision_values(&k);
        let q90 = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.9, &p, None).decision_values(&k);
        let mean_gap: f32 =
            q90.iter().zip(&q10).map(|(a, b)| a - b).sum::<f32>() / y.len() as f32;
        assert!(mean_gap > 0.0, "q90 below q10 on average: {mean_gap}");
    }

    #[test]
    fn coverage_tracks_tau() {
        let (_, k, y) = setup(300, 5);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.8, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let below = f.iter().zip(&y).filter(|(fi, yi)| *yi <= *fi).count();
        let cov = below as f32 / y.len() as f32;
        assert!((0.65..=0.95).contains(&cov), "coverage {cov} for tau=0.8");
    }

    #[test]
    fn beta_within_box() {
        let (_, k, y) = setup(80, 6);
        let lambda = 1e-3;
        let tau = 0.25;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, tau, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for &b in &sol.coef {
            assert!(b >= c * (tau - 1.0) - 1e-6 && b <= c * tau + 1e-6);
        }
    }

    #[test]
    fn pinball_loss_beats_zero_predictor() {
        let (_, k, y) = setup(200, 7);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.7, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let loss = Loss::Pinball { tau: 0.7 };
        let zeros = vec![0.0; y.len()];
        assert!(loss.mean(&y, &f) < loss.mean(&y, &zeros));
    }
}
