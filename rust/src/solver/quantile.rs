//! Pinball-loss plugin for quantile regression.
//!
//! Dual of the offset-free pinball problem at quantile τ:
//!
//!   min_β ½ βᵀKβ − yᵀβ,    C(τ−1) ≤ β_i ≤ Cτ,    C = 1/(2λn).
//!
//! The "straightforward modification" of the hinge machinery the
//! paper mentions for the quantile case: only the box bounds and the
//! linear term change, so this plugin contributes exactly those two
//! things (plus the objective formula) and selects the
//! single-coordinate greedy engine.  Gradient maintenance, the fused
//! select+update sweep, shrinking, and KKT stopping are the shared
//! core's ([`crate::solver::core`]).

use super::core::{Loss, Mode};
use super::box_c;

/// The quantile [`Loss`] plugin: the τ-asymmetric box and the `y`
/// linear term.
pub struct QuantileLoss<'a> {
    y: &'a [f32],
    lo: f32,
    hi: f32,
}

impl<'a> QuantileLoss<'a> {
    pub fn new(y: &'a [f32], lambda: f32, tau: f32) -> QuantileLoss<'a> {
        assert!((0.0..=1.0).contains(&tau), "quantile level in (0,1)");
        let c = box_c(lambda, y.len());
        QuantileLoss { y, lo: c * (tau - 1.0), hi: c * tau }
    }
}

impl Loss for QuantileLoss<'_> {
    #[inline]
    fn n(&self) -> usize {
        self.y.len()
    }

    #[inline]
    fn mode(&self) -> Mode {
        Mode::Greedy { pairwise: false }
    }

    #[inline]
    fn bounds(&self, _i: usize) -> (f32, f32) {
        (self.lo, self.hi)
    }

    #[inline]
    fn init_state(&self, i: usize) -> f32 {
        -self.y[i]
    }

    /// ½βᵀKβ − yᵀβ = ½βᵀ(g+y) − yᵀβ = ½βᵀg − ½yᵀβ.
    fn objective(&self, x: &[f32], g: &[f32]) -> f32 {
        x.iter()
            .zip(g)
            .zip(self.y)
            .map(|((&b, &gi), &yi)| 0.5 * b * gi - 0.5 * yi * b)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::metrics::Loss;
    use crate::solver::{Solution, SolverKind, SolverParams};

    fn solve(
        k: &mut DenseGram,
        y: &[f32],
        lambda: f32,
        tau: f32,
        params: &SolverParams,
        warm: Option<&[f32]>,
    ) -> Solution {
        crate::solver::solve(SolverKind::Quantile { tau }, k, y, lambda, params, warm)
    }

    fn setup(n: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let d = crate::data::synth::sinc_hetero(n, seed);
        let k = GramBackend::Blocked.gram(&d.x, &d.x, 0.8, KernelKind::Gauss);
        (d.x.clone(), k, d.y)
    }

    #[test]
    fn median_splits_residuals() {
        let (_, k, y) = setup(150, 3);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.5, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let above = f.iter().zip(&y).filter(|(fi, yi)| *yi > *fi).count();
        let frac = above as f32 / y.len() as f32;
        assert!((0.35..=0.65).contains(&frac), "above-fraction {frac}");
    }

    #[test]
    fn upper_quantile_sits_higher() {
        let (_, k, y) = setup(150, 4);
        let p = SolverParams::default();
        let q10 = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.1, &p, None).decision_values(&k);
        let q90 = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.9, &p, None).decision_values(&k);
        let mean_gap: f32 =
            q90.iter().zip(&q10).map(|(a, b)| a - b).sum::<f32>() / y.len() as f32;
        assert!(mean_gap > 0.0, "q90 below q10 on average: {mean_gap}");
    }

    #[test]
    fn coverage_tracks_tau() {
        let (_, k, y) = setup(300, 5);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.8, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let below = f.iter().zip(&y).filter(|(fi, yi)| *yi <= *fi).count();
        let cov = below as f32 / y.len() as f32;
        assert!((0.65..=0.95).contains(&cov), "coverage {cov} for tau=0.8");
    }

    #[test]
    fn beta_within_box() {
        let (_, k, y) = setup(80, 6);
        let lambda = 1e-3;
        let tau = 0.25;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, tau, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for &b in &sol.coef {
            assert!(b >= c * (tau - 1.0) - 1e-6 && b <= c * tau + 1e-6);
        }
    }

    #[test]
    fn pinball_loss_beats_zero_predictor() {
        let (_, k, y) = setup(200, 7);
        let sol = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.7, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        let loss = Loss::Pinball { tau: 0.7 };
        let zeros = vec![0.0; y.len()];
        assert!(loss.mean(&y, &f) < loss.mean(&y, &zeros));
    }

    #[test]
    fn shrinking_preserves_objective() {
        let (_, k, y) = setup(120, 8);
        let off = SolverParams { shrink_every: 0, ..Default::default() };
        let on = SolverParams { shrink_every: 32, ..Default::default() };
        let a = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.3, &off, None);
        let b = solve(&mut DenseGram::new(&k), &y, 1e-4, 0.3, &on, None);
        assert!(
            (a.objective - b.objective).abs() < 1e-2 * (1.0 + a.objective.abs()),
            "{} vs {}",
            a.objective,
            b.objective
        );
    }
}
