//! The shared solver engine — one shrinking coordinate-descent core
//! under all four losses (see DESIGN.md §Solver-core).
//!
//! The paper's "very carefully implemented solvers" (§3, after
//! Steinwart–Hush–Scovel 2011) previously existed four times over:
//! each loss hand-rolled its own gradient maintenance, working-set
//! selection, and stopping logic.  This module owns that machinery
//! exactly once; `hinge`/`ls`/`quantile`/`expectile` are thin [`Loss`]
//! plugins that contribute only what genuinely differs per loss — box
//! bounds, the sign pattern folded into Q, the linear term, the exact
//! 1-d/2-d subproblem solves, and the objective formula.
//!
//! Three iteration strategies reproduce the historical per-loss
//! algorithms bit-for-bit when shrinking is off:
//!
//! * [`Mode::Greedy`] — greedy KKT-violation coordinate descent over a
//!   box, single-coordinate (quantile) or two-coordinate with exact
//!   2×2 solves (hinge).  Gradient updates and the next working-set
//!   pick are fused into one sweep.
//! * [`Mode::Cyclic`] — cyclic sweeps with exact per-coordinate
//!   piecewise solves ([`Loss::prox`]), stopping on the largest
//!   scaled coordinate move (expectile).
//! * [`Mode::ConjugateGradient`] — CG on the shifted system
//!   `(K + σI) x = b` (least squares; σ = nλ).
//!
//! **Shrinking** (Glasmachers 2022's biggest single-node win for the
//! CV-grid workload): every `SolverParams::shrink_every` coordinate
//! updates the greedy engine drops coordinates pinned at a box bound
//! whose gradient is strongly feasible (the cyclic engine drops
//! coordinates whose last sweep barely moved them), and subsequent
//! sweeps touch only the active set through the Gram plane's
//! [`GramSource::gather`] row-gather path — O(|active|) per sweep on
//! cached, buffered, and streamed sources alike.  Gradients of shrunk
//! coordinates go stale; before ANY termination the engine rebuilds
//! them and re-checks the stopping criterion over *all* coordinates
//! (the mandatory unshrink pass), so the returned solution satisfies
//! exactly the same ε-KKT / sweep-convergence criterion as a
//! shrink-off run — accuracy is preserved, not approximated.
//! `shrink_every = 0` disables shrinking entirely, and a disabled run
//! executes the identical instruction sequence as the pre-engine
//! solvers (property-tested against reference implementations in
//! `tests/solver_core.rs`).
//!
//! Work accounting: the process-wide `solver_sweeps` counter tallies
//! gradient/state entries written (the O(n·iters) core cost shrinking
//! attacks), `shrink_active` accumulates the active-set size at each
//! refresh, and `unshrink_passes` counts stale-gradient
//! reconstructions — all surfaced in the CV display and serve `stats`.

use crate::kernel::plane::GramSource;
use crate::metrics::counters;

use super::{Solution, SolverParams};

/// Diagonal entries at or below this floor are treated as exactly
/// degenerate by [`clip_step`] (the 1-d objective is linear there).
const Q_FLOOR: f32 = 1e-12;

/// Fraction of the cyclic stopping threshold below which a
/// coordinate's last move marks it shrinkable.
const CYCLIC_SHRINK_FRACTION: f32 = 0.25;

/// How the engine iterates for a loss — each variant reproduces the
/// historical per-loss algorithm exactly (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Greedy KKT-violation selection over a box; `pairwise` adds the
    /// exact 2-coordinate subproblem (hinge).
    Greedy { pairwise: bool },
    /// Cyclic sweeps with exact per-coordinate [`Loss::prox`] solves
    /// (expectile).
    Cyclic,
    /// Conjugate gradients on `(K + σI) x = b` (least squares).
    ConjugateGradient,
}

/// What a loss contributes to the shared engine: the box, the sign
/// pattern, the linear term, the exact subproblem solves, and the
/// objective.  Everything else — incremental gradient/state
/// maintenance, fused select+update sweeps, KKT/sweep stopping,
/// shrinking, warm-start clipping — lives in the engine, once.
pub trait Loss {
    /// Problem size (number of dual variables).
    fn n(&self) -> usize;

    /// Iteration strategy reproducing this loss's historical solver.
    fn mode(&self) -> Mode;

    /// Box `[lo, hi]` for coordinate `i` (`±∞` when unconstrained).
    fn bounds(&self, i: usize) -> (f32, f32);

    /// Sign `s_i` folded into the effective quadratic `Q = s sᵀ ∘ K`
    /// (hinge: `y_i`; every other loss: `1`).
    #[inline]
    fn sign(&self, i: usize) -> f32 {
        let _ = i;
        1.0
    }

    /// Initial value of the maintained state vector at `x = 0`: the
    /// negated linear term for gradient-state losses (`−1` hinge,
    /// `−y_i` quantile/LS), `0` for the expectile `f = Kx` state.
    fn init_state(&self, i: usize) -> f32;

    /// Diagonal shift σ added to `K` (least squares: `nλ`).
    #[inline]
    fn diag_shift(&self) -> f32 {
        0.0
    }

    /// Scale multiplying `eps` in the cyclic stopping criterion.
    #[inline]
    fn stop_scale(&self) -> f32 {
        1.0
    }

    /// Exact 1-d subproblem solve → step for coordinate `i` with
    /// gradient `g` and curvature `q`.  Default: Newton step clipped
    /// into the box, degenerate diagonals going straight to the
    /// descent-side bound.
    #[inline]
    fn solve1(&self, i: usize, x: f32, g: f32, q: f32) -> f32 {
        let (lo, hi) = self.bounds(i);
        clip_step(x, g, q, lo, hi)
    }

    /// Exact 2-d subproblem solve → steps for the pair `(i1, i2)`
    /// with `q = (q11, q22, q12)` already sign-adjusted.  Default:
    /// unconstrained 2×2 Newton, then the best of the four clamped
    /// edges (exact for a 2-d box QP).
    #[inline]
    fn solve2(
        &self,
        i1: usize,
        i2: usize,
        x: (f32, f32),
        g: (f32, f32),
        q: (f32, f32, f32),
    ) -> (f32, f32) {
        let (lo1, hi1) = self.bounds(i1);
        let (lo2, hi2) = self.bounds(i2);
        solve2_box(x.0, x.1, g.0, g.1, q.0, q.1, q.2, lo1, hi1, lo2, hi2)
    }

    /// Exact per-coordinate solve for [`Mode::Cyclic`]: the new value
    /// of `x_i` given the maintained state `state_i` and curvature
    /// `q`.  Only cyclic losses implement this.
    #[inline]
    fn prox(&self, i: usize, x: f32, state: f32, q: f32) -> f32 {
        let _ = (i, state, q);
        x
    }

    /// Objective at termination from the final `x` and maintained
    /// state (gradient for greedy/CG losses, `Kx` for cyclic).
    fn objective(&self, x: &[f32], state: &[f32]) -> f32;

    /// Map the optimization variable to expansion coefficients
    /// (hinge: `α_i y_i`; default: identity).
    #[inline]
    fn coef(&self, x: Vec<f32>) -> Vec<f32> {
        x
    }
}

/// KKT violation of coordinate `x` with gradient `g` in `[lo, hi]`
/// (how much the objective can decrease by moving it): positive ⇒
/// movable.
#[inline]
pub(crate) fn violation(x: f32, g: f32, lo: f32, hi: f32) -> f32 {
    let mut v: f32 = 0.0;
    if x < hi {
        v = v.max(-g); // can increase x
    }
    if x > lo {
        v = v.max(g); // can decrease x
    }
    v
}

/// Exact minimizer of `½ q d² + g d` over `x + d ∈ [lo, hi]`, as a
/// relative step.  A (numerically) zero diagonal makes the coordinate
/// objective linear, so the exact solve goes straight to the
/// descent-side box bound — not through a `g/ε`-scale Newton target
/// (the degenerate-diagonal rule every loss inherits).
#[inline]
pub(crate) fn clip_step(x: f32, g: f32, q: f32, lo: f32, hi: f32) -> f32 {
    if q <= Q_FLOOR {
        return if g > 0.0 {
            lo - x
        } else if g < 0.0 {
            hi - x
        } else {
            0.0
        };
    }
    (x - g / q).clamp(lo, hi) - x
}

/// Exact 2-d box-QP solve: unconstrained 2×2 Newton step if it stays
/// in the box, otherwise the best of the four clamped edges.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn solve2_box(
    x1: f32,
    x2: f32,
    g1: f32,
    g2: f32,
    q11: f32,
    q22: f32,
    q12: f32,
    lo1: f32,
    hi1: f32,
    lo2: f32,
    hi2: f32,
) -> (f32, f32) {
    let det = q11 * q22 - q12 * q12;
    let (mut d1, mut d2);
    if det > 1e-12 * q11 * q22 {
        d1 = (-g1 * q22 + g2 * q12) / det;
        d2 = (-g2 * q11 + g1 * q12) / det;
    } else {
        d1 = -g1 / q11;
        d2 = 0.0;
    }
    let in_box = |a: f32, lo: f32, hi: f32| a >= lo - 1e-12 && a <= hi + 1e-12;
    if !(in_box(x1 + d1, lo1, hi1) && in_box(x2 + d2, lo2, hi2)) {
        // best of the four clamped edges (exact for a 2-d box QP)
        let mut best = (f32::INFINITY, 0.0f32, 0.0f32);
        for &(fix1, bound) in &[(true, lo1), (true, hi1), (false, lo2), (false, hi2)] {
            let (e1, e2) = if fix1 {
                let dd1 = bound - x1;
                // minimize over x2 with x1 fixed
                let g2p = g2 + q12 * dd1;
                let dd2 = clip_step(x2, g2p, q22, lo2, hi2);
                (dd1, dd2)
            } else {
                let dd2 = bound - x2;
                let g1p = g1 + q12 * dd2;
                let dd1 = clip_step(x1, g1p, q11, lo1, hi1);
                (dd1, dd2)
            };
            // objective change of the candidate step
            let dobj =
                g1 * e1 + g2 * e2 + 0.5 * (q11 * e1 * e1 + q22 * e2 * e2) + q12 * e1 * e2;
            if dobj < best.0 {
                best = (dobj, e1, e2);
            }
        }
        d1 = best.1;
        d2 = best.2;
    }
    (d1, d2)
}

/// Two-slot greedy tracker: top violation and runner-up, first index
/// winning ties (the stability tie-break every greedy solver used).
struct Top2 {
    i1: usize,
    v1: f32,
    i2: usize,
    v2: f32,
}

impl Top2 {
    fn new() -> Top2 {
        Top2 { i1: usize::MAX, v1: 0.0, i2: usize::MAX, v2: 0.0 }
    }

    #[inline]
    fn push(&mut self, j: usize, v: f32) {
        if v > self.v1 {
            self.i2 = self.i1;
            self.v2 = self.v1;
            self.i1 = j;
            self.v1 = v;
        } else if v > self.v2 {
            self.i2 = j;
            self.v2 = v;
        }
    }
}

/// Batched per-solve tallies, flushed to the global counters once at
/// exit (no atomics in the hot loop).
#[derive(Default)]
struct Tally {
    sweeps: u64,
    shrink_active: u64,
    unshrinks: u64,
}

impl Tally {
    fn flush(&self) {
        counters::SOLVER_SWEEPS.add(self.sweeps);
        counters::SOLVER_SHRINK_ACTIVE.add(self.shrink_active);
        counters::SOLVER_UNSHRINK_PASSES.add(self.unshrinks);
    }
}

/// Solve the loss's problem over a square Gram source — the single
/// entry point behind [`crate::solver::solve`].
pub fn solve_loss<L: Loss, K: GramSource + ?Sized>(
    loss: &L,
    k: &mut K,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = loss.n();
    assert_eq!(k.rows(), n);
    assert_eq!(k.cols(), n);
    // one span per solve (never per sweep) — tracing cost stays out of
    // the coordinate loops, matching the batched Tally idiom below
    let _sp = crate::obs::span("solver.solve");
    match loss.mode() {
        Mode::Greedy { pairwise } => greedy_cd(loss, k, params, warm, pairwise),
        Mode::Cyclic => cyclic_cd(loss, k, params, warm),
        Mode::ConjugateGradient => conj_grad(loss, k, params, warm),
    }
}

/// Select the top-2 violators over the full set or an active list.
fn select(
    x: &[f32],
    g: &[f32],
    lo: &[f32],
    hi: &[f32],
    active: Option<&[usize]>,
) -> Top2 {
    let mut top = Top2::new();
    match active {
        None => {
            for j in 0..x.len() {
                top.push(j, violation(x[j], g[j], lo[j], hi[j]));
            }
        }
        Some(idx) => {
            for &j in idx {
                top.push(j, violation(x[j], g[j], lo[j], hi[j]));
            }
        }
    }
    top
}

/// Rebuild the stale state entries of shrunk coordinates from
/// scratch: `state_j = init_j + Σ_{i: x_i ≠ 0} s_j (x_i s_i) K_ij`
/// with `sign = Some(s)` (the greedy gradient state), or the unsigned
/// `state_j = init_j + Σ x_i K_ij` with `sign = None` (the cyclic
/// `f = Kx` state).  Sources accumulate in ascending order — the same
/// order as a fresh warm-start build.  Costs O(#nonzero·|stale|)
/// through the gather path.
#[allow(clippy::too_many_arguments)]
fn rebuild_stale<L: Loss, K: GramSource + ?Sized>(
    loss: &L,
    k: &mut K,
    x: &[f32],
    sign: Option<&[f32]>,
    state: &mut [f32],
    is_active: &[bool],
    buf: &mut Vec<f32>,
    tally: &mut Tally,
) {
    let n = x.len();
    let stale: Vec<usize> = (0..n).filter(|&j| !is_active[j]).collect();
    if stale.is_empty() {
        return;
    }
    for &j in &stale {
        state[j] = loss.init_state(j);
    }
    buf.resize(stale.len(), 0.0);
    for src in 0..n {
        if x[src] != 0.0 {
            k.gather(src, &stale, buf);
            match sign {
                Some(s) => {
                    let sx = x[src] * s[src];
                    for (t, &j) in stale.iter().enumerate() {
                        state[j] += s[j] * sx * buf[t];
                    }
                }
                None => {
                    let bx = x[src];
                    for (t, &j) in stale.iter().enumerate() {
                        state[j] += bx * buf[t];
                    }
                }
            }
            tally.sweeps += stale.len() as u64;
        }
    }
    tally.unshrinks += 1;
}

/// Greedy coordinate descent over a box with optional shrinking —
/// the engine under hinge (`pairwise`) and quantile (single).
fn greedy_cd<L: Loss, K: GramSource + ?Sized>(
    loss: &L,
    k: &mut K,
    params: &SolverParams,
    warm: Option<&[f32]>,
    pairwise: bool,
) -> Solution {
    let n = loss.n();
    let mut lo = vec![0.0f32; n];
    let mut hi = vec![0.0f32; n];
    for i in 0..n {
        let (l, h) = loss.bounds(i);
        lo[i] = l;
        hi[i] = h;
    }
    let s: Vec<f32> = (0..n).map(|i| loss.sign(i)).collect();

    // warm start: clip the previous solution into the new box (smaller
    // λ ⇒ bigger box ⇒ a no-op on the canonical λ ordering; across γ
    // the clip genuinely binds)
    let mut x: Vec<f32> = match warm {
        Some(prev) => prev.iter().enumerate().map(|(i, &a)| a.clamp(lo[i], hi[i])).collect(),
        None => vec![0.0; n],
    };

    let mut tally = Tally::default();

    // gradient state g = Qx − b, built from non-zero coordinates only
    let mut g: Vec<f32> = (0..n).map(|i| loss.init_state(i)).collect();
    for j in 0..n {
        if x[j] != 0.0 {
            let sxj = x[j] * s[j];
            let krow = k.row(j);
            for i in 0..n {
                g[i] += s[i] * sxj * krow[i];
            }
            tally.sweeps += n as u64;
        }
    }

    // shrinking state: `None` = all coordinates active (and the sweep
    // code below takes the exact historical full-row path)
    let shrink_every = params.shrink_every;
    let mut active: Option<Vec<usize>> = None;
    let mut is_active = vec![true; n];
    let mut since_refresh = 0usize;
    let (mut row1, mut row2): (Vec<f32>, Vec<f32>) = (Vec::new(), Vec::new());

    let t = select(&x, &g, &lo, &hi, None);
    let (mut i1, mut v1, mut i2) = (t.i1, t.v1, t.i2);

    let mut iters = 0usize;
    while iters < params.max_iter {
        // periodic active-set refresh: drop coordinates pinned at a
        // bound whose gradient is strongly feasible (they cannot move
        // while the top violation stays above the margin)
        if shrink_every > 0 && since_refresh >= shrink_every {
            since_refresh = 0;
            let margin = v1.max(params.eps);
            let src: Vec<usize> = match &active {
                None => (0..n).collect(),
                Some(idx) => idx.clone(),
            };
            // exact bound equality is deliberate: a dropped coordinate
            // then provably has zero KKT violation, so shrinking never
            // removes an unconverged violator (which would force a
            // guaranteed unshrink round later).  A coordinate landing
            // one ulp inside its bound with an outward gradient is a
            // live violator — selection steps it, and the final hop is
            // a Sterbenz-exact subtraction that lands exactly ON the
            // bound, after which it qualifies here.
            let next: Vec<usize> = src
                .into_iter()
                .filter(|&j| {
                    !((x[j] == lo[j] && g[j] > margin) || (x[j] == hi[j] && g[j] < -margin))
                })
                .collect();
            tally.shrink_active += next.len() as u64;
            if next.len() < n {
                is_active.fill(false);
                for &j in &next {
                    is_active[j] = true;
                }
                active = Some(next);
            } else {
                active = None;
            }
        }

        if i1 == usize::MAX || v1 <= params.eps {
            // apparent convergence on the active set: the mandatory
            // unshrink pass rebuilds stale gradients and re-checks the
            // ε-KKT criterion over ALL coordinates before terminating
            if active.is_some() {
                rebuild_stale(loss, k, &x, Some(&s), &mut g, &is_active, &mut row1, &mut tally);
                active = None;
                is_active.fill(true);
                since_refresh = 0;
                let t = select(&x, &g, &lo, &hi, None);
                (i1, v1, i2) = (t.i1, t.v1, t.i2);
                if i1 == usize::MAX || v1 <= params.eps {
                    break;
                }
                continue;
            }
            break;
        }

        if !pairwise {
            // single-coordinate engine (quantile's historical loop):
            // exact 1-d solve, then one fused update+select sweep
            let d = loss.solve1(i1, x[i1], g[i1], k.diag(i1));
            x[i1] += d;
            let sd = s[i1] * d;
            let mut top = Top2::new();
            match &active {
                None => {
                    let krow = k.row(i1);
                    for j in 0..n {
                        let gj = g[j] + s[j] * (sd * krow[j]);
                        g[j] = gj;
                        top.push(j, violation(x[j], gj, lo[j], hi[j]));
                    }
                    tally.sweeps += n as u64;
                }
                Some(idx) => {
                    row1.resize(idx.len(), 0.0);
                    k.gather(i1, idx, &mut row1);
                    for (t, &j) in idx.iter().enumerate() {
                        let gj = g[j] + s[j] * (sd * row1[t]);
                        g[j] = gj;
                        top.push(j, violation(x[j], gj, lo[j], hi[j]));
                    }
                    tally.sweeps += idx.len() as u64;
                }
            }
            (i1, v1, i2) = (top.i1, top.v1, top.i2);
            iters += 1;
            since_refresh += 1;
            continue;
        }

        if i2 == usize::MAX || i2 == i1 {
            // single movable coordinate (hinge's historical fallback):
            // plain update pass, then a separate full reselect
            let d = loss.solve1(i1, x[i1], g[i1], k.diag(i1));
            if d != 0.0 {
                x[i1] += d;
                let sd = s[i1] * d;
                match &active {
                    None => {
                        let krow = k.row(i1);
                        for j in 0..n {
                            g[j] += s[j] * (sd * krow[j]);
                        }
                        tally.sweeps += n as u64;
                    }
                    Some(idx) => {
                        row1.resize(idx.len(), 0.0);
                        k.gather(i1, idx, &mut row1);
                        for (t, &j) in idx.iter().enumerate() {
                            g[j] += s[j] * (sd * row1[t]);
                        }
                        tally.sweeps += idx.len() as u64;
                    }
                }
            }
            let t = select(&x, &g, &lo, &hi, active.as_deref());
            (i1, v1, i2) = (t.i1, t.v1, t.i2);
            iters += 1;
            since_refresh += 1;
            continue;
        }

        // exact 2-d solve on (i1, i2)
        let q11 = k.diag(i1).max(1e-12);
        let q22 = k.diag(i2).max(1e-12);
        let q12 = s[i1] * s[i2] * k.get(i1, i2);
        let (d1, d2) = loss.solve2(i1, i2, (x[i1], x[i2]), (g[i1], g[i2]), (q11, q22, q12));

        // fused pass: apply both gradient updates AND pick the next
        // working pair in a single sweep over the active set
        x[i1] += d1;
        x[i2] += d2;
        let s1d = s[i1] * d1;
        let s2d = s[i2] * d2;
        let mut top = Top2::new();
        match &active {
            None => {
                let (k1, k2) = k.row_pair(i1, i2);
                for j in 0..n {
                    let gj = g[j] + s[j] * (s1d * k1[j] + s2d * k2[j]);
                    g[j] = gj;
                    top.push(j, violation(x[j], gj, lo[j], hi[j]));
                }
                tally.sweeps += n as u64;
            }
            Some(idx) => {
                row1.resize(idx.len(), 0.0);
                row2.resize(idx.len(), 0.0);
                k.gather(i1, idx, &mut row1);
                k.gather(i2, idx, &mut row2);
                for (t, &j) in idx.iter().enumerate() {
                    let gj = g[j] + s[j] * (s1d * row1[t] + s2d * row2[t]);
                    g[j] = gj;
                    top.push(j, violation(x[j], gj, lo[j], hi[j]));
                }
                tally.sweeps += idx.len() as u64;
            }
        }
        (i1, v1, i2) = (top.i1, top.v1, top.i2);
        // a 2-coordinate step is 2 coordinate updates — counted as
        // such so iteration totals compare like with like across losses
        iters += 2;
        since_refresh += 2;
    }

    // a max_iter exit can leave shrunk coordinates stale: rebuild so
    // the reported objective is exact
    if active.is_some() {
        rebuild_stale(loss, k, &x, Some(&s), &mut g, &is_active, &mut row1, &mut tally);
    }

    let obj = loss.objective(&x, &g);
    tally.flush();
    let mut sol = Solution::from_coef(loss.coef(x), obj, iters);
    sol.sweep_entries = tally.sweeps;
    sol
}

/// Cyclic exact-solve sweeps with optional shrinking — the engine
/// under expectile.  Maintains `state = Kx` incrementally; stops when
/// a sweep's largest scaled move falls below `eps · stop_scale`.
fn cyclic_cd<L: Loss, K: GramSource + ?Sized>(
    loss: &L,
    k: &mut K,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = loss.n();
    let mut x: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);

    let mut tally = Tally::default();

    // state f = Kx maintained incrementally, built sparsely
    let mut f: Vec<f32> = (0..n).map(|i| loss.init_state(i)).collect();
    for j in 0..n {
        if x[j] != 0.0 {
            let bj = x[j];
            let krow = k.row(j);
            for i in 0..n {
                f[i] += bj * krow[i];
            }
            tally.sweeps += n as u64;
        }
    }

    let threshold = params.eps * loss.stop_scale();
    let shrink_every = params.shrink_every;
    let mut active: Option<Vec<usize>> = None;
    let mut is_active = vec![true; n];
    let mut since_refresh = 0usize;
    // last scaled move per coordinate, the cyclic shrink signal
    let mut last_move = vec![f32::INFINITY; n];
    let mut row = Vec::new();

    let mut iters = 0usize;
    let mut sweep_max = f32::INFINITY;
    while sweep_max > threshold && iters < params.max_iter {
        sweep_max = 0.0;
        let idx = active.as_deref();
        let len = idx.map_or(n, <[usize]>::len);
        for t in 0..len {
            let i = idx.map_or(t, |v| v[t]);
            let kii = k.diag(i).max(1e-12);
            let new_b = loss.prox(i, x[i], f[i], kii);
            let d = new_b - x[i];
            if d != 0.0 {
                x[i] = new_b;
                match idx {
                    None => {
                        let krow = k.row(i);
                        for (j, fj) in f.iter_mut().enumerate() {
                            *fj += d * krow[j];
                        }
                        tally.sweeps += n as u64;
                    }
                    Some(v) => {
                        row.resize(v.len(), 0.0);
                        k.gather(i, v, &mut row);
                        for (u, &j) in v.iter().enumerate() {
                            f[j] += d * row[u];
                        }
                        tally.sweeps += v.len() as u64;
                    }
                }
                let mv = d.abs() * kii;
                sweep_max = sweep_max.max(mv);
                last_move[i] = mv;
            } else {
                last_move[i] = 0.0;
            }
            iters += 1;
            since_refresh += 1;
            if iters >= params.max_iter {
                break;
            }
        }

        if sweep_max <= threshold && active.is_some() {
            // the active sweep converged: mandatory unshrink — rebuild
            // stale state and keep sweeping the FULL set until it
            // satisfies the same criterion as a shrink-off run
            rebuild_stale(loss, k, &x, None, &mut f, &is_active, &mut row, &mut tally);
            active = None;
            is_active.fill(true);
            since_refresh = 0;
            sweep_max = f32::INFINITY;
            continue;
        }

        // refresh at sweep boundaries only (a partial sweep must not
        // change the set mid-flight)
        if shrink_every > 0 && since_refresh >= shrink_every && iters < params.max_iter {
            since_refresh = 0;
            let margin = CYCLIC_SHRINK_FRACTION * threshold;
            let src: Vec<usize> = match &active {
                None => (0..n).collect(),
                Some(idx) => idx.clone(),
            };
            let next: Vec<usize> = src.into_iter().filter(|&j| last_move[j] > margin).collect();
            tally.shrink_active += next.len() as u64;
            // an empty refresh result can only arise from a full set
            // whose sweep already converged (the unshrink branch above
            // owns that case) — leave the current set untouched so no
            // stale coordinate is ever silently reactivated
            if !next.is_empty() {
                if next.len() < n {
                    is_active.fill(false);
                    for &j in &next {
                        is_active[j] = true;
                    }
                    active = Some(next);
                } else {
                    active = None;
                    is_active.fill(true);
                }
            }
        }
    }

    if active.is_some() {
        rebuild_stale(loss, k, &x, None, &mut f, &is_active, &mut row, &mut tally);
    }

    let obj = loss.objective(&x, &f);
    tally.flush();
    let mut sol = Solution::from_coef(loss.coef(x), obj, iters);
    sol.sweep_entries = tally.sweeps;
    sol
}

/// `out ← (K + σI)·x` — the fused matvec + shift under the CG engine
/// (and the residual checks in the LS tests).
pub fn matvec_shifted<K: GramSource + ?Sized>(k: &mut K, shift: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    for i in 0..n {
        let row = k.row(i);
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        out[i] = s + shift * x[i];
    }
}

/// Conjugate gradients on `(K + σI) x = b` — the engine under least
/// squares.  No box ⇒ nothing to shrink; `iterations` reports
/// `rounds · n` (each CG round updates every coordinate once, so the
/// totals compare like with like with the coordinate solvers), while
/// `max_iter` keeps its historical meaning of a CG-round cap.
fn conj_grad<L: Loss, K: GramSource + ?Sized>(
    loss: &L,
    k: &mut K,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = loss.n();
    let shift = loss.diag_shift();
    let b: Vec<f32> = (0..n).map(|i| -loss.init_state(i)).collect();

    let mut x: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    let mut tmp = vec![0.0f32; n];
    let mut tally = Tally::default();

    // r = b − (K + σI)x
    matvec_shifted(k, shift, &x, &mut tmp);
    tally.sweeps += n as u64;
    let mut r: Vec<f32> = b.iter().zip(&tmp).map(|(&a, &t)| a - t).collect();
    let mut p = r.clone();
    let mut rs: f32 = r.iter().map(|v| v * v).sum();
    let b_norm: f32 = b.iter().map(|v| v * v).sum::<f32>().max(1e-12);
    let tol2 = (params.eps * params.eps) * b_norm;

    let mut rounds = 0usize;
    let max_cg = params.max_iter.min(4 * n + 50);
    while rs > tol2 && rounds < max_cg {
        matvec_shifted(k, shift, &p, &mut tmp);
        tally.sweeps += n as u64;
        let pap: f32 = p.iter().zip(&tmp).map(|(&a, &t)| a * t).sum();
        if pap <= 0.0 {
            break; // K + σI is SPD; this only trips on round-off
        }
        let a = rs / pap;
        for i in 0..n {
            x[i] += a * p[i];
            r[i] -= a * tmp[i];
        }
        let rs_new: f32 = r.iter().map(|v| v * v).sum();
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        rounds += 1;
    }

    matvec_shifted(k, shift, &x, &mut tmp);
    tally.sweeps += n as u64;
    let obj = loss.objective(&x, &tmp);
    tally.flush();
    let mut sol = Solution::from_coef(loss.coef(x), obj, rounds * n);
    sol.sweep_entries = tally.sweeps;
    sol
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_respects_bounds() {
        // pinned at the lower bound with a feasible gradient: immovable
        assert_eq!(violation(0.0, 2.0, 0.0, 1.0), 0.0);
        // pinned at the lower bound with a descent direction: movable
        assert_eq!(violation(0.0, -2.0, 0.0, 1.0), 2.0);
        // interior point: both directions checked
        assert_eq!(violation(0.5, 3.0, 0.0, 1.0), 3.0);
        assert_eq!(violation(0.5, -3.0, 0.0, 1.0), 3.0);
        // pinned at the upper bound
        assert_eq!(violation(1.0, -2.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn clip_step_newton_within_box() {
        // q=2, g=1 from x=0.5: target 0 ⇒ step −0.5
        assert!((clip_step(0.5, 1.0, 2.0, 0.0, 1.0) + 0.5).abs() < 1e-7);
        // target outside the box clamps to the bound
        assert!((clip_step(0.5, 10.0, 1.0, 0.0, 1.0) + 0.5).abs() < 1e-7);
    }

    #[test]
    fn clip_step_degenerate_diag_goes_to_bound() {
        // zero diagonal + positive gradient ⇒ exact step to the lower
        // bound, not a 1e12-scale Newton target
        let d = clip_step(0.4, 1e-20, 0.0, 0.0, 1.0);
        assert_eq!(d, -0.4);
        let d = clip_step(0.4, -1e-20, 0.0, 0.0, 1.0);
        assert_eq!(d, 0.6);
        assert_eq!(clip_step(0.4, 0.0, 0.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn solve2_box_unconstrained_newton() {
        // identity Q, interior solution
        let (d1, d2) = solve2_box(0.5, 0.5, 0.2, -0.1, 1.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0);
        assert!((d1 + 0.2).abs() < 1e-6);
        assert!((d2 - 0.1).abs() < 1e-6);
    }

    #[test]
    fn solve2_box_clamps_to_edges() {
        // strong negative gradients push both coordinates to the top
        let (d1, d2) = solve2_box(0.0, 0.0, -5.0, -5.0, 1.0, 1.0, 0.5, 0.0, 1.0, 0.0, 1.0);
        assert!(0.0 + d1 <= 1.0 + 1e-6 && 0.0 + d2 <= 1.0 + 1e-6);
        assert!(d1 > 0.0 && d2 > 0.0);
    }

    #[test]
    fn top2_orders_and_breaks_ties_by_first_index() {
        let mut t = Top2::new();
        t.push(0, 1.0);
        t.push(1, 1.0); // tie: first index keeps the top slot
        t.push(2, 3.0);
        assert_eq!((t.i1, t.i2), (2, 0));
        assert_eq!((t.v1, t.v2), (3.0, 1.0));
    }
}
