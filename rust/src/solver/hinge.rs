//! (Weighted) hinge-loss plugin — liquidSVM's classification core,
//! after Steinwart, Hush & Scovel (2011).
//!
//! Dual problem (no offset ⇒ no equality constraint):
//!
//!   min_α  ½ αᵀQα − 1ᵀα,   0 ≤ α_i ≤ C_i,   Q_ij = y_i y_j K_ij,
//!
//! with C_i = 2w·C for positive samples and 2(1−w)·C for negatives
//! (C = 1/(2λn); w = 0.5 recovers the unweighted machine).  Because
//! the constraint set is a box, a two-coordinate working set can be
//! solved *exactly* — which is why this plugin selects the pairwise
//! greedy engine ([`Mode::Greedy`] with `pairwise`).  Everything
//! algorithmic — incremental gradient, fused select+update sweeps,
//! shrinking, KKT stopping, warm-start clipping — lives once in
//! [`crate::solver::core`]; this file contributes only the hinge
//! box, the `y_i` sign pattern folded into Q, the dual objective, and
//! the α → signed-coefficient map.

use super::core::{Loss, Mode};
use super::box_c;

/// The hinge [`Loss`] plugin: per-label box heights and the label
/// sign pattern.
pub struct HingeLoss<'a> {
    y: &'a [f32],
    hi: Vec<f32>,
}

impl<'a> HingeLoss<'a> {
    pub fn new(y: &'a [f32], lambda: f32, w: f32) -> HingeLoss<'a> {
        let c = box_c(lambda, y.len());
        let hi = y
            .iter()
            .map(|&yi| if yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c })
            .collect();
        HingeLoss { y, hi }
    }
}

impl Loss for HingeLoss<'_> {
    #[inline]
    fn n(&self) -> usize {
        self.y.len()
    }

    #[inline]
    fn mode(&self) -> Mode {
        Mode::Greedy { pairwise: true }
    }

    #[inline]
    fn bounds(&self, i: usize) -> (f32, f32) {
        (0.0, self.hi[i])
    }

    #[inline]
    fn sign(&self, i: usize) -> f32 {
        self.y[i]
    }

    #[inline]
    fn init_state(&self, _i: usize) -> f32 {
        -1.0
    }

    /// Dual objective ½αᵀQα − 1ᵀα = ½αᵀ(g − 1)  (since g = Qα − 1 ⇒
    /// αᵀQα = αᵀg + 1ᵀα).
    fn objective(&self, x: &[f32], g: &[f32]) -> f32 {
        x.iter().zip(g).map(|(&a, &gi)| 0.5 * a * (gi - 1.0)).sum()
    }

    /// Signed expansion coefficients `coef_i = α_i y_i`, so downstream
    /// code never needs labels again.
    fn coef(&self, x: Vec<f32>) -> Vec<f32> {
        x.iter().zip(self.y).map(|(&a, &yi)| a * yi).collect()
    }
}

/// Raw dual α values (needed by warm-start bookkeeping in the CV loop,
/// which stores α rather than signed coefficients).
pub fn alpha_from_solution(sol: &super::Solution, y: &[f32]) -> Vec<f32> {
    sol.coef.iter().zip(y).map(|(&c, &yi)| c * yi).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::solver::{Solution, SolverKind, SolverParams};

    fn solve(
        k: &mut DenseGram,
        y: &[f32],
        lambda: f32,
        w: f32,
        params: &SolverParams,
        warm: Option<&[f32]>,
    ) -> Solution {
        crate::solver::solve(SolverKind::Hinge { w }, k, y, lambda, params, warm)
    }

    fn separable() -> (Matrix, Vec<f32>) {
        // two tight clusters at ±2 in 1-d
        let x = Matrix::from_rows(&[&[-2.0], &[-1.9], &[-2.1], &[2.0], &[1.9], &[2.1]]);
        let y = vec![-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let k = GramBackend::Blocked.gram(&x, &x, 2.0, KernelKind::Gauss);
        (k, y)
    }

    #[test]
    fn separates_clusters() {
        let (k, y) = separable();
        let sol = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        for (fi, yi) in f.iter().zip(&y) {
            assert!(fi * yi > 0.0, "decision {fi} label {yi}");
        }
    }

    #[test]
    fn alpha_within_box() {
        let (k, y) = separable();
        let lambda = 0.05;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, 0.5, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for (ci, yi) in sol.coef.iter().zip(&y) {
            let a = ci * yi; // recover α
            assert!((-1e-6..=c + 1e-6).contains(&a), "alpha {a} outside [0,{c}]");
        }
    }

    #[test]
    fn warm_start_fewer_iterations() {
        let (k, y) = separable();
        let cold = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        let warm_alpha = alpha_from_solution(&cold, &y);
        let warm =
            solve(&mut DenseGram::new(&k), &y, 0.008, 0.5, &SolverParams::default(), Some(&warm_alpha));
        let cold2 = solve(&mut DenseGram::new(&k), &y, 0.008, 0.5, &SolverParams::default(), None);
        assert!(warm.iterations <= cold2.iterations, "{} > {}", warm.iterations, cold2.iterations);
        assert!((warm.objective - cold2.objective).abs() < 1e-3 * (1.0 + cold2.objective.abs()));
    }

    #[test]
    fn weighted_box_asymmetric() {
        let (k, y) = separable();
        let lambda = 0.05;
        let w = 0.9;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, w, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for (ci, yi) in sol.coef.iter().zip(&y) {
            let a = ci * yi;
            let hi = if *yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c };
            assert!(a <= hi + 1e-6);
        }
    }

    #[test]
    fn objective_decreases_with_smaller_lambda() {
        // smaller λ ⇒ bigger box ⇒ lower (more negative) dual minimum
        let (k, y) = separable();
        let a = solve(&mut DenseGram::new(&k), &y, 0.1, 0.5, &SolverParams::default(), None);
        let b = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        assert!(b.objective <= a.objective + 1e-6);
    }

    #[test]
    fn shrinking_preserves_objective() {
        let (k, y) = separable();
        let off = SolverParams { shrink_every: 0, ..Default::default() };
        let on = SolverParams { shrink_every: 4, ..Default::default() };
        let a = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &off, None);
        let b = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &on, None);
        assert!(
            (a.objective - b.objective).abs() < 1e-2 * (1.0 + a.objective.abs()),
            "{} vs {}",
            a.objective,
            b.objective
        );
    }
}
