//! Offset-free (weighted) hinge-loss solver — liquidSVM's classification
//! core, after Steinwart, Hush & Scovel (2011).
//!
//! Dual problem (no offset ⇒ no equality constraint):
//!
//!   min_α  ½ αᵀQα − 1ᵀα,   0 ≤ α_i ≤ C_i,   Q_ij = y_i y_j K_ij,
//!
//! with C_i = 2w·C for positive samples and 2(1−w)·C for negatives
//! (C = 1/(2λn); w = 0.5 recovers the unweighted machine).  Because the
//! constraint set is a box, a two-coordinate working set can be solved
//! *exactly* (unconstrained 2×2 Newton step, then the best of the four
//! clamped edges), which is the design the paper's solvers follow.
//! The gradient is maintained incrementally, stopping is by maximal KKT
//! violation, and warm starting clips a previous α into the new box and
//! rebuilds the gradient at O(n·#SV).

use crate::kernel::plane::GramSource;

use super::{box_c, Solution, SolverParams};

/// KKT violation of coordinate `i` (how much the objective can decrease
/// by moving α_i): positive ⇒ movable.
#[inline]
fn violation(alpha: f32, g: f32, hi: f32) -> f32 {
    let mut v: f32 = 0.0;
    if alpha < hi {
        v = v.max(-g); // can increase α
    }
    if alpha > 0.0 {
        v = v.max(g); // can decrease α
    }
    v
}

/// Exact minimizer of ½ q a² + g a over a ∈ [lo, hi] relative step.
#[inline]
fn clip_step(alpha: f32, g: f32, q: f32, lo: f32, hi: f32) -> f32 {
    let target = alpha - g / q.max(1e-12);
    target.clamp(lo, hi) - alpha
}

pub fn solve<K: GramSource + ?Sized>(
    k: &mut K,
    y: &[f32],
    lambda: f32,
    w: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    let n = y.len();
    assert_eq!(k.rows(), n);
    assert_eq!(k.cols(), n);
    let c = box_c(lambda, n);
    let hi: Vec<f32> = y
        .iter()
        .map(|&yi| if yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c })
        .collect();

    // warm start: clip previous α into the new box (α from a smaller C
    // is always feasible when λ decreases, so clipping is a no-op on
    // the canonical grid ordering)
    let mut alpha: Vec<f32> = match warm {
        Some(prev) => prev.iter().zip(&hi).map(|(&a, &h)| a.clamp(0.0, h)).collect(),
        None => vec![0.0; n],
    };

    // gradient g = Qα − 1, built from non-zero coordinates only
    let mut g: Vec<f32> = vec![-1.0; n];
    for j in 0..n {
        if alpha[j] != 0.0 {
            let aj = alpha[j] * y[j];
            let krow = k.row(j);
            for i in 0..n {
                g[i] += y[i] * aj * krow[i];
            }
        }
    }

    // initial greedy selection; subsequent selections are fused into
    // the gradient-update pass (one O(n) sweep per iteration instead of
    // three — ~2x measured on the CV hot path, §Perf)
    let select = |alpha: &[f32], g: &[f32]| {
        let (mut i1, mut v1) = (usize::MAX, 0.0f32);
        let (mut i2, mut v2) = (usize::MAX, 0.0f32);
        for i in 0..alpha.len() {
            let v = violation(alpha[i], g[i], hi[i]);
            if v > v1 {
                i2 = i1;
                v2 = v1;
                i1 = i;
                v1 = v;
            } else if v > v2 {
                i2 = i;
                v2 = v;
            }
        }
        (i1, v1, i2, v2)
    };
    let (mut i1, mut v1, mut i2, mut _v2) = select(&alpha, &g);

    let mut iters = 0usize;
    while iters < params.max_iter {
        if i1 == usize::MAX || v1 <= params.eps {
            break;
        }

        if i2 == usize::MAX || i2 == i1 {
            // single movable coordinate
            let d = clip_step(alpha[i1], g[i1], k.diag(i1), 0.0, hi[i1]);
            apply_step(k, y, &mut alpha, &mut g, i1, d);
            (i1, v1, i2, _v2) = select(&alpha, &g);
            iters += 1;
            continue;
        }

        // exact 2-d box solve on (i1, i2)
        let q11 = k.diag(i1).max(1e-12);
        let q22 = k.diag(i2).max(1e-12);
        let q12 = y[i1] * y[i2] * k.get(i1, i2);
        let (g1, g2) = (g[i1], g[i2]);
        let det = q11 * q22 - q12 * q12;
        let (mut d1, mut d2);
        if det > 1e-12 * q11 * q22 {
            d1 = (-g1 * q22 + g2 * q12) / det;
            d2 = (-g2 * q11 + g1 * q12) / det;
        } else {
            d1 = -g1 / q11;
            d2 = 0.0;
        }
        let in_box = |a: f32, lo: f32, hi_: f32| a >= lo - 1e-12 && a <= hi_ + 1e-12;
        if !(in_box(alpha[i1] + d1, 0.0, hi[i1]) && in_box(alpha[i2] + d2, 0.0, hi[i2])) {
            // best of the four clamped edges (exact for a 2-d box QP)
            let mut best = (f32::INFINITY, 0.0f32, 0.0f32);
            for &(fix1, bound) in &[(true, 0.0f32), (true, hi[i1]), (false, 0.0), (false, hi[i2])]
            {
                let (e1, e2) = if fix1 {
                    let a1 = bound;
                    let dd1 = a1 - alpha[i1];
                    // minimize over a2 with a1 fixed
                    let g2p = g2 + q12 * dd1;
                    let dd2 = clip_step(alpha[i2], g2p, q22, 0.0, hi[i2]);
                    (dd1, dd2)
                } else {
                    let a2 = bound;
                    let dd2 = a2 - alpha[i2];
                    let g1p = g1 + q12 * dd2;
                    let dd1 = clip_step(alpha[i1], g1p, q11, 0.0, hi[i1]);
                    (dd1, dd2)
                };
                // objective change of the candidate step
                let dobj = g1 * e1
                    + g2 * e2
                    + 0.5 * (q11 * e1 * e1 + q22 * e2 * e2)
                    + q12 * e1 * e2;
                if dobj < best.0 {
                    best = (dobj, e1, e2);
                }
            }
            d1 = best.1;
            d2 = best.2;
        }

        // fused pass: apply both gradient updates AND pick the next
        // working pair in a single sweep
        alpha[i1] += d1;
        alpha[i2] += d2;
        let yi_d1 = y[i1] * d1;
        let yi_d2 = y[i2] * d2;
        let (k1, k2) = k.row_pair(i1, i2);
        let (mut n1, mut w1) = (usize::MAX, 0.0f32);
        let (mut n2, mut w2) = (usize::MAX, 0.0f32);
        for j in 0..n {
            let gj = g[j] + y[j] * (yi_d1 * k1[j] + yi_d2 * k2[j]);
            g[j] = gj;
            let v = violation(alpha[j], gj, hi[j]);
            if v > w1 {
                n2 = n1;
                w2 = w1;
                n1 = j;
                w1 = v;
            } else if v > w2 {
                n2 = j;
                w2 = v;
            }
        }
        (i1, v1, i2, _v2) = (n1, w1, n2, w2);
        iters += 1;
    }

    // dual objective ½αᵀQα − 1ᵀα = ½αᵀ(g − 1)  (since g = Qα − 1 ⇒
    // αᵀQα = αᵀg + 1ᵀα)
    let obj: f32 = alpha
        .iter()
        .zip(&g)
        .map(|(&a, &gi)| 0.5 * a * (gi - 1.0))
        .sum();
    let coef: Vec<f32> = alpha.iter().zip(y).map(|(&a, &yi)| a * yi).collect();
    Solution::from_coef(coef, obj, iters)
}

#[inline]
fn apply_step<K: GramSource + ?Sized>(
    k: &mut K,
    y: &[f32],
    alpha: &mut [f32],
    g: &mut [f32],
    i: usize,
    d: f32,
) {
    if d == 0.0 {
        return;
    }
    alpha[i] += d;
    let yi_d = y[i] * d;
    let krow = k.row(i);
    for (j, gj) in g.iter_mut().enumerate() {
        *gj += y[j] * yi_d * krow[j];
    }
}

/// Raw dual α values (needed by warm-start bookkeeping in the CV loop,
/// which stores α rather than signed coefficients).
pub fn alpha_from_solution(sol: &Solution, y: &[f32]) -> Vec<f32> {
    sol.coef.iter().zip(y).map(|(&c, &yi)| c * yi).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::plane::DenseGram;
    use crate::kernel::{GramBackend, KernelKind};
    use crate::data::matrix::Matrix;

    fn separable() -> (Matrix, Vec<f32>) {
        // two tight clusters at ±2 in 1-d
        let x = Matrix::from_rows(&[&[-2.0], &[-1.9], &[-2.1], &[2.0], &[1.9], &[2.1]]);
        let y = vec![-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let k = GramBackend::Blocked.gram(&x, &x, 2.0, KernelKind::Gauss);
        (k, y)
    }

    #[test]
    fn separates_clusters() {
        let (k, y) = separable();
        let sol = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        let f = sol.decision_values(&k);
        for (fi, yi) in f.iter().zip(&y) {
            assert!(fi * yi > 0.0, "decision {fi} label {yi}");
        }
    }

    #[test]
    fn alpha_within_box() {
        let (k, y) = separable();
        let lambda = 0.05;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, 0.5, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for (ci, yi) in sol.coef.iter().zip(&y) {
            let a = ci * yi; // recover α
            assert!((-1e-6..=c + 1e-6).contains(&a), "alpha {a} outside [0,{c}]");
        }
    }

    #[test]
    fn warm_start_fewer_iterations() {
        let (k, y) = separable();
        let cold = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        let warm_alpha = alpha_from_solution(&cold, &y);
        let warm = solve(&mut DenseGram::new(&k), &y, 0.008, 0.5, &SolverParams::default(), Some(&warm_alpha));
        let cold2 = solve(&mut DenseGram::new(&k), &y, 0.008, 0.5, &SolverParams::default(), None);
        assert!(warm.iterations <= cold2.iterations, "{} > {}", warm.iterations, cold2.iterations);
        assert!((warm.objective - cold2.objective).abs() < 1e-3 * (1.0 + cold2.objective.abs()));
    }

    #[test]
    fn weighted_box_asymmetric() {
        let (k, y) = separable();
        let lambda = 0.05;
        let w = 0.9;
        let sol = solve(&mut DenseGram::new(&k), &y, lambda, w, &SolverParams::default(), None);
        let c = box_c(lambda, y.len());
        for (ci, yi) in sol.coef.iter().zip(&y) {
            let a = ci * yi;
            let hi = if *yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c };
            assert!(a <= hi + 1e-6);
        }
    }

    #[test]
    fn objective_decreases_with_smaller_lambda() {
        // smaller λ ⇒ bigger box ⇒ lower (more negative) dual minimum
        let (k, y) = separable();
        let a = solve(&mut DenseGram::new(&k), &y, 0.1, 0.5, &SolverParams::default(), None);
        let b = solve(&mut DenseGram::new(&k), &y, 0.01, 0.5, &SolverParams::default(), None);
        assert!(b.objective <= a.objective + 1e-6);
    }
}
