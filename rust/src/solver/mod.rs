//! SVM dual solvers.
//!
//! All solvers follow the design principles of the offset-free hinge
//! solver of Steinwart, Hush & Scovel (2011) ("Training SVMs without
//! offset", JMLR 12) that the paper cites as the basis of every
//! liquidSVM solver (§3): solve the dual of
//!
//!   min_f  λ‖f‖²_H + (1/n) Σ L_w(y_i, f(x_i))           (paper eq. 1)
//!
//! without a bias term, by coordinate descent over the dual variables
//! with greedy working-set selection, exact 1-d/2-d subproblem solves,
//! KKT-violation stopping, and warm starts along the (γ, λ) grid.
//! Predictions are `f(x) = Σ_j coef_j · k(x_j, x)` with signed
//! coefficients, so downstream code never needs labels again.
//!
//! Since the solver-core rebuild (DESIGN.md §Solver-core) the
//! algorithmic machinery lives exactly once, in [`core`]: a [`Loss`]
//! trait (box bounds, sign pattern, exact 1-d/2-d solves, objective)
//! that the four losses implement as thin plugins, and a shared
//! engine owning incremental gradient maintenance, fused
//! select+update sweeps, KKT stopping, **shrinking** (periodically
//! dropping coordinates pinned at a box bound, with a mandatory
//! unshrink verification pass before any termination), and warm-start
//! clipping.  `SolverParams::shrink_every` controls the shrink
//! cadence; `0` disables it and reproduces the pre-engine solvers
//! bit-for-bit.
//!
//! Solvers read kernel values through the Gram plane's
//! [`GramSource`] contract (rows, row pairs, entries, and the
//! active-set `gather` path shrinking relies on) rather than a
//! concrete `&Matrix`, so the same code runs against a borrowed dense
//! Gram ([`DenseGram`]), a worker's reusable exponentiation buffer
//! (`kernel::plane::GramBuffer`), or a memory-capped streaming source
//! (`kernel::plane::StreamedGram`) — see DESIGN.md §Compute-plane.
//!
//! * [`hinge`]     — (weighted) hinge loss, classification
//! * [`ls`]        — least squares, mean regression (CG on K + nλI)
//! * [`quantile`]  — pinball loss, quantile regression
//! * [`expectile`] — asymmetric LS, expectile regression (Farooq &
//!                   Steinwart 2017)

pub mod core;
pub mod expectile;
pub mod hinge;
pub mod ls;
pub mod quantile;

pub use self::core::{Loss, Mode};

use crate::data::matrix::Matrix;
use crate::kernel::plane::{DenseGram, GramSource};

/// Which loss/solver to run for a task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SolverKind {
    /// weighted hinge; `w` is the positive-class weight in (0,1),
    /// 0.5 = unweighted
    Hinge { w: f32 },
    /// least squares regression / OvA-LS classification
    LeastSquares,
    /// pinball at quantile `tau`
    Quantile { tau: f32 },
    /// asymmetric least squares at expectile `tau`
    Expectile { tau: f32 },
}

/// Solver tolerances / limits (liquidSVM's solver controls).
#[derive(Clone, Copy, Debug)]
pub struct SolverParams {
    /// KKT-violation stopping threshold
    pub eps: f32,
    /// hard cap on coordinate-descent iterations (coordinate updates;
    /// a 2-coordinate step spends 2).  Exception: the CG
    /// least-squares engine keeps its historical semantics and reads
    /// this as a cap on CG *rounds* (further bounded at 4n+50), while
    /// still *reporting* `Solution::iterations` as coordinate updates
    /// (rounds·n)
    pub max_iter: usize,
    /// coordinate updates between active-set refreshes of the
    /// shrinking engine; `0` disables shrinking (every sweep touches
    /// all n coordinates, reproducing the pre-engine solvers
    /// bit-for-bit)
    pub shrink_every: usize,
}

impl Default for SolverParams {
    fn default() -> Self {
        SolverParams { eps: 1e-3, max_iter: 200_000, shrink_every: 1000 }
    }
}

/// A trained dual solution for one (λ, γ) pair on one working set.
#[derive(Clone, Debug)]
pub struct Solution {
    /// signed expansion coefficients; `f(x) = Σ coef_j k(x_j, x)`
    pub coef: Vec<f32>,
    /// dual objective value at termination
    pub objective: f32,
    /// coordinate updates performed (a 2-coordinate step counts as 2,
    /// a CG round as n — totals compare like with like across losses)
    pub iterations: usize,
    /// number of non-zero coefficients
    pub n_sv: usize,
    /// gradient/state entries written by the engine's sweeps — the
    /// O(n·iterations) core cost; a shrunk sweep writes |active|
    /// entries instead of n, so this is the per-solve view of the
    /// global `solver_sweeps` counter
    pub sweep_entries: u64,
}

impl Solution {
    pub fn from_coef(coef: Vec<f32>, objective: f32, iterations: usize) -> Self {
        let n_sv = coef.iter().filter(|&&c| c != 0.0).count();
        Solution { coef, objective, iterations, n_sv, sweep_entries: 0 }
    }

    /// Decision values on a precomputed cross-Gram `[m × n]`.
    pub fn decision_values(&self, k_cross: &Matrix) -> Vec<f32> {
        self.decision_values_src(&mut DenseGram::new(k_cross))
    }

    /// Decision values through any [`GramSource`] (dense, reusable
    /// buffer, or streamed) — one row sweep, no materialization.
    /// Zero coefficients are skipped (most are, at hinge solutions;
    /// prediction cost scales with #SV) via the plane's shared
    /// [`dot_sparse`](crate::kernel::plane::dot_sparse).
    pub fn decision_values_src<K: GramSource + ?Sized>(&self, k: &mut K) -> Vec<f32> {
        let n = self.coef.len();
        assert_eq!(k.cols(), n);
        (0..k.rows())
            .map(|i| crate::kernel::plane::dot_sparse(&self.coef, k.row(i)))
            .collect()
    }
}

/// Solve (1) for the given Gram source / labels / λ with an optional
/// warm start: build the loss plugin and hand it to the shared engine.
pub fn solve<K: GramSource + ?Sized>(
    kind: SolverKind,
    k: &mut K,
    y: &[f32],
    lambda: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    match kind {
        SolverKind::Hinge { w } => {
            self::core::solve_loss(&hinge::HingeLoss::new(y, lambda, w), k, params, warm)
        }
        SolverKind::LeastSquares => {
            self::core::solve_loss(&ls::LsLoss::new(y, lambda), k, params, warm)
        }
        SolverKind::Quantile { tau } => {
            self::core::solve_loss(&quantile::QuantileLoss::new(y, lambda, tau), k, params, warm)
        }
        SolverKind::Expectile { tau } => {
            self::core::solve_loss(&expectile::ExpectileLoss::new(y, lambda, tau), k, params, warm)
        }
    }
}

/// [`solve`] over a borrowed dense Gram matrix — the adapter for call
/// sites that still hold a materialized `&Matrix` (baselines, tests).
pub fn solve_dense(
    kind: SolverKind,
    k: &Matrix,
    y: &[f32],
    lambda: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> Solution {
    solve(kind, &mut DenseGram::new(k), y, lambda, params, warm)
}

/// The clipped regularization constant shared by the box-constrained
/// solvers: C = 1/(2λn) (offset-free formulation).
#[inline]
pub(crate) fn box_c(lambda: f32, n: usize) -> f32 {
    1.0 / (2.0 * lambda * n as f32)
}

/// Extract the warm-start vector for the *next* grid point from a
/// finished solution.  The hinge solver warm-starts on dual α (= coef·y);
/// the regression solvers warm-start on the coefficients directly.
/// The engine clips the vector into the target point's box, so the
/// same vector serves both the λ chain and the γ handoff of the
/// (γ, λ) warm-start plane.
pub fn warm_vector(kind: SolverKind, sol: &Solution, y: &[f32]) -> Vec<f32> {
    match kind {
        SolverKind::Hinge { .. } => sol.coef.iter().zip(y).map(|(&c, &yi)| c * yi).collect(),
        _ => sol.coef.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_values_skip_zero_coefs() {
        let sol = Solution::from_coef(vec![0.0, 2.0], 0.0, 1);
        assert_eq!(sol.n_sv, 1);
        let k = Matrix::from_rows(&[&[0.5, 0.25]]);
        assert_eq!(sol.decision_values(&k), vec![0.5]);
    }

    #[test]
    fn box_c_scales_inverse_n_lambda() {
        assert!((box_c(0.5, 10) - 0.1).abs() < 1e-7);
    }

    #[test]
    fn default_params_enable_shrinking() {
        let p = SolverParams::default();
        assert_eq!(p.shrink_every, 1000);
        // struct-update syntax keeps call sites that only tweak eps
        let q = SolverParams { eps: 1e-5, ..Default::default() };
        assert_eq!(q.shrink_every, 1000);
    }
}
