//! libsvm-style SMO baseline: C-SVC **with** offset, solved by the
//! classic two-variable SMO with maximal-violating-pair selection.
//!
//! This is the comparator for the e1071/libsvm columns of Tables 1/6/7.
//! Structural differences to our solver are the ones that matter in the
//! paper's comparison and are kept faithfully:
//! * equality constraint Σ α_i y_i = 0 (the offset), so the working set
//!   is always a (i,j) pair moved in opposite directions;
//! * no warm starts across the (γ, cost) grid — every grid point starts
//!   from α = 0 (libsvm behaviour);
//! * the kernel is evaluated with libsvm's `exp(-γ_lib·d²)`
//!   parameterization.

use crate::data::matrix::Matrix;

/// SMO solution with offset.
#[derive(Clone, Debug)]
pub struct SmoModel {
    /// signed coefficients α_i·y_i over the training set
    pub coef: Vec<f32>,
    pub bias: f32,
    pub iterations: usize,
}

/// Train C-SVC with offset on a precomputed Gram matrix.
pub fn train_smo(k: &Matrix, y: &[f32], c: f32, eps: f32, max_iter: usize) -> SmoModel {
    let n = y.len();
    let mut alpha = vec![0.0f32; n];
    // g_i = ∇_i = Σ_j α_j y_i y_j K_ij − 1
    let mut g = vec![-1.0f32; n];
    let mut iters = 0usize;

    while iters < max_iter {
        // maximal violating pair (Keerthi et al. / libsvm WSS1)
        let mut i_up = usize::MAX;
        let mut g_up = f32::NEG_INFINITY; // max of −y_i g_i over I_up
        let mut i_lo = usize::MAX;
        let mut g_lo = f32::INFINITY; // min of −y_i g_i over I_low
        for t in 0..n {
            let v = -y[t] * g[t];
            let can_up = (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0);
            let can_lo = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c);
            if can_up && v > g_up {
                g_up = v;
                i_up = t;
            }
            if can_lo && v < g_lo {
                g_lo = v;
                i_lo = t;
            }
        }
        if i_up == usize::MAX || i_lo == usize::MAX || g_up - g_lo <= eps {
            break;
        }
        let (i, j) = (i_up, i_lo);

        // two-variable subproblem along the constraint Σ α y = 0
        let kii = k.get(i, i);
        let kjj = k.get(j, j);
        let kij = k.get(i, j);
        let eta = (kii + kjj - 2.0 * kij).max(1e-12);
        // step on α_i in the y_i direction
        let delta = (g_up - g_lo) / eta;
        // box limits for the pair move
        let mut di = y[i] * delta;
        // clamp α_i
        let ai = (alpha[i] + di).clamp(0.0, c);
        di = ai - alpha[i];
        let mut dj = -y[i] * y[j] * di;
        let aj = (alpha[j] + dj).clamp(0.0, c);
        dj = aj - alpha[j];
        di = -y[i] * y[j] * dj;

        alpha[i] += di;
        alpha[j] += dj;
        let (yi_di, yj_dj) = (y[i] * di, y[j] * dj);
        let ki = k.row(i);
        let kj = k.row(j);
        for t in 0..n {
            g[t] += y[t] * (yi_di * ki[t] + yj_dj * kj[t]);
        }
        iters += 1;
    }

    // bias from the margin support vectors (libsvm's rho)
    let mut sum = 0.0f32;
    let mut cnt = 0usize;
    for t in 0..n {
        if alpha[t] > 1e-8 && alpha[t] < c - 1e-8 {
            sum += -y[t] * g[t];
            cnt += 1;
        }
    }
    let bias = if cnt > 0 {
        sum / cnt as f32
    } else {
        // fall back to midpoint of the violating-pair bounds
        let mut up = f32::NEG_INFINITY;
        let mut lo = f32::INFINITY;
        for t in 0..n {
            let v = -y[t] * g[t];
            up = up.max(v);
            lo = lo.min(v);
        }
        0.5 * (up + lo)
    };

    let coef = alpha.iter().zip(y).map(|(&a, &yi)| a * yi).collect();
    SmoModel { coef, bias, iterations: iters }
}

impl SmoModel {
    /// Decision values on a cross-Gram `[m × n]`.
    pub fn decision_values(&self, k_cross: &Matrix) -> Vec<f32> {
        (0..k_cross.rows())
            .map(|i| {
                let row = k_cross.row(i);
                let mut s = self.bias;
                for (j, &c) in self.coef.iter().enumerate() {
                    if c != 0.0 {
                        s += c * row[j];
                    }
                }
                s
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{GramBackend, KernelKind};

    fn gram(x: &Matrix, gamma_lib: f32) -> Matrix {
        // libsvm parameterization
        let g = KernelKind::from_libsvm_gamma(gamma_lib);
        GramBackend::Blocked.gram(x, x, g, KernelKind::Gauss)
    }

    #[test]
    fn separates_shifted_clusters() {
        let x = Matrix::from_rows(&[
            &[-2.0, 0.0], &[-2.2, 0.1], &[-1.9, -0.2],
            &[2.0, 0.0], &[2.1, 0.2], &[1.8, -0.1],
        ]);
        let y = vec![-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let k = gram(&x, 0.5);
        let m = train_smo(&k, &y, 10.0, 1e-3, 100_000);
        let f = m.decision_values(&k);
        for (fi, yi) in f.iter().zip(&y) {
            assert!(fi * yi > 0.0, "{fi} vs label {yi}");
        }
    }

    #[test]
    fn equality_constraint_preserved() {
        let x = Matrix::from_rows(&[&[-1.0], &[-0.8], &[0.9], &[1.1], &[1.3]]);
        let y = vec![-1.0, -1.0, 1.0, 1.0, 1.0];
        let k = gram(&x, 1.0);
        let m = train_smo(&k, &y, 5.0, 1e-4, 100_000);
        // Σ coef = Σ α y must be ~0 (offset dual constraint)
        let s: f32 = m.coef.iter().sum();
        assert!(s.abs() < 1e-4, "sum alpha*y = {s}");
    }

    #[test]
    fn alphas_in_box() {
        let x = Matrix::from_rows(&[&[-1.0], &[0.0], &[0.5], &[1.0]]);
        let y = vec![-1.0, -1.0, 1.0, 1.0];
        let c = 2.0;
        let k = gram(&x, 1.0);
        let m = train_smo(&k, &y, c, 1e-4, 100_000);
        for (cf, yi) in m.coef.iter().zip(&y) {
            let a = cf * yi;
            assert!((-1e-5..=c + 1e-5).contains(&a));
        }
    }
}
