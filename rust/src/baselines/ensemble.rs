//! EnsembleSVM-style baseline (Table 3 "Esvm"): bag-of-SVMs — train
//! full (offset) SMO machines on random subsamples of size `k` and
//! combine by majority vote.  Unlike liquidSVM's spatial cells the
//! chunks are random, every machine sees a diluted version of the whole
//! problem, and prediction pays for ALL machines on every test point —
//! both effects visible in the paper's Table 3/9 columns.

use crate::data::dataset::Dataset;
use crate::data::matrix::Matrix;
use crate::data::rng::Rng;
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::Confusion;

use super::smo::{train_smo, SmoModel};

/// A bagged ensemble of offset SVMs.
pub struct EnsembleModel {
    pub members: Vec<(SmoModel, Matrix)>,
    pub gamma: f32,
}

/// Train `n_members` machines on random subsamples of size `chunk`.
pub fn train_ensemble(
    data: &Dataset,
    chunk: usize,
    n_members: usize,
    gamma: f32,
    cost: f32,
    seed: u64,
) -> EnsembleModel {
    let n = data.len();
    let mut rng = Rng::new(seed ^ 0xe5b);
    let members = (0..n_members)
        .map(|_| {
            let idx = rng.sample_indices(n, chunk.min(n));
            let sub = data.subset(&idx);
            let k = GramBackend::Blocked.gram(&sub.x, &sub.x, gamma, KernelKind::Gauss);
            let m = train_smo(&k, &sub.y, cost, 1e-3, 200_000);
            (m, sub.x)
        })
        .collect();
    EnsembleModel { members, gamma }
}

impl EnsembleModel {
    /// Majority vote over member sign predictions.
    pub fn predict(&self, x: &Matrix) -> Vec<f32> {
        let mut votes = vec![0i32; x.rows()];
        for (m, sv) in &self.members {
            let k = GramBackend::Blocked.gram(x, sv, self.gamma, KernelKind::Gauss);
            for (i, v) in m.decision_values(&k).into_iter().enumerate() {
                votes[i] += if v >= 0.0 { 1 } else { -1 };
            }
        }
        votes.iter().map(|&v| if v >= 0 { 1.0 } else { -1.0 }).collect()
    }

    pub fn test_error(&self, test: &Dataset) -> f32 {
        let preds = self.predict(&test.x);
        Confusion::from_scores(&test.y, &preds).error()
    }
}

/// Outer grid CV for the ensemble (scripted, as in the paper's B.2).
pub fn ensemble_grid_cv(
    data: &Dataset,
    chunk: usize,
    n_members: usize,
    gammas: &[f32],
    costs: &[f32],
    seed: u64,
) -> (EnsembleModel, f32) {
    let split = data.split(data.len() * 4 / 5, seed);
    let mut best: Option<(EnsembleModel, f32)> = None;
    for &g in gammas {
        for &c in costs {
            let m = train_ensemble(&split.train, chunk, n_members, g, c, seed);
            let err = m.test_error(&split.test);
            if best.as_ref().map_or(true, |(_, be)| err < *be) {
                best = Some((m, err));
            }
        }
    }
    best.expect("non-empty grid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn ensemble_learns_banana() {
        let d = synth::banana_binary(400, 1);
        let m = train_ensemble(&d, 100, 5, 1.0, 10.0, 2);
        let test = synth::banana_binary(150, 3);
        assert!(m.test_error(&test) < 0.25);
    }

    #[test]
    fn more_members_not_worse() {
        let d = synth::by_name("cod-rna", 600, 4).unwrap();
        let test = synth::by_name("cod-rna", 300, 5).unwrap();
        let one = train_ensemble(&d, 120, 1, 1.0, 10.0, 6).test_error(&test);
        let five = train_ensemble(&d, 120, 7, 1.0, 10.0, 6).test_error(&test);
        assert!(five <= one + 0.05, "7 members {five} vs 1 member {one}");
    }

    #[test]
    fn vote_output_is_sign() {
        let d = synth::banana_binary(120, 8);
        let m = train_ensemble(&d, 60, 3, 1.0, 5.0, 9);
        for p in m.predict(&d.x) {
            assert!(p == 1.0 || p == -1.0);
        }
    }
}
