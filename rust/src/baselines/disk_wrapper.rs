//! SVMlight-through-klaR baseline shape (Table 1's slowest column).
//!
//! klaR wraps the SVMlight *command line*, so every single grid point
//! round-trips the fold data through files on disk before the solver
//! even starts ("SVMlight is quite slow here due to disk accesses in
//! the wrapper").  This baseline reproduces that tax honestly: for each
//! (γ, cost, fold) it writes train+validation sets in LIBSVM text
//! format, re-reads and re-parses them, and only then trains (with the
//! same SMO core as the libsvm baseline — the wrapper overhead, not the
//! solver, is what distinguishes the column).

use std::path::PathBuf;

use anyhow::Result;

use crate::data::dataset::Dataset;
use crate::data::folds::{make_folds, FoldKind};
use crate::data::io::{read_libsvm, write_libsvm};
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::Loss;

use super::naive_cv::OuterCvResult;
use super::smo::train_smo;

/// Grid search with per-point disk round-trips.
pub fn disk_wrapper_cv(
    data: &Dataset,
    gammas_lib: &[f32],
    costs: &[f32],
    folds: usize,
    seed: u64,
    work_dir: &PathBuf,
) -> Result<OuterCvResult> {
    std::fs::create_dir_all(work_dir)?;
    let f = make_folds(data, folds, FoldKind::Stratified, seed);
    let mut best = (f32::NAN, f32::NAN, f32::INFINITY);
    let mut gram_computations = 0usize;
    for &gl in gammas_lib {
        let gamma = KernelKind::from_libsvm_gamma(gl);
        for &c in costs {
            let mut loss_sum = 0.0f32;
            for fi in 0..folds {
                // === the klaR wrapper tax: write → spawn → read =====
                let tr_path = work_dir.join(format!("train-{fi}.light"));
                let va_path = work_dir.join(format!("val-{fi}.light"));
                write_libsvm(&tr_path, &data.subset(&f.train_indices(fi)))?;
                write_libsvm(&va_path, &data.subset(f.val_indices(fi)))?;
                let tr = read_libsvm(&tr_path, data.dim())?;
                let va = read_libsvm(&va_path, data.dim())?;
                // ====================================================
                let kt = GramBackend::Blocked.gram(&tr.x, &tr.x, gamma, KernelKind::Gauss);
                let kv = GramBackend::Blocked.gram(&va.x, &tr.x, gamma, KernelKind::Gauss);
                gram_computations += 2;
                let m = train_smo(&kt, &tr.y, c, 1e-3, 200_000);
                let preds = m.decision_values(&kv);
                loss_sum += Loss::Classification.mean(&va.y, &preds);
            }
            let mean = loss_sum / folds as f32;
            if mean < best.2 {
                best = (gamma, c, mean);
            }
        }
    }
    Ok(OuterCvResult {
        best_gamma: best.0,
        best_cost_or_lambda: best.1,
        best_val_loss: best.2,
        gram_computations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn disk_wrapper_works_and_is_slower() {
        let d = synth::banana_binary(120, 3);
        let dir = std::env::temp_dir().join(format!("liquidsvm-dw-{}", std::process::id()));
        let t0 = std::time::Instant::now();
        let r = disk_wrapper_cv(&d, &[1.0], &[1.0], 3, 1, &dir).unwrap();
        let disk_time = t0.elapsed();
        assert!(r.best_val_loss < 0.4);
        let t1 = std::time::Instant::now();
        let _ = super::super::naive_cv::outer_cv_smo(&d, &[1.0], &[1.0], 3, 1);
        let mem_time = t1.elapsed();
        // the wrapper must pay a measurable tax over the in-memory loop
        assert!(disk_time > mem_time, "{disk_time:?} <= {mem_time:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
