//! BudgetedSVM-style baseline (Table 3 "Bsvm"): **LLSVM** — low-rank
//! linearization.  The budget is `k` landmark points; samples are
//! mapped to the k-dimensional feature φ(x) = K_z⁻½ · k(x, Z) (Nyström
//! feature space) and a *linear* SVM is trained there by SGD (Pegasos
//! shape), exactly the algorithmic family BudgetedSVM's LLSVM
//! implements.  Quality is capped by the budget (Table 9: Bsvm errors
//! well above the cell-split errors at equal k), while cost scales with
//! n·k instead of n².

use crate::data::dataset::Dataset;
use crate::data::matrix::Matrix;
use crate::data::rng::Rng;
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::Confusion;

use super::gurls::cholesky;

/// Trained LLSVM model.
pub struct LlsvmModel {
    pub landmarks: Matrix,
    /// K_z^{-1/2}-ish mapping: we store the Cholesky factor of
    /// (K_z + εI) and map via triangular solve (equivalent feature
    /// space up to rotation, which a linear SVM is invariant to)
    chol: Matrix,
    pub w: Vec<f32>,
    pub bias: f32,
    pub gamma: f32,
}

/// Nyström feature for one row: solve L f = k(x, Z).
fn nystrom_feature(chol: &Matrix, kz: &[f32]) -> Vec<f32> {
    let n = chol.rows();
    let mut f = vec![0.0f32; n];
    for i in 0..n {
        let mut s = kz[i];
        for k in 0..i {
            s -= chol.get(i, k) * f[k];
        }
        f[i] = s / chol.get(i, i);
    }
    f
}

/// Train LLSVM with `budget` landmarks and Pegasos SGD.
pub fn train_llsvm(
    data: &Dataset,
    budget: usize,
    gamma: f32,
    lambda: f32,
    epochs: usize,
    seed: u64,
) -> LlsvmModel {
    let n = data.len();
    let k = budget.min(n);
    let mut rng = Rng::new(seed ^ 0x11a4d);
    let picks = rng.sample_indices(n, k);
    let landmarks = data.x.select_rows(&picks);

    // landmark kernel matrix + ridge for stability
    let mut kz = GramBackend::Blocked.gram(&landmarks, &landmarks, gamma, KernelKind::Gauss);
    for i in 0..k {
        kz.set(i, i, kz.get(i, i) + 1e-4);
    }
    let chol = cholesky(&kz).expect("K_z + εI SPD");

    // features for all training points (n × k kernel evaluations — the
    // budget model's cost profile)
    let kx = GramBackend::Blocked.gram(&data.x, &landmarks, gamma, KernelKind::Gauss);
    let feats: Vec<Vec<f32>> = (0..n).map(|i| nystrom_feature(&chol, kx.row(i))).collect();

    // Pegasos: hinge SGD with step 1/(λ t)
    let mut w = vec![0.0f32; k];
    let mut bias = 0.0f32;
    let mut t = 1usize;
    for _ in 0..epochs {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            let eta = 1.0 / (lambda * t as f32);
            let f = &feats[i];
            let margin = data.y[i]
                * (f.iter().zip(&w).map(|(&a, &b)| a * b).sum::<f32>() + bias);
            // shrink
            let shrink = 1.0 - eta * lambda;
            for wj in &mut w {
                *wj *= shrink;
            }
            if margin < 1.0 {
                for (wj, &fj) in w.iter_mut().zip(f) {
                    *wj += eta * data.y[i] * fj;
                }
                bias += eta * data.y[i] * 0.1; // damped bias update
            }
            t += 1;
        }
    }
    LlsvmModel { landmarks, chol, w, bias, gamma }
}

impl LlsvmModel {
    pub fn decision_values(&self, x: &Matrix) -> Vec<f32> {
        let kx = GramBackend::Blocked.gram(x, &self.landmarks, self.gamma, KernelKind::Gauss);
        (0..x.rows())
            .map(|i| {
                let f = nystrom_feature(&self.chol, kx.row(i));
                f.iter().zip(&self.w).map(|(&a, &b)| a * b).sum::<f32>() + self.bias
            })
            .collect()
    }

    pub fn test_error(&self, test: &Dataset) -> f32 {
        Confusion::from_scores(&test.y, &self.decision_values(&test.x)).error()
    }
}

/// Grid-search wrapper (BudgetedSVM is tuned by outer scripts too).
pub fn llsvm_grid_cv(
    data: &Dataset,
    budget: usize,
    gammas: &[f32],
    lambdas: &[f32],
    seed: u64,
) -> (LlsvmModel, f32) {
    let split = data.split(data.len() * 4 / 5, seed);
    let mut best: Option<(LlsvmModel, f32)> = None;
    for &g in gammas {
        for &l in lambdas {
            let m = train_llsvm(&split.train, budget, g, l, 3, seed);
            let err = m.test_error(&split.test);
            if best.as_ref().map_or(true, |(_, be)| err < *be) {
                best = Some((m, err));
            }
        }
    }
    best.expect("non-empty grid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn llsvm_learns_banana() {
        let d = synth::banana_binary(400, 1);
        let m = train_llsvm(&d, 60, 1.0, 1e-4, 5, 2);
        let test = synth::banana_binary(200, 3);
        let err = m.test_error(&test);
        assert!(err < 0.25, "llsvm error {err}");
    }

    #[test]
    fn budget_caps_quality() {
        let d = synth::by_name("covtype", 700, 4).unwrap();
        let test = synth::by_name("covtype", 400, 5).unwrap();
        let tiny = train_llsvm(&d, 8, 2.0, 1e-4, 4, 6).test_error(&test);
        let big = train_llsvm(&d, 128, 2.0, 1e-4, 4, 6).test_error(&test);
        assert!(big <= tiny + 0.02, "budget 128 ({big}) vs 8 ({tiny})");
    }

    #[test]
    fn grid_cv_returns_best() {
        let d = synth::banana_binary(300, 7);
        let (_, err) = llsvm_grid_cv(&d, 40, &[0.5, 2.0], &[1e-3, 1e-5], 8);
        assert!(err < 0.35);
    }
}
