//! Comparator implementations for the paper's benchmark tables.
//!
//! Every package liquidSVM is compared against is re-implemented here
//! at the *algorithmic-family* level, so the benchmarks measure the
//! same structural differences the paper measures (integrated CV vs
//! wrapped loops, offset vs no offset, budget vs cells, disk wrappers
//! vs in-memory — see DESIGN.md §Substitutions):
//!
//! * [`smo`]          — libsvm / e1071: C-SVC with offset (SMO)
//! * [`naive_cv`]     — e1071::tune-style outer grid loops
//! * [`disk_wrapper`] — klaR/SVMlight: per-grid-point disk round-trips
//! * [`gurls`]        — GURLS: OvA kernel ridge, Cholesky per λ
//! * [`llsvm`]        — BudgetedSVM: landmark low-rank + linear SGD
//! * [`ensemble`]     — EnsembleSVM: bagged subsample SVMs, voting

pub mod disk_wrapper;
pub mod ensemble;
pub mod gurls;
pub mod llsvm;
pub mod naive_cv;
pub mod smo;
