//! "Outer CV" baselines for Table 1/6: grid search implemented by
//! *wrapping loops around a solver*, the way e1071::tune / manual Bash
//! scripts do it for packages without integrated CV.
//!
//! Two variants:
//! * [`outer_cv_smo`]   — libsvm-through-e1071 shape: SMO with offset,
//!   full Gram recomputed and solver cold-started at every
//!   (γ, cost, fold) triple;
//! * [`outer_cv_liquid`] — the paper's "liquidSVM (outer cv)" column:
//!   OUR solver, but driven the naive way (one SVM per grid point, no
//!   kernel reuse, no warm starts).  The gap between this and the
//!   integrated engine isolates exactly the CV-integration speedup.

use crate::data::dataset::Dataset;
use crate::data::folds::{make_folds, FoldKind};
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::Loss;
use crate::solver::{solve_dense, SolverKind, SolverParams};

use super::smo::train_smo;

/// Outcome of a naive grid search.
#[derive(Clone, Debug)]
pub struct OuterCvResult {
    pub best_gamma: f32,
    pub best_cost_or_lambda: f32,
    pub best_val_loss: f32,
    /// Gram matrices computed (the waste the integrated engine avoids)
    pub gram_computations: usize,
}

/// libsvm grid search: gammas in libsvm parameterization, costs as C.
pub fn outer_cv_smo(
    data: &Dataset,
    gammas_lib: &[f32],
    costs: &[f32],
    folds: usize,
    seed: u64,
) -> OuterCvResult {
    let f = make_folds(data, folds, FoldKind::Stratified, seed);
    let mut best = (f32::NAN, f32::NAN, f32::INFINITY);
    let mut gram_computations = 0usize;
    for &gl in gammas_lib {
        let gamma = KernelKind::from_libsvm_gamma(gl);
        for &c in costs {
            let mut loss_sum = 0.0f32;
            for fi in 0..folds {
                let tr = data.subset(&f.train_indices(fi));
                let va = data.subset(f.val_indices(fi));
                // the naive loop recomputes BOTH Grams at every point
                let kt = GramBackend::Blocked.gram(&tr.x, &tr.x, gamma, KernelKind::Gauss);
                let kv = GramBackend::Blocked.gram(&va.x, &tr.x, gamma, KernelKind::Gauss);
                gram_computations += 2;
                let m = train_smo(&kt, &tr.y, c, 1e-3, 200_000);
                let preds = m.decision_values(&kv);
                loss_sum += Loss::Classification.mean(&va.y, &preds);
            }
            let mean = loss_sum / folds as f32;
            if mean < best.2 {
                best = (gamma, c, mean);
            }
        }
    }
    OuterCvResult {
        best_gamma: best.0,
        best_cost_or_lambda: best.1,
        best_val_loss: best.2,
        gram_computations,
    }
}

/// Our solver driven naively: "solves in every grid-point a single SVM".
pub fn outer_cv_liquid(
    data: &Dataset,
    gammas: &[f32],
    lambdas: &[f32],
    folds: usize,
    seed: u64,
) -> OuterCvResult {
    let f = make_folds(data, folds, FoldKind::Stratified, seed);
    let params = SolverParams::default();
    let mut best = (f32::NAN, f32::NAN, f32::INFINITY);
    let mut gram_computations = 0usize;
    for &gamma in gammas {
        for &lambda in lambdas {
            let mut loss_sum = 0.0f32;
            for fi in 0..folds {
                let tr = data.subset(&f.train_indices(fi));
                let va = data.subset(f.val_indices(fi));
                let kt = GramBackend::Blocked.gram(&tr.x, &tr.x, gamma, KernelKind::Gauss);
                let kv = GramBackend::Blocked.gram(&va.x, &tr.x, gamma, KernelKind::Gauss);
                gram_computations += 2;
                // cold start, every time
                let sol =
                    solve_dense(SolverKind::Hinge { w: 0.5 }, &kt, &tr.y, lambda, &params, None);
                let preds = sol.decision_values(&kv);
                loss_sum += Loss::Classification.mean(&va.y, &preds);
            }
            let mean = loss_sum / folds as f32;
            if mean < best.2 {
                best = (gamma, lambda, mean);
            }
        }
    }
    OuterCvResult {
        best_gamma: best.0,
        best_cost_or_lambda: best.1,
        best_val_loss: best.2,
        gram_computations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn outer_smo_finds_workable_point() {
        let d = synth::banana_binary(150, 1);
        let r = outer_cv_smo(&d, &[0.5, 2.0], &[1.0, 10.0], 3, 5);
        assert!(r.best_val_loss < 0.3, "loss {}", r.best_val_loss);
        // 2 gammas x 2 costs x 3 folds x 2 grams
        assert_eq!(r.gram_computations, 24);
    }

    #[test]
    fn outer_liquid_matches_quality() {
        let d = synth::banana_binary(150, 2);
        let r = outer_cv_liquid(&d, &[1.0, 3.0], &[1e-3, 1e-4], 3, 5);
        assert!(r.best_val_loss < 0.3, "loss {}", r.best_val_loss);
    }
}
