//! GURLS-style baseline (Table 2): multiclass one-vs-all **kernel
//! regularized least squares**, solved by direct factorization.
//!
//! Faithful structural differences to our LS path:
//! * a fresh Cholesky factorization of (K + nλI) at every λ candidate
//!   (GURLS selects the cost parameter internally but re-factorizes;
//!   no warm starts, no iterative reuse);
//! * the kernel bandwidth is NOT cross-validated — GURLS sets it once
//!   by the "lower quartile of the distance matrix" heuristic the
//!   paper describes;
//! * all OvA right-hand sides share the factorization (GURLS does
//!   exploit that much).

use crate::data::dataset::Dataset;
use crate::data::folds::{make_folds, FoldKind};
use crate::data::matrix::Matrix;
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::multiclass_error;

/// Dense Cholesky factorization (in place, lower triangular).
/// Returns None if the matrix is not positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Solve L Lᵀ x = b given the Cholesky factor.
pub fn cholesky_solve(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    // forward substitution
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.get(i, k) * y[k];
        }
        y[i] = s / l.get(i, i);
    }
    // backward substitution
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.get(k, i) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// GURLS's bandwidth heuristic: lower quartile of pairwise distances.
pub fn quartile_gamma(x: &Matrix, max_sample: usize, seed: u64) -> f32 {
    let n = x.rows();
    let m = n.min(max_sample);
    let idx = crate::data::rng::Rng::new(seed).sample_indices(n, m);
    let sub = x.select_rows(&idx);
    let d2 = GramBackend::Blocked.sq_dists(&sub, &sub);
    let mut ds: Vec<f32> = Vec::with_capacity(m * (m - 1) / 2);
    for i in 0..m {
        for j in 0..i {
            ds.push(d2.get(i, j).sqrt());
        }
    }
    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ds.get(ds.len() / 4).copied().unwrap_or(1.0).max(1e-3)
}

/// A trained GURLS-style model.
pub struct GurlsModel {
    pub gamma: f32,
    pub lambda: f32,
    /// `coef[class][i]` expansion coefficients per OvA machine
    pub coef: Vec<Vec<f32>>,
    pub classes: Vec<f32>,
    pub train_x: Matrix,
    /// factorizations performed (the cost the integrated CV avoids)
    pub factorizations: usize,
}

/// Train with internal λ selection by hold-out (GURLS's `paramsel`),
/// bandwidth from the quartile heuristic.
pub fn train_gurls(data: &Dataset, lambdas: &[f32], seed: u64) -> GurlsModel {
    let gamma = quartile_gamma(&data.x, 400, seed);
    let classes = data.classes();
    let folds = make_folds(data, 5, FoldKind::Stratified, seed);
    let tr_idx = folds.train_indices(0);
    let va_idx = folds.val_indices(0).to_vec();
    let tr = data.subset(&tr_idx);
    let va = data.subset(&va_idx);

    let ktr = GramBackend::Blocked.gram(&tr.x, &tr.x, gamma, KernelKind::Gauss);
    let kva = GramBackend::Blocked.gram(&va.x, &tr.x, gamma, KernelKind::Gauss);
    let ys: Vec<Vec<f32>> = classes
        .iter()
        .map(|&c| tr.y.iter().map(|&v| if v == c { 1.0 } else { -1.0 }).collect())
        .collect();

    let mut factorizations = 0usize;
    let mut best = (lambdas[0], f32::INFINITY);
    for &lambda in lambdas {
        // fresh factorization per λ — the structural cost of the baseline
        let mut shifted = ktr.clone();
        let nl = lambda * tr.len() as f32;
        for i in 0..tr.len() {
            shifted.set(i, i, shifted.get(i, i) + nl);
        }
        let Some(l) = cholesky(&shifted) else { continue };
        factorizations += 1;
        let coefs: Vec<Vec<f32>> = ys.iter().map(|y| cholesky_solve(&l, y)).collect();
        let preds = ova_predict(&kva, &coefs, &classes);
        let err = multiclass_error(&va.y, &preds);
        if err < best.1 {
            best = (lambda, err);
        }
    }

    // final train on everything at the selected λ
    let kfull = GramBackend::Blocked.gram(&data.x, &data.x, gamma, KernelKind::Gauss);
    let mut shifted = kfull;
    let nl = best.0 * data.len() as f32;
    for i in 0..data.len() {
        shifted.set(i, i, shifted.get(i, i) + nl);
    }
    let l = cholesky(&shifted).expect("K + nλI must be SPD");
    factorizations += 1;
    let coef: Vec<Vec<f32>> = classes
        .iter()
        .map(|&c| {
            let y: Vec<f32> = data.y.iter().map(|&v| if v == c { 1.0 } else { -1.0 }).collect();
            cholesky_solve(&l, &y)
        })
        .collect();

    GurlsModel {
        gamma,
        lambda: best.0,
        coef,
        classes,
        train_x: data.x.clone(),
        factorizations,
    }
}

fn ova_predict(k_cross: &Matrix, coefs: &[Vec<f32>], classes: &[f32]) -> Vec<f32> {
    let m = k_cross.rows();
    (0..m)
        .map(|i| {
            let row = k_cross.row(i);
            let mut best = (0usize, f32::NEG_INFINITY);
            for (c, coef) in coefs.iter().enumerate() {
                let s: f32 = row.iter().zip(coef).map(|(&k, &a)| k * a).sum();
                if s > best.1 {
                    best = (c, s);
                }
            }
            classes[best.0]
        })
        .collect()
}

impl GurlsModel {
    pub fn predict(&self, x: &Matrix) -> Vec<f32> {
        let k = GramBackend::Blocked.gram(x, &self.train_x, self.gamma, KernelKind::Gauss);
        ova_predict(&k, &self.coef, &self.classes)
    }

    pub fn test_error(&self, test: &Dataset) -> f32 {
        multiclass_error(&test.y, &self.predict(&test.x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn cholesky_roundtrip() {
        // SPD matrix A = B Bᵀ + I
        let b = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 1.0]]);
        let mut a = Matrix::zeros(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..2 {
                    s += b.get(i, k) * b.get(j, k);
                }
                a.set(i, j, s);
            }
        }
        let l = cholesky(&a).unwrap();
        let x = cholesky_solve(&l, &[1.0, 2.0]);
        // check A x = b
        for i in 0..2 {
            let got: f32 = (0..2).map(|j| a.get(i, j) * x[j]).sum();
            let want = [1.0, 2.0][i];
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn quartile_gamma_positive() {
        let d = synth::by_name("landsat", 200, 1).unwrap();
        let g = quartile_gamma(&d.x, 100, 2);
        assert!(g > 0.0 && g.is_finite());
    }

    #[test]
    fn gurls_learns_multiclass() {
        let tt = synth::banana_mc(250, 120, 9);
        let m = train_gurls(&tt.train, &[1e-2, 1e-4, 1e-6], 3);
        let err = m.test_error(&tt.test);
        assert!(err < 0.35, "gurls error {err}");
        assert!(m.factorizations >= 3);
    }
}
