//! Hyper-parameter grids (paper §4 + Appendix B/C).
//!
//! * the **libsvm grid**: the 10×11 grid from libsvm's tools/grid.py,
//!   γ_lib ∈ {2³ … 2⁻¹⁵}, cost ∈ {2⁻⁵ … 2¹⁵}, converted into liquidSVM's
//!   parameterization (γ = 1/√γ_lib, λ = 1/(2·cost·n));
//! * the **default grids** (`grid_choice` 0/1/2): geometrically spaced
//!   10×10 / 15×15 / 20×20 grids "where the endpoints are scaled to
//!   accommodate the number of samples in every fold, the cell size,
//!   and the dimension".

/// A (γ, λ) candidate grid.  γ is in liquidSVM parameterization
/// (`exp(-d²/γ²)`), λ is the regularization weight of eq. (1).
#[derive(Clone, Debug)]
pub struct Grid {
    pub gammas: Vec<f32>,
    pub lambdas: Vec<f32>,
}

impl Grid {
    pub fn size(&self) -> usize {
        self.gammas.len() * self.lambdas.len()
    }

    /// Geometric sequence from hi to lo (descending), `k` points.
    pub fn geomspace_desc(hi: f32, lo: f32, k: usize) -> Vec<f32> {
        assert!(hi > lo && lo > 0.0 && k >= 2);
        let ratio = (lo / hi).powf(1.0 / (k - 1) as f32);
        (0..k).map(|i| hi * ratio.powi(i as i32)).collect()
    }

    /// The libsvm 10×11 grid for a fold of `n_fold` training samples.
    pub fn libsvm(n_fold: usize) -> Grid {
        let gammas_lib: Vec<f32> =
            [3i32, 1, -1, -3, -5, -7, -9, -11, -13, -15].iter().map(|&e| 2f32.powi(e)).collect();
        let costs: Vec<f32> =
            [-5i32, -3, -1, 1, 3, 5, 7, 9, 11, 13, 15].iter().map(|&e| 2f32.powi(e)).collect();
        Grid {
            // γ = 1/√γ_lib; ascending γ_lib ⇒ descending bandwidth —
            // order by descending γ (wide kernels first) for warm starts
            gammas: {
                let mut g: Vec<f32> = gammas_lib.iter().map(|&gl| (1.0 / gl).sqrt()).collect();
                g.sort_by(|a, b| b.partial_cmp(a).unwrap());
                g
            },
            // λ = 1/(2·C·n): descending λ (strong regularization first)
            // so each solution warm-starts the next bigger box
            lambdas: costs.iter().map(|&c| 1.0 / (2.0 * c * n_fold as f32)).collect(),
        }
    }

    /// liquidSVM default geometric grid.  `grid_choice`: 0 ⇒ 10×10,
    /// 1 ⇒ 15×15, 2 ⇒ 20×20 (Appendix C).  Endpoints are scaled by the
    /// fold size `n_fold` and dimension `d` following the package's
    /// heuristics: bandwidths span the data diameter down to the
    /// nearest-neighbour scale n^(-1/d), costs span weak to strong
    /// regularization proportionally to 1/n.
    pub fn default_grid(grid_choice: u8, n_fold: usize, d: usize) -> Grid {
        let k = match grid_choice {
            0 => 10,
            1 => 15,
            2 => 20,
            other => panic!("grid_choice {other} not in 0..=2"),
        };
        let n = n_fold.max(4) as f32;
        let dd = d.max(1) as f32;
        // data is scaled to ~unit box: diameter ~ √d
        let gamma_max = 5.0 * dd.sqrt();
        // nearest-neighbour spacing heuristic: n^(-1/d) of the diameter,
        // floored so the grid stays sane in low dimensions
        let gamma_min = (gamma_max * n.powf(-1.0 / dd.max(2.0))).max(gamma_max / 500.0);
        let lambda_max = 10.0 / n;
        let lambda_min = 1.0 / (5000.0 * n);
        Grid {
            gammas: Self::geomspace_desc(gamma_max, gamma_min, k),
            lambdas: Self::geomspace_desc(lambda_max, lambda_min, k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn libsvm_grid_is_10x11() {
        let g = Grid::libsvm(1000);
        assert_eq!(g.gammas.len(), 10);
        assert_eq!(g.lambdas.len(), 11);
        assert_eq!(g.size(), 110);
    }

    #[test]
    fn libsvm_gamma_conversion() {
        let g = Grid::libsvm(100);
        // γ_lib = 2^-15 is the smallest ⇒ γ = 2^7.5 is the largest
        let max = g.gammas.first().unwrap();
        assert!((max - 2f32.powf(7.5)).abs() < 1e-3);
    }

    #[test]
    fn grids_descend_for_warm_starts() {
        for g in [Grid::libsvm(500), Grid::default_grid(0, 800, 10)] {
            assert!(g.gammas.windows(2).all(|w| w[0] > w[1]));
            assert!(g.lambdas.windows(2).all(|w| w[0] > w[1]));
        }
    }

    #[test]
    fn grid_choice_sizes() {
        assert_eq!(Grid::default_grid(0, 1000, 5).size(), 100);
        assert_eq!(Grid::default_grid(1, 1000, 5).size(), 225);
        assert_eq!(Grid::default_grid(2, 1000, 5).size(), 400);
    }

    #[test]
    fn endpoints_scale_with_n_and_d() {
        let small = Grid::default_grid(0, 100, 4);
        let big = Grid::default_grid(0, 10_000, 4);
        // more samples ⇒ finer minimum bandwidth and smaller λ_max
        assert!(big.gammas.last().unwrap() <= small.gammas.last().unwrap());
        assert!(big.lambdas[0] < small.lambdas[0]);
        let lo_d = Grid::default_grid(0, 1000, 2);
        let hi_d = Grid::default_grid(0, 1000, 128);
        assert!(hi_d.gammas[0] > lo_d.gammas[0]);
    }
}
