//! The integrated cross-validation engine — the heart of liquidSVM's
//! speed claim (paper §2 "Hyper-Parameter Selection").
//!
//! For each fold the engine computes ONE squared-distance matrix pair
//! (train×train, val×train) and reuses it across the whole γ grid
//! ([`crate::kernel::DistanceCache`]); within each γ it walks the λ
//! grid from strong to weak regularization, warm-starting every solve
//! from the previous solution.  This is why the integrated CV is an
//! order of magnitude faster than wrapping a solver in grid loops
//! (Table 1's "outer cv" column): the naive loop pays O(n²d) kernel
//! work and a cold solver start at *every* grid point.
//!
//! `adaptivity_control` (Appendix C) prunes the grid after the first
//! fold: only candidates whose fold-0 loss is within the best
//! half/quarter are evaluated on the remaining folds.

pub mod grid;

pub use grid::Grid;

use crate::data::dataset::Dataset;
use crate::data::folds::{make_folds, FoldKind, Folds};
use crate::kernel::{DistanceCache, GramBackend, KernelKind};
use crate::metrics::Loss;
use crate::solver::{solve, warm_vector, Solution, SolverKind, SolverParams};

/// What to do after selecting (γ*, λ*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectMethod {
    /// keep the k fold models and average their decision values at test
    /// time (liquidSVM's time-efficient default)
    FoldAverage,
    /// retrain one model on the full working set at (γ*, λ*)
    RetrainOnFull,
}

/// Full CV configuration for one working set (cell × task).
#[derive(Clone, Debug)]
pub struct CvConfig {
    pub folds: usize,
    pub fold_kind: FoldKind,
    pub grid: Grid,
    pub val_loss: Loss,
    pub solver: SolverKind,
    pub kernel: KernelKind,
    /// 0 = full grid, 1 = keep best 50% after fold 0, 2 = keep best 25%
    pub adaptivity: u8,
    pub select: SelectMethod,
    pub params: SolverParams,
    pub backend: GramBackend,
    pub seed: u64,
}

impl CvConfig {
    pub fn new(grid: Grid, solver: SolverKind, val_loss: Loss) -> Self {
        CvConfig {
            folds: 5,
            fold_kind: FoldKind::Stratified,
            grid,
            val_loss,
            solver,
            kernel: KernelKind::Gauss,
            adaptivity: 0,
            select: SelectMethod::FoldAverage,
            params: SolverParams::default(),
            backend: GramBackend::default(),
            seed: 0,
        }
    }
}

/// One trained fold model: expansion coefficients over its training
/// subset (indices into the *working set* the CV ran on).
#[derive(Clone, Debug)]
pub struct FoldModel {
    pub train_idx: Vec<usize>,
    pub coef: Vec<f32>,
}

/// CV outcome for one working set.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub best_gamma: f32,
    pub best_lambda: f32,
    /// mean validation loss at the selected point
    pub best_val_loss: f32,
    /// `val[gi][li]` = mean validation loss (NaN where pruned)
    pub val_matrix: Vec<Vec<f32>>,
    pub models: Vec<FoldModel>,
    /// total coordinate/CG iterations spent (for perf accounting)
    pub total_iterations: usize,
    /// grid points actually solved (≠ grid size under adaptivity)
    pub points_evaluated: usize,
}

/// Run the integrated k-fold CV on a working set.
pub fn run_cv(data: &Dataset, cfg: &CvConfig) -> CvResult {
    let n = data.len();
    assert!(n >= cfg.folds, "working set smaller than fold count");
    let folds = make_folds(data, cfg.folds, effective_fold_kind(cfg, data), cfg.seed);
    let (ng, nl) = (cfg.grid.gammas.len(), cfg.grid.lambdas.len());

    let mut val_sum = vec![vec![0.0f32; nl]; ng];
    let mut val_cnt = vec![vec![0usize; nl]; ng];
    let mut active = vec![vec![true; nl]; ng];
    let mut total_iterations = 0usize;
    let mut points_evaluated = 0usize;

    for f in 0..folds.k() {
        let tr_idx = folds.train_indices(f);
        let va_idx = folds.val_indices(f).to_vec();
        let dtr = data.subset(&tr_idx);
        let dva = data.subset(&va_idx);
        // per-solve iteration budget scaled to the fold size: extreme
        // grid corners (huge C) would otherwise burn 10-20x more
        // iterations for solutions the selection phase discards anyway
        // (liquidSVM bounds the inner solver the same way); measured:
        // 5x CV speedup at identical selection + test error (§Perf)
        let params = SolverParams {
            max_iter: cfg.params.max_iter.min(4 * dtr.len().max(64)),
            ..cfg.params
        };

        // ONE distance computation per fold, reused across all γ
        let mut ktr = DistanceCache::new(&cfg.backend, &dtr.x, &dtr.x, cfg.kernel);
        let mut kva = DistanceCache::new(&cfg.backend, &dva.x, &dtr.x, cfg.kernel);

        for (gi, &gamma) in cfg.grid.gammas.iter().enumerate() {
            if !active[gi].iter().any(|&a| a) {
                continue;
            }
            let kt = ktr.gram(gamma).clone();
            let mut warm: Option<Vec<f32>> = None;
            let mut fold_solutions: Vec<Option<Solution>> = vec![None; nl];
            for (li, &lambda) in cfg.grid.lambdas.iter().enumerate() {
                if !active[gi][li] {
                    // pruned points are contiguous tails in practice; a
                    // cold gap costs more than it saves, so just skip
                    continue;
                }
                let sol = solve(cfg.solver, &kt, &dtr.y, lambda, &params, warm.as_deref());
                total_iterations += sol.iterations;
                points_evaluated += 1;
                warm = Some(warm_vector(cfg.solver, &sol, &dtr.y));
                fold_solutions[li] = Some(sol);
            }
            let kv = kva.gram(gamma);
            for (li, sol) in fold_solutions.iter().enumerate() {
                if let Some(sol) = sol {
                    let preds = sol.decision_values(kv);
                    val_sum[gi][li] += cfg.val_loss.mean(&dva.y, &preds);
                    val_cnt[gi][li] += 1;
                }
            }
        }

        // adaptive grid pruning after the first fold
        if f == 0 && cfg.adaptivity > 0 {
            prune_grid(&mut active, &val_sum, cfg.adaptivity);
        }
    }

    // mean losses; pick best (first hit wins ties — grids descend, so
    // that is the more strongly regularized model, liquidSVM's
    // stability tie-break)
    let mut val_matrix = vec![vec![f32::NAN; nl]; ng];
    let mut best = (0usize, 0usize, f32::INFINITY);
    for gi in 0..ng {
        for li in 0..nl {
            if val_cnt[gi][li] > 0 {
                let m = val_sum[gi][li] / val_cnt[gi][li] as f32;
                val_matrix[gi][li] = m;
                if m < best.2 - 1e-9 {
                    best = (gi, li, m);
                }
            }
        }
    }
    let (bg, bl, bloss) = best;
    let best_gamma = cfg.grid.gammas[bg];
    let best_lambda = cfg.grid.lambdas[bl];

    // final models at the selected point
    let models = match cfg.select {
        SelectMethod::FoldAverage => (0..folds.k())
            .map(|f| train_fold_model(data, &folds, f, cfg, best_gamma, best_lambda))
            .collect(),
        SelectMethod::RetrainOnFull => {
            let all: Vec<usize> = (0..n).collect();
            let kt = cfg.backend.gram(&data.x, &data.x, best_gamma, cfg.kernel);
            let sol = solve(cfg.solver, &kt, &data.y, best_lambda, &cfg.params, None);
            vec![FoldModel { train_idx: all, coef: sol.coef }]
        }
    };

    CvResult {
        best_gamma,
        best_lambda,
        best_val_loss: bloss,
        val_matrix,
        models,
        total_iterations,
        points_evaluated,
    }
}

/// Stratified folds only make sense for classification labels; fall
/// back to random folds for regression-like targets.
fn effective_fold_kind(cfg: &CvConfig, data: &Dataset) -> FoldKind {
    if cfg.fold_kind == FoldKind::Stratified && data.classes().len() > 16 {
        FoldKind::Random
    } else {
        cfg.fold_kind
    }
}

fn train_fold_model(
    data: &Dataset,
    folds: &Folds,
    f: usize,
    cfg: &CvConfig,
    gamma: f32,
    lambda: f32,
) -> FoldModel {
    let tr_idx = folds.train_indices(f);
    let dtr = data.subset(&tr_idx);
    let kt = cfg.backend.gram(&dtr.x, &dtr.x, gamma, cfg.kernel);
    // final models get a roomier budget than the selection sweeps
    let params =
        SolverParams { max_iter: cfg.params.max_iter.min(16 * dtr.len().max(64)), ..cfg.params };
    let sol = solve(cfg.solver, &kt, &dtr.y, lambda, &params, None);
    FoldModel { train_idx: tr_idx, coef: sol.coef }
}

/// Keep only grid points whose fold-0 loss is within the best
/// 50% (adaptivity 1) / 25% (adaptivity 2) quantile.
fn prune_grid(active: &mut [Vec<bool>], fold0: &[Vec<f32>], adaptivity: u8) {
    let mut losses: Vec<f32> = fold0.iter().flatten().copied().collect();
    losses.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let keep_frac = match adaptivity {
        1 => 0.5,
        _ => 0.25,
    };
    let cut_idx = ((losses.len() as f32 * keep_frac) as usize).clamp(1, losses.len() - 1);
    let cutoff = losses[cut_idx];
    for (gi, row) in active.iter_mut().enumerate() {
        for (li, a) in row.iter_mut().enumerate() {
            if fold0[gi][li] > cutoff {
                *a = false;
            }
        }
    }
}

/// Average the decision values of the fold models on test data — the
/// default test-phase combination (paper §2: "how these k models are
/// combined during the test phase").
pub fn predict_average(
    models: &[FoldModel],
    train: &Dataset,
    test_x: &crate::data::matrix::Matrix,
    gamma: f32,
    kernel: KernelKind,
    backend: &GramBackend,
) -> Vec<f32> {
    let mut acc = vec![0.0f32; test_x.rows()];
    for m in models {
        let sv = train.x.select_rows(&m.train_idx);
        let k = backend.gram(test_x, &sv, gamma, kernel);
        let sol = Solution::from_coef(m.coef.clone(), 0.0, 0);
        for (a, v) in acc.iter_mut().zip(sol.decision_values(&k)) {
            *a += v;
        }
    }
    let inv = 1.0 / models.len().max(1) as f32;
    for a in &mut acc {
        *a *= inv;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn small_cfg(n_fold: usize) -> CvConfig {
        let mut cfg = CvConfig::new(
            Grid::default_grid(0, n_fold, 2),
            SolverKind::Hinge { w: 0.5 },
            Loss::Classification,
        );
        cfg.folds = 3;
        cfg
    }

    #[test]
    fn cv_learns_banana() {
        let d = synth::banana_binary(240, 7);
        let cfg = small_cfg(160);
        let res = run_cv(&d, &cfg);
        assert!(res.best_val_loss < 0.25, "val loss {}", res.best_val_loss);
        assert_eq!(res.models.len(), 3);
        assert_eq!(res.points_evaluated, 3 * cfg.grid.size());
    }

    #[test]
    fn adaptivity_prunes_points() {
        let d = synth::banana_binary(200, 8);
        let mut cfg = small_cfg(133);
        cfg.adaptivity = 2;
        let full = run_cv(&d, &small_cfg(133));
        let pruned = run_cv(&d, &cfg);
        assert!(pruned.points_evaluated < full.points_evaluated);
        // pruning must not destroy accuracy
        assert!(pruned.best_val_loss <= full.best_val_loss + 0.08);
    }

    #[test]
    fn retrain_on_full_yields_one_model() {
        let d = synth::banana_binary(150, 9);
        let mut cfg = small_cfg(100);
        cfg.select = SelectMethod::RetrainOnFull;
        let res = run_cv(&d, &cfg);
        assert_eq!(res.models.len(), 1);
        assert_eq!(res.models[0].train_idx.len(), 150);
    }

    #[test]
    fn val_matrix_has_means() {
        let d = synth::banana_binary(120, 10);
        let res = run_cv(&d, &small_cfg(80));
        let finite = res.val_matrix.iter().flatten().filter(|v| v.is_finite()).count();
        assert_eq!(finite, res.val_matrix.len() * res.val_matrix[0].len());
    }

    #[test]
    fn fold_average_prediction_works() {
        let d = synth::banana_binary(200, 11);
        let cfg = small_cfg(133);
        let res = run_cv(&d, &cfg);
        let test = synth::banana_binary(100, 12);
        let preds = predict_average(
            &res.models, &d, &test.x, res.best_gamma, cfg.kernel, &cfg.backend,
        );
        let err = Loss::Classification.mean(&test.y, &preds);
        assert!(err < 0.3, "test error {err}");
    }

    #[test]
    fn quantile_cv_selects() {
        let d = synth::sinc_hetero(150, 13);
        let mut cfg = CvConfig::new(
            Grid::default_grid(0, 100, 1),
            SolverKind::Quantile { tau: 0.5 },
            Loss::Pinball { tau: 0.5 },
        );
        cfg.folds = 3;
        cfg.fold_kind = FoldKind::Random;
        let res = run_cv(&d, &cfg);
        assert!(res.best_val_loss.is_finite());
        assert!(res.best_val_loss < 0.2, "pinball {}", res.best_val_loss);
    }
}
