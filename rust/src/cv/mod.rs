//! The integrated cross-validation engine — the heart of liquidSVM's
//! speed claim (paper §2 "Hyper-Parameter Selection") — rebuilt on the
//! Gram plane as a **parallel grid of per-fold (γ, λ) warm-start
//! chains** (see DESIGN.md §Compute-plane and §Solver-core).
//!
//! Structure: one *task* is a fold.  Within a task the whole (γ, λ)
//! grid is walked in fixed order — γ from wide to narrow bandwidth,
//! the λ chain inside each γ from strong to weak regularization — and
//! every solve warm-starts from the previous solution: along the λ
//! chain as before, **and across the γ handoff**, where the previous
//! γ-chain's terminal α seeds the next γ's first λ (clipped into the
//! new box by the solver engine).  This is the (γ, λ) *warm-start
//! plane*: adjacent bandwidths have similar solutions, so the handoff
//! converts most first-λ solves from cold starts into a few cleanup
//! sweeps (Glasmachers 2022's "aggressive warm-starting").  The chain
//! is the part of the engine that fundamentally cannot parallelize
//! without losing that win, so parallelism lives *across folds* (and
//! across cells above this layer): fold tasks run on scoped worker
//! threads that share the read-only per-fold squared-distance
//! matrices and each own **one reusable [`GramBuffer`]** pair — per γ
//! the worker exponentiates distances in place, so the hot loop
//! performs *zero* Gram allocations (the `gram_allocs` counter stays
//! flat while `gram_misses` advances).
//!
//! Memory is governed by `CvConfig::max_gram_mb` through three tiers,
//! chosen once per run (deterministically, so results never depend on
//! scheduling):
//!
//! * **all-cached** — every fold's distance matrices fit: precompute
//!   them all and run every fold chain as one wave (maximum
//!   parallelism, the default for cell-sized working sets);
//! * **per-fold** — only one fold fits: folds run sequentially (the
//!   seed's memory profile; the grid phase is serial in this tier
//!   since the γ chain inside a fold is ordered);
//! * **streamed** — even one fold's n² won't fit: no distance matrix is
//!   ever materialized; solvers read row-tiles recomputed on demand
//!   ([`StreamedGram`]), bit-identical to the cached path.
//!
//! Parallel output is **bit-identical** to `jobs = 1`: each fold's
//! chain is a pure sequential function of the fold, results are
//! merged in fixed (fold, γ, λ) order, and tier selection does not
//! depend on worker count beyond the documented buffer budget (and
//! the tiers themselves agree bitwise).  Property-tested in
//! `tests/property_tests.rs`.
//!
//! `adaptivity_control` (Appendix C) prunes the grid after the first
//! fold: fold 0 runs as its own wave, then only candidates whose
//! fold-0 loss is within the best half/quarter are evaluated on the
//! remaining folds.

pub mod grid;

pub use grid::Grid;

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;

use crate::data::csr::CsrMatrix;
use crate::data::dataset::{distinct_labels, Dataset};
use crate::data::folds::{make_folds_y, FoldKind, Folds};
use crate::data::matrix::Matrix;
use crate::data::store::{Store, StoreRef, WorkingSet};
use crate::kernel::plane::{self, GramBuffer, GramSource, SparseGram, StreamedGram, TileBuffer};
use crate::kernel::{GramBackend, KernelKind};
use crate::metrics::Loss;
use crate::solver::{solve, warm_vector, Solution, SolverKind, SolverParams};

/// What to do after selecting (γ*, λ*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectMethod {
    /// keep the k fold models and average their decision values at test
    /// time (liquidSVM's time-efficient default)
    FoldAverage,
    /// retrain one model on the full working set at (γ*, λ*)
    RetrainOnFull,
}

/// Full CV configuration for one working set (cell × task).
#[derive(Clone, Debug)]
pub struct CvConfig {
    pub folds: usize,
    pub fold_kind: FoldKind,
    pub grid: Grid,
    pub val_loss: Loss,
    pub solver: SolverKind,
    pub kernel: KernelKind,
    /// 0 = full grid, 1 = keep best 50% after fold 0, 2 = keep best 25%
    pub adaptivity: u8,
    pub select: SelectMethod,
    pub params: SolverParams,
    pub backend: GramBackend,
    pub seed: u64,
    /// worker threads for the per-fold chain grid (1 = sequential); the
    /// coordinator derives this from the shared `--jobs` budget so
    /// cell-level and grid-level parallelism compose
    pub jobs: usize,
    /// byte budget (MiB) for resident distance/Gram state; governs the
    /// all-cached / per-fold / streamed tiers.  `None` is unlimited,
    /// which buys maximum parallelism by keeping EVERY fold's distance
    /// matrices resident at once (~(k+1)/2× the one-fold-at-a-time
    /// peak) — set a finite cap to get the fold-sequential memory
    /// profile on big monolithic working sets.
    pub max_gram_mb: Option<usize>,
}

impl CvConfig {
    pub fn new(grid: Grid, solver: SolverKind, val_loss: Loss) -> Self {
        CvConfig {
            folds: 5,
            fold_kind: FoldKind::Stratified,
            grid,
            val_loss,
            solver,
            kernel: KernelKind::Gauss,
            adaptivity: 0,
            select: SelectMethod::FoldAverage,
            params: SolverParams::default(),
            backend: GramBackend::default(),
            seed: 0,
            jobs: 1,
            max_gram_mb: None,
        }
    }
}

/// One trained fold model: expansion coefficients over its training
/// subset (indices into the *working set* the CV ran on).
#[derive(Clone, Debug)]
pub struct FoldModel {
    pub train_idx: Vec<usize>,
    pub coef: Vec<f32>,
}

/// CV outcome for one working set.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub best_gamma: f32,
    pub best_lambda: f32,
    /// mean validation loss at the selected point
    pub best_val_loss: f32,
    /// `val[gi][li]` = mean validation loss (NaN where pruned)
    pub val_matrix: Vec<Vec<f32>>,
    pub models: Vec<FoldModel>,
    /// total coordinate/CG iterations spent (for perf accounting)
    pub total_iterations: usize,
    /// grid points actually solved (≠ grid size under adaptivity)
    pub points_evaluated: usize,
}

/// One fold's immutable context, shared read-only across the γ tasks
/// of that fold.  Sample storage is a [`Store`]: fold subsets keep the
/// working set's layout (dense or CSR), so the same grid runs either
/// flavor (see DESIGN.md §Data-plane).
struct FoldCtx {
    xtr: Store,
    ytr: Vec<f32>,
    xva: Store,
    yva: Vec<f32>,
    params: SolverParams,
}

/// The kernel-state flavor a fold's tasks read through — either shared
/// cached distance matrices (exponentiated into per-worker buffers) or
/// just the row norms for streamed access.
enum FoldData {
    Cached { d2_tr: Matrix, d2_va: Matrix, ep_tr: u64, ep_va: u64 },
    Streamed { tr_norms: Vec<f32>, va_norms: Vec<f32> },
}

impl FoldData {
    fn cached(backend: &GramBackend, ctx: &FoldCtx) -> FoldData {
        let mut sp = crate::obs::span("cv.fold_data");
        let (ntr, nva) = (ctx.ytr.len(), ctx.yva.len());
        sp.add_bytes(4 * (ntr * ntr + nva * ntr) as u64);
        FoldData::Cached {
            d2_tr: backend.sq_dists_ref(ctx.xtr.as_ref(), ctx.xtr.as_ref()),
            d2_va: backend.sq_dists_ref(ctx.xva.as_ref(), ctx.xtr.as_ref()),
            ep_tr: plane::next_epoch(),
            ep_va: plane::next_epoch(),
        }
    }

    fn streamed(ctx: &FoldCtx) -> FoldData {
        let _sp = crate::obs::span("cv.fold_data");
        FoldData::Streamed {
            tr_norms: ctx.xtr.as_ref().row_sq_norms(),
            va_norms: ctx.xva.as_ref().row_sq_norms(),
        }
    }
}

/// Per-solve iteration budget for a fold of `ntr` training samples:
/// `mult`·n coordinate updates, doubled for the pairwise hinge engine
/// because a 2-coordinate step now honestly counts as 2 updates — the
/// doubled figure covers the same number of *pair* selection steps
/// the pre-engine solver's cap allowed, so capped grid-corner solves
/// keep the seed's effective budget (single-movable fallback steps
/// still cost 1, so a mixed sequence can run slightly longer than the
/// seed's pass-counted cap — strictly roomier, never tighter).  The
/// CG least-squares engine treats the cap as CG rounds, its
/// historical semantics, and bounds itself at 4n+50 rounds regardless.
fn fold_cap(solver: SolverKind, mult: usize, ntr: usize) -> usize {
    let steps = mult * ntr.max(64);
    match solver {
        SolverKind::Hinge { .. } => steps.saturating_mul(2),
        _ => steps,
    }
}

/// Memory tier of a CV run (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Tier {
    AllCached,
    PerFold,
    Streamed,
}

fn pick_tier(cap_mb: Option<usize>, jobs: usize, per_fold_elems: &[usize]) -> Tier {
    let Some(mb) = cap_mb else { return Tier::AllCached };
    let cap = mb.saturating_mul(1 << 20) / 4; // f32 elements
    let total: usize = per_fold_elems.iter().sum();
    let max_fold = per_fold_elems.iter().copied().max().unwrap_or(0);
    // cached tiers hold the shared d² plus, worst case, one
    // exponentiated fold per worker
    let worker_over = jobs.max(1).saturating_mul(max_fold);
    if total.saturating_add(worker_over) <= cap {
        Tier::AllCached
    } else if max_fold.saturating_add(worker_over) <= cap {
        Tier::PerFold
    } else {
        Tier::Streamed
    }
}

/// Per-worker reusable Gram buffers (train + validation) — the "one
/// reusable buffer per worker" half of the plane contract.
#[derive(Default)]
struct WorkerBufs {
    ktr: GramBuffer,
    kva: GramBuffer,
}

/// Run `n` independent tasks on `jobs` scoped workers, each owning its
/// buffer pair; results come back in task order (deterministic merge).
fn run_wave<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut WorkerBufs) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        let mut bufs = WorkerBufs::default();
        return (0..n).map(|i| f(i, &mut bufs)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cells: Vec<Mutex<&mut Option<T>>> = slots.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs.min(n) {
            scope.spawn(|| {
                let mut bufs = WorkerBufs::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i, &mut bufs);
                    **cells[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    drop(cells);
    slots.into_iter().map(|s| s.expect("cv worker died before finishing task")).collect()
}

/// Result of one fold task: the per-(γ, λ) validation losses plus
/// perf accounting.  `evaluated` marks points actually solved (vs
/// pruned) — kept separate from the loss value so a genuinely-NaN
/// validation loss (diverged solver) still poisons the candidate's
/// mean exactly like the sequential engine, instead of being mistaken
/// for "pruned".
struct FoldOut {
    losses: Vec<Vec<f32>>,
    evaluated: Vec<Vec<bool>>,
    iterations: usize,
    points: usize,
}

impl FoldOut {
    fn new(ng: usize, nl: usize) -> FoldOut {
        FoldOut {
            losses: vec![vec![f32::NAN; nl]; ng],
            evaluated: vec![vec![false; nl]; ng],
            iterations: 0,
            points: 0,
        }
    }
}

/// One γ's λ chain inside a fold task: strong→weak regularization
/// with warm starts, then one validation sweep per solved λ.  `warm`
/// is the fold's running warm-start vector — it enters holding the
/// *previous* γ-chain's terminal α (the γ handoff of the warm-start
/// plane) and leaves holding this chain's.
#[allow(clippy::too_many_arguments)]
fn chain_gamma<KT, KV>(
    cfg: &CvConfig,
    ctx: &FoldCtx,
    gi: usize,
    active: &[bool],
    kt: &mut KT,
    kv: &mut KV,
    warm: &mut Option<Vec<f32>>,
    out: &mut FoldOut,
) where
    KT: GramSource + ?Sized,
    KV: GramSource + ?Sized,
{
    let nl = cfg.grid.lambdas.len();
    let mut sols: Vec<Option<Solution>> = vec![None; nl];
    for (li, &lambda) in cfg.grid.lambdas.iter().enumerate() {
        if !active[li] {
            // pruned points are contiguous tails in practice; a cold
            // gap costs more than it saves, so just skip
            continue;
        }
        let sol = solve(cfg.solver, kt, &ctx.ytr, lambda, &ctx.params, warm.as_deref());
        out.iterations += sol.iterations;
        out.points += 1;
        *warm = Some(warm_vector(cfg.solver, &sol, &ctx.ytr));
        sols[li] = Some(sol);
    }
    for (li, s) in sols.iter().enumerate() {
        if let Some(sol) = s {
            out.losses[gi][li] = cfg.val_loss.mean(&ctx.yva, &sol.decision_values_src(kv));
            out.evaluated[gi][li] = true;
        }
    }
}

/// One fold's full (γ, λ) chain through the fold's kernel-state
/// flavor.  γs whose whole λ row is pruned are skipped; the warm
/// vector is carried through the gap so the next surviving γ still
/// warm-starts from the last solved chain (deterministic at any
/// `jobs`, since the whole chain lives inside this one task).
fn run_fold_task(
    cfg: &CvConfig,
    ctx: &FoldCtx,
    data: &FoldData,
    active: &[Vec<bool>],
    bufs: &mut WorkerBufs,
) -> FoldOut {
    let _sp = crate::obs::span("cv.fold_chain");
    let (ng, nl) = (cfg.grid.gammas.len(), cfg.grid.lambdas.len());
    let mut out = FoldOut::new(ng, nl);
    let mut warm: Option<Vec<f32>> = None;
    for (gi, &gamma) in cfg.grid.gammas.iter().enumerate() {
        if !active[gi].iter().any(|&a| a) {
            continue;
        }
        match data {
            FoldData::Cached { d2_tr, d2_va, ep_tr, ep_va } => {
                bufs.ktr.fill(*ep_tr, d2_tr, cfg.kernel, gamma);
                // the validation Gram is only needed after the chain,
                // but filling both up front keeps the borrow of each
                // buffer disjoint and costs the same exponentiation
                bufs.kva.fill(*ep_va, d2_va, cfg.kernel, gamma);
                let WorkerBufs { ktr, kva } = bufs;
                chain_gamma(cfg, ctx, gi, &active[gi], ktr, kva, &mut warm, &mut out);
            }
            FoldData::Streamed { tr_norms, va_norms } => match (&ctx.xtr, &ctx.xva) {
                (Store::Dense(xtr), Store::Dense(xva)) => {
                    let mut ktr = StreamedGram::new(
                        &cfg.backend, xtr, xtr, tr_norms, tr_norms, cfg.kernel, gamma,
                    );
                    let mut kva = StreamedGram::new(
                        &cfg.backend, xva, xtr, va_norms, tr_norms, cfg.kernel, gamma,
                    );
                    chain_gamma(cfg, ctx, gi, &active[gi], &mut ktr, &mut kva, &mut warm, &mut out);
                }
                (Store::Sparse(xtr), Store::Sparse(xva)) => {
                    let mut ktr = SparseGram::new(
                        &cfg.backend, xtr, xtr, tr_norms, tr_norms, cfg.kernel, gamma,
                    );
                    let mut kva = SparseGram::new(
                        &cfg.backend, xva, xtr, va_norms, tr_norms, cfg.kernel, gamma,
                    );
                    chain_gamma(cfg, ctx, gi, &active[gi], &mut ktr, &mut kva, &mut warm, &mut out);
                }
                _ => unreachable!("fold subsets share the working set's storage flavor"),
            },
        }
    }
    out
}

/// Run the integrated k-fold CV on a dense working set.
pub fn run_cv(data: &Dataset, cfg: &CvConfig) -> CvResult {
    run_cv_x(StoreRef::Dense(&data.x), &data.y, cfg)
}

/// Run the integrated k-fold CV on a CSR working set — the same grid,
/// tiers, and solvers as [`run_cv`], reading kernels through the
/// sparse data plane (no n×d densification anywhere).
pub fn run_cv_sparse(x: &CsrMatrix, y: &[f32], cfg: &CvConfig) -> CvResult {
    run_cv_x(StoreRef::Sparse(x), y, cfg)
}

/// [`run_cv`] over a [`WorkingSet`] (either layout).
pub fn run_cv_ws(ws: &WorkingSet, cfg: &CvConfig) -> CvResult {
    run_cv_x(ws.x.as_ref(), &ws.y, cfg)
}

/// The CV engine over either sample layout.
pub fn run_cv_x(x: StoreRef, y: &[f32], cfg: &CvConfig) -> CvResult {
    let _sp = crate::obs::span("cv.run");
    let n = y.len();
    assert_eq!(x.rows(), n, "sample/label count mismatch");
    assert!(n >= cfg.folds, "working set smaller than fold count");
    let folds = make_folds_y(y, cfg.folds, effective_fold_kind(cfg, y), cfg.seed);
    let (ng, nl) = (cfg.grid.gammas.len(), cfg.grid.lambdas.len());
    let jobs = cfg.jobs.max(1);

    // per-fold contexts (subsets + per-solve iteration budget scaled to
    // the fold size: extreme grid corners (huge C) would otherwise burn
    // 10-20x more iterations for solutions the selection phase discards
    // anyway (liquidSVM bounds the inner solver the same way); measured:
    // 5x CV speedup at identical selection + test error (§Perf))
    let fctx: Vec<FoldCtx> = (0..folds.k())
        .map(|f| {
            let tr_idx = folds.train_indices(f);
            let va_idx = folds.val_indices(f);
            let ytr: Vec<f32> = tr_idx.iter().map(|&i| y[i]).collect();
            let yva: Vec<f32> = va_idx.iter().map(|&i| y[i]).collect();
            let params = SolverParams {
                max_iter: cfg.params.max_iter.min(fold_cap(cfg.solver, 4, ytr.len())),
                ..cfg.params
            };
            FoldCtx {
                xtr: x.select_rows(&tr_idx),
                ytr,
                xva: x.select_rows(va_idx),
                yva,
                params,
            }
        })
        .collect();

    let per_fold_elems: Vec<usize> = fctx
        .iter()
        .map(|c| c.ytr.len() * c.ytr.len() + c.yva.len() * c.ytr.len())
        .collect();
    let tier = pick_tier(cfg.max_gram_mb, jobs, &per_fold_elems);

    let mut val_sum = vec![vec![0.0f32; nl]; ng];
    let mut val_cnt = vec![vec![0usize; nl]; ng];
    let mut active = vec![vec![true; nl]; ng];
    let mut total_iterations = 0usize;
    let mut points_evaluated = 0usize;

    // merge one wave of fold outputs (folds listed in ascending order,
    // each contributing its (γ, λ) matrix in fixed order, so per-point
    // accumulation order matches the sequential engine)
    macro_rules! merge {
        ($outs:expr) => {
            for out in $outs {
                for (gi, row) in out.losses.into_iter().enumerate() {
                    for (li, loss) in row.into_iter().enumerate() {
                        if !out.evaluated[gi][li] {
                            continue;
                        }
                        // a NaN loss (diverged solver) poisons the mean
                        // so the candidate can never win selection —
                        // same disqualification the sequential engine
                        // applied
                        val_sum[gi][li] += loss;
                        val_cnt[gi][li] += 1;
                    }
                }
                total_iterations += out.iterations;
                points_evaluated += out.points;
            }
        };
    }

    // kept alive through the final-model wave in the all-cached and
    // streamed tiers so the selected models reuse the fold kernel
    // state instead of recomputing O(n²d) distances per fold
    let fold_data: Option<Vec<FoldData>> = match tier {
        Tier::AllCached | Tier::Streamed => {
            // materialize every fold's kernel state up front (for the
            // streamed tier this is just the row norms), in parallel
            let fdata: Vec<FoldData> = run_wave(jobs, fctx.len(), |f, _| match tier {
                Tier::Streamed => FoldData::streamed(&fctx[f]),
                _ => FoldData::cached(&cfg.backend, &fctx[f]),
            });
            if cfg.adaptivity > 0 {
                // wave 1: fold 0's full chain, then prune
                let outs = run_wave(1, 1, |_, bufs| {
                    run_fold_task(cfg, &fctx[0], &fdata[0], &active, bufs)
                });
                merge!(outs);
                prune_grid(&mut active, &val_sum, cfg.adaptivity);
                // wave 2: remaining folds' chains over the surviving
                // grid, in parallel
                let outs = run_wave(jobs, fctx.len() - 1, |t, bufs| {
                    run_fold_task(cfg, &fctx[t + 1], &fdata[t + 1], &active, bufs)
                });
                merge!(outs);
            } else {
                let outs = run_wave(jobs, fctx.len(), |f, bufs| {
                    run_fold_task(cfg, &fctx[f], &fdata[f], &active, bufs)
                });
                merge!(outs);
            }
            Some(fdata)
        }
        Tier::PerFold => {
            // one fold's distance matrices resident at a time; each
            // fold's (γ, λ) chain is ordered, so this tier's grid
            // phase is serial — the price of the one-fold memory
            // profile (the final-model wave below stays parallel)
            for f in 0..fctx.len() {
                let fd = FoldData::cached(&cfg.backend, &fctx[f]);
                let mut bufs = WorkerBufs::default();
                let out = run_fold_task(cfg, &fctx[f], &fd, &active, &mut bufs);
                merge!([out]);
                if f == 0 && cfg.adaptivity > 0 {
                    prune_grid(&mut active, &val_sum, cfg.adaptivity);
                }
            }
            None
        }
    };

    // mean losses; pick best (first hit wins ties — grids descend, so
    // that is the more strongly regularized model, liquidSVM's
    // stability tie-break)
    let mut val_matrix = vec![vec![f32::NAN; nl]; ng];
    let mut best = (0usize, 0usize, f32::INFINITY);
    for gi in 0..ng {
        for li in 0..nl {
            if val_cnt[gi][li] > 0 {
                let m = val_sum[gi][li] / val_cnt[gi][li] as f32;
                val_matrix[gi][li] = m;
                if m < best.2 - 1e-9 {
                    best = (gi, li, m);
                }
            }
        }
    }
    let (bg, bl, bloss) = best;
    let best_gamma = cfg.grid.gammas[bg];
    let best_lambda = cfg.grid.lambdas[bl];

    // final models at the selected point (independent per fold ⇒ same
    // wave executor).  The all-cached/streamed tiers reuse the fold
    // kernel state computed for the grid; the per-fold tier recomputes
    // it, so each of its workers transiently holds a fold's d² AND the
    // exponentiated Gram (~2·max_fold elems, vs the ~1 the grid phase
    // budgets per worker) — halve that wave's parallelism to stay
    // inside (1+jobs)·max_fold.
    let final_jobs = if tier == Tier::PerFold { ((jobs + 1) / 2).max(1) } else { jobs };
    let _sp_final = crate::obs::span("cv.final_models");
    let models = match cfg.select {
        SelectMethod::FoldAverage => run_wave(final_jobs, folds.k(), |f, bufs| {
            let fd = fold_data.as_ref().map(|v| &v[f]);
            train_fold_model(x, y, &folds, f, cfg, best_gamma, best_lambda, fd, bufs)
        }),
        SelectMethod::RetrainOnFull => {
            // the retrain works on the FULL working set, which is
            // bigger than any fold the tier was sized for: free the
            // grid-phase state first, then stream whenever the full
            // d² + Gram pair (2n²) would itself blow the cap.
            // `cfg.params.max_iter` is the user's budget and is
            // passed through verbatim — it counts coordinate updates
            // per the documented contract (a hinge pair step spends
            // 2), unlike the internally derived fold caps above which
            // are doubled to keep the seed's effective budget
            drop(fold_data);
            let retrain_streamed = tier == Tier::Streamed
                || cfg
                    .max_gram_mb
                    .is_some_and(|mb| 2 * n * n > mb.saturating_mul(1 << 20) / 4);
            let all: Vec<usize> = (0..n).collect();
            let sol = final_solve(
                cfg, x, y, best_gamma, best_lambda, &cfg.params, retrain_streamed,
            );
            vec![FoldModel { train_idx: all, coef: sol.coef }]
        }
    };

    CvResult {
        best_gamma,
        best_lambda,
        best_val_loss: bloss,
        val_matrix,
        models,
        total_iterations,
        points_evaluated,
    }
}

/// Stratified folds only make sense for classification labels; fall
/// back to random folds for regression-like targets.
fn effective_fold_kind(cfg: &CvConfig, y: &[f32]) -> FoldKind {
    if cfg.fold_kind == FoldKind::Stratified && distinct_labels(y).len() > 16 {
        FoldKind::Random
    } else {
        cfg.fold_kind
    }
}

/// Solve one final model on `x`/`y` at (γ, λ), honoring the run's
/// memory tier.
fn final_solve(
    cfg: &CvConfig,
    x: StoreRef,
    y: &[f32],
    gamma: f32,
    lambda: f32,
    params: &SolverParams,
    streamed: bool,
) -> Solution {
    if streamed {
        let norms = x.row_sq_norms();
        match x {
            StoreRef::Dense(x) => {
                let mut k =
                    StreamedGram::new(&cfg.backend, x, x, &norms, &norms, cfg.kernel, gamma);
                solve(cfg.solver, &mut k, y, lambda, params, None)
            }
            StoreRef::Sparse(x) => {
                let mut k =
                    SparseGram::new(&cfg.backend, x, x, &norms, &norms, cfg.kernel, gamma);
                solve(cfg.solver, &mut k, y, lambda, params, None)
            }
        }
    } else {
        let d2 = cfg.backend.sq_dists_ref(x, x);
        let mut buf = GramBuffer::new();
        buf.fill(plane::next_epoch(), &d2, cfg.kernel, gamma);
        solve(cfg.solver, &mut buf, y, lambda, params, None)
    }
}

/// Train one final fold model at the selected (γ*, λ*).  `fd` is the
/// fold's kernel state from the grid phase when the tier kept it alive
/// (cached d² is reused directly; streamed norms likewise); `None`
/// (the per-fold tier) recomputes the fold's distances.
#[allow(clippy::too_many_arguments)]
fn train_fold_model(
    x: StoreRef,
    y: &[f32],
    folds: &Folds,
    f: usize,
    cfg: &CvConfig,
    gamma: f32,
    lambda: f32,
    fd: Option<&FoldData>,
    bufs: &mut WorkerBufs,
) -> FoldModel {
    let tr_idx = folds.train_indices(f);
    let xtr = x.select_rows(&tr_idx);
    let ytr: Vec<f32> = tr_idx.iter().map(|&i| y[i]).collect();
    // final models get a roomier budget than the selection sweeps
    let params = SolverParams {
        max_iter: cfg.params.max_iter.min(fold_cap(cfg.solver, 16, ytr.len())),
        ..cfg.params
    };
    let sol = match fd {
        Some(FoldData::Cached { d2_tr, ep_tr, .. }) => {
            bufs.ktr.fill(*ep_tr, d2_tr, cfg.kernel, gamma);
            solve(cfg.solver, &mut bufs.ktr, &ytr, lambda, &params, None)
        }
        Some(FoldData::Streamed { tr_norms, .. }) => match &xtr {
            Store::Dense(xm) => {
                let mut k = StreamedGram::new(
                    &cfg.backend, xm, xm, tr_norms, tr_norms, cfg.kernel, gamma,
                );
                solve(cfg.solver, &mut k, &ytr, lambda, &params, None)
            }
            Store::Sparse(xm) => {
                let mut k = SparseGram::new(
                    &cfg.backend, xm, xm, tr_norms, tr_norms, cfg.kernel, gamma,
                );
                solve(cfg.solver, &mut k, &ytr, lambda, &params, None)
            }
        },
        None => {
            let d2 = cfg.backend.sq_dists_ref(xtr.as_ref(), xtr.as_ref());
            bufs.ktr.fill(plane::next_epoch(), &d2, cfg.kernel, gamma);
            solve(cfg.solver, &mut bufs.ktr, &ytr, lambda, &params, None)
        }
    };
    FoldModel { train_idx: tr_idx, coef: sol.coef }
}

/// Keep only grid points whose fold-0 loss is within the best
/// 50% (adaptivity 1) / 25% (adaptivity 2) quantile.
fn prune_grid(active: &mut [Vec<bool>], fold0: &[Vec<f32>], adaptivity: u8) {
    let mut losses: Vec<f32> = fold0.iter().flatten().copied().collect();
    losses.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let keep_frac = match adaptivity {
        1 => 0.5,
        _ => 0.25,
    };
    let cut_idx = ((losses.len() as f32 * keep_frac) as usize).clamp(1, losses.len() - 1);
    let cutoff = losses[cut_idx];
    for (gi, row) in active.iter_mut().enumerate() {
        for (li, a) in row.iter_mut().enumerate() {
            if fold0[gi][li] > cutoff {
                *a = false;
            }
        }
    }
}

/// Average the decision values of the fold models on test data — the
/// default test-phase combination (paper §2: "how these k models are
/// combined during the test phase").  Cross-kernel values are produced
/// tile-by-tile through the Gram plane into one reusable buffer
/// (bounded by `max_gram_mb`), never as a full `m × n` cross Gram per
/// model.
pub fn predict_average(
    models: &[FoldModel],
    train: &Dataset,
    test_x: &crate::data::matrix::Matrix,
    gamma: f32,
    kernel: KernelKind,
    backend: &GramBackend,
    max_gram_mb: Option<usize>,
) -> Vec<f32> {
    predict_average_x(
        models,
        StoreRef::Dense(&train.x),
        StoreRef::Dense(test_x),
        gamma,
        kernel,
        backend,
        max_gram_mb,
    )
}

/// [`predict_average`] over either storage layout on either side (the
/// coordinator's predict path — units may carry dense or CSR working
/// sets, and test batches arrive in either form).
pub fn predict_average_x(
    models: &[FoldModel],
    train_x: StoreRef,
    test_x: StoreRef,
    gamma: f32,
    kernel: KernelKind,
    backend: &GramBackend,
    max_gram_mb: Option<usize>,
) -> Vec<f32> {
    let mut acc = vec![0.0f32; test_x.rows()];
    let mut buf = TileBuffer::new();
    // test-row norms computed once, shared across all fold models
    let xn = test_x.row_sq_norms();
    for m in models {
        let sv = train_x.select_rows(&m.train_idx);
        plane::accumulate_decisions_x(
            backend, kernel, gamma, test_x, &xn, sv.as_ref(), &m.coef, max_gram_mb, &mut buf,
            &mut acc,
        );
    }
    let inv = 1.0 / models.len().max(1) as f32;
    for a in &mut acc {
        *a *= inv;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn small_cfg(n_fold: usize) -> CvConfig {
        let mut cfg = CvConfig::new(
            Grid::default_grid(0, n_fold, 2),
            SolverKind::Hinge { w: 0.5 },
            Loss::Classification,
        );
        cfg.folds = 3;
        cfg
    }

    #[test]
    fn cv_learns_banana() {
        let d = synth::banana_binary(240, 7);
        let cfg = small_cfg(160);
        let res = run_cv(&d, &cfg);
        assert!(res.best_val_loss < 0.25, "val loss {}", res.best_val_loss);
        assert_eq!(res.models.len(), 3);
        assert_eq!(res.points_evaluated, 3 * cfg.grid.size());
    }

    #[test]
    fn adaptivity_prunes_points() {
        let d = synth::banana_binary(200, 8);
        let mut cfg = small_cfg(133);
        cfg.adaptivity = 2;
        let full = run_cv(&d, &small_cfg(133));
        let pruned = run_cv(&d, &cfg);
        assert!(pruned.points_evaluated < full.points_evaluated);
        // pruning must not destroy accuracy
        assert!(pruned.best_val_loss <= full.best_val_loss + 0.08);
    }

    #[test]
    fn retrain_on_full_yields_one_model() {
        let d = synth::banana_binary(150, 9);
        let mut cfg = small_cfg(100);
        cfg.select = SelectMethod::RetrainOnFull;
        let res = run_cv(&d, &cfg);
        assert_eq!(res.models.len(), 1);
        assert_eq!(res.models[0].train_idx.len(), 150);
    }

    #[test]
    fn val_matrix_has_means() {
        let d = synth::banana_binary(120, 10);
        let res = run_cv(&d, &small_cfg(80));
        let finite = res.val_matrix.iter().flatten().filter(|v| v.is_finite()).count();
        assert_eq!(finite, res.val_matrix.len() * res.val_matrix[0].len());
    }

    #[test]
    fn fold_average_prediction_works() {
        let d = synth::banana_binary(200, 11);
        let cfg = small_cfg(133);
        let res = run_cv(&d, &cfg);
        let test = synth::banana_binary(100, 12);
        let preds = predict_average(
            &res.models, &d, &test.x, res.best_gamma, cfg.kernel, &cfg.backend, None,
        );
        let err = Loss::Classification.mean(&test.y, &preds);
        assert!(err < 0.3, "test error {err}");
    }

    #[test]
    fn quantile_cv_selects() {
        let d = synth::sinc_hetero(150, 13);
        let mut cfg = CvConfig::new(
            Grid::default_grid(0, 100, 1),
            SolverKind::Quantile { tau: 0.5 },
            Loss::Pinball { tau: 0.5 },
        );
        cfg.folds = 3;
        cfg.fold_kind = FoldKind::Random;
        let res = run_cv(&d, &cfg);
        assert!(res.best_val_loss.is_finite());
        assert!(res.best_val_loss < 0.2, "pinball {}", res.best_val_loss);
    }

    fn assert_identical(a: &CvResult, b: &CvResult) {
        assert_eq!(a.best_gamma.to_bits(), b.best_gamma.to_bits());
        assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits());
        assert_eq!(a.points_evaluated, b.points_evaluated);
        for (ra, rb) in a.val_matrix.iter().zip(&b.val_matrix) {
            for (va, vb) in ra.iter().zip(rb) {
                assert!(
                    va.to_bits() == vb.to_bits() || (va.is_nan() && vb.is_nan()),
                    "val {va} vs {vb}"
                );
            }
        }
        assert_eq!(a.models.len(), b.models.len());
        for (ma, mb) in a.models.iter().zip(&b.models) {
            assert_eq!(ma.train_idx, mb.train_idx);
            let ca: Vec<u32> = ma.coef.iter().map(|v| v.to_bits()).collect();
            let cb: Vec<u32> = mb.coef.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ca, cb, "fold coefficients differ");
        }
    }

    #[test]
    fn parallel_grid_bit_identical_to_sequential() {
        let d = synth::banana_binary(180, 14);
        let mut seq = small_cfg(120);
        seq.jobs = 1;
        let mut par = small_cfg(120);
        par.jobs = 4;
        assert_identical(&run_cv(&d, &seq), &run_cv(&d, &par));
    }

    #[test]
    fn parallel_adaptive_grid_bit_identical_to_sequential() {
        let d = synth::banana_binary(160, 15);
        let mut seq = small_cfg(107);
        seq.adaptivity = 1;
        seq.jobs = 1;
        let mut par = seq.clone();
        par.jobs = 3;
        assert_identical(&run_cv(&d, &seq), &run_cv(&d, &par));
    }

    #[test]
    fn streamed_tier_bit_identical_to_cached() {
        let d = synth::banana_binary(140, 16);
        let cached = small_cfg(94);
        let mut capped = cached.clone();
        capped.max_gram_mb = Some(0); // force the streamed tier
        assert_identical(&run_cv(&d, &cached), &run_cv(&d, &capped));
    }

    #[test]
    fn sparse_cv_bit_identical_to_densified() {
        // the same grid on a CSR working set vs its densified twin:
        // selection, val matrix, and fold coefficients must match
        // bitwise — in the cached tiers AND the streamed tier
        let mut rng = crate::data::rng::Rng::new(77);
        let (n, d) = (90usize, 40usize);
        let mut dense = Matrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            for _ in 0..5 {
                let j = rng.below(d);
                dense.set(i, j, rng.range(-1.5, 1.5));
            }
            let s: f32 = dense
                .row(i)
                .iter()
                .enumerate()
                .map(|(j, v)| if j % 2 == 0 { *v } else { -*v })
                .sum();
            y.push(if s >= 0.0 { 1.0 } else { -1.0 });
        }
        let csr = CsrMatrix::from_dense(&dense);
        let dd = Dataset::new(dense, y.clone());
        let cfg = small_cfg(60);
        assert_identical(&run_cv(&dd, &cfg), &run_cv_sparse(&csr, &y, &cfg));
        let mut capped = cfg.clone();
        capped.max_gram_mb = Some(0); // force the streamed tier
        assert_identical(&run_cv(&dd, &capped), &run_cv_sparse(&csr, &y, &capped));
    }

    #[test]
    fn tier_selection_follows_cap() {
        // 3 folds of 200 train / 100 val samples ⇒ 60k elems per fold
        let sizes = [200 * 200 + 100 * 200; 3];
        assert_eq!(pick_tier(None, 8, &sizes), Tier::AllCached);
        assert_eq!(pick_tier(Some(1024), 2, &sizes), Tier::AllCached);
        // 1 MiB = 262144 elems: with 2 workers, 3 folds + 2 buffers
        // (300k) overflow but 1 fold + 2 buffers (180k) fits
        assert_eq!(pick_tier(Some(1), 2, &sizes), Tier::PerFold);
        assert_eq!(pick_tier(Some(0), 1, &sizes), Tier::Streamed);
    }
}
