//! Std-backed stand-in for the [loom](https://docs.rs/loom) model
//! checker, exposing the API subset `liquid_svm::sync` and
//! `tests/loom_models.rs` consume.
//!
//! Why this exists: the offline registry this repo builds against does
//! not carry loom, and `cfg(loom)`-gated dependencies are still
//! *resolved* by every build.  This crate satisfies resolution with a
//! faithful API twin whose primitives are plain `std::sync` types and
//! whose [`model`] runs the closure exactly once — so
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_models` is runnable
//! anywhere as a smoke pass (single interleaving, real assertions).
//! CI's `loom` job swaps this path dependency for the real
//! `loom = "0.7"` from crates.io, and the same test file then explores
//! every bounded interleaving.  Keeping both legs compiling against
//! one API is the contract; add re-exports here only when the real
//! loom has them.

pub mod sync {
    pub use std::sync::{
        Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    };

    pub mod atomic {
        pub use std::sync::atomic::{
            AtomicBool, AtomicU16, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
        };
    }
}

pub mod thread {
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

/// Run `f` under the "model": the real loom executes it once per
/// reachable interleaving; this stand-in executes it exactly once
/// (the sequential interleaving), which still exercises every
/// assertion in the closure.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    f();
}

#[cfg(test)]
mod tests {
    #[test]
    fn model_runs_closure() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicUsize::new(0));
        let h2 = hits.clone();
        super::model(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn primitives_are_std() {
        // the stand-in must not wrap: identical types, identical
        // poisoning behavior
        use std::any::TypeId;
        assert_eq!(
            TypeId::of::<super::sync::Mutex<u8>>(),
            TypeId::of::<std::sync::Mutex<u8>>()
        );
        assert_eq!(
            TypeId::of::<super::sync::Condvar>(),
            TypeId::of::<std::sync::Condvar>()
        );
    }
}
