//! Bounded model checks for every concurrency seam in the crate
//! (DESIGN.md §Static-analysis).
//!
//! This file only compiles under `RUSTFLAGS="--cfg loom"`, where the
//! `crate::sync` shim swaps `std::sync` for loom's modeled primitives
//! and every `loom::model(..)` closure is executed once per reachable
//! interleaving (real loom; the vendored std-backed facade runs it
//! once as a smoke pass — see `vendor/loom`).  Each model follows the
//! loom playbook:
//!
//! * all shared state is created *inside* the closure, so every
//!   explored interleaving starts fresh;
//! * at most two spawned threads plus the main thread — state-space
//!   size is exponential in threads;
//! * assertions check the seam's invariant, not timing.
//!
//! Adding a new concurrency seam to `src/` means adding a model here —
//! that rule is stated in `src/sync.rs` and DESIGN.md §Static-analysis.

#![cfg(loom)]

use liquid_svm::coordinator::pool::JobCounter;
use liquid_svm::distributed::wire::{Claim, Shared};
use liquid_svm::obs::PhaseTable;
use liquid_svm::serve::registry::{LruInsert, ShardLru, SingleFlight};
use liquid_svm::serve::worker::BoundedQueue;
use liquid_svm::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use liquid_svm::sync::{Arc, Condvar, Mutex};

use std::collections::VecDeque;

// ---------------------------------------------------------------- shim

/// The shim itself: a mutex/condvar handshake must round-trip under
/// the model — if `crate::sync` ever re-exported mismatched types this
/// would fail to compile, and a lost-wakeup bug in the pattern would
/// deadlock loom.
#[test]
fn sync_shim_handshake() {
    loom::model(|| {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let t = loom::thread::spawn(move || {
            let (lock, cv) = &*p2;
            *lock.lock().unwrap() = true;
            cv.notify_one();
        });
        let (lock, cv) = &*pair;
        let mut ready = lock.lock().unwrap();
        while !*ready {
            ready = cv.wait(ready).unwrap();
        }
        drop(ready);
        t.join().unwrap();
    });
}

// ---------------------------------------------- serve: shutdown races

/// Regression model for the serve stop-flag ordering fix
/// (`serve/mod.rs::shutdown`): the `Release` store must publish every
/// write sequenced before it to a thread that `Acquire`-loads the
/// flag.  With both sides `Relaxed` — the original bug — loom finds an
/// execution where the observer sees `stop == true` but stale data.
#[test]
fn stop_flag_publishes() {
    loom::model(|| {
        let stop = Arc::new(AtomicBool::new(false));
        let data = Arc::new(AtomicUsize::new(0));
        let (s2, d2) = (Arc::clone(&stop), Arc::clone(&data));
        let t = loom::thread::spawn(move || {
            // shutdown path: finish the work, then publish the flag
            d2.store(42, Ordering::Relaxed);
            s2.store(true, Ordering::Release);
        });
        // worker loop: an Acquire load that observes the flag must
        // also observe everything before the Release store
        if stop.load(Ordering::Acquire) {
            assert_eq!(data.load(Ordering::Relaxed), 42);
        }
        t.join().unwrap();
    });
}

/// Regression model for the batcher shutdown race
/// (`serve/batcher.rs`): `closed` lives under the *same* mutex as the
/// pending map, so a submit that loses the race with
/// `discard_pending` is rejected instead of parking a row no flusher
/// will ever drain.  Modeled as (closed, pending-count) under one
/// lock; the invariant is "accepted ⇒ drained" — an accepted row is
/// always visible to the discard that closes the batcher.
#[test]
fn batcher_close_strands_no_row() {
    loom::model(|| {
        // (closed, pending rows)
        let state = Arc::new(Mutex::new((false, 0usize)));
        let s2 = Arc::clone(&state);
        let submit = loom::thread::spawn(move || {
            let mut st = s2.lock().unwrap();
            if st.0 {
                false // SubmitError::Closed
            } else {
                st.1 += 1;
                true
            }
        });
        // shutdown: close, then drain — atomically w.r.t. submit
        let drained = {
            let mut st = state.lock().unwrap();
            st.0 = true;
            std::mem::take(&mut st.1)
        };
        let accepted = submit.join().unwrap();
        let final_pending = state.lock().unwrap().1;
        if accepted {
            assert_eq!(drained, 1, "accepted row must be seen by the drain");
        }
        assert_eq!(final_pending, 0, "no row may remain parked after close");
    });
}

// ------------------------------------------- serve: the bounded queue

/// Backpressure accounting: with capacity 1 and a racing consumer,
/// every row the producer's `try_push` accepted is popped exactly
/// once — none lost, none duplicated — and the final `pop` after
/// `close` returns `None` instead of hanging.
#[test]
fn bounded_queue_loses_no_accepted_row() {
    loom::model(|| {
        let q = Arc::new(BoundedQueue::new(1));
        let q2 = Arc::clone(&q);
        let producer = loom::thread::spawn(move || {
            let mut accepted = 0usize;
            for row in 0..2usize {
                if q2.try_push(row).is_ok() {
                    accepted += 1;
                }
            }
            q2.close();
            accepted
        });
        let mut received = 0usize;
        while q.pop().is_some() {
            received += 1;
        }
        let accepted = producer.join().unwrap();
        assert!(accepted >= 1, "first push into an empty queue cannot fail");
        assert_eq!(received, accepted);
    });
}

/// Close-wakes-consumer: a consumer blocked in `pop` on an empty queue
/// must be woken by `close` and return `None`.  A missed
/// `notify_all` would show up as a loom-detected deadlock.
#[test]
fn bounded_queue_close_wakes_blocked_pop() {
    loom::model(|| {
        let q: Arc<BoundedQueue<usize>> = Arc::new(BoundedQueue::new(1));
        let q2 = Arc::clone(&q);
        let consumer = loom::thread::spawn(move || q2.pop());
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    });
}

// --------------------------------------- distributed: cell dispatch

/// Worker death vs. concurrent completion: worker 1 dies while holding
/// its claimed cell; worker 0 keeps draining its own queue *and* the
/// retry queue.  No cell may be lost (all `done` slots filled), no
/// cell dispatched twice into `done` (`n_done` equals the slot count),
/// and the in-flight ledger must return to zero.
#[test]
fn dispatch_survives_worker_death() {
    loom::model(|| {
        let queues = vec![VecDeque::from(vec![0usize]), VecDeque::from(vec![1usize])];
        let shared = Arc::new(Shared::new(queues, VecDeque::new(), 2, 2));

        let s0 = Arc::clone(&shared);
        let survivor = loom::thread::spawn(move || {
            while let Claim::Cell(c) = s0.claim(0) {
                s0.complete(c, vec![c as u8], 1);
            }
        });

        let s1 = Arc::clone(&shared);
        let dying = loom::thread::spawn(move || match s1.claim(1) {
            // died mid-train: the claimed cell must reach the retry queue
            Claim::Cell(c) => s1.worker_dead(1, Some(c)),
            // the survivor already finished everything before we ran
            Claim::Finished => 0,
        });

        survivor.join().unwrap();
        let moved = dying.join().unwrap();

        let st = shared.state.lock().unwrap();
        assert!(st.failed.is_none(), "run must not fail: {:?}", st.failed);
        assert_eq!(st.n_done, 2, "every cell trained exactly once");
        assert!(st.done.iter().all(Option::is_some), "no lost cell");
        assert_eq!(st.in_flight, 0, "in-flight ledger must drain");
        assert_eq!(st.redispatched, moved, "requeue accounting matches");
    });
}

/// Two live workers racing over disjoint queues: claims are exclusive
/// (each cell trained once), and the condvar protocol terminates —
/// both workers observe `Finished` without a lost wakeup.
#[test]
fn dispatch_claims_are_exclusive() {
    loom::model(|| {
        let queues = vec![VecDeque::from(vec![0usize]), VecDeque::from(vec![1usize])];
        let shared = Arc::new(Shared::new(queues, VecDeque::new(), 2, 2));
        let mut handles = Vec::new();
        for w in 0..2usize {
            let s = Arc::clone(&shared);
            handles.push(loom::thread::spawn(move || {
                let mut trained = 0usize;
                while let Claim::Cell(c) = s.claim(w) {
                    s.complete(c, vec![c as u8], 1);
                    trained += 1;
                }
                trained
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 2, "each cell claimed exactly once across workers");
        let st = shared.state.lock().unwrap();
        assert_eq!(st.n_done, 2);
        assert_eq!(st.in_flight, 0);
    });
}

// ------------------------------------------------- serve: shard LRU

/// Two threads lazily loading the *same* cold shard: exactly one
/// `insert` wins, the loser adopts the winner's value, and both end up
/// holding the same resident model — the adopt-winner contract that
/// keeps a race from double-caching one shard.
#[test]
fn shard_lru_adopts_single_winner() {
    loom::model(|| {
        let lru: Arc<ShardLru<usize>> = Arc::new(ShardLru::new(4, 1024));
        let l2 = Arc::clone(&lru);
        let t = loom::thread::spawn(move || match l2.insert(2, 111, 8) {
            LruInsert::Inserted { .. } => 111usize,
            LruInsert::Adopted(v) => v,
        });
        let mine = match lru.insert(2, 222, 8) {
            LruInsert::Inserted { .. } => 222usize,
            LruInsert::Adopted(v) => v,
        };
        let theirs = t.join().unwrap();
        assert_eq!(mine, theirs, "both threads must converge on one winner");
        assert_eq!(lru.touch(2), Some(mine), "the winner is resident");
        assert_eq!(lru.resident_count(), 1, "the race must not double-cache");
        assert!(lru.invariants_hold());
    });
}

/// Eviction racing a lazy load on a *different* cell: the byte budget
/// forces whichever insert runs second to evict the other entry, and
/// the resident-bytes ledger must stay consistent in every
/// interleaving (`invariants_hold` re-sums the map under the lock).
#[test]
fn shard_lru_eviction_keeps_ledger_consistent() {
    loom::model(|| {
        // budget 10, entries of 8 bytes: two residents never fit
        let lru: Arc<ShardLru<usize>> = Arc::new(ShardLru::new(4, 10));
        let l2 = Arc::clone(&lru);
        let t = loom::thread::spawn(move || {
            if l2.touch(0).is_none() {
                l2.insert(0, 100, 8);
            }
        });
        if lru.touch(1).is_none() {
            lru.insert(1, 200, 8);
        }
        t.join().unwrap();
        assert_eq!(lru.resident_count(), 1, "budget admits exactly one entry");
        assert_eq!(lru.resident_bytes(), 8);
        assert!(lru.invariants_hold());
    });
}

// ------------------------------------------------- serve: hot reload

/// Single-flight reload gate: two threads race `try_begin`; at most
/// one may be inside the critical section at a time, and the
/// drop-based release re-opens the gate (a panicking reload can no
/// longer wedge it shut — the guard's `Drop` runs during unwind).
#[test]
fn single_flight_admits_one_reloader() {
    loom::model(|| {
        let sf = Arc::new(SingleFlight::new());
        let in_crit = Arc::new(AtomicUsize::new(0));
        let entered = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let (sf, in_crit, entered) =
                (Arc::clone(&sf), Arc::clone(&in_crit), Arc::clone(&entered));
            handles.push(loom::thread::spawn(move || {
                if let Some(_flight) = sf.try_begin() {
                    assert_eq!(
                        in_crit.fetch_add(1, Ordering::SeqCst),
                        0,
                        "two reloaders inside the single-flight section"
                    );
                    entered.fetch_add(1, Ordering::SeqCst);
                    in_crit.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // the gate may reject a racing thread, but never both
        assert!(entered.load(Ordering::SeqCst) >= 1);
        // and it must be open again once the guards dropped
        assert!(sf.try_begin().is_some(), "gate must re-open after release");
    });
}

// ------------------------------------- serve: admission control seam

/// The event loop's connection-table seam (`serve/eventloop.rs`):
/// accept racing close racing a token-bucket charge and a prune tick.
/// One mutex guards the open count and the buckets, so in every
/// interleaving the cap admits at most one of the two racing accepts
/// *while a slot is held*, no slot leaks (the table drains to zero
/// once both connections close), and a stray extra `release` cannot
/// underflow the count and open the cap wide.
#[test]
fn admission_accept_close_spend() {
    use liquid_svm::serve::eventloop::Admission;
    loom::model(|| {
        let adm = Arc::new(Admission::new(1, 10));
        let peer = std::net::IpAddr::V4(std::net::Ipv4Addr::new(10, 0, 0, 1));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let adm = Arc::clone(&adm);
            handles.push(loom::thread::spawn(move || {
                if adm.try_accept() {
                    // an admitted connection charges the bucket, then
                    // closes: accept and release must pair exactly once
                    let _ = adm.try_spend(peer, 1, 0);
                    adm.release();
                    true
                } else {
                    false
                }
            }));
        }
        // the reactor's periodic prune races both connections
        adm.prune(1);
        let admitted: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(admitted.iter().any(|&a| a), "an empty table must admit someone");
        assert_eq!(adm.open(), 0, "every accept paired with exactly one release");
        // a stray double-close must saturate, not wrap the count open
        adm.release();
        assert_eq!(adm.open(), 0);
        assert!(adm.try_accept(), "released capacity must be reusable");
    });
}

// ------------------------------------------------ obs: span table

/// Concurrent span recording: two threads and main merge rows into
/// one table; counts and sums must equal the sequential totals in
/// every interleaving (the mutex is the whole story — this model
/// guards against anyone "optimizing" the table into racy shards).
#[test]
fn phase_table_merges_concurrent_records() {
    loom::model(|| {
        let table = Arc::new(PhaseTable::new());
        let t1 = {
            let t = Arc::clone(&table);
            loom::thread::spawn(move || t.record("test.a", 10, 5, 1))
        };
        let t2 = {
            let t = Arc::clone(&table);
            loom::thread::spawn(move || t.record("test.b", 20, 10, 2))
        };
        table.record("test.a", 30, 15, 4);
        t1.join().unwrap();
        t2.join().unwrap();
        let rows = table.phases();
        assert_eq!(rows.len(), 2);
        let (name_a, a) = rows[0];
        let (name_b, b) = rows[1];
        assert_eq!((name_a, a.calls, a.total_us, a.self_us, a.bytes), ("test.a", 2, 40, 20, 5));
        assert_eq!((name_b, b.calls, b.total_us, b.self_us, b.bytes), ("test.b", 1, 20, 10, 2));
    });
}

// ------------------------------------------- coordinator: job claims

/// The thread-pool job counter: racing claimants partition the job
/// indices — every index claimed exactly once, no index skipped, and
/// the counter drains to `None` for everyone.
#[test]
fn job_counter_partitions_jobs() {
    loom::model(|| {
        let counter = Arc::new(JobCounter::new(3));
        let c2 = Arc::clone(&counter);
        let t = loom::thread::spawn(move || {
            let mut mine = Vec::new();
            while let Some(i) = c2.claim() {
                mine.push(i);
            }
            mine
        });
        let mut mine = Vec::new();
        while let Some(i) = counter.claim() {
            mine.push(i);
        }
        let theirs = t.join().unwrap();
        let mut all: Vec<usize> = mine.iter().chain(theirs.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "claims must partition the job range");
        assert_eq!(counter.claim(), None, "drained counter stays drained");
    });
}
