//! Process-level tests of the distributed train wire (DESIGN.md
//! §Distributed-wire): a coordinator CLI process sharding cells to
//! real `liquidsvm worker` processes over loopback TCP.
//!
//! The contract under test is byte-identity: whatever the worker fleet
//! looks like — two healthy workers, or one that dies mid-run and has
//! its cells re-dispatched — the assembled `.sol.d` bundle must equal
//! the single-process `train --save` bundle byte for byte.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::process::{Child, Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_liquidsvm"))
}

/// A spawned `liquidsvm worker` process, killed on drop.  The first
/// stdout line is the documented parseable contract:
/// `worker listening on HOST:PORT`.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl WorkerProc {
    fn spawn(extra: &[&str]) -> WorkerProc {
        let mut child = bin()
            .args(["worker", "--port", "0"])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning worker");
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("worker stdout"))
            .read_line(&mut line)
            .expect("reading worker banner");
        let addr = line
            .trim()
            .strip_prefix("worker listening on ")
            .unwrap_or_else(|| panic!("bad worker banner: `{line}`"))
            .to_string();
        WorkerProc { child, addr }
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Read every file of a `.sol.d` bundle into (name → bytes).
fn read_bundle(dir: &std::path::Path) -> BTreeMap<String, Vec<u8>> {
    let mut files = BTreeMap::new();
    for entry in std::fs::read_dir(dir).unwrap_or_else(|e| panic!("reading {dir:?}: {e}")) {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().into_owned();
        files.insert(name, std::fs::read(entry.path()).unwrap());
    }
    files
}

fn assert_bundles_identical(mono: &std::path::Path, dist: &std::path::Path) {
    let a = read_bundle(mono);
    let b = read_bundle(dist);
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "bundle file sets differ"
    );
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "bundle file {name} differs between mono and wire");
    }
}

/// Shared flags: both the mono `train` and the wire `distributed` run
/// must see the same data, partition, and CV configuration.
const DATA_FLAGS: &[&str] = &[
    "--data", "banana", "--n", "500", "--seed", "21", "--folds", "2", "--cells", "1,100",
];

fn train_mono_bundle(out: &std::path::Path) {
    let r = bin()
        .args(["train", "--scenario", "binary"])
        .args(DATA_FLAGS)
        .args(["--save", out.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(r.status.success(), "mono train: {}", String::from_utf8_lossy(&r.stderr));
}

#[test]
fn wire_bundle_is_byte_identical_to_single_process() {
    let dir = std::env::temp_dir().join(format!("lsvm-wire-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mono = dir.join("mono.sol.d");
    let dist = dir.join("dist.sol.d");
    train_mono_bundle(&mono);

    let w1 = WorkerProc::spawn(&[]);
    let w2 = WorkerProc::spawn(&[]);
    let r = bin()
        .args(["distributed", "--workers", &format!("{},{}", w1.addr, w2.addr)])
        .args(DATA_FLAGS)
        .args(["--save", dist.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(r.status.success(), "wire train: {}", String::from_utf8_lossy(&r.stderr));
    let text = String::from_utf8_lossy(&r.stdout);
    assert!(text.contains("measured_wall="), "no measured wall in: {text}");
    assert!(text.contains("modelled_distributed="), "no modelled wall in: {text}");
    assert!(text.contains("redispatched=0"), "healthy run re-dispatched: {text}");

    assert_bundles_identical(&mono, &dist);

    // and the bundle predicts like any other saved model
    let r = bin()
        .args(["predict", "--model", dist.to_str().unwrap(), "--data", "banana", "--n", "200"])
        .output()
        .unwrap();
    assert!(r.status.success(), "predict: {}", String::from_utf8_lossy(&r.stderr));
    assert!(String::from_utf8_lossy(&r.stdout).contains("error="));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killed_worker_is_redispatched_with_identical_output() {
    let dir = std::env::temp_dir().join(format!("lsvm-wire-kill-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mono = dir.join("mono.sol.d");
    let dist = dir.join("dist.sol.d");
    train_mono_bundle(&mono);

    // worker 1 dies (exit 3) after streaming one shard; with ~5 cells
    // over 2 workers its remaining cells must flow to the survivor
    let w1 = WorkerProc::spawn(&["--fail-after", "1"]);
    let w2 = WorkerProc::spawn(&[]);
    let r = bin()
        .args(["distributed", "--workers", &format!("{},{}", w1.addr, w2.addr)])
        .args(DATA_FLAGS)
        .args(["--save", dist.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        r.status.success(),
        "wire train with dying worker: {}",
        String::from_utf8_lossy(&r.stderr)
    );
    let text = String::from_utf8_lossy(&r.stdout);
    let redispatched: u64 = text
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix("redispatched="))
        .expect("no redispatched= in output")
        .parse()
        .unwrap();
    assert!(redispatched >= 1, "worker death did not trigger re-dispatch: {text}");
    assert!(text.contains("live=1"), "dead worker still counted live: {text}");

    // fault tolerance must not cost bit-exactness
    assert_bundles_identical(&mono, &dist);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn text_mode_is_a_debug_session() {
    let w = WorkerProc::spawn(&[]);
    let mut stream = std::net::TcpStream::connect(&w.addr).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    writeln!(stream, "train-hello v1 text").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok train-hello v1 text");

    line.clear();
    writeln!(stream, "ping").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok pong");

    line.clear();
    writeln!(stream, "flarp").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("err bad-request"), "{line}");

    line.clear();
    writeln!(stream, "quit").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok bye");
}

#[test]
fn bad_hello_is_rejected_politely() {
    let w = WorkerProc::spawn(&[]);
    let mut stream = std::net::TcpStream::connect(&w.addr).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    writeln!(stream, "GET / HTTP/1.1").unwrap();
    let mut reply = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut reply).unwrap();
    assert!(reply.starts_with("err bad-hello"), "{reply}");
    // the worker closes the session after a bad hello…
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    // …but keeps accepting: a well-formed session still works
    let mut stream = std::net::TcpStream::connect(&w.addr).unwrap();
    writeln!(stream, "train-hello v1 text").unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "ok train-hello v1 text");
}

#[test]
fn wire_mode_requires_a_bundle_path() {
    let r = bin()
        .args(["distributed", "--workers", "127.0.0.1:1", "--data", "banana", "--n", "100"])
        .output()
        .unwrap();
    assert!(!r.status.success());
    let err = String::from_utf8_lossy(&r.stderr);
    assert!(err.contains("--save"), "unexpected error: {err}");

    let r = bin()
        .args([
            "distributed", "--workers", "127.0.0.1:1", "--data", "banana", "--n", "100",
            "--save", "not-a-bundle.sol",
        ])
        .output()
        .unwrap();
    assert!(!r.status.success());
    assert!(String::from_utf8_lossy(&r.stderr).contains(".sol.d"));
}
