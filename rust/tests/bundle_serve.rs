//! End-to-end tests of cell-sharded `.sol.d/` bundles behind the
//! server: a Voronoi model round-trips through a bundle, serves
//! predictions bit-identical to in-process `SvmModel::predict`, and —
//! under a skewed request mix — loads only the shards it touches
//! (resident-shard stats stay below the total bundle size).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use liquid_svm::cells::CellStrategy;
use liquid_svm::coordinator::persist::{read_manifest, save_bundle};
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::serve::{ServeConfig, Server};

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, req: &str) -> String {
        writeln!(self.writer, "{req}").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lsvm-bundle-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// `key=value` lookup in a stats-style report line.
fn stat<'a>(report: &'a str, key: &str) -> &'a str {
    report
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("missing {key}= in `{report}`"))
}

#[test]
fn sharded_bundle_serves_identically_and_lazily() {
    // a Voronoi-decomposed model with several cells
    let d = synth::by_name("cod-rna", 500, 61).unwrap();
    let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 100 });
    let model = svm_binary(&d, 0.5, &cfg).unwrap();
    let n_cells = model.partition.n_cells();
    assert!(n_cells >= 3, "need several cells, got {n_cells}");

    let dir = tmp("cov.sol.d");
    save_bundle(&model, &dir).unwrap();
    let total_bytes = read_manifest(&dir).unwrap().total_bytes();

    let server = Server::start(ServeConfig {
        port: 0,
        max_batch: 8,
        max_delay: Duration::from_millis(1),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut c = Client::connect(server.addr());

    let loaded = c.roundtrip(&format!("load cov {}", dir.display()));
    assert!(loaded.starts_with("ok loaded cov dim=8 shards="), "{loaded}");

    // nothing resident until traffic arrives
    let shards0 = c.roundtrip("shards cov");
    assert!(shards0.starts_with("ok name=cov"), "{shards0}");
    assert_eq!(stat(&shards0, "resident"), "0", "{shards0}");

    // skewed mix: only rows whose owner is one of the two largest cells
    let mut by_size: Vec<usize> = (0..n_cells).collect();
    by_size.sort_by_key(|&c| std::cmp::Reverse(model.partition.cells[c].len()));
    let hot: Vec<usize> = by_size[..2].to_vec();
    let mut sent = 0usize;
    for &cell in &hot {
        for &i in model.partition.cells[cell].iter().take(15) {
            let row = d.x.row(i);
            let x1 = Matrix::from_vec(row.to_vec(), 1, row.len());
            let expect = model.predict(&x1)[0];
            let row_text: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            let resp = c.roundtrip(&format!("predict cov {}", row_text.join(",")));
            let body = resp.strip_prefix("ok ").unwrap_or_else(|| panic!("bad resp {resp}"));
            assert_eq!(body.parse::<f32>().unwrap(), expect, "row {i} of cell {cell}");
            sent += 1;
        }
    }
    assert!(sent >= 20, "skewed mix too small: {sent}");

    // lazy loading: only the touched shards are resident, and the
    // resident byte count stays below the whole bundle
    let shards = c.roundtrip("shards cov");
    let resident: usize = stat(&shards, "resident").parse().unwrap();
    assert!(resident <= hot.len(), "{shards}");
    assert!(resident >= 1, "{shards}");
    let resident_bytes: u64 = stat(&shards, "resident_bytes").parse().unwrap();
    assert!(resident_bytes < total_bytes, "{shards}");

    let stats = c.roundtrip("stats");
    let shard_bytes = stat(&stats, "shard_bytes");
    let (res, tot) = shard_bytes.split_once('/').expect("shard_bytes=res/total");
    assert_eq!(res.parse::<u64>().unwrap(), resident_bytes);
    assert_eq!(tot.parse::<u64>().unwrap(), total_bytes);
    assert!(stat(&stats, "shard_loads").parse::<u64>().unwrap() >= 1, "{stats}");

    c.roundtrip("quit");
    server.shutdown();
}

#[test]
fn multi_row_predict_spans_cells_and_matches_in_process() {
    let d = synth::banana_binary(320, 62);
    let cfg = Config::default().folds(2).voronoi(CellStrategy::Voronoi { size: 80 });
    let model = svm_binary(&d, 0.5, &cfg).unwrap();
    let dir = tmp("banana.sol.d");
    save_bundle(&model, &dir).unwrap();

    let server = Server::start(ServeConfig {
        port: 0,
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut c = Client::connect(server.addr());
    assert!(c.roundtrip(&format!("load b {}", dir.display())).starts_with("ok loaded"));

    // one request whose rows route to different cells: replies must
    // come back in row order and equal the monolithic prediction
    let test = synth::banana_binary(12, 63);
    let expect = model.predict(&test.x);
    let rows: Vec<String> = (0..test.len())
        .map(|i| {
            test.x.row(i).iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
        })
        .collect();
    let resp = c.roundtrip(&format!("predict b {}", rows.join(";")));
    let got: Vec<f32> = resp
        .strip_prefix("ok ")
        .unwrap_or_else(|| panic!("bad resp {resp}"))
        .split(';')
        .map(|t| t.parse().unwrap())
        .collect();
    assert_eq!(got, expect);

    // a monolithic model answers `shards` with not-sharded
    let mono = tmp("mono.sol");
    liquid_svm::coordinator::persist::save_model(&model, &mono).unwrap();
    assert!(c.roundtrip(&format!("load m {}", mono.display())).starts_with("ok loaded"));
    assert!(c.roundtrip("shards m").starts_with("err not-sharded"), "shards on .sol");

    c.roundtrip("quit");
    server.shutdown();
}
