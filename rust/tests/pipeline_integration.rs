//! Integration tests across the full train→select→test pipeline:
//! every scenario × representative configs, on synthetic workloads
//! small enough for CI but large enough to demand real learning.

use liquid_svm::cells::CellStrategy;
use liquid_svm::coordinator::scenarios;
use liquid_svm::data::synth;
use liquid_svm::metrics::Loss;
use liquid_svm::prelude::*;

fn cfg3() -> Config {
    Config::default().folds(3)
}

#[test]
fn binary_all_small_datasets_beat_majority_vote() {
    for name in ["bank-marketing", "cod-rna", "covtype", "thyroid-ann"] {
        let train = synth::by_name(name, 400, 1).unwrap();
        let test = synth::by_name(name, 300, 2).unwrap();
        let m = svm_binary(&train, 0.5, &cfg3()).unwrap();
        let err = m.test(&test).error;
        // majority-vote error = minority fraction
        let pos = test.y.iter().filter(|&&v| v > 0.0).count() as f32 / test.y.len() as f32;
        let majority = pos.min(1.0 - pos);
        assert!(
            err <= majority + 0.03,
            "{name}: error {err} vs majority baseline {majority}"
        );
    }
}

#[test]
fn libsvm_grid_and_default_grid_agree_roughly() {
    let train = synth::by_name("cod-rna", 500, 3).unwrap();
    let test = synth::by_name("cod-rna", 300, 4).unwrap();
    let e_def = svm_binary(&train, 0.5, &cfg3()).unwrap().test(&test).error;
    let e_lib = svm_binary(&train, 0.5, &cfg3().libsvm_grid(true)).unwrap().test(&test).error;
    assert!((e_def - e_lib).abs() < 0.08, "default {e_def} vs libsvm {e_lib}");
}

#[test]
fn every_cell_strategy_trains_and_predicts() {
    let train = synth::by_name("covtype", 800, 5).unwrap();
    let test = synth::by_name("covtype", 400, 6).unwrap();
    for cells in [
        CellStrategy::None,
        CellStrategy::RandomChunks { size: 200 },
        CellStrategy::Voronoi { size: 200 },
        CellStrategy::OverlappingVoronoi { size: 200, overlap: 0.3 },
        CellStrategy::RecursiveTree { max_size: 200 },
    ] {
        let label = format!("{cells:?}");
        let m = svm_binary(&train, 0.5, &cfg3().voronoi(cells)).unwrap();
        let res = m.test(&test);
        assert!(res.error < 0.45, "{label}: error {}", res.error);
        assert_eq!(res.predictions.len(), 400);
    }
}

#[test]
fn ova_and_ava_agree_on_easy_multiclass() {
    let tt = synth::banana_mc(300, 200, 7);
    let e_ova = scenarios::mc_svm_type(&tt.train, true, &cfg3()).unwrap().test(&tt.test).error;
    let e_ava = scenarios::mc_svm_type(&tt.train, false, &cfg3()).unwrap().test(&tt.test).error;
    assert!(e_ova < 0.2, "ova {e_ova}");
    assert!(e_ava < 0.2, "ava {e_ava}");
}

#[test]
fn expectile_scenario_runs_and_is_calibrated() {
    let train = synth::sinc_hetero(250, 8);
    let test = synth::sinc_hetero(150, 9);
    let m = scenarios::ex_svm(&train, &[0.2, 0.8], &cfg3()).unwrap();
    let res = m.test(&test);
    // expectile curves must be ordered on average
    let gap: f32 = res.task_scores[1]
        .iter()
        .zip(&res.task_scores[0])
        .map(|(h, l)| h - l)
        .sum::<f32>()
        / 150.0;
    assert!(gap > 0.0, "expectile curves crossed");
}

#[test]
fn weighted_binary_shifts_operating_point() {
    let train = synth::by_name("thyroid-ann", 700, 10).unwrap();
    let test = synth::by_name("thyroid-ann", 500, 11).unwrap();
    // high positive weight ⇒ fewer false negatives (higher detection)
    let m_hi = svm_binary(&train, 0.9, &cfg3()).unwrap();
    let m_lo = svm_binary(&train, 0.1, &cfg3()).unwrap();
    let s_hi = m_hi.decision_values(&test.x);
    let s_lo = m_lo.decision_values(&test.x);
    let det = |scores: &Vec<f32>| {
        let c = liquid_svm::metrics::Confusion::from_scores(&test.y, scores);
        c.detection_rate()
    };
    assert!(
        det(&s_hi[0]) >= det(&s_lo[0]) - 0.02,
        "w=0.9 detection {} < w=0.1 detection {}",
        det(&s_hi[0]),
        det(&s_lo[0])
    );
}

#[test]
fn adaptivity_saves_work_keeps_quality() {
    let train = synth::by_name("cod-rna", 600, 12).unwrap();
    let test = synth::by_name("cod-rna", 400, 13).unwrap();
    let m_full = svm_binary(&train, 0.5, &cfg3()).unwrap();
    let m_adapt = svm_binary(&train, 0.5, &cfg3().adaptivity(2)).unwrap();
    assert!(m_adapt.points_evaluated < m_full.points_evaluated);
    let e_full = m_full.test(&test).error;
    let e_adapt = m_adapt.test(&test).error;
    assert!(e_adapt <= e_full + 0.05, "adaptive {e_adapt} vs full {e_full}");
}

#[test]
fn scaling_is_fitted_on_train_only() {
    // shifted test set: scaler must come from train stats, so shifted
    // test data lands outside [0,1] — predictions still work
    let train = synth::by_name("cod-rna", 300, 14).unwrap();
    let mut test = synth::by_name("cod-rna", 100, 15).unwrap();
    for v in test.x.as_mut_slice() {
        *v += 10.0;
    }
    let m = svm_binary(&train, 0.5, &cfg3()).unwrap();
    let preds = m.predict(&test.x);
    assert_eq!(preds.len(), 100);
    assert!(preds.iter().all(|p| p.is_finite()));
}

#[test]
fn regression_mse_beats_mean_predictor() {
    let train = synth::sinc_hetero(300, 16);
    let test = synth::sinc_hetero(200, 17);
    let m = scenarios::ls_svm(&train, &cfg3()).unwrap();
    let res = m.test(&test);
    let mean: f32 = test.y.iter().sum::<f32>() / test.y.len() as f32;
    let mean_preds = vec![mean; test.y.len()];
    let var = Loss::LeastSquares.mean(&test.y, &mean_preds);
    assert!(res.error < var, "mse {} vs variance {}", res.error, var);
}
